"""Deterministic selective-SPN structure generator.

The paper (§5.3, Table 1) learns SPN structures with SPFlow from four DEBD
datasets and then *fixes* them as the public, agreed architecture whose sum
weights (and Bernoulli leaf parameters) are learned privately.  We do not
have SPFlow/DEBD in this environment (see DESIGN.md substitution table), so
this module generates structures that

  * are complete, decomposable and *selective* (split-variable determinism:
    every sum node splits on one or two variables; each child product node
    carries "gate" Bernoulli leaves that claim a value pattern of the split
    variables, so at most one child of each sum has positive contribution
    for any complete instance — exactly the Peharz-style selectivity the
    paper's closed-form Eq. (2) requires), and

  * reproduce Table 1's statistics (sum / product / leaf counts, params,
    edges, layers) *exactly* for all four datasets — the recipes below were
    calibrated analytically, and `build()` asserts the match.

The structure is emitted in a layered dense form shared with the rust
coordinator (artifacts/<name>.structure.json):

  layer 0           : leaves (Bernoulli; `claim` in {-1,0,1} marks gates)
  layer l = 1..2K   : alternating product (odd) / sum (even) layers; the
                      *input* of layer l is concat(layer l-1 outputs, leaves)
                      so terminal leaves hanging off high products need no
                      pass-through chains.
  root              : the single node of layer 2K.

Counting semantics (what the AOT'd counts artifact computes per party):
  pos  (bottom-up) : leaf gate match / product AND / sum OR
  act  (top-down)  : act(root)=1, act(child) = act(parent) AND pos(child)
  n for sum edge (i -> product j): #instances with act(j)  (den: act(i))
  n for leaf Bernoulli theta:      #instances with act(leaf) AND x_v = 1
                                    (den: act(leaf))
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

# ----------------------------------------------------------------------------
# Recipes calibrated to Table 1 of the paper.
#
# levels[0] is the root sum: (scope_size, arity).  levels[i] lists the sums of
# level i+1 as (scope_size, arity); they are placed greedily on the branches
# (child products) of the previous level's sums.  arity 2 splits on one
# variable (children claim x_s=0 / x_s=1); arity 3 splits on two variables
# (children claim s=0 / s=1,t=0 / s=1,t=1).
# ----------------------------------------------------------------------------
RECIPES: dict[str, dict] = {
    "nltcs": dict(
        num_vars=16,
        rows=16181,
        levels=[
            [(16, 2)],
            [(5, 2), (5, 2)],
            [(4, 2)] * 4,
            [(3, 2)] * 4 + [(2, 2)] * 2,
        ],
    ),
    "jester": dict(
        num_vars=100,
        rows=9000,
        levels=[
            [(100, 2)],
            [(3, 2)] * 7 + [(2, 2)] * 2,
        ],
    ),
    "baudio": dict(
        num_vars=100,
        rows=15000,
        levels=[
            [(100, 2)],
            [(6, 3), (6, 3), (10, 2), (12, 2)],
            [(3, 2)] * 12,
        ],
    ),
    "bnetflix": dict(
        num_vars=100,
        rows=15000,
        levels=[
            [(100, 2)],
            [(6, 2)] * 6,
            [(2, 2)] * 9 + [(1, 2)] * 11,
        ],
    ),
    # Small extra structure used by tests / the quickstart path.
    "toy": dict(
        num_vars=4,
        rows=512,
        levels=[
            [(4, 2)],
            [(2, 2), (2, 2)],
        ],
    ),
}

# Table 1 of the paper — used as a hard assertion for the four DEBD names.
PAPER_TABLE1 = {
    "nltcs": dict(sum=13, product=26, leaf=74, params=100, edges=112, layers=9),
    "jester": dict(sum=10, product=20, leaf=225, params=245, edges=254, layers=5),
    "baudio": dict(sum=17, product=36, leaf=282, params=318, edges=334, layers=7),
    "bnetflix": dict(sum=27, product=54, leaf=265, params=319, edges=345, layers=7),
}


@dataclass
class _Sum:
    level: int
    scope: list[int]
    arity: int
    children: list["_Prod"] = field(default_factory=list)
    layer_pos: int = -1


@dataclass
class _Prod:
    level: int
    gates: list[tuple[int, int]]            # (var, claimed value)
    rest: list[int]                         # scope minus split vars
    child_sums: list[_Sum] = field(default_factory=list)
    terminal: list[int] = field(default_factory=list)   # vars -> Bernoulli leaves
    layer_pos: int = -1


def _split_patterns(scope: list[int], arity: int) -> tuple[list[list[tuple[int, int]]], list[int]]:
    """Gate patterns for an arity-way split and the remaining scope."""
    if arity == 2:
        s = scope[0]
        return [[(s, 0)], [(s, 1)]], scope[1:]
    if arity == 3:
        if len(scope) < 2:
            raise ValueError("arity-3 split needs scope >= 2")
        s, t = scope[0], scope[1]
        return [[(s, 0)], [(s, 1), (t, 0)], [(s, 1), (t, 1)]], scope[2:]
    raise ValueError(f"unsupported arity {arity}")


def _build_tree(name: str, cfg: dict, seed: int) -> _Sum:
    rng = np.random.default_rng(seed)
    nv = cfg["num_vars"]
    perm = list(rng.permutation(nv))
    levels = cfg["levels"]

    (root_scope_sz, root_arity) = levels[0][0]
    assert root_scope_sz == nv
    root = _Sum(level=1, scope=perm, arity=root_arity)
    frontier = [root]

    for li, sums_spec in enumerate(levels[1:], start=2):
        # Materialize the branches (product children) of the previous level.
        branches: list[_Prod] = []
        for s in frontier:
            patterns, rest = _split_patterns(s.scope, s.arity)
            for pat in patterns:
                # arity-3 children 1/2 lose two vars; child 0 keeps the
                # second split var in its rest scope (completeness).
                extra = [v for v, _ in pat[1:]] if False else []
                p_rest = list(rest) + extra
                if s.arity == 3 and len(pat) == 1:
                    # child 0 of a 3-way split claims only s=0; variable t is
                    # not consumed on this branch and stays in scope.
                    p_rest = [s.scope[1]] + list(rest)
                p = _Prod(level=s.level, gates=pat, rest=p_rest)
                s.children.append(p)
                branches.append(p)

        # Greedy placement of this level's sums on the branches.
        specs = sorted(sums_spec, key=lambda t: -t[0])
        caps = [len(b.rest) for b in branches]
        placed: list[list[tuple[int, int]]] = [[] for _ in branches]
        for (sz, ar) in specs:
            order = sorted(range(len(branches)), key=lambda i: -(caps[i]))
            for i in order:
                if caps[i] >= sz:
                    placed[i].append((sz, ar))
                    caps[i] -= sz
                    break
            else:
                raise ValueError(f"{name}: cannot place sum of scope {sz} at level {li}")

        new_frontier: list[_Sum] = []
        for b, specs_here in zip(branches, placed):
            rest = list(b.rest)
            for (sz, ar) in specs_here:
                sub_scope, rest = rest[:sz], rest[sz:]
                child = _Sum(level=li, scope=sub_scope, arity=ar)
                b.child_sums.append(child)
                new_frontier.append(child)
            b.terminal = rest
        frontier = new_frontier

    # The deepest level's branches keep their whole rest as terminal leaves.
    for s in frontier:
        patterns, rest = _split_patterns(s.scope, s.arity)
        for pat in patterns:
            p_rest = list(rest)
            if s.arity == 3 and len(pat) == 1:
                p_rest = [s.scope[1]] + list(rest)
            p = _Prod(level=s.level, gates=pat, rest=p_rest, terminal=list(p_rest))
            s.children.append(p)
    return root


def _collect(root: _Sum) -> tuple[list[_Sum], list[_Prod]]:
    sums, prods = [], []
    stack = [root]
    while stack:
        s = stack.pop()
        sums.append(s)
        for p in s.children:
            prods.append(p)
            stack.extend(p.child_sums)
    return sums, prods


def build(name: str, seed: int = 7) -> dict:
    """Build the structure dict (JSON-serializable) for a dataset name."""
    cfg = RECIPES[name]
    root = _build_tree(name, cfg, seed)
    sums, prods = _collect(root)
    num_levels = max(s.level for s in sums)
    num_layers = 2 * num_levels + 1        # paper counts the leaf layer

    # ---- leaves -------------------------------------------------------------
    # Each product owns its gate leaves and terminal Bernoulli leaves.
    leaf_var: list[int] = []
    leaf_claim: list[int] = []

    def new_leaf(var: int, claim: int) -> int:
        leaf_var.append(var)
        leaf_claim.append(claim)
        return len(leaf_var) - 1

    prod_leaf_children: dict[int, list[int]] = {}
    for pi, p in enumerate(prods):
        kids = [new_leaf(v, c) for (v, c) in p.gates]
        kids += [new_leaf(v, -1) for v in p.terminal]
        prod_leaf_children[pi] = kids
    w0 = len(leaf_var)

    # ---- layer assignment ---------------------------------------------------
    # sums of level i sit at layer 2*(K-i)+2, their products at 2*(K-i)+1.
    K = num_levels
    layers: list[dict] = []
    sum_ids = {id(s): i for i, s in enumerate(sums)}
    prod_ids = {id(p): i for i, p in enumerate(prods)}

    by_layer_sums: dict[int, list[int]] = {}
    by_layer_prods: dict[int, list[int]] = {}
    for i, s in enumerate(sums):
        by_layer_sums.setdefault(2 * (K - s.level) + 2, []).append(i)
    for i, p in enumerate(prods):
        by_layer_prods.setdefault(2 * (K - p.level) + 1, []).append(i)

    # position within each layer
    for l, ids in by_layer_sums.items():
        for pos, i in enumerate(ids):
            sums[i].layer_pos = pos
    for l, ids in by_layer_prods.items():
        for pos, i in enumerate(ids):
            prods[i].layer_pos = pos

    # ---- parameters ---------------------------------------------------------
    # Sum-edge params first (grouped per sum node), then leaf params.
    num_sum_edges = sum(len(s.children) for s in sums)
    param_kind = ["sum"] * num_sum_edges + ["leaf"] * w0
    # num/den indices are into the counts vector: concat(act of
    # [leaves, layer1, ..., layer 2K], x1-counts of leaves).
    layer_widths = [w0] + [
        len(by_layer_prods.get(l, []) or by_layer_sums.get(l, []))
        for l in range(1, 2 * K + 1)
    ]
    layer_offset = np.concatenate([[0], np.cumsum(layer_widths)]).tolist()
    total_nodes = layer_offset[-1]

    def gnode_sum(i: int) -> int:
        s = sums[i]
        return layer_offset[2 * (K - s.level) + 2] + s.layer_pos

    def gnode_prod(i: int) -> int:
        p = prods[i]
        return layer_offset[2 * (K - p.level) + 1] + p.layer_pos

    param_num: list[int] = []
    param_den: list[int] = []
    sum_edge_param: dict[tuple[int, int], int] = {}
    pid = 0
    for si, s in enumerate(sums):
        for p in s.children:
            pi = prod_ids[id(p)]
            sum_edge_param[(si, pi)] = pid
            param_num.append(gnode_prod(pi))
            param_den.append(gnode_sum(si))
            pid += 1
    for li in range(w0):
        param_num.append(total_nodes + li)     # x1 count segment
        param_den.append(li)                   # leaf act count
        pid += 1

    # ---- layered edge matrices ----------------------------------------------
    # Input of layer l is concat(prev layer outputs, leaves); for l == 1 the
    # previous width is 0 and the input is exactly the leaves.
    layers_json: list[dict] = []
    for l in range(1, 2 * K + 1):
        kind = "product" if l % 2 == 1 else "sum"
        prev_w = layer_widths[l - 1] if l > 1 else 0
        rows: list[int] = []
        cols: list[int] = []
        pids: list[int] = []
        if kind == "product":
            for pi in by_layer_prods.get(l, []):
                p = prods[pi]
                r = p.layer_pos
                for cs in p.child_sums:
                    rows.append(r)
                    cols.append(sums[sum_ids[id(cs)]].layer_pos)
                    pids.append(-1)
                for leaf in prod_leaf_children[pi]:
                    rows.append(r)
                    cols.append(prev_w + leaf)
                    pids.append(-1)
        else:
            for si in by_layer_sums.get(l, []):
                s = sums[si]
                r = s.layer_pos
                for p in s.children:
                    pi = prod_ids[id(p)]
                    rows.append(r)
                    cols.append(prods[pi].layer_pos)
                    pids.append(sum_edge_param[(si, pi)])
        layers_json.append(
            dict(kind=kind, width=layer_widths[l], in_width=prev_w + w0,
                 rows=rows, cols=cols, param=pids)
        )

    stats = dict(
        sum=len(sums),
        product=len(prods),
        leaf=w0,
        params=num_sum_edges + w0,
        edges=num_sum_edges + sum(len(l["rows"]) for l in layers_json if l["kind"] == "product"),
        layers=num_layers,
    )
    if name in PAPER_TABLE1:
        assert stats == PAPER_TABLE1[name], (name, stats, PAPER_TABLE1[name])

    # per-sum-node param groups (weights of one sum node sum to 1)
    groups = []
    pid = 0
    for s in sums:
        groups.append(list(range(pid, pid + len(s.children))))
        pid += len(s.children)

    return dict(
        name=name,
        num_vars=cfg["num_vars"],
        rows=cfg["rows"],
        seed=seed,
        num_layers=num_layers,
        leaf_var=leaf_var,
        leaf_claim=leaf_claim,
        layer_widths=layer_widths,
        layer_offset=layer_offset,
        total_nodes=total_nodes,
        layers=layers_json,
        num_params=num_sum_edges + w0,
        num_sum_edges=num_sum_edges,
        param_kind=param_kind,
        param_num=param_num,
        param_den=param_den,
        sum_groups=groups,
        stats=stats,
    )


def dense_matrices(st: dict) -> list[np.ndarray]:
    """Dense adjacency matrices, one per non-leaf layer (float32 0/1)."""
    mats = []
    for l in st["layers"]:
        m = np.zeros((l["width"], l["in_width"]), dtype=np.float32)
        m[l["rows"], l["cols"]] = 1.0
        mats.append(m)
    return mats


def save(st: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(st, f, default=int)


if __name__ == "__main__":
    for name in RECIPES:
        st = build(name)
        print(name, st["stats"])
