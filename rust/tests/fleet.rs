//! Acceptance pins of the sharded serve fleet (DESIGN.md §Fleet):
//!
//! * **Cross-shard byte-identity** — a query pinned to any shard (Sim and
//!   TCP backends) reveals the bit-identical `root`/`p` of its
//!   single-session oracle: a fresh identically-seeded session, identical
//!   training replay, the shard's tag stripe installed, one direct
//!   `Evaluator::eval_batch` in served order. Stripe 0 starts at tag 0,
//!   so shard 0 is additionally bit-identical to the *unsharded* oracle.
//! * **Tag-stripe discipline** — mixed-width ticks on S shards reserve
//!   ranges that are monotone, pairwise disjoint within the shard, and
//!   confined to the shard's stripe (the PR 5 freshness test, fleetized).
//! * **Chaos** — under 8-client concurrent load, killing a shard mid-run
//!   loses no query: every in-flight and queued query is answered by a
//!   survivor, post-kill queries pinned at the corpse are served
//!   elsewhere, and the server drains through a clean shutdown. The TCP
//!   variant severs real member sockets via the kill-shard command.
//! * **Dispatch** — unpinned pipelined load spreads over multiple live
//!   shards (least-loaded routing), with exact report totals.
//!
//! Everything runs on `Structure::mini_demo()` — artifact-free, CI-safe.

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use spn_mpc::coordinator::infer::private_eval_batch;
use spn_mpc::coordinator::serve::train_and_serve_fleet;
use spn_mpc::coordinator::train::{train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::net::fleet::{FleetReport, ShardSever};
use spn_mpc::net::serve::{render_query_json, ServeClient, ServeConfig};
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::learn;
use spn_mpc::spn::plan::{EvalPlan, Evaluator, Query, TagStripe};
use spn_mpc::spn::structure::Structure;

const MEMBERS: usize = 3;

fn mini_counts(st: &Structure, n: usize) -> (Vec<Vec<u64>>, u64) {
    // seeds 5/21: the same shards as serve.rs / integration.rs
    (datasets::synth_shard_counts(st, n, st.rows, 5, 21), st.rows as u64)
}

// Under `--features checked-session` every *fleet* session runs wrapped in
// the CheckedSession sanitizer while the oracles stay raw (see serve.rs);
// by default wrap() is the identity. Sever handles are always taken from
// the raw TcpSession BEFORE wrapping — severing is transport surgery, not
// a protocol call, and must bypass the sanitizer.
#[cfg(feature = "checked-session")]
use spn_mpc::protocols::checked::CheckedSession;
#[cfg(feature = "checked-session")]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> CheckedSession<S> {
    CheckedSession::new(s)
}
#[cfg(not(feature = "checked-session"))]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}
#[cfg(feature = "checked-session")]
fn wrap_engine(e: Engine) -> CheckedSession<Engine> {
    let schedule = e.cfg.schedule;
    CheckedSession::with_sim_accounting(e, schedule)
}
#[cfg(not(feature = "checked-session"))]
fn wrap_engine(e: Engine) -> Engine {
    e
}
#[cfg(feature = "checked-session")]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: CheckedSession<S>) -> S {
    s.into_inner()
}
#[cfg(not(feature = "checked-session"))]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}

/// A deterministic mixed stream (same shape as serve.rs): mostly
/// single-evidence marginals, every fifth query fully marginalized.
fn arrival_queries(st: &Structure, total: usize) -> Vec<Query> {
    (0..total)
        .map(|i| {
            let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
            if i % 5 != 0 {
                let v = i % st.num_vars;
                q.x[v] = ((i / 2) % 2) as u8;
                q.marg[v] = false;
            }
            q
        })
        .collect()
}

/// Shard s's single-session oracle: a fresh identically-seeded Sim
/// session, identical training replay, stripe s of `shards` installed,
/// one direct eval_batch over the queries that shard served, in served
/// order. (TCP ≡ Sim byte-identically under one seed, so this is the
/// oracle for both backends.)
fn shard_oracle(
    st: &Structure,
    n: usize,
    s: usize,
    shards: usize,
    queries: &[Query],
) -> Vec<i128> {
    let (counts, rows) = mini_counts(st, n);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    let plan = EvalPlan::compile(st, &theta, model.d);
    let mut ev = Evaluator::new(plan).clone_into_session(&mut eng, TagStripe::new(s, shards));
    let (roots, _) = ev.eval_batch(&mut eng, queries, &model.sum_w, model.leaf_theta.as_deref());
    roots
}

/// The unsharded oracle of serve.rs, for the shard-0 ≡ single-session pin.
fn plain_oracle(st: &Structure, n: usize, queries: &[Query]) -> Vec<i128> {
    let (counts, rows) = mini_counts(st, n);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    let (roots, _) = private_eval_batch(&mut eng, st, &model, queries, &theta);
    roots
}

/// Bind an ephemeral listener, then train + serve a fleet of `shards`
/// sessions on a background thread. TCP fleets get real sever handles so
/// `kill-shard` cuts member sockets; dead TCP shards are torn down
/// lossily after the drain (a leak would hang the test).
fn spawn_fleet(
    backend: &'static str,
    st: Structure,
    shards: usize,
    cfg: ServeConfig,
) -> (std::net::SocketAddr, thread::JoinHandle<FleetReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = thread::spawn(move || {
        let (counts, rows) = mini_counts(&st, MEMBERS);
        let theta = learn::default_leaf_theta(&st);
        let tcfg = TrainConfig::default();
        match backend {
            "tcp" => {
                let mut sessions = Vec::with_capacity(shards);
                let mut severs: Vec<Option<ShardSever>> = Vec::with_capacity(shards);
                for _ in 0..shards {
                    let sess =
                        TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(MEMBERS))
                            .unwrap();
                    // sever handle from the raw session, BEFORE wrapping
                    let sever = sess.sever_handle().unwrap();
                    severs.push(Some(Box::new(move || sever.sever())));
                    sessions.push(wrap(sess));
                }
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, &st, &counts, rows, &tcfg, &theta, listener, &cfg, severs,
                )
                .unwrap();
                for (s, sess) in sessions.into_iter().enumerate() {
                    let sess = unwrap_session(sess);
                    if report.per_shard[s].dead {
                        sess.shutdown_lossy();
                    } else {
                        sess.shutdown().unwrap();
                    }
                }
                report
            }
            _ => {
                let mut sessions: Vec<_> = (0..shards)
                    .map(|_| {
                        wrap_engine(Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()))
                    })
                    .collect();
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, &st, &counts, rows, &tcfg, &theta, listener, &cfg, Vec::new(),
                )
                .unwrap();
                report
            }
        }
    });
    (addr, h)
}

/// A query frame carrying the `"shard"` routing pin.
fn pinned_query_json(q: &Query, shard: usize) -> String {
    let mut s = render_query_json(q);
    s.truncate(s.len() - 1); // drop the closing brace
    format!("{s},\"shard\":{shard}}}")
}

#[test]
fn any_shard_matches_its_single_session_oracle_marginal_and_conditional() {
    let st = Structure::mini_demo();
    let shards = 3usize;
    // one marginal plus the two components of Pr(x0=1 | x1=1) — the
    // conditional is served as two queries; the client forms the ratio
    let marginal = Query { x: vec![1, 0], marg: vec![false, true] };
    let q_xe = Query { x: vec![1, 1], marg: vec![false, false] };
    let q_e = Query { x: vec![0, 1], marg: vec![true, false] };
    let served: Vec<Query> = vec![marginal, q_xe, q_e];
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    for backend in ["sim", "tcp"] {
        let (addr, h) = spawn_fleet(backend, st.clone(), shards, cfg);
        let mut c = ServeClient::connect(&addr.to_string()).unwrap();
        assert_eq!(c.hello.shards, shards, "{backend}: hello reports the fleet width");
        let mut roots_by_shard: Vec<Vec<i128>> = Vec::new();
        for s in 0..shards {
            // closed loop, pinned: shard s serves exactly these three
            // queries, in this order
            let mut got = Vec::new();
            for q in &served {
                c.send_raw(&pinned_query_json(q, s)).unwrap();
                let r = c.recv().unwrap();
                assert_eq!(r.shard, Some(s), "{backend}: pin to live shard {s} is honored");
                // p is the shortest-roundtrip rendering of root.max(0)/d
                assert_eq!(r.p, r.root.max(0) as f64 / 256.0);
                got.push(r.root);
            }
            let want = shard_oracle(&st, MEMBERS, s, shards, &served);
            assert_eq!(
                got, want,
                "{backend} shard {s}: served roots must be bit-identical to the \
                 single-session oracle with stripe {s} of {shards}"
            );
            // conditional: the served ratio equals the oracle ratio exactly
            let ratio = |v: &[i128]| {
                if v[2] <= 0 {
                    0.0
                } else {
                    (v[1].max(0) as f64 / v[2] as f64).min(1.0)
                }
            };
            assert_eq!(ratio(&got), ratio(&want), "{backend} shard {s}: conditional p");
            roots_by_shard.push(got);
        }
        // stripe 0 starts at tag 0 → shard 0 ≡ the unsharded single session
        assert_eq!(
            roots_by_shard[0],
            plain_oracle(&st, MEMBERS, &served),
            "{backend}: shard 0 must equal the unsharded oracle bit-for-bit"
        );
        // across shards the masks differ (different tag stripes), so roots
        // may differ by the ±1-per-divpub rounding — never more
        for s in 1..shards {
            for (a, b) in roots_by_shard[0].iter().zip(&roots_by_shard[s]) {
                assert!((a - b).abs() <= 8, "shard {s} root {b} vs shard 0 root {a}");
            }
        }
        ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
        let report = h.join().unwrap();
        assert_eq!(report.queries, (shards * served.len()) as u64);
        assert_eq!(report.shards, shards);
        assert_eq!(report.dead_shards, 0);
        assert_eq!(report.redispatched, 0);
    }
}

#[test]
fn mixed_width_ticks_stay_confined_to_each_shards_stripe() {
    // The PR 5 tag-freshness pin, fleetized: on every shard of a 3-way
    // fleet, mixed-width ticks reserve monotone, pairwise-disjoint ranges
    // that never leave the shard's stripe — and the stripes themselves
    // are disjoint across shards by construction.
    let st = Structure::mini_demo();
    let shards = 3usize;
    let (counts, rows) = mini_counts(&st, MEMBERS);
    let theta = learn::default_leaf_theta(&st);
    let widths = [1usize, 3, 2, 7, 1, 5, 4, 2, 6, 1]; // mixed traffic
    let mut all_ranges: Vec<Vec<(u64, u64)>> = Vec::new();
    for s in 0..shards {
        let stripe = TagStripe::new(s, shards);
        let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()));
        let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
        let plan = EvalPlan::compile(&st, &theta, model.d);
        let m = plan.divpubs_per_query;
        let mut ev = Evaluator::new(plan).clone_into_session(&mut eng, stripe);
        assert_eq!(ev.stripe(), Some(stripe));
        let mut ranges: Vec<(u64, u64)> = Vec::new();
        for (t, &w) in widths.iter().enumerate() {
            let batch = arrival_queries(&st, w);
            let (roots, _) =
                ev.eval_batch(&mut eng, &batch, &model.sum_w, model.leaf_theta.as_deref());
            assert_eq!(roots.len(), w);
            let (start, end) = ev.last_tags().unwrap();
            assert_eq!(end - start, m * w as u64, "shard {s} tick {t}: width must be m·B");
            assert!(
                start >= stripe.base() && end <= stripe.limit(),
                "shard {s} tick {t}: range [{start}, {end}) escapes its stripe"
            );
            if let Some(&(_, prev_end)) = ranges.last() {
                assert!(start >= prev_end, "shard {s} tick {t}: ranges must be monotone");
            }
            ranges.push((start, end));
        }
        for i in 0..ranges.len() {
            for j in i + 1..ranges.len() {
                let (a, b) = ranges[i];
                let (c, d) = ranges[j];
                assert!(b <= c || d <= a, "shard {s}: tick ranges {i}/{j} overlap");
            }
        }
        all_ranges.push(ranges);
    }
    for i in 0..shards {
        for j in i + 1..shards {
            for &(a, b) in &all_ranges[i] {
                for &(c, d) in &all_ranges[j] {
                    assert!(b <= c || d <= a, "shards {i}/{j} share tags — stripes broken");
                }
            }
        }
    }
}

#[test]
fn killing_a_shard_under_load_degrades_without_losing_queries() {
    // The chaos pin: 8 concurrent clients, one kills shard 0 mid-run.
    // Every query — in flight, queued on the corpse, or sent afterwards —
    // still gets a correct answer from a survivor, and the fleet drains
    // through a clean shutdown.
    let st = Structure::mini_demo();
    let shards = 2usize;
    let clients = 8usize;
    let per = 6usize;
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let (addr, h) = spawn_fleet("sim", st.clone(), shards, cfg);
    let all_marg = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
    let mut workers = Vec::new();
    for t in 0..clients {
        let a = addr.to_string();
        let q = all_marg.clone();
        workers.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            let mut out = Vec::new();
            for i in 0..per {
                if t == 0 && i == per / 2 {
                    // mid-run, with the other 7 clients still loading
                    let mut killer = ServeClient::connect(&a).unwrap();
                    killer.kill_shard(0).unwrap();
                }
                let r = c.query(&q).unwrap();
                out.push((r.root, r.shard));
            }
            out
        }));
    }
    let answered: Vec<(i128, Option<usize>)> =
        workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    assert_eq!(answered.len(), clients * per, "no query may be lost to the kill");
    for &(root, shard) in &answered {
        // S(∅)·d ≈ d on every shard (masks differ per stripe, value doesn't)
        assert!((root - 256).abs() <= 32, "root {root} from shard {shard:?}");
        assert!(matches!(shard, Some(0) | Some(1)));
    }
    // the kill has long landed: queries pinned at the corpse must be
    // served by the survivor
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    let post = 4usize;
    for _ in 0..post {
        c.send_raw(&pinned_query_json(&all_marg, 0)).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.shard, Some(1), "a dead pin falls back to the survivor");
        assert!((r.root - 256).abs() <= 32);
    }
    drop(c);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap();
    assert_eq!(report.queries, (clients * per + post) as u64, "exact accounting");
    assert_eq!(report.dead_shards, 1);
    assert!(report.per_shard[0].dead, "shard 0 is the corpse");
    assert!(!report.per_shard[1].dead);
    assert_eq!(
        report.per_shard[0].queries + report.per_shard[1].queries,
        report.queries,
        "per-shard counts partition the total"
    );
    // 8 workers + 1 killer + 1 post-kill client + 1 shutdown connection
    assert_eq!(report.clients, clients as u64 + 3);
}

#[test]
fn tcp_fleet_kill_severs_member_sockets_and_survivors_serve() {
    // The TCP chaos variant: kill-shard cuts shard 0's real member
    // sockets out from under its session; the fleet degrades and the
    // dead member set is torn down lossily.
    let st = Structure::mini_demo();
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let (addr, h) = spawn_fleet("tcp", st.clone(), 2, cfg);
    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    let before = {
        c.send_raw(&pinned_query_json(&q, 0)).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.shard, Some(0), "shard 0 serves while alive");
        r.root
    };
    let mut killer = ServeClient::connect(&addr.to_string()).unwrap();
    killer.kill_shard(0).unwrap();
    for _ in 0..3 {
        let r = c.query(&q).unwrap();
        assert_eq!(r.shard, Some(1), "only the survivor serves after the kill");
        assert!((r.root - before).abs() <= 8, "same query, rounding-close root");
    }
    drop(c);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap(); // member threads joined in spawn_fleet
    assert_eq!(report.queries, 4);
    assert_eq!(report.dead_shards, 1);
    assert!(report.per_shard[0].dead);
}

#[test]
fn unpinned_pipelined_load_spreads_over_live_shards() {
    // Least-loaded dispatch: one client pipelining a burst must light up
    // both shards (while a shard evaluates, new arrivals route to the
    // other), with exact totals and no deaths.
    let st = Structure::mini_demo();
    let total = 12usize;
    let queries = arrival_queries(&st, total);
    let cfg = ServeConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        max_queries: Some(total as u64),
    };
    let (addr, h) = spawn_fleet("sim", st.clone(), 2, cfg);
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    for q in &queries {
        c.send(q).unwrap();
    }
    let mut used = [0u64; 2];
    for _ in 0..total {
        let r = c.recv().unwrap();
        let s = r.shard.expect("fleet responses name their shard");
        used[s] += 1;
        assert!(r.batch >= 1 && r.batch <= 2);
    }
    let report = h.join().unwrap(); // max_queries reached → self-shutdown
    assert_eq!(report.queries, total as u64);
    assert_eq!(report.dead_shards, 0);
    assert!(used[0] > 0 && used[1] > 0, "both shards must serve ({used:?})");
    assert_eq!(report.per_shard[0].queries, used[0]);
    assert_eq!(report.per_shard[1].queries, used[1]);
}
