//! Persistent-server throughput: load against the micro-batching
//! scheduler of `net::serve` at rising client concurrency.
//!
//! Spins up the full serve stack (Sim backend, mini structure, 3 members)
//! and drives it with C ∈ {1, 8, 32} concurrent connections, each issuing
//! a fixed number of closed-loop queries — so the system-wide offered
//! concurrency is C and the scheduler can coalesce up to C queries per
//! tick. Reports queries/s, secure **rounds per query** (from the
//! server's summed tick deltas), and client-observed p50/p99 latency.
//!
//! The acceptance claim this bench charts: rounds/query **strictly
//! decreases** as concurrency rises 1 → 32 — micro-batching amortizes
//! MPC round-trips across concurrent users exactly like the offline
//! `infer_batch` amortization curve, but on live traffic. `--json <path>`
//! writes the `{bench, metric, value}` rows `make bench-json` commits as
//! BENCH_serve_throughput.json. Never skips (no artifacts needed).

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use spn_mpc::bench::JsonSink;
use spn_mpc::coordinator::serve::train_and_serve;
use spn_mpc::coordinator::train::TrainConfig;
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::net::serve::{ServeClient, ServeConfig, ServeReport};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::plan::Query;
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::learn;

const CONCURRENCY: [usize; 3] = [1, 8, 32];
const QUERIES_PER_CONN: usize = 24;
const MEMBERS: usize = 3;

/// One load run: serve on a background thread (auto-shutdown after the
/// exact query count), C closed-loop client threads, per-query latencies.
fn run_load(st: &Structure, conc: usize) -> (ServeReport, Vec<f64>, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let total = (conc * QUERIES_PER_CONN) as u64;
    let cfg = ServeConfig {
        max_batch: 32,
        max_wait: Duration::from_millis(3),
        max_queries: Some(total),
    };
    let st2 = st.clone();
    let server = thread::spawn(move || {
        // seeds 5/21: the same training as the serve/integration tests
        let counts = datasets::synth_shard_counts(&st2, MEMBERS, st2.rows, 5, 21);
        let rows = st2.rows as u64;
        let theta = learn::default_leaf_theta(&st2);
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched());
        let (report, _) = train_and_serve(
            &mut eng,
            &st2,
            &counts,
            rows,
            &TrainConfig::default(),
            &theta,
            listener,
            &cfg,
        )
        .unwrap();
        report
    });

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..conc {
        let a = addr.clone();
        let nv = st.num_vars;
        handles.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            let mut lats = Vec::with_capacity(QUERIES_PER_CONN);
            for i in 0..QUERIES_PER_CONN {
                let mut q = Query { x: vec![0; nv], marg: vec![true; nv] };
                let v = (t + i) % nv;
                q.x[v] = (i % 2) as u8;
                q.marg[v] = false;
                let tq = Instant::now();
                let r = c.query(&q).unwrap();
                assert!(r.batch >= 1);
                lats.push(tq.elapsed().as_secs_f64());
            }
            lats
        }));
    }
    let mut lats: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    let report = server.join().unwrap();
    lats.sort_by(f64::total_cmp);
    (report, lats, wall)
}

fn main() {
    let mut json = JsonSink::from_env_args();
    let st = Structure::mini_demo();
    let mut rows = Vec::new();
    let mut rpq_curve = Vec::new();
    for &c in &CONCURRENCY {
        let (report, lats, wall) = run_load(&st, c);
        assert_eq!(report.queries, (c * QUERIES_PER_CONN) as u64, "every query answered");
        let total = report.queries as f64;
        let qps = total / wall;
        let rpq = report.stats.rounds as f64 / total;
        let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize] * 1e3;
        let (p50, p99) = (pct(0.50), pct(0.99));
        rpq_curve.push(rpq);
        json.push("serve_throughput", &format!("queries_per_s_c{c}"), qps);
        json.push("serve_throughput", &format!("rounds_per_query_c{c}"), rpq);
        json.push("serve_throughput", &format!("p50_ms_c{c}"), p50);
        json.push("serve_throughput", &format!("p99_ms_c{c}"), p99);
        json.push("serve_throughput", &format!("max_tick_c{c}"), report.max_tick as f64);
        rows.push(vec![
            c.to_string(),
            report.queries.to_string(),
            report.batches.to_string(),
            report.max_tick.to_string(),
            format!("{qps:.0}"),
            format!("{rpq:.1}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
        ]);
    }
    assert!(
        rpq_curve[0] > rpq_curve[1] && rpq_curve[1] > rpq_curve[2],
        "rounds/query must strictly decrease as concurrency rises: {rpq_curve:?}"
    );
    println!(
        "{}",
        render_table(
            "Persistent server — micro-batched private inference (mini, sim backend, 3 members)",
            &["conc", "queries", "ticks", "max tick", "q/s", "rounds/q", "p50 ms", "p99 ms"],
            &rows
        )
    );
    json.finish().expect("write --json output");
    println!("serve_throughput OK");
}
