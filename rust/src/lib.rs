//! # spn-mpc — Fast Private Parameter Learning and Inference for SPNs
//!
//! A production-grade reproduction of Althaus, Dousti, Kramer & Rassau,
//! *"Fast Private Parameter Learning and Inference for Sum-Product
//! Networks"* (2021): honest-but-curious multiparty learning of selective
//! SPN sum-weights over horizontally partitioned data using **secret
//! sharing only** (no homomorphic encryption or oblivious transfer on the
//! main path), plus private marginal inference and private k-means on the
//! same division primitive.
//!
//! Architecture (three layers; see DESIGN.md):
//! * **rust (this crate)** — the Layer-3 coordinator: fields, shares, the
//!   transport-agnostic session API ([`protocols::MpcSession`]) with its
//!   two backends (the exercise engine with exact message accounting, and
//!   real-TCP member threads), the paper's protocols, baselines, CLI.
//! * **JAX (python/compile)** — Layer-2 per-party local counting/eval
//!   graphs, AOT-compiled to HLO text artifacts.
//! * **Pallas (python/compile/kernels)** — Layer-1 masked-matmul layer
//!   kernels inside those graphs.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT and runs
//! them from rust; python never executes at request time.

pub mod bench;
pub mod coordinator;
pub mod datasets;
pub mod field;
pub mod gc;
pub mod he;
pub mod json;
pub mod kmeans;
pub mod metrics;
pub mod net;
pub mod protocols;
pub mod rng;
pub mod runtime;
pub mod sharing;
pub mod spn;
