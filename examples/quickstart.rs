//! Quickstart: the paper's own worked examples, end to end.
//!
//! 1. Figure 1 — build the 2-variable SPN, print the node values the paper
//!    lists, and run a marginal query.
//! 2. Example 1 (§3.2) — the approximate sharing walkthrough with the
//!    paper's exact numbers.
//! 3. The §3.4 exact division — three parties privately compute
//!    d·(Σnum)/(Σden) with secret shares only, and we check it against the
//!    plain division.
//!
//! Run: `cargo run --release --example quickstart`

use spn_mpc::coordinator::approx::{approx_divide, LocalFraction};
use spn_mpc::field::{Field, EXAMPLE_P};
use spn_mpc::net::NetConfig;
use spn_mpc::protocols::division::{private_divide, DivisionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::graph::{figure1, Node};

fn main() -> anyhow::Result<()> {
    // ------------------------------------------------------------------ 1.
    println!("— Figure 1: the paper's example SPN —");
    let g = figure1();
    g.validate()?;
    let x = [1u8, 1u8]; // X1 = 1, X2 = 1
    let vals = g.eval_all(&x, &[false, false]);
    for (i, n) in g.nodes.iter().enumerate() {
        let label = match n {
            Node::Indicator { var, value } => format!("X{}={}", var + 1, value),
            Node::Sum { .. } => format!("S (node {i})"),
            Node::Product { .. } => format!("P (node {i})"),
            Node::Bernoulli { .. } => unreachable!(),
        };
        println!("  {label:12} -> {:.4}", vals[i]);
    }
    println!("  S(X1=1, X2=1) = {:.4}", g.eval(&x, &[false, false]));
    println!(
        "  Pr(X1=1 | X2=1) = {:.4}",
        g.conditional(&[1, 1], &[0], &[1])
    );

    // ------------------------------------------------------------------ 2.
    println!("\n— Example 1 (§3.2): approximate path, paper's exact numbers —");
    let f = Field::new(EXAMPLE_P);
    let locals = vec![vec![
        LocalFraction { num: 71, den: 256 },
        LocalFraction { num: 209, den: 786 },
        LocalFraction { num: 320, den: 1127 },
    ]];
    let out = approx_divide(&f, &locals, 1000, NetConfig::default(), 1);
    println!(
        "  3 parties, p = 2^20+7, d = 1000: shared approx = {} (true 0.277, paper 0.276)",
        out.revealed[0] as f64 / 1000.0
    );

    // ------------------------------------------------------------------ 3.
    println!("\n— §3.4 exact path: private division over Shamir shares —");
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(3));
    // party-local numerators/denominators from Example 1, entered as shares
    let num = eng.input(1, &[71 + 209 + 320])[0];
    let den = eng.input(1, &[256 + 786 + 1127])[0];
    let w = private_divide(&mut eng, num, den, 4096, &DivisionConfig::default());
    let got = eng.peek_int(w);
    println!(
        "  d·num/den = {} (exact {} at d = 256); {} messages, {:.1} virtual seconds",
        got,
        256 * 600 / 2169,
        eng.net.stats.messages,
        eng.net.stats.virtual_time_s
    );
    println!("\nquickstart OK");
    Ok(())
}
