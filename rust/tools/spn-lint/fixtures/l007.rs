//! L007 fixture: plan-step internals re-derived outside spn/plan.rs.
// A comment naming PlanStep::Product is a decoy and must not fire.

fn reschedule(step: &PlanStep) -> usize {
    match step {
        PlanStep::Product { rounds, .. } => rounds.len(),
        // lint:allow(L007) — suppressed decoy, must not fire
        PlanStep::Sum { width, .. } => *width,
    }
}
