//! L001 fixture: an untagged divpub in a file outside the division core.
//! Exactly one finding must come from this file (the self-check asserts
//! the global L001 count is 1, so the decoys double as skip-rule canaries).

fn evaluate(sess: &mut Sess, prods: &[u64]) -> Vec<u64> {
    // decoy: divpub_vec( in a comment line
    sess.divpub_vec(prods, 256)
}

// decoy: a definition, not a call
fn divpub_vec(us: &[u64], _d: u128) -> Vec<u64> {
    us.to_vec()
}

// decoy: the tagged variant is the sanctioned one
fn tagged(sess: &mut Sess, prods: &[u64]) -> Vec<u64> {
    sess.divpub_vec_tagged(prods, 256, 0)
}
