//! Private marginal inference (§4): servers hold shares of the learned
//! weights; a client shares its query; the network is evaluated bottom-up
//! with secure sums and products; only the root value is revealed (to the
//! client).
//!
//! Since the compiled-plan refactor the layer wiring is derived **once**
//! per structure ([`EvalPlan::compile`]) instead of per query, and whole
//! query batches evaluate simultaneously: [`private_eval_batch`] walks the
//! plan's dependency-DAG waves and issues each wave's mul/lin/divpub
//! traffic as one coalesced flight (`submit`/`complete`), so warm rounds
//! per batch collapse to `6·critical_depth + 9` while every query's
//! revealed value stays **bit-identical** to a sequential
//! [`private_eval`] (the tagged-divpub invariant — see `spn::plan` and
//! DESIGN.md §Round scheduler). For a standing service,
//! use [`crate::coordinator::serve`] (the `spn-mpc serve` subcommand),
//! which compiles once and drives one persistent [`Evaluator`] behind a
//! micro-batching scheduler; the free functions here recompile per call
//! for convenience.
//!
//! Fixed-point convention: every node value is an integer ≈ d·(true value)
//! with d = 256 (§5.3); each secure multiplication of two d-scaled values
//! is followed by a truncation by d (divpub).  Like the paper's setting,
//! deep conjunctive queries underflow at this precision — marginal queries
//! over a handful of evidence variables (CryptoSPN's use case) are the
//! intended workload; the `infer` tests quantify accuracy against the
//! float oracle.

use crate::protocols::session::MpcSession;
use crate::coordinator::train::SharedModel;
use crate::net::NetStats;
use crate::spn::plan::{EvalPlan, Evaluator};
use crate::spn::structure::Structure;

pub use crate::spn::plan::Query;

/// Evaluate S(query) over shares on any [`MpcSession`] backend; returns
/// the revealed d-scaled root value and the traffic spent.
pub fn private_eval<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    q: &Query,
    default_leaf_theta: &[f64],
) -> (i128, NetStats) {
    let (vals, stats) =
        private_eval_batch(sess, st, model, std::slice::from_ref(q), default_leaf_theta);
    (vals[0], stats)
}

/// Evaluate a whole batch of queries simultaneously: one compiled plan,
/// one coalesced secure call per plan step. Returns the revealed d-scaled
/// root value per query (same order) and the total traffic. Each value is
/// bit-identical to what the same query would reveal through a sequential
/// [`private_eval`] at the same position in the session.
pub fn private_eval_batch<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    queries: &[Query],
    default_leaf_theta: &[f64],
) -> (Vec<i128>, NetStats) {
    let plan = EvalPlan::compile(st, default_leaf_theta, model.d);
    let mut ev = Evaluator::new(plan);
    ev.eval_batch(sess, queries, &model.sum_w, model.leaf_theta.as_deref())
}

/// Conditional Pr(x | e) = S(x∧e)/S(e) — the two evaluations run as one
/// compiled-plan batch (their secure rounds coalesce, and the revealed
/// values are bit-identical to sequential evaluation); the client divides
/// the revealed d-scaled values (§4).
pub fn private_conditional<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    x_assign: &[(usize, u8)],
    e_assign: &[(usize, u8)],
    default_leaf_theta: &[f64],
) -> (f64, NetStats) {
    let nv = st.num_vars;
    let mut x = vec![0u8; nv];
    let mut marg_xe = vec![true; nv];
    for &(v, b) in x_assign.iter().chain(e_assign) {
        x[v] = b;
        marg_xe[v] = false;
    }
    let mut marg_e = vec![true; nv];
    for &(v, b) in e_assign {
        x[v] = b;
        marg_e[v] = false;
    }
    let queries =
        [Query { x: x.clone(), marg: marg_xe }, Query { x, marg: marg_e }];
    let (vals, stats) = private_eval_batch(sess, st, model, &queries, default_leaf_theta);
    let (sxe, se) = (vals[0], vals[1]);
    let p = if se <= 0 { 0.0 } else { (sxe.max(0) as f64) / (se as f64) };
    (p.min(1.0), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::{train, TrainConfig};
    use crate::datasets;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig, Schedule};
    use crate::spn::{eval, learn};
    use crate::spn::structure::Structure;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    fn trained(n: usize) -> Option<(Structure, Engine, SharedModel, Vec<f64>)> {
        let st = toy()?;
        let gt = datasets::ground_truth_params(&st, 5);
        let data = datasets::sample(&st, &gt, 3000, 11);
        let shards = datasets::partition(&data, n);
        let shard_counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
        let (model, _) = train(&mut eng, &st, &shard_counts, 3000, &TrainConfig::default());
        // float oracle params from the revealed weights (same quantization)
        let fixed = super::super::train::peek_weights(&eng, &model);
        let theta = learn::default_leaf_theta(&st);
        let params = learn::params_from_fixed(&st, &fixed, &theta, 256);
        Some((st, eng, model, params))
    }

    #[test]
    fn private_eval_matches_float_oracle_marginal() {
        let Some((st, mut eng, model, params)) = trained(5) else { return };
        let theta = learn::default_leaf_theta(&st);
        // evidence on one variable, rest marginalized: shallow, no underflow
        for v in 0..st.num_vars {
            for b in [0u8, 1] {
                let mut q =
                    Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
                q.x[v] = b;
                q.marg[v] = false;
                let (got, _) = private_eval(&mut eng, &st, &model, &q, &theta);
                let marg: Vec<bool> = q.marg.clone();
                let want = eval::logeval(&st, &q.x, &marg, &params).exp();
                let got_f = got.max(0) as f64 / 256.0;
                assert!(
                    (got_f - want).abs() < 0.08,
                    "v={v} b={b}: private {got_f} vs oracle {want}"
                );
            }
        }
    }

    #[test]
    fn batch_eval_matches_sequential_bit_exact() {
        // The acceptance pin of the compiled-plan refactor: a batch reveals
        // exactly the values B sequential evaluations reveal under the same
        // seed. Two identically-seeded engines (so tag reservations line
        // up), identical training, then sequential vs batched inference.
        let Some((st, mut eng_seq, model_seq, _)) = trained(3) else { return };
        let Some((_, mut eng_bat, model_bat, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let mut queries = Vec::new();
        for v in 0..st.num_vars {
            for b in [0u8, 1] {
                let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
                q.x[v] = b;
                q.marg[v] = false;
                queries.push(q);
            }
        }
        queries.push(Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] });

        let seq: Vec<i128> = queries
            .iter()
            .map(|q| private_eval(&mut eng_seq, &st, &model_seq, q, &theta).0)
            .collect();
        let (bat, _) = private_eval_batch(&mut eng_bat, &st, &model_bat, &queries, &theta);
        assert_eq!(seq, bat, "batched evaluation must be bit-identical to sequential");
    }

    #[test]
    fn batch_rounds_sublinear_in_batch_size() {
        // Rounds per plan step are batch-width-independent under the
        // Batched schedule, so a B-query batch pays ~1/B the rounds of B
        // sequential evaluations.
        let Some((st, mut eng, model, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        let (_, one) = private_eval(&mut eng, &st, &model, &q, &theta);
        let batch: Vec<Query> = (0..16).map(|_| q.clone()).collect();
        let (_, sixteen) = private_eval_batch(&mut eng, &st, &model, &batch, &theta);
        assert!(
            sixteen.rounds < 4 * one.rounds,
            "16-query batch must cost far less than 16× one query: {} vs 16×{}",
            sixteen.rounds,
            one.rounds
        );
    }

    #[test]
    fn private_conditional_close_to_oracle() {
        let Some((st, mut eng, model, params)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let (p, stats) =
            private_conditional(&mut eng, &st, &model, &[(0, 1)], &[(1, 1)], &theta);
        // oracle
        let mut x = vec![0u8; st.num_vars];
        x[0] = 1;
        x[1] = 1;
        let mut m_xe = vec![true; st.num_vars];
        m_xe[0] = false;
        m_xe[1] = false;
        let mut m_e = vec![true; st.num_vars];
        m_e[1] = false;
        let want = eval::logeval(&st, &x, &m_xe, &params).exp()
            / eval::logeval(&st, &x, &m_e, &params).exp();
        assert!((p - want).abs() < 0.25, "private {p} vs oracle {want}");
        assert!(stats.messages > 0);
    }

    #[test]
    fn all_marginal_query_gives_d() {
        // S(∅) = 1 → d-scaled root ≈ d.
        let Some((st, mut eng, model, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        let (got, _) = private_eval(&mut eng, &st, &model, &q, &theta);
        assert!((got - 256).abs() <= 26, "S(∅)·d = {got}");
    }

    #[test]
    fn inference_cost_scales_with_edges() {
        let Some((st, mut eng, model, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        // PerOp accounting: one exercise slot per vector *element*, so the
        // paper-mode cost still scales with the edge count even though the
        // plan coalesces elements into few vector calls.
        eng.cfg.schedule = Schedule::PerOp;
        let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        let (_, stats) = private_eval(&mut eng, &st, &model, &q, &theta);
        // at least one secure op per edge
        assert!(stats.exercises as usize >= st.stats.edges / 2);
    }
}
