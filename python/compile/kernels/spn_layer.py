"""Layer-1 Pallas kernels: one SPN layer as a masked dense matmul + epilogue.

The per-party training hot path (computing the selective activation counts
n_ij over a data shard, §3.1 of the paper) is reformulated from SPFlow's
per-node graph walk into *layered dense matmuls*:

  bottom-up positivity   pos_out = OR / AND (M @ pos_in)
  top-down activation    act_in  = (Mᵀ @ act_out) ⊙ pos_in

Every step is `Y = X @ Mᵀ` over a `(batch, width)` tile followed by a cheap
elementwise epilogue, which is exactly what the MXU wants.  On TPU, X tiles
stream HBM→VMEM along the batch axis via the BlockSpec grid while M (a few
hundred KB at most for Table-1 structures) stays resident in VMEM; see
DESIGN.md §Hardware-Adaptation and EXPERIMENTS.md §Perf for the footprint
and utilization estimates.

All kernels are lowered with interpret=True: the CPU PJRT plugin used by the
rust runtime cannot execute Mosaic custom-calls (see /opt/xla-example
README), so the interpret path is both the correctness oracle target and
what ships in the HLO artifacts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Epilogue modes.
MODE_NONE = 0        # plain matmul
MODE_OR = 1          # y > 0.5          (sum-node positivity: any child positive)
MODE_AND = 2         # y > rowdeg - 0.5 (product-node positivity: all children)
MODE_GATE = 3        # y * gate         (top-down activation masking)

_INTERPRET = True    # Mosaic lowering is compile-only on this image.


def _layer_kernel(x_ref, m_ref, deg_ref, gate_ref, o_ref, *, mode: int):
    """One (batch_tile, in_w) x (in_w, out_w) tile."""
    x = x_ref[...]
    m = m_ref[...]
    y = jnp.dot(x, m, preferred_element_type=jnp.float32)
    if mode == MODE_OR:
        y = (y > 0.5).astype(jnp.float32)
    elif mode == MODE_AND:
        y = (y > deg_ref[...][None, :] - 0.5).astype(jnp.float32)
    elif mode == MODE_GATE:
        y = y * gate_ref[...]
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("mode", "block_b"))
def layer_apply(x: jax.Array, mt: jax.Array, deg: jax.Array,
                gate: jax.Array, mode: int, block_b: int = 128) -> jax.Array:
    """Apply one SPN layer.

    x    : (B, in_w)  activations / positivities entering the layer
    mt   : (in_w, out_w)  transposed adjacency or weight matrix
    deg  : (out_w,)   row degrees (only used by MODE_AND)
    gate : (B, out_w) positivity gate (only used by MODE_GATE)
    """
    b, in_w = x.shape
    out_w = mt.shape[1]
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_layer_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, in_w), lambda i: (i, 0)),
            pl.BlockSpec((in_w, out_w), lambda i: (0, 0)),
            pl.BlockSpec((out_w,), lambda i: (0,)),
            pl.BlockSpec((block_b, out_w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, out_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, out_w), jnp.float32),
        interpret=_INTERPRET,
    )(x, mt, deg, gate)


def _masked_count_kernel(a_ref, w_ref, o_ref):
    """Column-sum of a ⊙ w (row weights) accumulated across the batch grid."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    w = w_ref[...]
    o_ref[...] += jnp.sum(a * w[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def masked_count(a: jax.Array, row_mask: jax.Array, block_b: int = 128) -> jax.Array:
    """sum_batch(row_mask[b] * a[b, j]) — the count reduction."""
    b, w = a.shape
    assert b % block_b == 0
    grid = (b // block_b,)
    return pl.pallas_call(
        _masked_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((w,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((w,), jnp.float32),
        interpret=_INTERPRET,
    )(a, row_mask)


def vmem_footprint_bytes(batch_tile: int, in_w: int, out_w: int) -> int:
    """Analytic VMEM footprint of one layer_apply tile (f32).

    Used by the §Perf notes: X tile + M + deg + gate + Y tile, double-buffered
    on the streaming (batch) operands.
    """
    stream = (batch_tile * in_w + batch_tile * out_w + batch_tile * out_w) * 4
    resident = (in_w * out_w + out_w) * 4
    return 2 * stream + resident
