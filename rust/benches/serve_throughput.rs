//! Persistent-server throughput: load against the micro-batching
//! scheduler of `net::serve` at rising client concurrency, then against
//! the sharded fleet of `net::fleet` at rising shard counts.
//!
//! Part 1 spins up the full single-session serve stack (Sim backend, mini
//! structure, 3 members) and drives it with C ∈ {1, 8, 32} concurrent
//! connections, each issuing a fixed number of closed-loop queries — so
//! the system-wide offered concurrency is C and the scheduler can
//! coalesce up to C queries per tick. Reports queries/s, secure **rounds
//! per query** (from the server's summed tick deltas), and
//! client-observed p50/p99 latency. The acceptance claim: rounds/query
//! **strictly decreases** as concurrency rises — micro-batching amortizes
//! MPC round-trips across concurrent users exactly like the offline
//! `infer_batch` amortization curve, but on live traffic.
//!
//! Part 2 holds C fixed at 32 and serves through `--shards S` fleets,
//! S ∈ {1, 2, 4}: S independent sessions replicated by deterministic
//! replay, each evaluating its own ticks on its own thread. The
//! acceptance claim: q/s **increases with S** (near-linear in sim, where
//! each session's evaluation is CPU-bound on one thread). Every fleet
//! JSON row carries the shard count (`shards_c{C}_s{S}`).
//!
//! `--json <path>` writes the `{bench, metric, value}` rows `make
//! bench-json` commits as BENCH_serve_throughput.json; `--smoke` shrinks
//! to C ∈ {1, 8}, 6 queries/connection, fleet C=8 with S ∈ {1, 2} — the
//! CI serve-smoke job runs that path on every push. Never skips (no
//! artifacts needed).

use std::net::TcpListener;
use std::thread;
use std::time::{Duration, Instant};

use spn_mpc::bench::JsonSink;
use spn_mpc::coordinator::serve::{train_and_serve, train_and_serve_fleet};
use spn_mpc::coordinator::train::TrainConfig;
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::net::fleet::FleetReport;
use spn_mpc::net::serve::{ServeClient, ServeConfig, ServeReport};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::plan::Query;
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::learn;

const MEMBERS: usize = 3;

fn serve_cfg(total: u64) -> ServeConfig {
    ServeConfig { max_batch: 32, max_wait: Duration::from_millis(3), max_queries: Some(total) }
}

/// C closed-loop client threads against a running server; returns sorted
/// per-query latencies and the wall-clock of the whole load.
fn drive_clients(addr: &str, conc: usize, per_conn: usize, nv: usize) -> (Vec<f64>, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..conc {
        let a = addr.to_string();
        handles.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            let mut lats = Vec::with_capacity(per_conn);
            for i in 0..per_conn {
                let mut q = Query { x: vec![0; nv], marg: vec![true; nv] };
                let v = (t + i) % nv;
                q.x[v] = (i % 2) as u8;
                q.marg[v] = false;
                let tq = Instant::now();
                let r = c.query(&q).unwrap();
                assert!(r.batch >= 1);
                lats.push(tq.elapsed().as_secs_f64());
            }
            lats
        }));
    }
    let mut lats: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    (lats, wall)
}

/// One single-session load run: serve on a background thread
/// (auto-shutdown after the exact query count), then drive it.
fn run_load(st: &Structure, conc: usize, per_conn: usize) -> (ServeReport, Vec<f64>, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = serve_cfg((conc * per_conn) as u64);
    let st2 = st.clone();
    let server = thread::spawn(move || {
        // seeds 5/21: the same training as the serve/integration tests
        let counts = datasets::synth_shard_counts(&st2, MEMBERS, st2.rows, 5, 21);
        let rows = st2.rows as u64;
        let theta = learn::default_leaf_theta(&st2);
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched());
        let (report, _) = train_and_serve(
            &mut eng,
            &st2,
            &counts,
            rows,
            &TrainConfig::default(),
            &theta,
            listener,
            &cfg,
        )
        .unwrap();
        report
    });
    let (lats, wall) = drive_clients(&addr, conc, per_conn, st.num_vars);
    (server.join().unwrap(), lats, wall)
}

/// One fleet load run: S replicated Sim sessions behind the fleet
/// front-end, same closed-loop client load.
fn run_load_fleet(
    st: &Structure,
    conc: usize,
    shards: usize,
    per_conn: usize,
) -> (FleetReport, Vec<f64>, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = serve_cfg((conc * per_conn) as u64);
    let st2 = st.clone();
    let server = thread::spawn(move || {
        let counts = datasets::synth_shard_counts(&st2, MEMBERS, st2.rows, 5, 21);
        let rows = st2.rows as u64;
        let theta = learn::default_leaf_theta(&st2);
        let mut sessions: Vec<Engine> = (0..shards)
            .map(|_| Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()))
            .collect();
        let (report, _) = train_and_serve_fleet(
            &mut sessions,
            &st2,
            &counts,
            rows,
            &TrainConfig::default(),
            &theta,
            listener,
            &cfg,
            Vec::new(),
        )
        .unwrap();
        report
    });
    let (lats, wall) = drive_clients(&addr, conc, per_conn, st.num_vars);
    (server.join().unwrap(), lats, wall)
}

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    let mut json = JsonSink::from_env_args();
    let st = Structure::mini_demo();
    let concurrency: Vec<usize> = if smoke { vec![1, 8] } else { vec![1, 8, 32] };
    let per_conn = if smoke { 6 } else { 24 };
    let pct = |lats: &[f64], p: f64| lats[((lats.len() - 1) as f64 * p) as usize] * 1e3;

    // Part 1 — single session, rising concurrency (legacy metric names).
    let mut rows = Vec::new();
    let mut rpq_curve = Vec::new();
    for &c in &concurrency {
        let (report, lats, wall) = run_load(&st, c, per_conn);
        assert_eq!(report.queries, (c * per_conn) as u64, "every query answered");
        let total = report.queries as f64;
        let qps = total / wall;
        let rpq = report.stats.rounds as f64 / total;
        let (p50, p99) = (pct(&lats, 0.50), pct(&lats, 0.99));
        rpq_curve.push(rpq);
        json.push("serve_throughput", &format!("queries_per_s_c{c}"), qps);
        json.push("serve_throughput", &format!("rounds_per_query_c{c}"), rpq);
        json.push("serve_throughput", &format!("p50_ms_c{c}"), p50);
        json.push("serve_throughput", &format!("p99_ms_c{c}"), p99);
        json.push("serve_throughput", &format!("max_tick_c{c}"), report.max_tick as f64);
        rows.push(vec![
            c.to_string(),
            report.queries.to_string(),
            report.batches.to_string(),
            report.max_tick.to_string(),
            format!("{qps:.0}"),
            format!("{rpq:.1}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
        ]);
    }
    for w in rpq_curve.windows(2) {
        assert!(
            w[0] > w[1],
            "rounds/query must strictly decrease as concurrency rises: {rpq_curve:?}"
        );
    }
    println!(
        "{}",
        render_table(
            "Persistent server — micro-batched private inference (mini, sim backend, 3 members)",
            &["conc", "queries", "ticks", "max tick", "q/s", "rounds/q", "p50 ms", "p99 ms"],
            &rows
        )
    );

    // Part 2 — fleet scaling: fixed C, rising shard count. Every JSON row
    // carries the shard count in its name plus an explicit shards row.
    let fleet_c = if smoke { 8 } else { 32 };
    let shard_counts: Vec<usize> = if smoke { vec![1, 2] } else { vec![1, 2, 4] };
    let mut frows = Vec::new();
    let mut qps_curve = Vec::new();
    for &s in &shard_counts {
        let (report, lats, wall) = run_load_fleet(&st, fleet_c, s, per_conn);
        assert_eq!(report.queries, (fleet_c * per_conn) as u64, "every query answered");
        assert_eq!(report.shards, s);
        assert_eq!(report.dead_shards, 0, "no shard may die under clean load");
        let total = report.queries as f64;
        let qps = total / wall;
        let rpq = report.stats.rounds as f64 / total;
        let (p50, p99) = (pct(&lats, 0.50), pct(&lats, 0.99));
        qps_curve.push(qps);
        json.push("serve_throughput", &format!("shards_c{fleet_c}_s{s}"), s as f64);
        json.push("serve_throughput", &format!("queries_per_s_c{fleet_c}_s{s}"), qps);
        json.push("serve_throughput", &format!("rounds_per_query_c{fleet_c}_s{s}"), rpq);
        json.push("serve_throughput", &format!("p50_ms_c{fleet_c}_s{s}"), p50);
        json.push("serve_throughput", &format!("p99_ms_c{fleet_c}_s{s}"), p99);
        json.push("serve_throughput", &format!("max_tick_c{fleet_c}_s{s}"), report.max_tick as f64);
        frows.push(vec![
            s.to_string(),
            fleet_c.to_string(),
            report.queries.to_string(),
            report.batches.to_string(),
            report.max_tick.to_string(),
            format!("{qps:.0}"),
            format!("{rpq:.1}"),
            format!("{p50:.2}"),
            format!("{p99:.2}"),
        ]);
    }
    if !smoke {
        // the fleet acceptance curve (near-linear is the target; the hard
        // floor here is "more shards must not serve slower")
        assert!(
            qps_curve.last().unwrap() > qps_curve.first().unwrap(),
            "q/s must increase with shard count at C={fleet_c}: {qps_curve:?}"
        );
    }
    println!(
        "{}",
        render_table(
            "Serve fleet — sharded sessions, fixed concurrency (mini, sim backend, 3 members)",
            &["shards", "conc", "queries", "ticks", "max tick", "q/s", "rounds/q", "p50 ms", "p99 ms"],
            &frows
        )
    );
    json.finish().expect("write --json output");
    println!("serve_throughput OK");
}
