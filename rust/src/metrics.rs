//! Reporting helpers: table formatting and run summaries shared by the CLI,
//! examples, and benches.

use crate::net::NetStats;

/// Render an aligned ASCII table (paper-style).
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for r in rows {
        out.push_str(&fmt_row(r, &widths));
        out.push('\n');
    }
    out
}

/// Format a NetStats row in the paper's Tables 2/3 column layout.
pub fn stats_row(dataset: &str, s: &NetStats) -> Vec<String> {
    vec![
        dataset.to_string(),
        group_thousands(s.messages),
        format!("{:.0}", s.megabytes()),
        format!("{:.0}", s.virtual_time_s),
    ]
}

/// 4.231.815-style thousands grouping (as printed in the paper).
pub fn group_thousands(x: u64) -> String {
    let s = x.to_string();
    let bytes = s.as_bytes();
    let mut out = String::new();
    for (i, c) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push('.');
        }
        out.push(*c as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands_grouping_matches_paper_style() {
        assert_eq!(group_thousands(4231815), "4.231.815");
        assert_eq!(group_thousands(915273), "915.273");
        assert_eq!(group_thousands(170), "170");
        assert_eq!(group_thousands(0), "0");
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "Test",
            &["Dataset", "msgs"],
            &[
                vec!["nltcs".into(), "123".into()],
                vec!["bnetflix".into(), "4567".into()],
            ],
        );
        assert!(t.contains("nltcs"));
        assert!(t.contains("bnetflix"));
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 5);
    }
}
