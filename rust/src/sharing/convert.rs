//! SQ2PQ: additive-to-polynomial share conversion [14] (§2.2.2).
//!
//! Each party Shamir-deals its additive share; every party then sums the
//! sub-shares it received.  Because Shamir sharing is linearly homomorphic,
//! the resulting polynomial shares encode `Σ additive_i = x`.
//!
//! This module provides the party-local pieces; the exercise engine in
//! `protocols::engine` wires them with message accounting.

use crate::rng::Rng;

use super::shamir::ShamirCtx;

/// Party-local half of SQ2PQ: deal one's additive share as Shamir shares.
/// Returns `n` sub-shares, entry `j` to be sent to party `j+1`.
pub fn sq2pq_local_deal<R: Rng + ?Sized>(
    ctx: &ShamirCtx,
    additive_share: u128,
    rng: &mut R,
) -> Vec<u128> {
    ctx.share(additive_share, rng)
}

/// Combine the sub-shares a party received (one from each dealer).
pub fn sq2pq_combine(ctx: &ShamirCtx, received: &[u128]) -> u128 {
    ctx.f.sum(received)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::sharing::additive::additive_share;
    use crate::rng::Prng;

    fn run_sq2pq(n: usize, x: u128, seed: u64) -> (ShamirCtx, Vec<u128>) {
        let f = Field::paper();
        let ctx = ShamirCtx::new(f, n);
        let mut rng = Prng::seed_from_u64(seed);
        let adds = additive_share(&f, x, n, &mut rng);
        // deal: dealt[i][j] = sub-share from dealer i to party j
        let dealt: Vec<Vec<u128>> = adds
            .iter()
            .map(|&a| sq2pq_local_deal(&ctx, a, &mut rng))
            .collect();
        // combine: party j sums column j
        let poly: Vec<u128> = (0..n)
            .map(|j| sq2pq_combine(&ctx, &dealt.iter().map(|row| row[j]).collect::<Vec<_>>()))
            .collect();
        (ctx, poly)
    }

    #[test]
    fn converts_and_reconstructs() {
        for n in [1, 3, 5, 13] {
            let (ctx, poly) = run_sq2pq(n, 987654321, 7);
            assert_eq!(ctx.reconstruct(&poly), 987654321);
        }
    }

    #[test]
    fn result_is_degree_t() {
        // t+1 shares suffice after conversion.
        let (ctx, poly) = run_sq2pq(7, 42, 8);
        let pts: Vec<(usize, u128)> = (1..=ctx.t + 1).map(|i| (i, poly[i - 1])).collect();
        assert_eq!(ctx.reconstruct_subset(&pts, ctx.t), 42);
    }

    #[test]
    fn prop_sq2pq() {
        crate::rng::property(64, |rng| {
            use crate::rng::Rng;
            let x = rng.gen_range_u128(crate::field::PAPER_P);
            let n = 1 + rng.gen_range_u64(9) as usize;
            let seed = rng.next_u64();
            let (ctx, poly) = run_sq2pq(n, x, seed);
            assert_eq!(ctx.reconstruct(&poly), x);
        });
    }
}
