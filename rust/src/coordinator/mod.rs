//! The paper's system coordinators.
//!
//! * [`approx`] — the §3.2 approximate path (additive shares + JRSZ), with
//!   the paper's Example 1 reproduced digit-for-digit in tests.
//! * [`train`]  — the §3.4 exact path: per-party counts → SQ2PQ → one
//!   Newton inversion per sum node → per-edge multiply + truncate.
//! * [`infer`]  — §4 private marginal inference over the learned shares.

pub mod approx;
pub mod infer;
pub mod train;

pub use train::{train, SharedModel, TrainConfig, TrainReport};
