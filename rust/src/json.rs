//! Minimal JSON parser (RFC 8259 subset) for the structure artifacts.
//!
//! The vendored crate set has no `serde_json`, so this small recursive-
//! descent parser covers what `artifacts/*.structure.json` and
//! `manifest.json` need: objects, arrays, numbers (integers and floats),
//! strings (with escapes), booleans, null.  It is strict about structure
//! but permissive about whitespace, and reports byte offsets on error.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> &Json {
        match self {
            Json::Obj(m) => m.get(key).unwrap_or_else(|| panic!("missing key {key:?}")),
            _ => panic!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(x) => *x,
            _ => panic!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        let x = self.as_f64();
        assert!(x >= 0.0 && x.fract() == 0.0, "not a usize: {x}");
        x as usize
    }

    pub fn as_i64(&self) -> i64 {
        let x = self.as_f64();
        assert!(x.fract() == 0.0, "not an integer: {x}");
        x as i64
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(v) => v,
            _ => panic!("not an array: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Vec<usize> {
        self.as_arr().iter().map(|x| x.as_usize()).collect()
    }

    pub fn i64_vec(&self) -> Vec<i64> {
        self.as_arr().iter().map(|x| x.as_i64()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // BMP only (artifacts are ASCII anyway)
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().len(), 3);
        assert_eq!(v.get("a").as_arr()[2].get("b").as_str(), "c");
        assert_eq!(*v.get("d"), Json::Null);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"w": [3, 4, 5], "k": [-1, 0, 7]}"#).unwrap();
        assert_eq!(v.get("w").usize_vec(), vec![3, 4, 5]);
        assert_eq!(v.get("k").i64_vec(), vec![-1, 0, 7]);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn parses_real_structure_artifact_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/toy.structure.json");
        if let Ok(s) = std::fs::read_to_string(path) {
            let v = Json::parse(&s).unwrap();
            assert_eq!(v.get("name").as_str(), "toy");
            assert!(v.get("num_params").as_usize() > 0);
        }
    }
}
