//! L005 fixture: the shared-layout module pinning the version constant.

pub const WIRE_LAYOUT_VERSION: u32 = 2;
