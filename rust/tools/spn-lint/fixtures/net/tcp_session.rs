//! L005 fixture, framing module B — deliberately one version behind.
//! wire-layout: v1 (disagrees: the self-check expects L005 to fire here)
