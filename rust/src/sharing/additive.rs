//! Additive secret sharing over `Z_p` (§2.2.2).
//!
//! Shares of `x` are `x_1..x_n` with `Σ x_i = x (mod p)`; all but the last
//! are uniform.  `jrsz` is the paper's *joint random sharing of zero*
//! protocol, `JRSZ(Z_p)`: a dealer (third party / manager) hands each party
//! a share of 0, consumed by the approximate path (§3.2) to mask the locally
//! computed fractions.

use crate::rng::Rng;

use crate::field::Field;

/// Split `x` into `n` additive shares.
pub fn additive_share<R: Rng + ?Sized>(f: &Field, x: u128, n: usize, rng: &mut R) -> Vec<u128> {
    assert!(n >= 1);
    let mut shares = Vec::with_capacity(n);
    let mut acc = 0u128;
    for _ in 0..n - 1 {
        let s = f.rand(rng);
        acc = f.add(acc, s);
        shares.push(s);
    }
    shares.push(f.sub(f.reduce(x), acc));
    shares
}

/// Reconstruct from all `n` additive shares.
pub fn reconstruct_additive(f: &Field, shares: &[u128]) -> u128 {
    f.sum(shares)
}

/// Joint random sharing of zero: `n` shares summing to 0 mod p.
pub fn jrsz<R: Rng + ?Sized>(f: &Field, n: usize, rng: &mut R) -> Vec<u128> {
    additive_share(f, 0, n, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, EXAMPLE_P};
    use crate::rng::Prng;

    #[test]
    fn roundtrip() {
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(1);
        for _ in 0..100 {
            let x = f.rand(&mut rng);
            let sh = additive_share(&f, x, 7, &mut rng);
            assert_eq!(reconstruct_additive(&f, &sh), x);
        }
    }

    #[test]
    fn jrsz_sums_to_zero() {
        let f = Field::new(EXAMPLE_P);
        let mut rng = Prng::seed_from_u64(2);
        for n in 1..10 {
            let sh = jrsz(&f, n, &mut rng);
            assert_eq!(reconstruct_additive(&f, &sh), 0);
        }
    }

    #[test]
    fn shares_are_additive_homomorphic() {
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(3);
        let (x, y) = (f.rand(&mut rng), f.rand(&mut rng));
        let sx = additive_share(&f, x, 5, &mut rng);
        let sy = additive_share(&f, y, 5, &mut rng);
        let sz: Vec<u128> = sx.iter().zip(&sy).map(|(&a, &b)| f.add(a, b)).collect();
        assert_eq!(reconstruct_additive(&f, &sz), f.add(x, y));
    }

    #[test]
    fn single_party_degenerates_to_value() {
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(4);
        let sh = additive_share(&f, 42, 1, &mut rng);
        assert_eq!(sh, vec![42]);
    }

    #[test]
    fn first_shares_are_uniformish() {
        // Chi-square-lite: bucket the first share of many sharings of the
        // SAME secret; counts should not concentrate (secrecy smoke test).
        let f = Field::new(EXAMPLE_P);
        let mut rng = Prng::seed_from_u64(5);
        let mut buckets = [0u32; 16];
        for _ in 0..4096 {
            let sh = additive_share(&f, 123, 3, &mut rng);
            buckets[(sh[0] % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((150..=370).contains(&b), "bucket skew: {buckets:?}");
        }
    }

    #[test]
    fn prop_roundtrip() {
        let f = Field::new(EXAMPLE_P);
        crate::rng::property(128, |rng| {
            let x = f.rand(rng);
            let n = 1 + rng.gen_range_u64(11) as usize;
            let sh = additive_share(&f, x, n, rng);
            assert_eq!(sh.len(), n);
            assert_eq!(reconstruct_additive(&f, &sh), x);
        });
    }
}
