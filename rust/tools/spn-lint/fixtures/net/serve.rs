//! L004 fixture: a panicking unwrap on a serve-layer lock.
//! (A comment saying .unwrap() is a decoy and must not fire.)

fn tick(state: &std::sync::Mutex<u64>) -> u64 {
    *state.lock().unwrap()
}

fn guarded(state: &std::sync::Mutex<u64>) -> u64 {
    // lint:allow(L004) — decoy: suppressed by the preceding line
    *state.lock().unwrap()
}
