//! Textbook Paillier: the additively homomorphic scheme for the §3.3
//! baseline.
//!
//! KeyGen: n = p·q, λ = lcm(p-1, q-1), g = n+1, μ = λ⁻¹ mod n.
//! Enc(m; r) = (1+n)^m · r^n mod n², Dec(c) = L(c^λ mod n²)·μ mod n with
//! L(x) = (x-1)/n.  Enc(m₁)·Enc(m₂) = Enc(m₁+m₂) — the property §3.3 uses
//! to aggregate `Σ d·numᵢ` and `Σ denᵢ` at the leader.

use super::bigint::BigUint;
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct Keypair {
    pub n: BigUint,
    pub n2: BigUint,
    lambda: BigUint,
    mu: BigUint,
}

pub struct Paillier;

impl Paillier {
    /// Generate a keypair with an n of ~`bits` bits.
    pub fn keygen<R: Rng + ?Sized>(rng: &mut R, bits: usize) -> Keypair {
        loop {
            let p = BigUint::gen_prime(rng, bits / 2);
            let q = BigUint::gen_prime(rng, bits / 2);
            if p == q {
                continue;
            }
            let n = p.mul(&q);
            let one = BigUint::one();
            let lambda = p.sub(&one).lcm(&q.sub(&one));
            let n2 = n.mul(&n);
            // μ = (L(g^λ mod n²))⁻¹ mod n with g = n+1:
            // g^λ = (1+n)^λ = 1 + λn (mod n²) → L = λ mod n
            let l = lambda.rem(&n);
            let Some(mu) = l.modinv(&n) else { continue };
            return Keypair { n, n2, lambda, mu };
        }
    }

    pub fn encrypt<R: Rng + ?Sized>(kp: &Keypair, m: &BigUint, rng: &mut R) -> BigUint {
        assert!(m.cmp_big(&kp.n) == std::cmp::Ordering::Less, "message too large");
        // (1+n)^m = 1 + m·n (mod n²) — the standard shortcut
        let gm = BigUint::one().add(&m.mulmod(&kp.n, &kp.n2)).rem(&kp.n2);
        // r coprime to n
        let r = loop {
            let c = BigUint::rand_bits(rng, kp.n.bits() - 1);
            if !c.is_zero() && c.gcd(&kp.n).to_u128() == Some(1) {
                break c;
            }
        };
        let rn = r.modpow(&kp.n, &kp.n2);
        gm.mulmod(&rn, &kp.n2)
    }

    pub fn decrypt(kp: &Keypair, c: &BigUint) -> BigUint {
        let x = c.modpow(&kp.lambda, &kp.n2);
        // L(x) = (x-1)/n
        let l = x.sub(&BigUint::one()).divrem(&kp.n).0;
        l.mulmod(&kp.mu, &kp.n)
    }

    /// Homomorphic addition: Enc(a)·Enc(b) mod n².
    pub fn add(kp: &Keypair, a: &BigUint, b: &BigUint) -> BigUint {
        a.mulmod(b, &kp.n2)
    }

    /// Homomorphic scalar multiplication: Enc(a)^k = Enc(k·a).
    pub fn scalar_mul(kp: &Keypair, a: &BigUint, k: &BigUint) -> BigUint {
        a.modpow(k, &kp.n2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Prng;

    fn kp(bits: usize, seed: u64) -> Keypair {
        let mut rng = Prng::seed_from_u64(seed);
        Paillier::keygen(&mut rng, bits)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let kp = kp(128, 1);
        let mut rng = Prng::seed_from_u64(2);
        for m in [0u128, 1, 42, 100_000, 1 << 40] {
            let c = Paillier::encrypt(&kp, &BigUint::from_u128(m), &mut rng);
            assert_eq!(Paillier::decrypt(&kp, &c).to_u128(), Some(m));
        }
    }

    #[test]
    fn homomorphic_addition_aggregates() {
        // the §3.3 flow: parties encrypt local num/den; leader multiplies.
        let kp = kp(128, 3);
        let mut rng = Prng::seed_from_u64(4);
        let nums = [71u128, 209, 320];
        let mut acc = Paillier::encrypt(&kp, &BigUint::from_u128(0), &mut rng);
        for &x in &nums {
            let c = Paillier::encrypt(&kp, &BigUint::from_u128(x), &mut rng);
            acc = Paillier::add(&kp, &acc, &c);
        }
        assert_eq!(Paillier::decrypt(&kp, &acc).to_u128(), Some(600));
    }

    #[test]
    fn scalar_multiplication() {
        let kp = kp(128, 5);
        let mut rng = Prng::seed_from_u64(6);
        let c = Paillier::encrypt(&kp, &BigUint::from_u128(7), &mut rng);
        let c3 = Paillier::scalar_mul(&kp, &c, &BigUint::from_u128(3));
        assert_eq!(Paillier::decrypt(&kp, &c3).to_u128(), Some(21));
    }

    #[test]
    fn ciphertexts_are_randomized() {
        let kp = kp(128, 7);
        let mut rng = Prng::seed_from_u64(8);
        let m = BigUint::from_u128(5);
        let c1 = Paillier::encrypt(&kp, &m, &mut rng);
        let c2 = Paillier::encrypt(&kp, &m, &mut rng);
        assert_ne!(c1, c2, "semantic security needs randomized ciphertexts");
        assert_eq!(Paillier::decrypt(&kp, &c1), Paillier::decrypt(&kp, &c2));
    }

    #[test]
    fn larger_modulus_still_correct() {
        let kp = kp(256, 9);
        let mut rng = Prng::seed_from_u64(10);
        let m = BigUint::from_u128(123456789);
        let c = Paillier::encrypt(&kp, &m, &mut rng);
        assert_eq!(Paillier::decrypt(&kp, &c).to_u128(), Some(123456789));
    }
}
