//! The approximate solution of §3.2: each party computes its local fraction
//! `f^k = num^k/den^k`, scales it to `F^k = ⌊d·f^k/N⌉`, and masks it with a
//! JRSZ zero-share.  The sum of the masked shares is (d times) the average
//! of local fractions — correct when shards are near-iid, biased otherwise
//! (the `ablation_approx_vs_exact` bench quantifies the bias vs skew).
//!
//! Like the exact path, the protocol is *vectorized across parameters*: the
//! masks for every parameter travel in one preprocessing round and the
//! masked reveals in one more, so a whole batch of parameters costs the
//! same 2 rounds (and 2·N messages) as a single one; only the byte count
//! scales with the parameter count.

use crate::field::Field;
use crate::net::{NetConfig, NetStats, SimNet};
use crate::protocols::session::MpcSession;
use crate::rng::Prng;
use crate::sharing::additive::jrsz;

/// One party's input for one parameter.
#[derive(Clone, Copy, Debug)]
pub struct LocalFraction {
    pub num: u64,
    pub den: u64,
}

/// Result of the approximate protocol for a batch of parameters.
pub struct ApproxOutcome {
    /// Additive shares: shares[k][party] (each party holds one element).
    pub shares: Vec<Vec<u128>>,
    /// Revealed d-scaled approximations (for verification / reporting).
    pub revealed: Vec<u128>,
    pub stats: NetStats,
}

/// Run §3.2 for `params.len()` parameters across `n` parties.
/// `params[k][i]` is party i's local (num, den) for parameter k.
pub fn approx_divide(
    f: &Field,
    params: &[Vec<LocalFraction>],
    d: u128,
    net_cfg: NetConfig,
    seed: u64,
) -> ApproxOutcome {
    let n = params.first().map(|p| p.len()).unwrap_or(0);
    assert!(n > 0);
    let mut net = SimNet::new(net_cfg);
    let mut rng = Prng::seed_from_u64(seed);
    let mut shares = Vec::with_capacity(params.len());
    let mut revealed = Vec::with_capacity(params.len());

    // Preprocessing: JRSZ dealt by the manager (third party) for every
    // parameter; each member receives all its masks in one message — one
    // round for the whole batch.
    for locals in params {
        let masks = jrsz(f, n, &mut rng);
        // Local: F^k = round(d * num / den / N), masked.
        let mut sh = Vec::with_capacity(n);
        for (i, loc) in locals.iter().enumerate() {
            let fk = local_scaled_fraction(loc, d, n);
            sh.push(f.add(fk % f.p, masks[i]));
        }
        shares.push(sh);
    }
    for i in 0..n {
        net.send(usize::MAX, i, params.len() as u64);
    }
    net.end_round();

    // Reveal to manager: every parameter's masked share in one message per
    // member — one more round.
    for i in 0..n {
        net.send(i, usize::MAX, params.len() as u64);
    }
    net.end_round();
    for sh in &shares {
        revealed.push(f.sum(sh));
    }

    ApproxOutcome { shares, revealed, stats: net.stats }
}

/// The local scaled fraction `F^k = ⌊d·num/(den·N)⌉` each party computes
/// before masking (0 when the party holds no mass).
pub fn local_scaled_fraction(loc: &LocalFraction, d: u128, n: usize) -> u128 {
    if loc.den == 0 {
        0
    } else {
        let numer = d * loc.num as u128 * 2 + (loc.den as u128 * n as u128);
        numer / (2 * loc.den as u128 * n as u128)
    }
}

/// §3.2 over any [`MpcSession`] backend: each party's local `F^k` enters as
/// its additive SQ2PQ contribution (which hides individual terms exactly
/// like the JRSZ mask does) and only the sum is revealed. Functionally
/// identical to [`approx_divide`] — the revealed values match element for
/// element — but deployable over real TCP parties through the same session
/// the exact path uses. The standalone [`approx_divide`] remains the
/// reference for the paper's 2-round JRSZ accounting.
pub fn approx_divide_session<S: MpcSession>(
    sess: &mut S,
    params: &[Vec<LocalFraction>],
    d: u128,
) -> (Vec<u128>, NetStats) {
    let n = sess.n();
    let before = sess.stats();
    for locals in params {
        assert_eq!(locals.len(), n);
    }
    // One vectorized SQ2PQ for all parameters: member i contributes its
    // local F^k for every k in a single exercise (k elements per frame).
    let contribs: Vec<Vec<u128>> = (0..n)
        .map(|i| params.iter().map(|locals| local_scaled_fraction(&locals[i], d, n)).collect())
        .collect();
    let ids = sess.sq2pq_vec(&contribs);
    sess.mark_outputs(&ids); // §3.2 reveals exactly the summed fractions
    let revealed = sess.reveal_vec(&ids);
    (revealed, sess.stats().delta_since(&before))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, EXAMPLE_P};

    /// Example 1 of the paper, digit for digit.
    #[test]
    fn paper_example_1() {
        let f = Field::new(EXAMPLE_P); // p = 2^20 + 7
        let d = 1000u128;
        let n = 3;
        let r = [752508u128, 776879, 567779]; // given JRSZ output
        assert_eq!(f.sum(&r), 0, "paper's r-values sum to 0 mod p");
        let nums = [71u64, 209, 320];
        let dens = [256u64, 786, 1127];

        // F^k = round(d * f^k / N) as the paper computes them
        let mut fk = Vec::new();
        for i in 0..n {
            let numer = d * nums[i] as u128 * 2 + dens[i] as u128 * n as u128;
            fk.push(numer / (2 * dens[i] as u128 * n as u128));
        }
        assert_eq!(fk, vec![92, 89, 95], "paper's (F¹,F²,F³)");

        let shares: Vec<u128> = (0..n).map(|i| f.add(fk[i], r[i])).collect();
        assert_eq!(shares, vec![752600, 776968, 567874], "paper's (F̂¹,F̂²,F̂³)");
        assert_eq!(f.sum(&shares), 276, "reconstruction = 0.276 · d");

        // true value for comparison: 0.277 scaled
        let true_w = (71.0 + 209.0 + 320.0) / (256.0 + 786.0 + 1127.0);
        assert!((f.sum(&shares) as f64 / d as f64 - true_w).abs() < 0.002);
    }

    #[test]
    fn approx_protocol_end_to_end() {
        let f = Field::new(EXAMPLE_P);
        let locals = vec![
            vec![
                LocalFraction { num: 71, den: 256 },
                LocalFraction { num: 209, den: 786 },
                LocalFraction { num: 320, den: 1127 },
            ],
        ];
        let out = approx_divide(&f, &locals, 1000, NetConfig::default(), 1);
        assert_eq!(out.revealed.len(), 1);
        // average of fractions ≈ 0.276; allow rounding
        let got = out.revealed[0] as f64 / 1000.0;
        assert!((got - 0.276).abs() < 0.003, "{got}");
        // accounting: 2 rounds, 2n messages
        assert_eq!(out.stats.messages, 6);
        assert_eq!(out.stats.rounds, 2);
    }

    #[test]
    fn approx_batches_parameters_into_two_rounds() {
        // Rounds (and messages) are flat in the parameter count — only the
        // payload grows: the cross-parameter vectorization of §3.2.
        let f = Field::new(EXAMPLE_P);
        let one = vec![vec![
            LocalFraction { num: 1, den: 4 },
            LocalFraction { num: 2, den: 4 },
            LocalFraction { num: 3, den: 4 },
        ]];
        let five: Vec<Vec<LocalFraction>> =
            (0..5).map(|_| one[0].clone()).collect();
        let a = approx_divide(&f, &one, 1000, NetConfig::default(), 7);
        let b = approx_divide(&f, &five, 1000, NetConfig::default(), 7);
        assert_eq!(a.stats.rounds, 2);
        assert_eq!(b.stats.rounds, 2);
        assert_eq!(a.stats.messages, b.stats.messages);
        assert!(b.stats.bytes > a.stats.bytes);
        assert!(b.revealed.iter().all(|&v| v == b.revealed[0]));
    }

    #[test]
    fn approx_bias_under_skew() {
        // identical num/den ratios → unbiased; skewed ratios → biased
        let f = Field::new(EXAMPLE_P);
        let iid = vec![vec![
            LocalFraction { num: 100, den: 400 },
            LocalFraction { num: 101, den: 399 },
            LocalFraction { num: 99, den: 401 },
        ]];
        let skew = vec![vec![
            LocalFraction { num: 0, den: 800 },
            LocalFraction { num: 300, den: 300 },
            LocalFraction { num: 0, den: 100 },
        ]];
        let d = 10_000u128;
        let got_iid =
            approx_divide(&f, &iid, d, NetConfig::default(), 2).revealed[0] as f64 / d as f64;
        let got_skew =
            approx_divide(&f, &skew, d, NetConfig::default(), 2).revealed[0] as f64 / d as f64;
        let truth = 300.0 / 1200.0;
        assert!((got_iid - truth).abs() < 0.001);
        assert!((got_skew - truth).abs() > 0.05, "skew should bias: {got_skew}");
    }

    #[test]
    fn session_variant_matches_standalone_protocol() {
        use crate::protocols::engine::{Engine, EngineConfig};
        let f = Field::new(EXAMPLE_P);
        let locals = vec![
            vec![
                LocalFraction { num: 71, den: 256 },
                LocalFraction { num: 209, den: 786 },
                LocalFraction { num: 320, den: 1127 },
            ],
            vec![
                LocalFraction { num: 0, den: 0 },
                LocalFraction { num: 50, den: 100 },
                LocalFraction { num: 10, den: 40 },
            ],
        ];
        let standalone = approx_divide(&f, &locals, 1000, NetConfig::default(), 4);
        // the session runs over the paper field; values are small ints so
        // reconstruction agrees across moduli
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(3).batched());
        let (revealed, stats) = approx_divide_session(&mut eng, &locals, 1000);
        assert_eq!(revealed, standalone.revealed);
        assert!(stats.messages > 0);
    }

    #[test]
    fn zero_denominator_contributes_zero() {
        let f = Field::new(EXAMPLE_P);
        let locals =
            vec![vec![LocalFraction { num: 0, den: 0 }, LocalFraction { num: 50, den: 100 }]];
        let out = approx_divide(&f, &locals, 1000, NetConfig::default(), 3);
        // average of (0, 0.5)/2 = 0.25
        assert!((out.revealed[0] as f64 / 1000.0 - 0.25).abs() < 0.002);
    }
}
