//! Sum-product network substrate (§2.3 of the paper).
//!
//! * [`graph`]     — node-based SPN DAG with validation (completeness,
//!   decomposability, selectivity) and exact evaluation; includes the
//!   paper's Figure-1 network as a constructor.
//! * [`structure`] — the layered dense structure format shared with the
//!   python compile path (`artifacts/<name>.structure.json`).
//! * [`eval`]      — batched layered evaluation in rust: bottom-up
//!   positivity, top-down activation, counts (the plaintext mirror of the
//!   AOT'd counts artifact) and log-domain evaluation.
//! * [`learn`]     — the closed-form ML weights of Eq. (2) from counts,
//!   plus dataset log-likelihood.
//! * [`plan`]      — compiled evaluation plans: the structure lowered once
//!   into vectorized secure steps, executed for whole query batches by the
//!   private-inference coordinator (DESIGN.md §Evaluation Plan).

pub mod eval;
pub mod graph;
pub mod learn;
pub mod plan;
pub mod structure;

pub use plan::{DagUnit, EvalPlan, Evaluator, PlanStep, Query, Src};
pub use structure::{Layer, LayerKind, ParamKind, Structure};
