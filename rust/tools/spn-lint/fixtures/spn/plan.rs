//! Path-routing decoy: this file ends in `spn/plan.rs`, the one place
//! PlanStep internals are legal — nothing here may fire L007.

fn compile_step(step: &PlanStep) -> usize {
    match step {
        PlanStep::Product { rounds, .. } => rounds.len(),
        PlanStep::Sum { width, .. } => *width,
    }
}
