//! # spn-mpc — Fast Private Parameter Learning and Inference for SPNs
//!
//! A production-grade reproduction of Althaus, Dousti, Kramer & Rassau,
//! *"Fast Private Parameter Learning and Inference for Sum-Product
//! Networks"* (2021): honest-but-curious multiparty learning of selective
//! SPN sum-weights over horizontally partitioned data using **secret
//! sharing only** (no homomorphic encryption or oblivious transfer on the
//! main path), plus private marginal inference and private k-means on the
//! same division primitive.
//!
//! Architecture (three layers; see DESIGN.md):
//! * **rust (this crate)** — the Layer-3 coordinator: fields, shares, the
//!   transport-agnostic session API ([`protocols::MpcSession`]) with its
//!   two backends (the exercise engine with exact message accounting, and
//!   real-TCP member threads), the paper's protocols, baselines, CLI.
//! * **JAX (python/compile)** — Layer-2 per-party local counting/eval
//!   graphs, AOT-compiled to HLO text artifacts.
//! * **Pallas (python/compile/kernels)** — Layer-1 masked-matmul layer
//!   kernels inside those graphs.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT and runs
//! them from rust; python never executes at request time.

// The whole crate is safe Rust; keep it that way.
#![deny(unsafe_code)]
// CI runs clippy with `-D warnings` (blocking). The classes below are
// allowed crate-wide, each for a standing reason rather than ad-hoc
// site-by-site waivers; anything not listed here fails the build.
#![allow(
    // MPC protocol entry points take (session, shares, bounds, config, ...)
    // — splitting them into builder structs would hide the protocol shape.
    clippy::too_many_arguments,
    // Share/stat tuples like Vec<(u64, Vec<(u64, u128)>)> mirror the wire
    // and paper notation; aliasing them away hurts cross-referencing.
    clippy::type_complexity,
    // Indexed loops are deliberate wherever index = party id / element slot
    // (the math is index-addressed; iterators obscure the stride layout).
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    // Small config/report types where a bare `new` or `len` is the idiom.
    clippy::new_without_default,
    clippy::len_without_is_empty,
    clippy::large_enum_variant,
    clippy::result_large_err,
    // Formatting / style families where the codebase predates the lint's
    // preferred spelling and churning every site would bury real diffs.
    clippy::uninlined_format_args,
    clippy::many_single_char_names,
    clippy::module_inception,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain,
    clippy::identity_op,
    clippy::assign_op_pattern,
    clippy::ptr_arg,
    clippy::manual_div_ceil
)]

pub mod bench;
pub mod coordinator;
pub mod datasets;
pub mod field;
pub mod gc;
pub mod he;
pub mod json;
pub mod kmeans;
pub mod metrics;
pub mod net;
pub mod parallel;
pub mod protocols;
pub mod rng;
pub mod runtime;
pub mod sharing;
pub mod spn;
