//! Real TCP transport (std::net + threads) for smoke-scale distributed runs.
//!
//! The simulation in [`super::SimNet`] reproduces the paper's accounting;
//! this module proves the same protocol messages actually move over
//! sockets.  Each frame is: `exercise_id: u64 | from: u32 | n_elems: u32 |
//! elems: n × 16-byte little-endian field elements` (the accountant's
//! 24-byte-header + 10-byte-element model is the paper-calibrated wire
//! estimate; see DESIGN.md §4).
//!
//! Framing is generic over `Read`/`Write` so sessions can run it over
//! `BufReader`/`BufWriter` (the [`super::tcp_session`] data plane does —
//! one flush per frame, `TCP_NODELAY` on every socket), and
//! [`read_frame_into`] reuses the caller's element buffer so a lockstep
//! event loop performs no per-frame heap allocation (DESIGN.md §Data
//! plane). [`write_frame`] writes header then elements directly: callers
//! on a raw socket should wrap it in a `BufWriter` to avoid per-element
//! syscalls.
//!
//! The vendored crate set has no async runtime, so this uses blocking
//! sockets and `std::thread` — entirely adequate for the N ≤ 13 member
//! sessions. [`super::tcp_session::TcpSession`] drives the full
//! transport-agnostic session vocabulary over these frames.
//!
//! wire-layout: v3 (geometry and strides defined in [`super::wire`];
//! change them there and both sides of the socket move together — v3
//! added the coalesced `OP_FLIGHT` container, whose runs reuse the
//! standalone op-body layouts unchanged).

use std::io::{BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::{bail, Result};

pub use super::wire::{wire_bytes_for, MAX_FRAME_ELEMS};

/// A framed protocol message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub exercise_id: u64,
    pub from: u32,
    pub elems: Vec<u128>,
}

impl Frame {
    /// An empty frame to [`read_frame_into`]; its element buffer grows on
    /// first use and is reused thereafter.
    pub fn empty() -> Frame {
        Frame { exercise_id: 0, from: 0, elems: Vec::new() }
    }

    /// Bytes on the wire for this frame.
    pub fn wire_bytes(&self) -> usize {
        wire_bytes_for(self.elems.len())
    }
}

/// Write one frame from its parts — the allocation-free path: sessions
/// pass their reusable scratch slice directly instead of moving it into a
/// [`Frame`].
pub fn write_frame_parts<W: Write>(
    s: &mut W,
    exercise_id: u64,
    from: u32,
    elems: &[u128],
) -> Result<()> {
    if elems.len() > MAX_FRAME_ELEMS {
        bail!("refusing to write a {}-element frame (max {MAX_FRAME_ELEMS})", elems.len());
    }
    let mut hdr = [0u8; 16];
    hdr[0..8].copy_from_slice(&exercise_id.to_le_bytes());
    hdr[8..12].copy_from_slice(&from.to_le_bytes());
    hdr[12..16].copy_from_slice(&(elems.len() as u32).to_le_bytes());
    s.write_all(&hdr)?;
    for e in elems {
        s.write_all(&e.to_le_bytes())?;
    }
    Ok(())
}

pub fn write_frame<W: Write>(s: &mut W, f: &Frame) -> Result<()> {
    write_frame_parts(s, f.exercise_id, f.from, &f.elems)
}

/// Read one frame into `fr`, reusing its element buffer (no allocation
/// once the buffer has grown to the session's steady-state frame width).
/// The body is read through a stack chunk buffer, one `read_exact` per
/// 256 elements — not per element — so the call count (and, on raw
/// streams, the syscall count) stays low for wide vectorized frames.
pub fn read_frame_into<R: Read>(s: &mut R, fr: &mut Frame) -> Result<()> {
    let mut hdr = [0u8; 16];
    s.read_exact(&mut hdr)?;
    fr.exercise_id = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
    fr.from = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
    let n = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
    if n > MAX_FRAME_ELEMS {
        bail!("frame header claims {n} elements (max {MAX_FRAME_ELEMS}): corrupt or desynced stream");
    }
    fr.elems.clear();
    fr.elems.reserve(n);
    let mut buf = [0u8; 256 * 16];
    let mut remaining = n;
    while remaining > 0 {
        let take = remaining.min(256);
        let bytes = &mut buf[..take * 16];
        s.read_exact(bytes)?;
        for c in bytes.chunks_exact(16) {
            fr.elems.push(u128::from_le_bytes(c.try_into().unwrap()));
        }
        remaining -= take;
    }
    Ok(())
}

pub fn read_frame<R: Read>(s: &mut R) -> Result<Frame> {
    let mut fr = Frame::empty();
    read_frame_into(s, &mut fr)?;
    Ok(fr)
}

/// Install (or clear, with `None`) matching read and write deadlines on a
/// stream — the transport-hardening primitive behind
/// [`super::tcp_session::TcpSessionConfig::io_deadline_ms`]: blocking I/O
/// against a hung peer becomes a timely `WouldBlock`/`TimedOut` error the
/// session layer can treat as member death.
pub fn set_io_deadlines(s: &TcpStream, deadline: Option<std::time::Duration>) -> Result<()> {
    s.set_read_timeout(deadline)?;
    s.set_write_timeout(deadline)?;
    Ok(())
}

/// "Reveal to manager" over real sockets: accept `n` member connections,
/// sum the first element of each frame mod `p`, reply with the sum.
pub fn reveal_server_on(listener: TcpListener, n: usize, p: u128) -> Result<u128> {
    let mut acc = 0u128;
    let mut conns = Vec::new();
    for _ in 0..n {
        let (mut s, _) = listener.accept()?;
        s.set_nodelay(true)?;
        let f = read_frame(&mut s)?;
        acc = (acc + f.elems[0] % p) % p;
        conns.push(s);
    }
    for s in conns.iter_mut() {
        let mut w = BufWriter::new(s);
        write_frame(&mut w, &Frame { exercise_id: 0, from: u32::MAX, elems: vec![acc] })?;
        w.flush()?;
    }
    Ok(acc)
}

/// Member half of the smoke test: connect, send one share, read the sum.
pub fn reveal_client(addr: &str, from: u32, share: u128) -> Result<u128> {
    let s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    let mut w = BufWriter::new(s.try_clone()?);
    write_frame(&mut w, &Frame { exercise_id: 0, from, elems: vec![share] })?;
    w.flush()?;
    let mut s = s;
    Ok(read_frame(&mut s)?.elems[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::thread;

    #[test]
    fn frame_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let want = Frame { exercise_id: 7, from: 3, elems: vec![1, u128::MAX / 3, 42] };
        let w2 = want.clone();
        let srv = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, &w2).unwrap();
        assert_eq!(srv.join().unwrap(), want);
    }

    #[test]
    fn empty_frame_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let want = Frame { exercise_id: 1, from: 0, elems: vec![] };
        let w2 = want.clone();
        let srv = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, &w2).unwrap();
        assert_eq!(srv.join().unwrap(), want);
    }

    #[test]
    fn read_frame_into_reuses_the_body_buffer() {
        // Serialize two frames back-to-back, read both into ONE Frame: the
        // second read must reuse the capacity the first one grew.
        let a = Frame { exercise_id: 1, from: 2, elems: (0..64u128).collect() };
        let b = Frame { exercise_id: 9, from: 5, elems: vec![7, 8] };
        let mut bytes = Vec::new();
        write_frame(&mut bytes, &a).unwrap();
        write_frame(&mut bytes, &b).unwrap();
        assert_eq!(bytes.len(), a.wire_bytes() + b.wire_bytes());

        let mut cur = Cursor::new(bytes);
        let mut fr = Frame::empty();
        read_frame_into(&mut cur, &mut fr).unwrap();
        assert_eq!(fr, a);
        let cap = fr.elems.capacity();
        read_frame_into(&mut cur, &mut fr).unwrap();
        assert_eq!(fr, b);
        assert_eq!(fr.elems.capacity(), cap, "shrinking frames must not reallocate");
    }

    #[test]
    fn wire_bytes_matches_parts_writer() {
        let f = Frame { exercise_id: 3, from: 1, elems: vec![10, 20, 30] };
        let mut bytes = Vec::new();
        write_frame_parts(&mut bytes, f.exercise_id, f.from, &f.elems).unwrap();
        assert_eq!(bytes.len(), f.wire_bytes());
        assert_eq!(wire_bytes_for(3), 16 + 48);
    }

    #[test]
    fn additive_reveal_over_tcp() {
        use crate::field::Field;
        use crate::rng::Prng;
        use crate::sharing::additive::additive_share;

        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(9);
        let secret = 123456789u128;
        let shares = additive_share(&f, secret, 4, &mut rng);

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let srv = thread::spawn(move || reveal_server_on(listener, 4, crate::field::PAPER_P));
        let mut handles = Vec::new();
        for (i, sh) in shares.into_iter().enumerate() {
            let a = addr.clone();
            handles.push(thread::spawn(move || reveal_client(&a, i as u32, sh)));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap(), secret);
        }
        assert_eq!(srv.join().unwrap().unwrap(), secret);
    }
}
