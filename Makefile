# spn-mpc build driver.
#
#   make artifacts  — lower the JAX/Pallas graphs to HLO-text + structure
#                     JSON artifacts under rust/artifacts/ (skips cleanly
#                     when the python/JAX toolchain is absent: every
#                     artifact-dependent rust test/bench then skips itself,
#                     so `make test` stays green on a rust-only machine)
#   make build      — cargo build --release (whole workspace)
#   make test       — artifacts (best effort) + cargo test -q
#   make bench      — artifacts (best effort) + all plain-main bench targets
#   make bench-json — instrumented benches → machine-readable BENCH_*.json
#                     rows ({bench, metric, value}); artifact-dependent
#                     targets write an empty array when artifacts are absent.
#                     BENCH_*.json are the repo's perf trajectory: meant to
#                     be committed when refreshed (so neither gitignored
#                     nor removed by `make clean`)
#   make doc        — cargo doc --no-deps (zero warnings is the contract)
#   make lint       — spn-lint protocol-contract source pass (L001–L009)
#                     over rust/src, then its --self-check against the
#                     committed fixtures. Blocking in CI; zero findings is
#                     the contract (see DESIGN.md §Static analysis)
#   make clean      — remove build output and generated artifacts

PY            ?= python3
ARTIFACTS_DIR := rust/artifacts
DATASETS      ?= toy,nltcs,jester,baudio,bnetflix

.PHONY: all build test bench bench-json doc lint artifacts fmt clean

all: build

build:
	cargo build --release

# Artifact generation degrades gracefully: if JAX is not importable we print
# why and succeed, matching the skip-if-missing contract of
# rust/tests/integration.rs and the bench guards.
artifacts:
	@if $(PY) -c "import jax" >/dev/null 2>&1; then \
		mkdir -p $(ARTIFACTS_DIR) && \
		cd python && $(PY) -m compile.aot --out $(abspath $(ARTIFACTS_DIR)) --datasets $(DATASETS); \
	else \
		echo "make artifacts: no python/JAX toolchain — skipping (artifact-dependent"; \
		echo "                tests and benches will skip themselves; see DESIGN.md)"; \
	fi

test: artifacts
	cargo test -q

bench: artifacts
	cargo bench

bench-json: artifacts
	cargo bench --bench microbench_field -- --json BENCH_microbench_field.json
	cargo bench --bench table2_members13 -- --json BENCH_table2_members13.json
	cargo bench --bench table3_members5 -- --json BENCH_table3_members5.json
	cargo bench --bench kmeans_bench -- --json BENCH_kmeans.json
	cargo bench --bench infer_batch -- --json BENCH_infer_batch.json
	cargo bench --bench mpc_throughput -- --json BENCH_mpc_throughput.json
	cargo bench --bench serve_throughput -- --json BENCH_serve_throughput.json
	@echo "NOTE: if BENCH_mpc_throughput.json or BENCH_serve_throughput.json"
	@echo "      replaced a projected baseline (provenance_projected_not_measured"
	@echo "      row gone), refresh the matching EXPERIMENTS.md §Perf table."

doc:
	cargo doc --no-deps

lint:
	cargo run --release -p spn-lint -- --root .
	cargo run --release -p spn-lint -- --self-check --root .

fmt:
	cargo fmt --all --check

clean:
	cargo clean
	rm -rf $(ARTIFACTS_DIR)
