#![allow(dead_code)]
//! Shared helpers for the bench targets (plain-main harness; the vendored
//! crate set has no criterion).
//!
//! Every loader returns `Option` and every target starts with a
//! [`guard`]-style check: on a fresh checkout without `make artifacts` the
//! benches print a skip message and exit 0 instead of panicking — the same
//! contract as `rust/tests/integration.rs` (the skip path itself is
//! unit-tested in `spn_mpc::bench`).

use spn_mpc::coordinator::train::{train, TrainConfig, TrainReport};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::spn::eval;
use spn_mpc::spn::structure::Structure;

pub const DEBD: [&str; 4] = ["nltcs", "jester", "baudio", "bnetflix"];

/// Load a generated structure; `None` (not a panic) when `make artifacts`
/// has not run.
pub fn load(name: &str) -> Option<Structure> {
    spn_mpc::bench::try_load_structure(name)
}

/// Skip-or-proceed guard for a bench target needing `names`' artifacts.
/// Prints the standard skip message and returns false when they're absent.
pub fn guard(target: &str, names: &[&str]) -> bool {
    if spn_mpc::bench::artifacts_available(names) {
        true
    } else {
        println!("{}", spn_mpc::bench::skip_message(target));
        false
    }
}

/// Full private-training accounting run for one dataset (native counts —
/// the runtime path is exercised by the examples/integration tests; benches
/// measure the protocol). `None` when the structure artifact is absent.
pub fn train_run(name: &str, members: usize, schedule: Schedule) -> Option<(TrainReport, f64)> {
    let st = load(name)?;
    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, st.rows, 42);
    let shards = datasets::partition(&data, members);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
    let mut cfg = EngineConfig::new(members);
    cfg.schedule = schedule;
    let mut eng = Engine::new(Field::paper(), cfg);
    let t0 = std::time::Instant::now();
    let (_, report) = train(&mut eng, &st, &counts, st.rows as u64, &TrainConfig::default());
    Some((report, t0.elapsed().as_secs_f64()))
}
