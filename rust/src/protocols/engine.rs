//! The Manager/Member exercise engine (paper §5.2 + Appendix A).
//!
//! The Manager schedules *exercises*; every Member executes its local part
//! against its private share store and exchanges sub-shares with the other
//! members; the Manager waits for all "finished" messages before scheduling
//! the next exercise.  This module implements that machine with per-member
//! state kept strictly separate (each [`Member`] owns its store and RNG —
//! protocol code only moves data between members through [`SimNet::send`]
//! accounting), which both documents the privacy boundary and makes the
//! message/byte/round counts of Tables 2–3 exact.
//!
//! Two scheduling modes ([`Schedule`]):
//! * `PerOp`   — one exercise per scalar operation, like the paper's
//!   implementation (and its message counts);
//! * `Batched` — vectorized exercises that pack k elements per message;
//!   the §Perf optimization (same rounds, ~k× fewer messages).

use std::collections::HashMap;

use crate::field::Field;
use crate::net::{NetConfig, SimNet};
use crate::rng::Prng;
use crate::sharing::shamir::ShamirCtx;

/// Handle to a secret-shared value distributed across the members.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataId(pub u64);

/// How the manager schedules vector operations — the message-accounting
/// contract behind Tables 2–3 (see DESIGN.md §2).
///
/// For a k-wide vector operation whose body needs one full-mesh sub-share
/// exchange (e.g. [`Engine::mul_vec`]) with `n` members:
///
/// * **`PerOp`** schedules k exercises. Each costs one schedule broadcast
///   (n messages), `n·(n−1)` single-element body messages in their own
///   round, and n "finished" messages — so k·(n² + n) messages and
///   3·k rounds. This is how the paper's implementation runs, and the
///   mode its Tables 2–3 are reproduced in.
/// * **`Batched`** schedules one exercise for the whole vector; each link
///   carries all k elements in one message (`n·(n−1)` body messages
///   total, each k elements). Same round *structure*, ~k× fewer messages
///   and k× fewer rounds — the §Perf optimization, quantified by
///   `batched_mul_fewer_messages_same_result`.
///
/// Virtual time charges `latency + max_bytes/bandwidth` per round either
/// way, so `Batched` also wins wall-clock on latency-dominated links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// One exercise (and one message per link) per scalar op — paper mode.
    PerOp,
    /// One exercise per vector op; messages carry k elements.
    Batched,
}

/// Configuration for [`Engine::new`]: party count, threshold, schedule,
/// masking width, determinism seed and network cost model.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Number of computing members (the Manager is not a member).
    pub n: usize,
    /// Shamir degree; defaults to ⌊(n-1)/2⌋ (see DESIGN.md §4).
    pub threshold: Option<usize>,
    /// Vector-operation scheduling mode; see [`Schedule`].
    pub schedule: Schedule,
    /// Security parameter ρ for division-by-public (§3.4); r ∈ [0, 2^ρ).
    pub rho_bits: u32,
    /// Seed for the per-member deterministic RNGs (reproducible runs).
    pub seed: u64,
    /// Latency/bandwidth/framing model for the accounted network.
    pub net: NetConfig,
}

impl EngineConfig {
    /// Paper-mode defaults for `n` members: `PerOp` schedule, ρ = 64,
    /// honest-majority threshold, 10 ms / 1 Gbit links.
    pub fn new(n: usize) -> Self {
        EngineConfig {
            n,
            threshold: None,
            schedule: Schedule::PerOp,
            rho_bits: 64,
            seed: 0xC0FFEE,
            net: NetConfig::default(),
        }
    }

    /// Switch to the vectorized [`Schedule::Batched`] mode.
    pub fn batched(mut self) -> Self {
        self.schedule = Schedule::Batched;
        self
    }
}

/// One computing party. `store` maps DataId → this member's share.
pub struct Member {
    /// Member id in `1..=n` (also the Shamir evaluation point).
    pub id: usize,
    store: HashMap<u64, u128>,
    rng: Prng,
}

impl Member {
    /// Diagnostics/tests only: expose this member's raw share (used by the
    /// privacy smoke tests to check shares don't coincide with secrets).
    /// Compiled only for the crate's own tests or under the opt-in
    /// `test-introspection` feature — a raw-share accessor is
    /// privacy-sensitive and not part of the advertised public API.
    #[cfg(any(test, feature = "test-introspection"))]
    #[doc(hidden)]
    pub fn share_for_test(&self, a: DataId) -> u128 {
        self.get(a)
    }

    fn get(&self, a: DataId) -> u128 {
        *self.store.get(&a.0).unwrap_or_else(|| panic!("member {} missing {:?}", self.id, a))
    }
    fn put(&mut self, a: DataId, v: u128) {
        self.store.insert(a.0, v);
    }
}

/// The Manager plus all Members plus the accounted network.
pub struct Engine {
    /// The prime field all shares live in.
    pub field: Field,
    /// Shamir context (party set + threshold + Lagrange coefficients).
    pub shamir: ShamirCtx,
    /// The configuration this engine was built with. `schedule` may be
    /// switched between runs to compare accounting modes.
    pub cfg: EngineConfig,
    /// The computing parties, each with a private store and RNG.
    pub members: Vec<Member>,
    /// The accounted network; read `net.stats` for cost reports.
    pub net: SimNet,
    next_id: u64,
    next_tag: u64,
    #[allow(dead_code)]
    manager_rng: Prng,
}

impl Engine {
    /// Build an engine: constructs the Shamir context (honest-majority
    /// threshold unless overridden) and one [`Member`] per party.
    pub fn new(field: Field, cfg: EngineConfig) -> Self {
        let shamir = match cfg.threshold {
            Some(t) => ShamirCtx::with_threshold(field, cfg.n, t),
            None => ShamirCtx::new(field, cfg.n),
        };
        let members = (1..=cfg.n)
            .map(|id| Member {
                id,
                store: HashMap::new(),
                rng: Prng::seed_from_u64(cfg.seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            })
            .collect();
        Engine {
            field,
            shamir,
            cfg,
            members,
            net: SimNet::new(cfg.net),
            next_id: 0,
            next_tag: 0,
            manager_rng: Prng::seed_from_u64(cfg.seed ^ 0xABCD),
        }
    }

    /// Allocate `count` fresh divpub tags (monotone, never reissued); see
    /// [`Engine::divpub_vec_tagged`].
    pub fn reserve_tags(&mut self, count: u64) -> u64 {
        let base = self.next_tag;
        self.next_tag += count;
        base
    }

    /// Number of computing members.
    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// Allocate a fresh [`DataId`] handle.
    pub fn alloc(&mut self) -> DataId {
        self.next_id += 1;
        DataId(self.next_id)
    }

    fn alloc_vec(&mut self, k: usize) -> Vec<DataId> {
        (0..k).map(|_| self.alloc()).collect()
    }

    /// Number of exercise "slots" a vector op of width k consumes under the
    /// current schedule (PerOp: k, Batched: 1); used for overhead accounting.
    fn slots(&self, k: usize) -> u64 {
        match self.cfg.schedule {
            Schedule::PerOp => k as u64,
            Schedule::Batched => 1,
        }
    }

    /// Elements per message for a k-wide op (PerOp sends k single-element
    /// messages per link; Batched packs them).
    fn begin_exercise(&mut self, k: usize) {
        for _ in 0..self.slots(k) {
            self.net.exercise_overhead(self.cfg.n);
        }
    }

    fn finish_exercise(&mut self, k: usize) {
        for _ in 0..self.slots(k) {
            self.net.exercise_finish(self.cfg.n);
        }
    }

    /// Account a full-mesh sub-share exchange of k elements per ordered pair.
    fn mesh_exchange(&mut self, k: usize) {
        let n = self.cfg.n;
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for i in 0..n {
                        for j in 0..n {
                            if i != j {
                                self.net.send(i, j, 1);
                            }
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            self.net.send(i, j, k as u64);
                        }
                    }
                }
                self.net.end_round();
            }
        }
    }

    /// Account a star exchange (one sender or one receiver) of k elements.
    fn star_exchange(&mut self, center_sends: bool, k: usize) {
        let n = self.cfg.n;
        let links = n - 1;
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for l in 0..links {
                        if center_sends {
                            self.net.send(usize::MAX, l, 1);
                        } else {
                            self.net.send(l, usize::MAX, 1);
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for l in 0..links {
                    if center_sends {
                        self.net.send(usize::MAX, l, k as u64);
                    } else {
                        self.net.send(l, usize::MAX, k as u64);
                    }
                }
                self.net.end_round();
            }
        }
    }

    // ---------------------------------------------------------------------
    // Exercises
    // ---------------------------------------------------------------------

    /// `input`: party `owner` (1-based) Shamir-deals its private values.
    pub fn input(&mut self, owner: usize, values: &[u128]) -> Vec<DataId> {
        let ids = self.alloc_vec(values.len());
        self.begin_exercise(values.len());
        for (v, &id) in values.iter().zip(&ids) {
            let o = owner - 1;
            let shares = {
                let m = &mut self.members[o];
                let v = *v % self.field.p;
                self.shamir.share(v, &mut m.rng)
            };
            for (j, &s) in shares.iter().enumerate() {
                self.members[j].put(id, s);
            }
        }
        self.star_exchange(true, values.len()); // owner → others
        self.finish_exercise(values.len());
        ids
    }

    /// A public constant as a (constant-polynomial) shared value. Local.
    pub fn constant(&mut self, c: u128) -> DataId {
        let id = self.alloc();
        let c = c % self.field.p;
        for m in &mut self.members {
            m.put(id, c);
        }
        id
    }

    /// Linear exercise: out = c0 + Σ ck·[ak]. Local math, but still a
    /// scheduled exercise (Appendix A counts them).
    pub fn lin(&mut self, c0: i128, terms: &[(i128, DataId)]) -> DataId {
        self.lin_vec(&[(c0, terms.to_vec())])[0]
    }

    /// Vectorized [`Engine::lin`]: each entry is `(c0, [(ck, ak), ...])`.
    pub fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId> {
        let ids = self.alloc_vec(ops.len());
        self.begin_exercise(ops.len());
        let f = self.field;
        for m in &mut self.members {
            for ((c0, terms), &id) in ops.iter().zip(&ids) {
                let mut acc = f.from_i128(*c0);
                for &(c, a) in terms {
                    acc = f.add(acc, f.mul(f.from_i128(c), m.get(a)));
                }
                m.put(id, acc);
            }
        }
        self.finish_exercise(ops.len());
        ids
    }

    /// `[a] + [b]` (local linear exercise).
    pub fn add(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (1, b)])
    }

    /// `[a] - [b]` (local linear exercise).
    pub fn sub(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (-1, b)])
    }

    /// Secure multiplication (BGW): local product (degree 2t) + resharing
    /// degree reduction. One mesh round; n(n-1) messages in PerOp mode.
    pub fn mul(&mut self, a: DataId, b: DataId) -> DataId {
        self.mul_vec(&[(a, b)])[0]
    }

    /// Vectorized [`Engine::mul`]: one mesh exchange for all pairs under
    /// the `Batched` schedule.
    pub fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId> {
        let k = pairs.len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let n = self.cfg.n;
        let f = self.field;
        // dealt[i][j][e]: sub-share of element e from member i to member j
        let mut dealt: Vec<Vec<Vec<u128>>> = vec![vec![Vec::with_capacity(k); n]; n];
        for i in 0..n {
            for &(a, b) in pairs {
                let (z, shares) = {
                    let m = &mut self.members[i];
                    let z = f.mul(m.get(a), m.get(b));
                    let sh = self.shamir.share(z, &mut m.rng);
                    (z, sh)
                };
                let _ = z;
                for (j, &s) in shares.iter().enumerate() {
                    dealt[i][j].push(s);
                }
            }
        }
        self.mesh_exchange(k);
        let lambda = self.shamir.lambda().to_vec();
        for j in 0..n {
            for (e, &id) in ids.iter().enumerate() {
                let mut acc = 0u128;
                for i in 0..n {
                    acc = f.add(acc, f.mul(lambda[i], dealt[i][j][e]));
                }
                self.members[j].put(id, acc);
            }
        }
        self.finish_exercise(k);
        ids
    }

    /// Reveal to the manager (star inward). Returns the reconstruction.
    pub fn reveal(&mut self, a: DataId) -> u128 {
        self.reveal_vec(&[a])[0]
    }

    /// Vectorized [`Engine::reveal`].
    pub fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128> {
        self.begin_exercise(ids.len());
        self.star_exchange(false, ids.len());
        let out = ids
            .iter()
            .map(|&id| {
                let shares: Vec<u128> = self.members.iter().map(|m| m.get(id)).collect();
                self.shamir.reconstruct(&shares)
            })
            .collect();
        self.finish_exercise(ids.len());
        out
    }

    /// Division by a public `d` (§3.4): see [`super::divpub`] for the pure
    /// math; this wires Alice (member 1) and Bob (member 2) with accounting.
    /// Requires the shared value `u` to be an integer in `[0, 2^62]`
    /// (guaranteed by the Newton bounds; debug-asserted in tests via reveal).
    pub fn divpub(&mut self, u: DataId, d: u128) -> DataId {
        self.divpub_vec(&[u], d)[0]
    }

    /// Vectorized [`Engine::divpub`]: Alice/Bob deal for all k values in
    /// one exercise (one message per link per phase under `Batched`).
    pub fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId> {
        self.divpub_impl(us, d, None)
    }

    /// Tagged [`Engine::divpub_vec`]: element `e`'s §3.4 mask is derived as
    /// `PRF(seed, tags[e])` ([`super::divpub::tagged_r`]) instead of the
    /// next draw of Alice's RNG stream, so the ±1 rounding of each element
    /// is a function of its tag alone — invariant under any batching or
    /// evaluation order. Same wire shape and accounting as the untagged
    /// variant. Tags must be fresh ([`Engine::reserve_tags`]).
    pub fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId> {
        assert_eq!(us.len(), tags.len());
        self.divpub_impl(us, d, Some(tags))
    }

    fn divpub_impl(&mut self, us: &[DataId], d: u128, tags: Option<&[u64]>) -> Vec<DataId> {
        assert!(d > 0);
        let k = us.len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let n = self.cfg.n;
        let f = self.field;
        let alice = 0usize;
        let bob = if n > 1 { 1 } else { 0 };
        let rho = self.cfg.rho_bits;
        let seed = self.cfg.seed;

        // Phase 1: Alice deals [r], [q = r mod d].
        let mut r_sh: Vec<Vec<u128>> = Vec::with_capacity(k); // [e][party]
        let mut q_sh: Vec<Vec<u128>> = Vec::with_capacity(k);
        for e in 0..k {
            let (rs, qs) = {
                let m = &mut self.members[alice];
                let r = match tags {
                    Some(t) => super::divpub::tagged_r(seed, t[e], rho),
                    None => super::divpub::sample_r(&mut m.rng, rho),
                };
                let q = r % d;
                let rs = self.shamir.share(r, &mut m.rng);
                let qs = self.shamir.share(q, &mut m.rng);
                (rs, qs)
            };
            r_sh.push(rs);
            q_sh.push(qs);
        }
        // Alice → everyone else: 2 elements per value per link.
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for j in 0..n {
                        if j != alice {
                            self.net.send(alice, j, 2);
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for j in 0..n {
                    if j != alice {
                        self.net.send(alice, j, 2 * k as u64);
                    }
                }
                self.net.end_round();
            }
        }

        // Phase 2: everyone computes [z'] = [u] + [r] and sends to Bob.
        let mut z_shares: Vec<Vec<u128>> = vec![vec![0; n]; k]; // [e][party]
        for j in 0..n {
            for (e, &u_id) in us.iter().enumerate() {
                let zu = f.add(self.members[j].get(u_id), r_sh[e][j]);
                z_shares[e][j] = zu;
            }
        }
        self.star_exchange(false, k); // members → Bob

        // Phase 3: Bob reconstructs z' = u + r (an integer < 2^(ρ+1) « p),
        // computes w = z' mod d, and deals [w].
        let mut w_sh: Vec<Vec<u128>> = Vec::with_capacity(k);
        for e in 0..k {
            let z = self.shamir.reconstruct(&z_shares[e]);
            let (w, ws) = {
                let m = &mut self.members[bob];
                let w = z % d;
                let ws = self.shamir.share(w, &mut m.rng);
                (w, ws)
            };
            let _ = w;
            w_sh.push(ws);
        }
        self.star_exchange(true, k); // Bob → others

        // Phase 4 (local): [v] = ([u] + [q] - [w]) · d^{-1} mod p.
        // NOTE the paper prints [u] - [q] + [w]; that has residue 2(u mod d)
        // mod d — the sign must be flipped for z ≡ 0 (mod d). See DESIGN.md
        // §4 "erratum" and divpub::tests::paper_identity.
        let dinv = f.inv(d % f.p);
        for j in 0..n {
            for (e, &u_id) in us.iter().enumerate() {
                let v = f.mul(
                    f.sub(f.add(self.members[j].get(u_id), q_sh[e][j]), w_sh[e][j]),
                    dinv,
                );
                self.members[j].put(id_at(&ids, e), v);
            }
        }
        self.finish_exercise(k);
        ids
    }

    /// Convert per-party additive shares (each member holds one) into
    /// polynomial shares via SQ2PQ: every member deals, then sums. Used to
    /// enter the exact pipeline from locally-computed counts (Eq. 3).
    pub fn sq2pq_inputs(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId> {
        // local_values[i][e]: member i's additive contribution to element e
        let n = self.cfg.n;
        assert_eq!(local_values.len(), n);
        let k = local_values[0].len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let f = self.field;
        let mut dealt: Vec<Vec<Vec<u128>>> = vec![vec![Vec::with_capacity(k); n]; n];
        for i in 0..n {
            for e in 0..k {
                let shares = {
                    let m = &mut self.members[i];
                    self.shamir.share(local_values[i][e] % f.p, &mut m.rng)
                };
                for (j, &s) in shares.iter().enumerate() {
                    dealt[i][j].push(s);
                }
            }
        }
        self.mesh_exchange(k);
        for j in 0..n {
            for (e, &id) in ids.iter().enumerate() {
                let mut acc = 0u128;
                for i in 0..n {
                    acc = f.add(acc, dealt[i][j][e]);
                }
                self.members[j].put(id, acc);
            }
        }
        self.finish_exercise(k);
        ids
    }

    /// Test/diagnostic-only: reconstruct without counting traffic.
    pub fn peek(&self, a: DataId) -> u128 {
        let shares: Vec<u128> = self.members.iter().map(|m| m.get(a)).collect();
        self.shamir.reconstruct(&shares)
    }

    /// Test/diagnostic-only: signed small-integer view of a shared value.
    pub fn peek_int(&self, a: DataId) -> i128 {
        self.field.to_i128(self.peek(a))
    }
}

fn id_at(ids: &[DataId], e: usize) -> DataId {
    ids[e]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;

    fn engine(n: usize) -> Engine {
        Engine::new(Field::paper(), EngineConfig::new(n))
    }

    #[test]
    fn input_and_reveal_roundtrip() {
        let mut e = engine(5);
        let ids = e.input(2, &[42, 9999]);
        assert_eq!(e.reveal(ids[0]), 42);
        assert_eq!(e.reveal(ids[1]), 9999);
    }

    #[test]
    fn linear_ops() {
        let mut e = engine(5);
        let a = e.input(1, &[10])[0];
        let b = e.input(2, &[4])[0];
        let s = e.add(a, b);
        let d = e.sub(a, b);
        let l = e.lin(100, &[(3, a), (-2, b)]);
        assert_eq!(e.peek(s), 14);
        assert_eq!(e.peek(d), 6);
        assert_eq!(e.peek(l), 100 + 30 - 8);
    }

    #[test]
    fn secure_mul_correct() {
        for n in [3, 5, 13] {
            let mut e = engine(n);
            let a = e.input(1, &[123456])[0];
            let b = e.input(2, &[789])[0];
            let c = e.mul(a, b);
            assert_eq!(e.peek(c), 123456 * 789, "n={n}");
        }
    }

    #[test]
    fn mul_chain_stays_degree_t() {
        // After a mul, result must again be multiplicable (degree t).
        let mut e = engine(5);
        let a = e.input(1, &[7])[0];
        let b = e.input(2, &[11])[0];
        let c = e.mul(a, b);
        let d = e.mul(c, c);
        assert_eq!(e.peek(d), 7 * 11 * 7 * 11);
    }

    #[test]
    fn divpub_is_close() {
        let mut e = engine(5);
        for (u, d) in [(1000u128, 256u128), (255, 256), (0, 7), (65536, 256), (12345, 100)] {
            let id = e.input(1, &[u])[0];
            let v = e.divpub(id, d);
            let got = e.peek_int(v);
            let want = (u / d) as i128;
            assert!((got - want).abs() <= 1, "u={u} d={d}: got {got} want {want}");
        }
    }

    #[test]
    fn tagged_divpub_is_order_invariant() {
        // The same logical (u, d, tag) element reveals the same value no
        // matter how the calls around it are batched or ordered — the
        // invariance the compiled-plan batch evaluator builds on. The
        // untagged variant has no such guarantee (its ±1 rounding follows
        // Alice's RNG stream position).
        let us = [100_000u128, 77_777, 54_321];
        let tags = [10u64, 11, 12];

        // Engine A: one batched tagged call.
        let mut a = engine(5);
        let ids_a = a.input(1, &us);
        let outs_a = a.divpub_vec_tagged(&ids_a, 256, &tags);
        let got_a: Vec<i128> = outs_a.iter().map(|&id| a.peek_int(id)).collect();

        // Engine B: scalar tagged calls in reverse order, with an unrelated
        // untagged divpub interleaved to shift every RNG stream.
        let mut b = engine(5);
        let ids_b = b.input(1, &us);
        let noise = b.input(2, &[999_999])[0];
        let mut got_b = vec![0i128; 3];
        for e in (0..3).rev() {
            let _ = b.divpub(noise, 17);
            let out = b.divpub_vec_tagged(&ids_b[e..e + 1], 256, &tags[e..e + 1])[0];
            got_b[e] = b.peek_int(out);
        }
        assert_eq!(got_a, got_b, "tagged divpub must not depend on call order");
        for (e, &u) in us.iter().enumerate() {
            assert!((got_a[e] - (u / 256) as i128).abs() <= 1, "element {e} out of ±1");
        }
    }

    #[test]
    fn reserve_tags_is_monotone_and_disjoint() {
        let mut e = engine(3);
        let a = e.reserve_tags(5);
        let b = e.reserve_tags(3);
        let c = e.reserve_tags(1);
        assert_eq!((a, b, c), (0, 5, 8));
    }

    #[test]
    fn divpub_message_count_per_op() {
        let n = 5;
        let mut e = engine(n);
        let id = e.input(1, &[1000])[0];
        let before = e.net.stats;
        let _ = e.divpub(id, 256);
        let msgs = e.net.stats.messages - before.messages;
        // schedule n + alice 2(n-1)... as messages: (n-1) + (n-1) + (n-1) + finished n
        let expected = n as u64 // schedule
            + (n as u64 - 1)    // alice deals (r,q) packed per link
            + (n as u64 - 1)    // z' -> bob
            + (n as u64 - 1)    // bob deals w
            + n as u64; // finished
        assert_eq!(msgs, expected);
    }

    #[test]
    fn mul_message_count_per_op() {
        let n = 5;
        let mut e = engine(n);
        let a = e.input(1, &[3])[0];
        let b = e.input(1, &[4])[0];
        let before = e.net.stats;
        let _ = e.mul(a, b);
        let msgs = e.net.stats.messages - before.messages;
        assert_eq!(msgs, n as u64 + (n * (n - 1)) as u64 + n as u64);
    }

    #[test]
    fn batched_mul_fewer_messages_same_result() {
        let mut per_op = Engine::new(Field::paper(), EngineConfig::new(5));
        let mut batched = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let pairs: Vec<(u128, u128)> = (1..20u128).map(|i| (i, i * 7 + 1)).collect();
        for eng in [&mut per_op, &mut batched] {
            let avals: Vec<u128> = pairs.iter().map(|p| p.0).collect();
            let bvals: Vec<u128> = pairs.iter().map(|p| p.1).collect();
            let a = eng.input(1, &avals);
            let b = eng.input(2, &bvals);
            let prods = eng.mul_vec(&a.iter().copied().zip(b).collect::<Vec<_>>());
            for (i, &(x, y)) in pairs.iter().enumerate() {
                assert_eq!(eng.peek(prods[i]), x * y);
            }
        }
        assert!(batched.net.stats.messages < per_op.net.stats.messages / 5);
        assert!(batched.net.stats.virtual_time_s < per_op.net.stats.virtual_time_s / 5.0);
    }

    #[test]
    fn sq2pq_inputs_sum_local_contributions() {
        let mut e = engine(4);
        // member i contributes i+1 and 10*(i+1)
        let locals: Vec<Vec<u128>> =
            (0..4).map(|i| vec![(i + 1) as u128, 10 * (i + 1) as u128]).collect();
        let ids = e.sq2pq_inputs(&locals);
        assert_eq!(e.peek(ids[0]), 1 + 2 + 3 + 4);
        assert_eq!(e.peek(ids[1]), 10 + 20 + 30 + 40);
    }

    #[test]
    fn virtual_time_advances_with_latency() {
        let mut e = engine(5);
        let t0 = e.net.stats.virtual_time_s;
        let a = e.input(1, &[5])[0];
        let _ = e.mul(a, a);
        assert!(e.net.stats.virtual_time_s > t0 + 0.04); // several 10ms rounds
    }

    #[test]
    fn two_party_works_degenerate() {
        // n=2 → t=0: no privacy, but protocols must stay correct.
        let mut e = engine(2);
        let a = e.input(1, &[6])[0];
        let b = e.input(2, &[7])[0];
        let c = e.mul(a, b);
        assert_eq!(e.peek(c), 42);
    }
}
