//! Prime-field arithmetic over `Z_p` for moduli up to 74 bits.
//!
//! The paper fixes `p = 13558774610046711780701` (a 74-bit prime, §5.3); the
//! approximate-path walkthrough (Example 1) uses `p = 2^20 + 7`.  To support
//! both, the modulus is a runtime value carried by a lightweight [`Field`]
//! context; elements are plain `u128` in `[0, p)`.
//!
//! Multiplication of two 74-bit values needs a 148-bit intermediate; we form
//! the product from 64-bit limbs and fold the high parts through
//! precomputed residues of 2^64/2^96/2^128 into ONE value < 2^119, reduced
//! by a single `u128 %`.  This is the outcome of the L3 perf pass (see
//! EXPERIMENTS.md §Perf): v1 used two `%` per multiply (~17 ns), a Barrett
//! replacement measured *slower* (~27 ns — data-dependent fixup loop beats
//! the short-quotient hardware division on this CPU) and was reverted; the
//! single-reduction fold landed at ~12 ns. `barrett()` is kept as the
//! documented experiment with a cross-check test.
//!
//! §Perf iteration 7 adds a **Montgomery domain** on top (DESIGN.md §Field
//! kernel): fixed protocol constants — Vandermonde rows, Lagrange λ,
//! memoized d⁻¹ — are stored once as `x·R mod p` with `R = 2^128`, and
//! [`Field::mont_mul`] folds one *canonical* and one *Montgomery* operand
//! through a two-round 64-bit-word REDC. The R·R⁻¹ factors cancel, so the
//! result is the canonical product with **no `u128` division at all**; dot
//! chains ([`Field::mont_mul_add`]) finish each term with two predictable
//! conditional subtracts instead of a per-chunk `%`. Shares, wire bytes,
//! openings and revealed values never enter the Montgomery domain, so the
//! routed kernels stay bit-identical to the canonical path (property-pinned
//! below — the `barrett()` lesson is to measure and pin, not assume).

use crate::rng::Rng;

/// The paper's 74-bit prime modulus (§5.3).
pub const PAPER_P: u128 = 13558774610046711780701;

/// Example 1's small prime, `2^20 + 7`.
pub const EXAMPLE_P: u128 = (1 << 20) + 7;

/// Maximum supported modulus width. `mul` relies on operands' high 64-bit
/// limbs being < 2^10 so the cross terms cannot overflow a `u128`.
pub const MAX_MOD_BITS: u32 = 74;

/// A prime-field context. Cheap to copy; all element ops are methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Field {
    /// The prime modulus; elements are `u128` in `[0, p)`.
    pub p: u128,
    /// 2^128 mod p, used to fold the high product limb.
    r128: u128,
    /// 2^96 mod p and 2^64 mod p, for the single-reduction fold in `mul`.
    r96: u128,
    r64: u128,
    /// R² = 2^256 mod p (R = 2^128), the Montgomery entry constant:
    /// `to_mont(a) = mont_mul(a, r2) = a·R mod p`.
    r2: u128,
    /// `-p⁻¹ mod 2^64`, the word-by-word REDC multiplier (p is odd).
    np0: u64,
    /// Barrett constant ⌊2^(k+64)/p⌋ with k = bit length of p, or 0 when
    /// Barrett is unsafe for this width (see `barrett`).
    mu: u128,
    /// Bit length of p.
    k: u32,
}

impl Field {
    /// Create a field context. `p` must be an odd prime below 2^74 (only
    /// primality of the two built-in moduli is unit-tested; callers passing
    /// composite moduli get garbage inverses, as in any Z_p library).
    pub fn new(p: u128) -> Self {
        assert!(p > 2, "modulus must be > 2");
        assert!(
            128 - p.leading_zeros() <= MAX_MOD_BITS,
            "modulus must fit in {MAX_MOD_BITS} bits"
        );
        // 2^128 mod p by repeated doubling (init-only; no width pitfalls).
        let mut r128 = 1u128 % p;
        for _ in 0..128 {
            r128 += r128;
            if r128 >= p {
                r128 -= p;
            }
        }
        // R² = 2^256 mod p: continue the doubling chain from r128.
        let mut r2 = r128;
        for _ in 0..128 {
            r2 += r2;
            if r2 >= p {
                r2 -= p;
            }
        }
        // -p⁻¹ mod 2^64 by Newton iteration: x ← x·(2 − p·x) doubles the
        // number of valid low bits per step; the seed x = p is correct to
        // 3 bits (p² ≡ 1 mod 8 for odd p), so 6 steps reach ≥ 64.
        let p_lo = p as u64;
        let mut pinv = p_lo;
        for _ in 0..6 {
            pinv = pinv.wrapping_mul(2u64.wrapping_sub(p_lo.wrapping_mul(pinv)));
        }
        debug_assert_eq!(p_lo.wrapping_mul(pinv), 1);
        let np0 = pinv.wrapping_neg();
        // residues of 2^64 and 2^96 for the single-reduction fold
        let r64 = ((u64::MAX as u128) + 1) % p;
        let mut r96 = r64;
        for _ in 0..32 {
            r96 += r96;
            if r96 >= p {
                r96 -= p;
            }
        }
        let k = 128 - p.leading_zeros();
        // Barrett constant ⌊2^(k+64)/p⌋ by binary long division (init-only).
        // Safe widths: k ≤ 62 (inputs < p² < 2^(k+63)) or k ≥ 65 (inputs
        // < 2^128 ≤ 2^(k+63)); the narrow 63..64 band falls back to `%`.
        let mu = if k <= 62 || k >= 65 {
            let bits = k + 64;
            let mut rem = 0u128;
            let mut q = 0u128;
            for i in (0..=bits).rev() {
                rem <<= 1;
                if i == bits {
                    rem |= 1;
                }
                q <<= 1;
                if rem >= p {
                    rem -= p;
                    q |= 1;
                }
            }
            q
        } else {
            0
        };
        Field { p, r128, r96, r64, r2, np0, mu, k }
    }

    /// The paper's field.
    pub fn paper() -> Self {
        Field::new(PAPER_P)
    }

    /// Reduce an arbitrary `u128` into `[0, p)`.
    #[inline]
    pub fn reduce(&self, x: u128) -> u128 {
        x % self.p
    }

    /// `a + b (mod p)` for reduced operands.
    #[inline]
    pub fn add(&self, a: u128, b: u128) -> u128 {
        let s = a + b; // a,b < p < 2^74: no overflow
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }

    /// `a - b (mod p)` for reduced operands.
    #[inline]
    pub fn sub(&self, a: u128, b: u128) -> u128 {
        if a >= b {
            a - b
        } else {
            a + self.p - b
        }
    }

    /// `-a (mod p)` for a reduced operand.
    #[inline]
    pub fn neg(&self, a: u128) -> u128 {
        if a == 0 {
            0
        } else {
            self.p - a
        }
    }

    /// Multiply via 64-bit limb decomposition + 2^128-residue fold.
    #[inline]
    pub fn mul(&self, a: u128, b: u128) -> u128 {
        self.mul_unreduced(a, b) % self.p
    }

    /// The limb-fold half of [`Field::mul`] **without the final reduction**:
    /// returns a value `< 2^119` congruent to `a·b (mod p)`. Deferred-
    /// reduction kernels (the Vandermonde dealing dot product, §Perf
    /// iteration 6) sum several of these raw folds — a chunk of 8 stays
    /// below `2^122`, far from `u128` overflow — and pay one `%` per chunk
    /// instead of one per term.
    #[inline]
    pub fn mul_unreduced(&self, a: u128, b: u128) -> u128 {
        debug_assert!(a < self.p && b < self.p);
        let (a0, a1) = (a & 0xFFFF_FFFF_FFFF_FFFF, a >> 64);
        let (b0, b1) = (b & 0xFFFF_FFFF_FFFF_FFFF, b >> 64);
        // a1, b1 < 2^10 because p < 2^74, so every term fits in u128.
        let ll = a0 * b0;
        let mid = a0 * b1 + a1 * b0; // < 2^75
        let hh = a1 * b1; // < 2^20
        // product = hh·2^128 + mid·2^64 + ll. Fold every power-of-2^32
        // residue into ONE value < 2^119 and reduce once (§Perf iteration 2:
        // replaces the two u128 `%` of the v1 fold with one).
        let l0 = ll & 0xFFFF_FFFF_FFFF_FFFF;
        let tmid = mid + (ll >> 64); // < 2^76
        let t0 = tmid & 0xFFFF_FFFF; // 32-bit pieces of the 2^64 coefficient
        let t1 = tmid >> 32; // < 2^44
        hh * self.r128 + t1 * self.r96 + t0 * self.r64 + l0 // < 2^119
    }

    /// Reduce `x` mod p without division (Barrett). §Perf iteration 2 —
    /// MEASURED SLOWER than the single `%` on this CPU (see module docs and
    /// EXPERIMENTS.md §Perf) and therefore not on the hot path; kept, with
    /// the cross-check test below, as the documented experiment.
    ///
    /// Correctness window: `q̂ = ((x >> k)·µ) >> 64 ≤ ⌊x/p⌋` (both floors
    /// only shrink), and the defect is bounded by the dropped low bits
    /// (`x mod 2^k < 2p`) plus the µ rounding (< 1) — at most a handful of
    /// subtractions. Overflow needs `(x >> k)·µ < 2^128`, i.e. `x <
    /// 2^(k+63)`: true for k ≤ 62 (inputs < p²) and k ≥ 65 (inputs < 2^128).
    #[inline]
    pub fn barrett(&self, x: u128) -> u128 {
        if self.mu == 0 {
            return x % self.p;
        }
        debug_assert!(
            self.k as usize + 63 >= 128 || x < (1u128 << (self.k + 63)),
            "barrett input outside domain"
        );
        let q = ((x >> self.k) * self.mu) >> 64;
        let mut r = x - q * self.p;
        while r >= self.p {
            r -= self.p;
        }
        r
    }

    /// Montgomery product **without the final conditional subtract**:
    /// returns a value `< 2p` congruent to `a·b·R⁻¹ (mod p)`, `R = 2^128`.
    ///
    /// REDC width argument for `p < 2^74` (DESIGN.md §Field kernel): with
    /// operands `< 2p < 2^75` the 150-bit product `T` is carried as three
    /// 64-bit words `(t2, t1, t0)` with `t2 < 2^23`. Each of the two REDC
    /// rounds adds `m·p` (`m < 2^64`, split as `m·p0 + m·p1·2^64` so no
    /// term exceeds `u128`; the one possible carry out of `t + m·p0` is
    /// recovered via `overflowing_add`) and shifts 64 bits out; after two
    /// rounds the result is `T·2⁻¹²⁸ + (m₀ + m₁·2^64)·p·2⁻¹²⁸ < T/2^128 +
    /// p ≤ 4p²/2^128 + p < 2p` (since `4p < 2^128`). So `< 2p` operands
    /// are *closed* under this op — unreduced Montgomery values may chain.
    #[inline]
    pub fn mont_mul_unreduced(&self, a: u128, b: u128) -> u128 {
        debug_assert!(a < 2 * self.p && b < 2 * self.p);
        const M64: u128 = 0xFFFF_FFFF_FFFF_FFFF;
        let (a0, a1) = (a & M64, a >> 64);
        let (b0, b1) = (b & M64, b >> 64);
        let ll = a0 * b0;
        let mid = a0 * b1 + a1 * b0; // < 2^77 (high limbs < 2^11)
        let hh = a1 * b1; // < 2^22
        // T = hh·2^128 + mid·2^64 + ll as 64-bit words t2:t1:t0.
        let t0 = ll & M64;
        let t1full = mid + (ll >> 64); // < 2^78
        let t1 = t1full & M64;
        let t2 = hh + (t1full >> 64); // < 2^23
        let (p0, p1) = (self.p & M64, self.p >> 64);
        // REDC round 1: zero t0, shift 64 bits out.
        let m0 = (t0 as u64).wrapping_mul(self.np0) as u128;
        let (s0, ov0) = (m0 * p0).overflowing_add(t0);
        debug_assert_eq!(s0 & M64, 0);
        let c0 = (s0 >> 64) + ((ov0 as u128) << 64);
        let u = (t2 << 64) + t1 + m0 * p1 + c0; // < 2^88
        // REDC round 2 on u = u1:u0.
        let (u0, u1) = (u & M64, u >> 64);
        let m1 = (u0 as u64).wrapping_mul(self.np0) as u128;
        let (s1, ov1) = (m1 * p0).overflowing_add(u0);
        debug_assert_eq!(s1 & M64, 0);
        let c1 = (s1 >> 64) + ((ov1 as u128) << 64);
        u1 + m1 * p1 + c1
    }

    /// Montgomery product, canonical result: `a·b·R⁻¹ mod p` in `[0, p)`.
    ///
    /// The hot-path usage is the **one-operand trick**: with `a` canonical
    /// and `b` a Montgomery-domain constant (`b = to_mont(x)`), the R
    /// factors cancel and `mont_mul(a, b) = a·x mod p` — the canonical
    /// product with no `u128` division anywhere.
    #[inline]
    pub fn mont_mul(&self, a: u128, b: u128) -> u128 {
        let r = self.mont_mul_unreduced(a, b);
        if r >= self.p {
            r - self.p
        } else {
            r
        }
    }

    /// Lift a canonical value into the Montgomery domain: `a·R mod p`.
    #[inline]
    pub fn to_mont(&self, a: u128) -> u128 {
        self.mont_mul(a, self.r2)
    }

    /// Drop a Montgomery-domain value back to canonical: `a·R⁻¹ mod p`.
    #[inline]
    pub fn from_mont(&self, a: u128) -> u128 {
        self.mont_mul(a, 1)
    }

    /// One deferred-reduction dot-product step: `acc + a·b_mont·R⁻¹ mod p`
    /// with `acc` and the result canonical. The unreduced term is `< 2p`,
    /// so `acc + term < 3p` and two *branch-free* conditional subtracts
    /// restore canonical form — the λ-recombination and Vandermonde dealing
    /// kernels chain this instead of paying a `u128 %` per chunk.
    #[inline]
    pub fn mont_mul_add(&self, acc: u128, a: u128, b_mont: u128) -> u128 {
        debug_assert!(acc < self.p);
        let mut s = acc + self.mont_mul_unreduced(a, b_mont);
        s -= self.p * ((s >= self.p) as u128);
        s -= self.p * ((s >= self.p) as u128);
        s
    }

    /// Inner product of a canonical slice against a Montgomery-domain
    /// constant table: `Σ aᵢ·xᵢ mod p` where `b_mont[i] = to_mont(xᵢ)`.
    /// Division-free; bit-identical to [`Field::dot`] on the canonical
    /// table (canonical form is unique).
    #[inline]
    pub fn dot_mont(&self, a: &[u128], b_mont: &[u128]) -> u128 {
        debug_assert_eq!(a.len(), b_mont.len());
        let mut acc = 0u128;
        for (&x, &y) in a.iter().zip(b_mont) {
            acc = self.mont_mul_add(acc, x, y);
        }
        acc
    }

    // A `mul_small` fast path (direct `a·b % p` when both operands fit
    // 64 bits) used to sit here behind #[allow(dead_code)]. Removed: no
    // caller ever materialized — shares in the EXAMPLE_P walkthrough still
    // route through the generic `mul`, whose limb fold costs the same one
    // `u128 %` for small operands (the high limbs are zero and the cross
    // terms fold to `ll`), so a width dispatch would add a branch to the
    // hot path for nothing. `prop_mul_matches_native_on_small_prime`
    // pins the equivalence the fast path would have exploited.

    /// `base^exp (mod p)` by square-and-multiply.
    pub fn pow(&self, mut base: u128, mut exp: u128) -> u128 {
        let mut acc: u128 = 1;
        base %= self.p;
        while exp > 0 {
            if exp & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            exp >>= 1;
        }
        acc
    }

    /// Multiplicative inverse via Fermat (p prime).
    pub fn inv(&self, a: u128) -> u128 {
        assert!(a != 0, "inverse of zero");
        self.pow(a, self.p - 2)
    }

    /// Uniform element of `[0, p)` (rejection sampling on the bit width).
    pub fn rand<R: Rng + ?Sized>(&self, rng: &mut R) -> u128 {
        let bits = 128 - self.p.leading_zeros();
        let mask = if bits >= 128 { u128::MAX } else { (1u128 << bits) - 1 };
        loop {
            let x = rng.next_u128() & mask;
            if x < self.p {
                return x;
            }
        }
    }

    /// Embed a signed integer (used for small public constants like `2G - s`).
    #[inline]
    pub fn from_i128(&self, v: i128) -> u128 {
        if v >= 0 {
            (v as u128) % self.p
        } else {
            self.p - ((-v) as u128) % self.p
        }
    }

    /// Interpret a field element as a signed integer in `(-p/2, p/2]`.
    /// Protocol intermediates are small integers; this recovers them.
    #[inline]
    pub fn to_i128(&self, v: u128) -> i128 {
        if v > self.p / 2 {
            -((self.p - v) as i128)
        } else {
            v as i128
        }
    }

    /// Σ over a slice of canonical elements, mod p. Deferred reduction:
    /// raw `u128` adds in chunks of 2^16 (each partial `< 2^16·2^74 =
    /// 2^90`), one `%` per chunk — bit-identical to the per-term
    /// `add` fold (pinned by `prop_sum_dot_match_naive_fold`).
    pub fn sum(&self, xs: &[u128]) -> u128 {
        let mut acc = 0u128;
        for chunk in xs.chunks(1 << 16) {
            let part = chunk.iter().fold(0u128, |s, &x| s + x);
            acc += part % self.p;
            acc -= self.p * ((acc >= self.p) as u128);
        }
        acc
    }

    /// Inner product Σ aᵢ·bᵢ mod p over canonical slices. Routed through
    /// the deferred-reduction kernel: chunks of 8 raw [`Field::mul_unreduced`]
    /// folds (`< 2^122` per partial) pay one `%` per chunk instead of one
    /// per term — the same kernel the Vandermonde dealing dot uses.
    pub fn dot(&self, a: &[u128], b: &[u128]) -> u128 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = 0u128;
        for (ca, cb) in a.chunks(8).zip(b.chunks(8)) {
            let mut part = 0u128;
            for (&x, &y) in ca.iter().zip(cb) {
                part += self.mul_unreduced(x, y);
            }
            acc += part % self.p;
            acc -= self.p * ((acc >= self.p) as u128);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Prng, Rng};

    #[test]
    fn paper_prime_is_74_bits() {
        assert_eq!(128 - PAPER_P.leading_zeros(), 74);
    }

    #[test]
    fn fermat_on_both_builtin_primes() {
        // a^(p-1) == 1 for a handful of witnesses: consistency of mul/pow and
        // a strong primality signal for the hardcoded moduli.
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            for a in [2u128, 3, 5, 7, 65537, 1 << 60] {
                assert_eq!(f.pow(a % p, p - 1), 1, "p={p} a={a}");
            }
        }
    }

    #[test]
    fn add_sub_roundtrip() {
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(1);
        for _ in 0..1000 {
            let a = f.rand(&mut rng);
            let b = f.rand(&mut rng);
            assert_eq!(f.sub(f.add(a, b), b), a);
            assert_eq!(f.add(f.sub(a, b), b), a);
            assert_eq!(f.add(a, f.neg(a)), 0);
        }
    }

    #[test]
    fn mul_matches_wide_reference() {
        // Reference: schoolbook through per-bit double-and-add (only additions).
        fn slow_mul(f: &Field, a: u128, mut b: u128) -> u128 {
            let mut acc = 0u128;
            let mut cur = a;
            while b > 0 {
                if b & 1 == 1 {
                    acc = f.add(acc, cur);
                }
                cur = f.add(cur, cur);
                b >>= 1;
            }
            acc
        }
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(2);
        for _ in 0..500 {
            let a = f.rand(&mut rng);
            let b = f.rand(&mut rng);
            assert_eq!(f.mul(a, b), slow_mul(&f, a, b));
        }
    }

    #[test]
    fn inv_is_inverse() {
        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(3);
        for _ in 0..50 {
            let a = f.rand(&mut rng);
            if a == 0 {
                continue;
            }
            assert_eq!(f.mul(a, f.inv(a)), 1);
        }
    }

    #[test]
    fn barrett_matches_modulo_on_its_domain() {
        // domain: x < 2^(k+63); for the paper prime that is all of u128,
        // for the small prime it is p^2-sized inputs (what mul produces).
        let mut rng = Prng::seed_from_u64(99);
        let f = Field::paper();
        for _ in 0..2000 {
            let x = rng.next_u128();
            assert_eq!(f.barrett(x), x % f.p);
        }
        let f = Field::new(EXAMPLE_P);
        for _ in 0..2000 {
            let x = rng.gen_bits(41); // < p^2
            assert_eq!(f.barrett(x), x % f.p);
        }
    }

    #[test]
    fn signed_roundtrip() {
        let f = Field::paper();
        for v in [-5i128, -1, 0, 1, 7, 1 << 40, -(1 << 40)] {
            assert_eq!(f.to_i128(f.from_i128(v)), v);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wide_modulus() {
        Field::new(1u128 << 90);
    }

    #[test]
    fn prop_mul_commutes_and_distributes() {
        let f = Field::paper();
        crate::rng::property(256, |rng| {
            let a = f.rand(rng);
            let b = f.rand(rng);
            let c = f.rand(rng);
            assert_eq!(f.mul(a, b), f.mul(b, a));
            assert_eq!(f.mul(a, f.add(b, c)), f.add(f.mul(a, b), f.mul(a, c)));
        });
    }

    #[test]
    fn prop_mul_unreduced_is_congruent_and_bounded() {
        for f in [Field::paper(), Field::new(EXAMPLE_P)] {
            crate::rng::property(256, |rng| {
                let a = f.rand(rng);
                let b = f.rand(rng);
                let raw = f.mul_unreduced(a, b);
                assert!(raw < 1u128 << 119);
                assert_eq!(raw % f.p, f.mul(a, b));
            });
        }
    }

    #[test]
    fn prop_mul_matches_native_on_small_prime() {
        let f = Field::new(EXAMPLE_P);
        crate::rng::property(256, |rng| {
            let a = f.rand(rng);
            let b = f.rand(rng);
            assert_eq!(f.mul(a, b), (a * b) % EXAMPLE_P);
        });
    }

    #[test]
    fn prop_dot_equals_sum_of_muls() {
        let f = Field::paper();
        crate::rng::property(64, |rng| {
            let n = rng.gen_range_u64(8) as usize;
            let xs: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
            let ys: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
            let d = f.dot(&xs, &ys);
            let mut acc = 0;
            for i in 0..n {
                acc = f.add(acc, f.mul(xs[i], ys[i]));
            }
            assert_eq!(d, acc);
        });
    }

    #[test]
    fn mont_roundtrip_on_both_builtin_primes() {
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            for a in [0u128, 1, 2, p - 1, p / 2, 65537 % p] {
                let m = f.to_mont(a);
                assert!(m < p, "to_mont must be canonical-range, p={p} a={a}");
                assert_eq!(f.from_mont(m), a, "round trip, p={p} a={a}");
            }
            crate::rng::property(128, |rng| {
                let a = f.rand(rng);
                assert_eq!(f.from_mont(f.to_mont(a)), a, "p={p}");
            });
        }
    }

    #[test]
    fn prop_mont_mul_matches_canonical_mul() {
        // Cross-domain parity on both primes: full mont×mont round trip
        // AND the one-operand trick the hot kernels rely on.
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            crate::rng::property(256, |rng| {
                let a = f.rand(rng);
                let b = f.rand(rng);
                let want = f.mul(a, b);
                assert_eq!(f.from_mont(f.mont_mul(f.to_mont(a), f.to_mont(b))), want, "p={p}");
                assert_eq!(f.mont_mul(a, f.to_mont(b)), want, "one-operand trick, p={p}");
            });
        }
    }

    #[test]
    fn prop_mont_unreduced_is_congruent_bounded_and_closed() {
        // < 2p operands stay < 2p through the two-round REDC (the closure
        // that lets unreduced Montgomery values chain), and every result
        // is congruent to a·b·R⁻¹.
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            crate::rng::property(256, |rng| {
                // draw in [0, 2p) to exercise the relaxed domain
                let a = f.rand(rng) + p * rng.gen_range_u64(2) as u128;
                let b = f.rand(rng) + p * rng.gen_range_u64(2) as u128;
                let raw = f.mont_mul_unreduced(a, b);
                assert!(raw < 2 * p, "closure, p={p}");
                let want = f.mul(f.mul(a % p, b % p), f.inv(f.to_mont(1)));
                assert_eq!(raw % p, want, "congruence, p={p}");
            });
        }
    }

    #[test]
    fn prop_mont_pow_matches_canonical_pow() {
        // Square-and-multiply entirely inside the Montgomery domain equals
        // the canonical pow (mont parity for the `pow` composition).
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            crate::rng::property(64, |rng| {
                let base = f.rand(rng);
                let exp = rng.gen_bits(20);
                let mut acc = f.to_mont(1);
                let mut cur = f.to_mont(base);
                let mut e = exp;
                while e > 0 {
                    if e & 1 == 1 {
                        acc = f.mont_mul(acc, cur);
                    }
                    cur = f.mont_mul(cur, cur);
                    e >>= 1;
                }
                assert_eq!(f.from_mont(acc), f.pow(base, exp), "p={p}");
            });
        }
    }

    #[test]
    fn prop_mont_dot_matches_dot() {
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            crate::rng::property(64, |rng| {
                let n = rng.gen_range_u64(16) as usize;
                let xs: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
                let ys: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
                let ys_mont: Vec<u128> = ys.iter().map(|&y| f.to_mont(y)).collect();
                assert_eq!(f.dot_mont(&xs, &ys_mont), f.dot(&xs, &ys), "p={p}");
            });
        }
    }

    #[test]
    fn prop_sum_dot_match_naive_fold() {
        // The deferred-reduction chunk kernels behind Field::sum/dot must be
        // bit-identical to the per-term add(mul(..)) folds they replaced —
        // lengths straddle the chunk width (8) to cover partial tails.
        for p in [PAPER_P, EXAMPLE_P] {
            let f = Field::new(p);
            crate::rng::property(64, |rng| {
                let n = rng.gen_range_u64(40) as usize;
                let xs: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
                let ys: Vec<u128> = (0..n).map(|_| f.rand(rng)).collect();
                let naive_sum = xs.iter().fold(0, |acc, &x| f.add(acc, x));
                let naive_dot = xs
                    .iter()
                    .zip(&ys)
                    .fold(0, |acc, (&x, &y)| f.add(acc, f.mul(x, y)));
                assert_eq!(f.sum(&xs), naive_sum, "p={p} n={n}");
                assert_eq!(f.dot(&xs, &ys), naive_dot, "p={p} n={n}");
            });
        }
    }
}
