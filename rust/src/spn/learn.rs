//! Closed-form maximum-likelihood parameters for selective SPNs (Eq. (2) of
//! the paper / Eq. (24) of the Sánchez-Cauce et al. survey) — the
//! *centralized* oracle the private protocol must match.

use super::structure::{ParamKind, Structure};

/// Laplace smoothing constant added to denominators (also guarantees the
/// Newton protocol's b ≥ 1 precondition; see protocols::newton).
pub const SMOOTH: u64 = 1;

/// ML parameters (floats in [0,1]) from a counts vector.
pub fn ml_params(st: &Structure, counts: &[u64]) -> Vec<f64> {
    assert_eq!(counts.len(), st.counts_len());
    let mut p = vec![0.0f64; st.num_params];
    for k in 0..st.num_params {
        let num = counts[st.param_num[k]] as f64;
        let den = (counts[st.param_den[k]] + SMOOTH) as f64;
        p[k] = num / den;
    }
    // renormalize each sum group (smoothing skews them slightly)
    for g in &st.sum_groups {
        let tot: f64 = g.iter().map(|&i| p[i]).sum();
        if tot > 0.0 {
            for &i in g {
                p[i] /= tot;
            }
        } else {
            for &i in g {
                p[i] = 1.0 / g.len() as f64;
            }
        }
    }
    p
}

/// Fixed-point (d-scaled) ML sum-edge weights — the integers the private
/// protocol outputs; leaf params untouched (paper mode trains sums only).
pub fn ml_weights_fixed(st: &Structure, counts: &[u64], d: u128) -> Vec<u128> {
    st.sum_groups
        .iter()
        .flat_map(|g| {
            let den = counts[st.param_den[g[0]]] as u128 + SMOOTH as u128;
            g.iter().map(move |&k| d * counts[st.param_num[k]] as u128 / den)
        })
        .collect()
}

/// Convert d-scaled integer sum weights (+ given leaf thetas) into a float
/// parameter vector, renormalizing each sum group.
pub fn params_from_fixed(
    st: &Structure,
    fixed_sum_weights: &[i128],
    leaf_theta: &[f64],
    d: u128,
) -> Vec<f64> {
    assert_eq!(fixed_sum_weights.len(), st.num_sum_edges);
    assert_eq!(leaf_theta.len(), st.num_leaves());
    let mut p = vec![0.0f64; st.num_params];
    for g in &st.sum_groups {
        let mut tot = 0.0;
        for &i in g {
            let w = fixed_sum_weights[i].max(0) as f64 / d as f64;
            p[i] = w;
            tot += w;
        }
        for &i in g {
            if tot > 0.0 {
                p[i] /= tot;
            } else {
                p[i] = 1.0 / g.len() as f64;
            }
        }
    }
    for (i, &t) in leaf_theta.iter().enumerate() {
        p[st.num_sum_edges + i] = t;
    }
    p
}

/// Default leaf parameters when leaves are not privately learned (paper
/// mode): gates get their claim-consistent near-degenerate θ, plain leaves
/// the global empirical frequency estimate 0.5.
pub fn default_leaf_theta(st: &Structure) -> Vec<f64> {
    st.leaf_claim
        .iter()
        .map(|&c| match c {
            1 => 1.0 - 1e-6,
            0 => 1e-6,
            _ => 0.5,
        })
        .collect()
}

/// Which params are sum edges (helper for reporting).
pub fn sum_edge_indices(st: &Structure) -> Vec<usize> {
    (0..st.num_params).filter(|&k| st.param_kind[k] == ParamKind::SumEdge).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Prng, Rng};
    use crate::spn::eval;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    fn gen_data(st: &Structure, n: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Prng::seed_from_u64(seed);
        (0..n).map(|_| (0..st.num_vars).map(|_| rng.gen_bool(0.4) as u8).collect()).collect()
    }

    #[test]
    fn ml_params_are_distributions() {
        let Some(st) = toy() else { return };
        let data = gen_data(&st, 500, 1);
        let cnt = eval::counts(&st, &data);
        let p = ml_params(&st, &cnt);
        for g in &st.sum_groups {
            let tot: f64 = g.iter().map(|&i| p[i]).sum();
            assert!((tot - 1.0).abs() < 1e-9);
        }
        for k in 0..st.num_params {
            assert!((0.0..=1.0).contains(&p[k]), "param {k} = {}", p[k]);
        }
    }

    #[test]
    fn ml_improves_loglik_over_uniform() {
        let Some(st) = toy() else { return };
        let data = gen_data(&st, 1000, 2);
        let cnt = eval::counts(&st, &data);
        let ml = ml_params(&st, &cnt);
        let mut uni = vec![0.0; st.num_params];
        for g in &st.sum_groups {
            for &i in g {
                uni[i] = 1.0 / g.len() as f64;
            }
        }
        for i in 0..st.num_leaves() {
            uni[st.num_sum_edges + i] = 0.5;
        }
        let ll_ml = eval::mean_loglik(&st, &data, &ml);
        let ll_uni = eval::mean_loglik(&st, &data, &uni);
        assert!(ll_ml > ll_uni, "ml {ll_ml} vs uniform {ll_uni}");
    }

    #[test]
    fn fixed_weights_approximate_float_weights() {
        let Some(st) = toy() else { return };
        let data = gen_data(&st, 800, 3);
        let cnt = eval::counts(&st, &data);
        let ml = ml_params(&st, &cnt);
        let fixed = ml_weights_fixed(&st, &cnt, 256);
        for (k, &fw) in fixed.iter().enumerate() {
            assert!((fw as f64 / 256.0 - ml[k]).abs() < 0.02, "param {k}");
        }
    }

    #[test]
    fn params_from_fixed_roundtrip() {
        let Some(st) = toy() else { return };
        let data = gen_data(&st, 800, 4);
        let cnt = eval::counts(&st, &data);
        let fixed: Vec<i128> =
            ml_weights_fixed(&st, &cnt, 256).iter().map(|&x| x as i128).collect();
        let theta = default_leaf_theta(&st);
        let p = params_from_fixed(&st, &fixed, &theta, 256);
        for g in &st.sum_groups {
            let tot: f64 = g.iter().map(|&i| p[i]).sum();
            assert!((tot - 1.0).abs() < 1e-9);
        }
    }
}
