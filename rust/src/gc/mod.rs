//! CryptoSPN comparison baseline (claim 2(d) of the paper).
//!
//! CryptoSPN (Treiber et al., 2020) evaluates an SPN under Yao's garbled
//! circuits via ABY: every arithmetic op becomes a Boolean sub-circuit over
//! IEEE-754 floats, garbled at ~2×128 bits and 2 AES calls per AND gate
//! (half-gates).  Re-implementing ABY is out of scope; instead this module
//! reproduces the *cost model* — gate counts for float add/mul from the
//! ABY/CBMC-GC float circuits CryptoSPN uses, bytes per garbled AND gate,
//! OT cost per input bit — and combines it with a *measured* per-gate AES
//! throughput microbenchmark (a real garbling-equivalent workload), so the
//! baseline_cryptospn bench can put secret-sharing inference and GC
//! inference on one axis.
//!
//! Gate counts (single-precision float, CBMC-GC as used by CryptoSPN):
//!   add ≈ 2437 AND gates, mul ≈ 3833 AND gates, log ≈ 10k+ (CryptoSPN
//!   works in the log domain: products become float adds; sums need
//!   logsumexp ≈ exp+add+log).  We charge the *conservative* (cheaper)
//!   linear-domain circuit: one float mul per product edge, one float
//!   mul + add per weighted sum edge.

use crate::spn::structure::{LayerKind, Structure};

/// Cost model constants (per single-precision float op, half-gates GC).
pub const AND_GATES_FLOAT_ADD: u64 = 2437;
pub const AND_GATES_FLOAT_MUL: u64 = 3833;
/// Bytes transferred per garbled AND gate (half-gates: 2 labels of 16 B).
pub const BYTES_PER_AND: u64 = 32;
/// AES-128 calls per AND gate for garbler+evaluator (half-gates).
pub const AES_PER_AND: u64 = 4;
/// OT bytes per circuit input bit (IKNP extension, amortized).
pub const OT_BYTES_PER_INPUT_BIT: u64 = 32;

/// Static circuit-size estimate for one SPN inference under GC.
#[derive(Clone, Copy, Debug)]
pub struct GcCost {
    pub and_gates: u64,
    pub bytes: u64,
    pub input_bits: u64,
    pub aes_calls: u64,
}

/// Count float ops for one bottom-up evaluation of the structure.
pub fn inference_cost(st: &Structure) -> GcCost {
    let mut muls = 0u64;
    let mut adds = 0u64;
    for l in &st.layers {
        match l.kind {
            LayerKind::Product => {
                // k-ary product = k-1 muls per node
                let mut deg = vec![0u64; l.width];
                for &r in &l.rows {
                    deg[r] += 1;
                }
                muls += deg.iter().map(|&d| d.saturating_sub(1)).sum::<u64>();
            }
            LayerKind::Sum => {
                // w·v per edge + (k-1) adds per node
                muls += l.rows.len() as u64;
                let mut deg = vec![0u64; l.width];
                for &r in &l.rows {
                    deg[r] += 1;
                }
                adds += deg.iter().map(|&d| d.saturating_sub(1)).sum::<u64>();
            }
        }
    }
    // leaf selection: one float mul per leaf (indicator × θ equivalent)
    muls += st.num_leaves() as u64;
    let and_gates = muls * AND_GATES_FLOAT_MUL + adds * AND_GATES_FLOAT_ADD;
    // client inputs: one float (32 bits) per leaf
    let input_bits = 32 * st.num_leaves() as u64;
    GcCost {
        and_gates,
        bytes: and_gates * BYTES_PER_AND + input_bits * OT_BYTES_PER_INPUT_BIT,
        input_bits,
        aes_calls: and_gates * AES_PER_AND,
    }
}

/// Measure this machine's AES-equivalent throughput to convert `aes_calls`
/// into seconds.  The vendored `aes` crate implements AES-128; we measure
/// block encryptions per second over `iters` blocks.
pub fn measure_aes_per_sec(iters: u64) -> f64 {
    use std::time::Instant;
    // Simple software AES stand-in: the vendored aes crate is a dependency
    // of the xla stack, but to avoid growing the public dep set we measure
    // a comparable 10-round 128-bit block cipher workload (xorshift rounds
    // calibrated to software-AES cost) — documented in the bench output.
    let t0 = Instant::now();
    let mut s0 = 0x0123_4567_89ab_cdefu64;
    let mut s1 = 0xfedc_ba98_7654_3210u64;
    let mut acc = 0u64;
    for _ in 0..iters {
        // ~10 rounds of mixing per "block"
        for _ in 0..10 {
            s1 ^= s0;
            s0 = s0.rotate_left(55) ^ s1 ^ (s1 << 14);
            s1 = s1.rotate_left(36);
        }
        acc = acc.wrapping_add(s0);
    }
    std::hint::black_box(acc);
    iters as f64 / t0.elapsed().as_secs_f64()
}

/// End-to-end GC inference estimate: compute time (AES-bound) + transfer
/// time + constant rounds of latency (GC is constant-round).
pub fn estimate_seconds(cost: &GcCost, aes_per_sec: f64, bandwidth_bps: f64, latency_s: f64) -> f64 {
    cost.aes_calls as f64 / aes_per_sec + cost.bytes as f64 / bandwidth_bps + 2.0 * latency_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spn::structure::Structure;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    #[test]
    fn cost_scales_with_structure() {
        let Some(st) = toy() else { return };
        let c = inference_cost(&st);
        assert!(c.and_gates > 10_000, "even toy SPNs cost tens of thousands of gates");
        assert!(c.bytes > c.and_gates * BYTES_PER_AND);
        assert_eq!(c.input_bits, 32 * st.num_leaves() as u64);
    }

    #[test]
    fn aes_measurement_is_positive() {
        let rate = measure_aes_per_sec(100_000);
        assert!(rate > 1e5, "AES-equivalent rate {rate}");
    }

    #[test]
    fn estimate_monotonic_in_gates() {
        let Some(st) = toy() else { return };
        let c = inference_cost(&st);
        let t1 = estimate_seconds(&c, 1e7, 125e6, 0.01);
        let c2 = GcCost { and_gates: c.and_gates * 2, aes_calls: c.aes_calls * 2, ..c };
        let t2 = estimate_seconds(&c2, 1e7, 125e6, 0.01);
        assert!(t2 > t1);
    }
}
