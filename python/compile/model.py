"""Layer-2 JAX model: the per-party local computation over a data shard.

Two jittable graphs are built from a structure dict (see structures.py):

* ``counts_fn``  — the training-side hot path.  Bottom-up positivity and
  top-down activation over the layered SPN (both passes call the Layer-1
  Pallas kernel per layer), then masked count reductions.  Output is the
  single vector ``concat(act-counts over [leaves, layer1..layer2K],
  x1-counts over leaves)`` that the rust coordinator slices into the
  per-parameter numerators/denominators of Eq. (2)/(3).

* ``logeval_fn`` — the inference oracle: batched log S(x) with Bernoulli
  leaves, weights as a runtime input so rust can feed privately learned
  parameters.  Marginalization mask per variable supports the paper's §4
  marginal queries Pr(x|e) = S(xe)/S(e).

Widths are padded to multiples of 8 inside this module only; the structure
JSON keeps logical widths and the padded outputs are sliced back before the
count reduction, so artifact outputs are logical-width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import spn_layer as K
from . import structures


def _pad8(n: int) -> int:
    return max(8, (n + 7) // 8 * 8)


class LayeredSpn:
    """Dense padded matrices + metadata derived from a structure dict."""

    def __init__(self, st: dict):
        self.st = st
        self.w0 = st["layer_widths"][0]
        self.w0p = _pad8(self.w0)
        self.nv = st["num_vars"]
        self.leaf_var = np.asarray(st["leaf_var"], dtype=np.int32)
        self.leaf_claim = np.asarray(st["leaf_claim"], dtype=np.float32)
        self.widths = st["layer_widths"][1:]
        self.padded = [_pad8(w) for w in self.widths]

        self.mats = []       # (out_p, in_p) adjacency, in = concat(prev, leaves)
        self.degs = []       # (out_p,) row degrees
        self.kinds = []
        for li, layer in enumerate(st["layers"]):
            prev_w = layer["in_width"] - self.w0
            prev_p = self.padded[li - 1] if li > 0 else 0
            in_p = prev_p + self.w0p
            out_p = self.padded[li]
            m = np.zeros((out_p, in_p), dtype=np.float32)
            for r, c in zip(layer["rows"], layer["cols"]):
                cc = c if c < prev_w else prev_p + (c - prev_w)
                m[r, cc] = 1.0
            deg = m.sum(axis=1).astype(np.float32)
            # padded product rows must not fire MODE_AND with deg 0
            if layer["kind"] == "product":
                deg[layer["width"]:] = 1e9
            self.mats.append(m)
            self.degs.append(deg)
            self.kinds.append(layer["kind"])

    # -- shared leaf preparation ---------------------------------------------
    def leaf_pos(self, x):
        """(B, w0p) positivity of leaves: gate claims or constant 1."""
        xl = x[:, self.leaf_var]                                  # (B, w0)
        claim = jnp.asarray(self.leaf_claim)
        pos = jnp.where(claim < 0.0, 1.0,
                        (jnp.abs(xl - claim) < 0.5).astype(jnp.float32))
        return jnp.pad(pos, ((0, 0), (0, self.w0p - self.w0))), xl


def build_counts_fn(st: dict, batch: int, block_b: int = 512):
    """Jittable (X:(B,nv) f32, row_mask:(B,) f32) -> counts:(total+w0,) f32.

    block_b = 512 (single grid step per 512-row chunk) is the outcome of the
    §Perf L1/L2 block sweep: 1.8x faster than 128 on the XLA CPU backend and
    still within the 16 MiB VMEM budget on TPU for Table-1 sized layers
    (see kernels.spn_layer.vmem_footprint_bytes and EXPERIMENTS.md §Perf).
    """
    block_b = min(block_b, batch)
    assert batch % block_b == 0, (batch, block_b)
    sp = LayeredSpn(st)
    L = len(sp.mats)
    mats_t = [jnp.asarray(m.T) for m in sp.mats]     # (in_p, out_p)
    mats = [jnp.asarray(m) for m in sp.mats]         # (out_p, in_p)
    degs = [jnp.asarray(d) for d in sp.degs]
    zero_gate = [jnp.zeros((batch, m.shape[1]), jnp.float32) for m in mats_t]

    def fn(x, row_mask):
        pos_leaf, xl = sp.leaf_pos(x)
        # ---- bottom-up positivity -----------------------------------------
        pos = [pos_leaf]
        for li in range(L):
            if li == 0:
                inp = pos_leaf
            else:
                inp = jnp.concatenate([pos[li], pos_leaf], axis=1)
            mode = K.MODE_AND if sp.kinds[li] == "product" else K.MODE_OR
            pos.append(K.layer_apply(inp, mats_t[li], degs[li],
                                     zero_gate[li], mode, block_b))
        # ---- top-down activation -------------------------------------------
        act = [None] * (L + 1)
        act[L] = pos[L]                                   # root act = pos
        act_leaf = jnp.zeros((batch, sp.w0p), jnp.float32)
        dummy_deg = [jnp.zeros((m.shape[1],), jnp.float32) for m in mats]
        for li in range(L - 1, -1, -1):
            if li > 0:
                gate = jnp.concatenate([pos[li], pos_leaf], axis=1)
            else:
                gate = pos_leaf
            contrib = K.layer_apply(act[li + 1], mats[li], dummy_deg[li],
                                    gate, K.MODE_GATE, block_b)
            prev_p = sp.padded[li - 1] if li > 0 else 0
            if li > 0:
                act[li] = contrib[:, :prev_p]
            act_leaf = act_leaf + contrib[:, prev_p:]
        # ---- count reductions -----------------------------------------------
        parts = [K.masked_count(act_leaf, row_mask, block_b)[: sp.w0]]
        for li in range(L):
            parts.append(K.masked_count(act[li + 1], row_mask, block_b)[: sp.widths[li]])
        x1 = K.masked_count(act_leaf[:, : sp.w0] * xl, row_mask, block_b)[: sp.w0]
        return (jnp.concatenate(parts + [x1]),)

    return fn


def build_logeval_fn(st: dict, batch: int):
    """Jittable (X:(B,nv), marg:(nv,), params:(P,)) -> (logS:(B,),)."""
    sp = LayeredSpn(st)
    L = len(sp.mats)
    nse = st["num_sum_edges"]
    NEG = -1e30

    # per-sum-layer COO, in padded input coordinates
    layer_coo = []
    for li, layer in enumerate(st["layers"]):
        prev_w = layer["in_width"] - sp.w0
        prev_p = sp.padded[li - 1] if li > 0 else 0
        rows = np.asarray(layer["rows"], dtype=np.int32)
        cols = np.asarray([c if c < prev_w else prev_p + (c - prev_w)
                           for c in layer["cols"]], dtype=np.int32)
        pids = np.asarray(layer["param"], dtype=np.int32)
        layer_coo.append((rows, cols, pids, layer["width"]))

    def fn(x, marg, params):
        xl = x[:, sp.leaf_var]                              # (B, w0)
        ml = marg[sp.leaf_var] > 0.5                        # (w0,)
        theta = jnp.clip(params[nse:], 1e-9, 1.0 - 1e-9)
        lp = jnp.where(xl > 0.5, jnp.log(theta)[None, :],
                       jnp.log1p(-theta)[None, :])
        leaf_ll = jnp.where(ml[None, :], 0.0, lp)           # (B, w0)
        leaf_p = jnp.pad(leaf_ll, ((0, 0), (0, sp.w0p - sp.w0)),
                         constant_values=0.0)
        vals = [leaf_p]
        for li in range(L):
            rows, cols, pids, width = layer_coo[li]
            if li == 0:
                inp = leaf_p
            else:
                inp = jnp.concatenate([vals[li], leaf_p], axis=1)
            if sp.kinds[li] == "product":
                # log-product: masked matmul (padded rows yield 0)
                o = inp @ jnp.asarray(sp.mats[li].T)
            else:
                # logsumexp over children with edge weights, via segment ops
                contrib = inp[:, cols] + jnp.log(
                    jnp.clip(params[pids], 1e-30, None))[None, :]  # (B, E)
                # max per row for stability
                mx = jax.ops.segment_max(contrib.T, rows,
                                         num_segments=sp.padded[li])   # (W,B)
                mx = jnp.maximum(mx, NEG)          # empty (padded) rows: finite
                se = jax.ops.segment_sum(
                    jnp.exp(contrib.T - mx[rows]), rows,
                    num_segments=sp.padded[li])
                o = jnp.maximum((mx + jnp.log(jnp.maximum(se, 1e-300))).T, NEG)
            vals.append(o)
        return (vals[-1][:, 0],)

    return fn


def initial_params(st: dict, seed: int = 0) -> np.ndarray:
    """Plausible ground-truth parameters for synthetic data generation."""
    rng = np.random.default_rng(seed)
    p = np.zeros(st["num_params"], dtype=np.float64)
    for g in st["sum_groups"]:
        w = rng.dirichlet(np.ones(len(g)) * 2.0)
        p[g] = w
    nse = st["num_sum_edges"]
    claims = np.asarray(st["leaf_claim"])
    theta = rng.uniform(0.15, 0.85, size=len(claims))
    # gate leaves: near-degenerate Bernoullis consistent with their claim
    theta = np.where(claims == 1, 0.95, np.where(claims == 0, 0.05, theta))
    p[nse:] = theta
    return p
