//! MPC data-plane throughput — the §Perf iteration-3 instrument
//! (EXPERIMENTS.md).
//!
//! Measures elements/sec for the vectorized session primitives over both
//! backends at k ∈ {1, 64, 4096} and n ∈ {3, 5, 13}:
//!
//! * `share_batch` — raw flat-buffer dealing ([`ShamirCtx::share_batch_into`]),
//!   no session around it: the data-plane kernel in isolation;
//! * `mul_vec` / `divpub_vec` — the full secure primitives through the
//!   `Batched` simulated engine (`sim`) and through real loopback TCP
//!   member threads (`tcp`);
//! * `pipelined mul+div` — the same work coalesced into one flight
//!   (`submit`/`complete`, DESIGN.md §Round scheduler): identical traffic,
//!   fewer lockstep synchronization points per call.
//!
//! Never skips (no artifacts needed). `--json <path>` writes the
//! `{bench, metric, value}` rows `make bench-json` commits as
//! BENCH_mpc_throughput.json — the data-plane perf trajectory baseline.
//! `--smoke` shrinks to k ∈ {1, 8}, n = 3 with 3 iterations: CI runs this
//! mode so the bench binary and its JSON schema cannot rot.

use spn_mpc::bench::{throughput, time_it, JsonSink};
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::protocols::engine::{DataId, Engine, EngineConfig};
use spn_mpc::protocols::flight::FlightOp;
use spn_mpc::protocols::session::MpcSession;
use spn_mpc::rng::Prng;
use spn_mpc::sharing::shamir::ShamirCtx;

/// (warmup, measured) iteration counts, scaled down as k grows so the
/// whole sweep stays in bench-budget territory.
fn iters_for(k: usize, smoke: bool) -> (u32, u32) {
    if smoke {
        (1, 3)
    } else if k >= 4096 {
        (2, 10)
    } else if k >= 64 {
        (2, 50)
    } else {
        (3, 200)
    }
}

fn fmt_eps(eps: f64) -> String {
    if eps >= 1e6 {
        format!("{:.2} M elems/s", eps / 1e6)
    } else if eps >= 1e3 {
        format!("{:.1} k elems/s", eps / 1e3)
    } else {
        format!("{eps:.0} elems/s")
    }
}

/// Time `mul_vec` and `divpub_vec` at width k on one session backend.
fn bench_session<S: MpcSession>(
    backend: &str,
    sess: &mut S,
    n: usize,
    k: usize,
    smoke: bool,
    json: &mut JsonSink,
    rows: &mut Vec<Vec<String>>,
) {
    let avals: Vec<u128> = (0..k as u128).map(|i| i * 7 + 3).collect();
    let bvals: Vec<u128> = (0..k as u128).map(|i| i * 11 + 1).collect();
    let a = sess.input_vec(1, &avals);
    let b = sess.input_vec(2, &bvals);
    let pairs: Vec<(DataId, DataId)> =
        a.iter().copied().zip(b.iter().copied()).collect();
    let (wu, it) = iters_for(k, smoke);

    let s = time_it(wu, it, || sess.mul_vec(&pairs));
    let eps = throughput(&s, k as u64);
    json.push("mpc_throughput", &format!("mul_vec_{backend}_n{n}_k{k}_elems_per_s"), eps);
    rows.push(vec![
        format!("mul_vec (n={n})"),
        backend.to_string(),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    let s = time_it(wu, it, || sess.divpub_vec(&a, 256));
    let eps = throughput(&s, k as u64);
    json.push("mpc_throughput", &format!("divpub_vec_{backend}_n{n}_k{k}_elems_per_s"), eps);
    rows.push(vec![
        format!("divpub_vec (n={n})"),
        backend.to_string(),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    // Pipelined dimension (DESIGN.md §Round scheduler): the same mul +
    // truncation work coalesced into ONE flight — one schedule broadcast,
    // one ordered relay pass — instead of two standalone round-trips. The
    // traffic is identical; what this row measures is the wall-clock win
    // of halving the lockstep synchronization points.
    let s = time_it(wu, it, || {
        let t0 = sess.reserve_tags(k as u64);
        let prods = sess.submit(FlightOp::Mul(pairs.clone()));
        let tags: Vec<u64> = (0..k as u64).map(|i| t0 + i).collect();
        let outs = sess.submit(FlightOp::DivpubTagged { us: prods, d: 256, tags });
        sess.complete();
        outs[0]
    });
    let eps = throughput(&s, k as u64);
    json.push(
        "mpc_throughput",
        &format!("pipelined_mul_div_{backend}_n{n}_k{k}_elems_per_s"),
        eps,
    );
    rows.push(vec![
        format!("pipelined mul+div (n={n})"),
        backend.to_string(),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    // Correctness anchor: the path we just timed must still reveal the
    // right values (mul is exact; divpub is ±1 around avals[0]·bvals[0]/d).
    let prod = sess.mul_vec(&pairs[..1])[0];
    assert_eq!(sess.reveal_vec(&[prod]), vec![avals[0] * bvals[0]], "{backend} n={n} k={k}");
    let q = sess.divpub(prod, 256);
    let got = sess.reveal_int(q);
    let want = (avals[0] * bvals[0] / 256) as i128;
    assert!((got - want).abs() <= 1, "{backend} n={n} k={k}: divpub {got} vs {want}");

    // ... and so must the flight path it raced against.
    let t0 = sess.reserve_tags(1);
    let fp = sess.submit(FlightOp::Mul(pairs[..1].to_vec()));
    let fq = sess.submit(FlightOp::DivpubTagged { us: fp.clone(), d: 256, tags: vec![t0] });
    sess.complete();
    assert_eq!(sess.reveal_vec(&fp), vec![avals[0] * bvals[0]], "{backend} n={n} k={k} flight");
    let got = sess.reveal_int(fq[0]);
    assert!((got - want).abs() <= 1, "{backend} n={n} k={k}: flight divpub {got} vs {want}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let mut json = JsonSink::from_env_args();
    let ks: Vec<usize> = if smoke { vec![1, 8] } else { vec![1, 64, 4096] };
    let ns: Vec<usize> = if smoke { vec![3] } else { vec![3, 5, 13] };
    let f = Field::paper();
    let mut rows: Vec<Vec<String>> = Vec::new();

    // --- raw flat-buffer dealing, no session ------------------------------
    for &n in &ns {
        let ctx = ShamirCtx::new(f, n);
        for &k in &ks {
            let mut rng = Prng::seed_from_u64(7);
            let secrets: Vec<u128> = (0..k as u128).map(|i| i * 97 + 5).collect();
            let mut out = vec![0u128; n * k];
            let (wu, it) = iters_for(k, smoke);
            let s = time_it(wu, it, || {
                ctx.share_batch_into(&secrets, ctx.t, &mut rng, &mut out);
                out[0]
            });
            let eps = throughput(&s, k as u64);
            json.push(
                "mpc_throughput",
                &format!("share_batch_local_n{n}_k{k}_elems_per_s"),
                eps,
            );
            json.push(
                "mpc_throughput",
                &format!("share_batch_local_n{n}_k{k}_ns_per_dealt_share"),
                s.mean_s * 1e9 / (n * k) as f64,
            );
            rows.push(vec![
                format!("share_batch (n={n})"),
                "local".to_string(),
                k.to_string(),
                fmt_eps(eps),
                s.per_iter_str(),
            ]);
        }
    }

    // --- full secure primitives, both backends ----------------------------
    for &n in &ns {
        for &k in &ks {
            let mut eng = Engine::new(f, EngineConfig::new(n).batched());
            bench_session("sim", &mut eng, n, k, smoke, &mut json, &mut rows);

            let mut tcp =
                TcpSession::spawn_local(f, TcpSessionConfig::new(n)).expect("spawn tcp session");
            bench_session("tcp", &mut tcp, n, k, smoke, &mut json, &mut rows);
            tcp.shutdown().expect("tcp shutdown");
        }
    }

    println!(
        "{}",
        render_table(
            "MPC data-plane throughput (flat-buffer dealing, dense stores, buffered TCP)",
            &["primitive", "backend", "k", "throughput", "latency/call"],
            &rows
        )
    );
    json.finish().expect("write --json output");
    println!("mpc_throughput OK");
}
