//! Cross-query batched private inference: the amortization curve.
//!
//! Runs the compiled-plan evaluator over the in-code mini structure (no
//! artifacts needed — this bench never skips) and, when artifacts exist,
//! over the paper's `nltcs` structure, at batch widths B ∈ {1, 8, 32}.
//! Reports secure rounds and messages *per query* under the `Batched`
//! schedule: rounds/query should fall ~B× (the per-step round count is
//! batch-width independent), which is exactly the claim the integration
//! test `batched_inference_rounds_strictly_sublinear` pins with a 4×
//! bound. Since the round scheduler (DESIGN.md §Round scheduler) the
//! batch path pipelines one coalesced flight per DAG wave; each width also
//! runs the stream-order sequential executor as the baseline and reports
//! the round speedup (`pipelined_round_speedup_b*`). `--json <path>`
//! writes the `{bench, metric, value}` rows that `make bench-json`
//! commits as BENCH_infer_batch.json.

use spn_mpc::bench::JsonSink;
use spn_mpc::coordinator::infer::{private_eval_batch, Query};
use spn_mpc::coordinator::train::{train, SharedModel, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::net::NetStats;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::plan::{EvalPlan, Evaluator};
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::{eval, learn};

const BATCHES: [usize; 3] = [1, 8, 32];
const MEMBERS: usize = 3;

fn trained(st: &Structure) -> (Engine, SharedModel) {
    let gt = datasets::ground_truth_params(st, 7);
    let data = datasets::sample(st, &gt, st.rows.min(2000), 42);
    let shards = datasets::partition(&data, MEMBERS);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(st, s)).collect();
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched());
    let (model, _) = train(&mut eng, st, &counts, data.len() as u64, &TrainConfig::default());
    (eng, model)
}

fn queries(st: &Structure, bsz: usize) -> Vec<Query> {
    (0..bsz)
        .map(|i| {
            let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
            let v = i % st.num_vars;
            q.x[v] = (i / st.num_vars % 2) as u8;
            q.marg[v] = false;
            q
        })
        .collect()
}

fn run(name: &str, st: &Structure, json: &mut JsonSink, rows: &mut Vec<Vec<String>>) {
    let (mut eng, model) = trained(st);
    let theta = learn::default_leaf_theta(st);
    // The sequential stream-order executor is the pipelined dimension's
    // baseline: same session, same model shares, one round-trip per plan
    // step instead of one flight per DAG wave.
    let mut seq_ev = Evaluator::new(EvalPlan::compile(st, &theta, model.d));
    let mut per_query_rounds = Vec::new();
    let mut total = NetStats::default();
    for &bsz in &BATCHES {
        let qs = queries(st, bsz);
        let t0 = std::time::Instant::now();
        let (roots, stats) = private_eval_batch(&mut eng, st, &model, &qs, &theta);
        let wall = t0.elapsed().as_secs_f64();
        total = total + stats;
        assert_eq!(roots.len(), bsz);
        let (sroots, sstats) =
            seq_ev.eval_batch_sequential(&mut eng, &qs, &model.sum_w, model.leaf_theta.as_deref());
        assert_eq!(sroots.len(), bsz);
        assert!(
            stats.rounds < sstats.rounds,
            "{name} B={bsz}: pipelined {} rounds must beat sequential {}",
            stats.rounds,
            sstats.rounds
        );
        let speedup = sstats.rounds as f64 / stats.rounds as f64;
        let rpq = stats.rounds as f64 / bsz as f64;
        let mpq = stats.messages as f64 / bsz as f64;
        per_query_rounds.push(rpq);
        json.push(&format!("infer_batch_{name}"), &format!("rounds_per_query_b{bsz}"), rpq);
        json.push(&format!("infer_batch_{name}"), &format!("messages_per_query_b{bsz}"), mpq);
        json.push(&format!("infer_batch_{name}"), &format!("wall_s_b{bsz}"), wall);
        json.push(
            &format!("infer_batch_{name}"),
            &format!("sequential_rounds_b{bsz}"),
            sstats.rounds as f64,
        );
        json.push(
            &format!("infer_batch_{name}"),
            &format!("pipelined_round_speedup_b{bsz}"),
            speedup,
        );
        rows.push(vec![
            name.to_string(),
            bsz.to_string(),
            stats.rounds.to_string(),
            sstats.rounds.to_string(),
            format!("{speedup:.1}×"),
            format!("{rpq:.1}"),
            format!("{mpq:.1}"),
            format!("{:.2}", stats.virtual_time_s / bsz as f64),
            format!("{:.4}", wall),
        ]);
    }
    // the amortization claim this bench exists to chart: B=32 pays at most
    // a quarter of 32 sequential evaluations (actually ~1/B)
    assert!(
        per_query_rounds[2] * 4.0 <= per_query_rounds[0],
        "{name}: rounds/query at B=32 ({:.1}) not ≤ 1/4 of B=1 ({:.1})",
        per_query_rounds[2],
        per_query_rounds[0]
    );
    println!(
        "[infer_batch] {name}: {} queries total over {} rounds / {} messages",
        BATCHES.iter().sum::<usize>(),
        total.rounds,
        total.messages
    );
}

fn main() {
    let mut json = JsonSink::from_env_args();
    let mut rows = Vec::new();

    run("mini", &Structure::mini_demo(), &mut json, &mut rows);
    match spn_mpc::bench::try_load_structure("nltcs") {
        Some(st) => run("nltcs", &st, &mut json, &mut rows),
        None => println!("[infer_batch] nltcs artifact absent — mini structure only"),
    }

    println!(
        "{}",
        render_table(
            "Batched private inference — rounds amortization (Batched schedule)",
            &[
                "Structure",
                "B",
                "rounds",
                "seq rounds",
                "speedup",
                "rounds/q",
                "msgs/q",
                "virtual s/q",
                "wall s",
            ],
            &rows
        )
    );
    json.finish().expect("write --json output");
    println!("infer_batch OK");
}
