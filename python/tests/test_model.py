"""Layer-2 correctness: the jitted counts/logeval graphs vs the independent
recursive oracle, plus statistical sanity of learned weights (Eq. 2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, structures
from compile.kernels import ref

B = 128


def _data(st, b, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, size=(b, st["num_vars"])).astype(np.float32)


@pytest.mark.parametrize("name", ["toy", "nltcs", "jester"])
def test_counts_match_recursive(name):
    st = structures.build(name)
    data = _data(st, B, seed=11)
    mask = np.ones(B, dtype=np.float32)
    fn = model.build_counts_fn(st, B)
    got = np.asarray(fn(jnp.asarray(data), jnp.asarray(mask))[0])
    want = ref.counts_recursive(st, data)
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_counts_row_mask():
    st = structures.build("toy")
    data = _data(st, B, seed=5)
    mask = (np.random.default_rng(6).random(B) < 0.6).astype(np.float32)
    fn = model.build_counts_fn(st, B)
    got = np.asarray(fn(jnp.asarray(data), jnp.asarray(mask))[0])
    want = ref.counts_recursive(st, data[mask > 0.5])
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_counts_shard_additivity():
    """counts(shard A) + counts(shard B) == counts(A ∪ B) — the property that
    makes Eq. (3)'s horizontal partitioning work."""
    st = structures.build("toy")
    data = _data(st, 2 * B, seed=7)
    ones = np.ones(B, dtype=np.float32)
    fn = model.build_counts_fn(st, B)
    a = np.asarray(fn(jnp.asarray(data[:B]), jnp.asarray(ones))[0])
    b = np.asarray(fn(jnp.asarray(data[B:]), jnp.asarray(ones))[0])
    fn2 = model.build_counts_fn(st, 2 * B)
    both = np.asarray(fn2(jnp.asarray(data), jnp.asarray(np.ones(2 * B, np.float32)))[0])
    np.testing.assert_allclose(a + b, both, atol=1e-3)


def test_counts_den_equals_children_sum():
    """Completeness+selectivity: act count of a sum node equals the sum of
    its children's act counts (the paper's den = Σ num identity)."""
    st = structures.build("nltcs")
    data = _data(st, B, seed=13)
    fn = model.build_counts_fn(st, B)
    cnt = np.asarray(fn(jnp.asarray(data), jnp.asarray(np.ones(B, np.float32)))[0])
    nse = st["num_sum_edges"]
    den = {}
    num_sum = {}
    for k in range(nse):
        d = st["param_den"][k]
        den[d] = cnt[d]
        num_sum[d] = num_sum.get(d, 0.0) + cnt[st["param_num"][k]]
    for d in den:
        np.testing.assert_allclose(den[d], num_sum[d], atol=1e-3)


@pytest.mark.parametrize("name", ["toy", "nltcs"])
def test_logeval_matches_recursive(name):
    st = structures.build(name)
    data = _data(st, B, seed=3)
    params = model.initial_params(st, seed=1).astype(np.float32)
    marg = np.zeros(st["num_vars"], dtype=np.float32)
    fn = model.build_logeval_fn(st, B)
    got = np.asarray(fn(jnp.asarray(data), jnp.asarray(marg), jnp.asarray(params))[0])
    want = ref.logeval_recursive(st, data, params.astype(np.float64), marg)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_logeval_marginal_all_is_zero():
    """Marginalizing every variable must give S = 1 (log S = 0): the network
    is a normalized distribution when weights are normalized."""
    st = structures.build("toy")
    data = _data(st, B, seed=9)
    params = model.initial_params(st, seed=2).astype(np.float32)
    marg = np.ones(st["num_vars"], dtype=np.float32)
    fn = model.build_logeval_fn(st, B)
    got = np.asarray(fn(jnp.asarray(data), jnp.asarray(marg), jnp.asarray(params))[0])
    np.testing.assert_allclose(got, 0.0, atol=1e-4)


def test_logeval_sums_to_one_over_all_instances():
    """Σ_x S(x) = 1 over the full instance space (toy has 4 vars → 16 rows)."""
    st = structures.build("toy")
    nv = st["num_vars"]
    rows = np.array([[(i >> v) & 1 for v in range(nv)] for i in range(2 ** nv)],
                    dtype=np.float32)
    pad = np.zeros((128 - len(rows), nv), dtype=np.float32)
    data = np.concatenate([rows, pad])
    params = model.initial_params(st, seed=4).astype(np.float32)
    fn = model.build_logeval_fn(st, 128)
    lo = np.asarray(fn(jnp.asarray(data), jnp.asarray(np.zeros(nv, np.float32)),
                       jnp.asarray(params))[0])[: 2 ** nv]
    np.testing.assert_allclose(np.exp(lo).sum(), 1.0, rtol=1e-4)


def test_ml_weights_recover_generator():
    """Eq. (2) weights from counts over data sampled from the SPN converge to
    the generating weights (closed-form ML for selective SPNs)."""
    st = structures.build("toy")
    params = model.initial_params(st, seed=8)
    rng = np.random.default_rng(0)
    n = 4096
    # ancestral sampling from the toy SPN: pick root child by weight, then
    # gates determine the claimed vars; terminal leaves sample Bernoulli.
    nv = st["num_vars"]
    data = np.zeros((n, nv), dtype=np.float32)
    # brute-force: sample from the explicit distribution via logeval
    rows = np.array([[(i >> v) & 1 for v in range(nv)] for i in range(2 ** nv)],
                    dtype=np.float32)
    pad = np.zeros((128 - len(rows), nv), dtype=np.float32)
    fn = model.build_logeval_fn(st, 128)
    lo = np.asarray(fn(jnp.asarray(np.concatenate([rows, pad])),
                       jnp.asarray(np.zeros(nv, np.float32)),
                       jnp.asarray(params.astype(np.float32)))[0])[: 2 ** nv]
    probs = np.exp(lo); probs /= probs.sum()
    idx = rng.choice(2 ** nv, size=n, p=probs)
    data = rows[idx]

    cfn = model.build_counts_fn(st, n)
    cnt = np.asarray(cfn(jnp.asarray(data), jnp.asarray(np.ones(n, np.float32)))[0])
    # sum-edge weights
    for g in st["sum_groups"]:
        nums = np.array([cnt[st["param_num"][p]] for p in g])
        den = cnt[st["param_den"][g[0]]]
        if den < 100:
            continue
        w_hat = nums / den
        w_true = params[g]
        np.testing.assert_allclose(w_hat, w_true, atol=0.08)
