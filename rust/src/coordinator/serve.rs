//! The standing-service coordinator: train once, then hand the live MPC
//! session to the micro-batching scheduler of [`crate::net::serve`]
//! (DESIGN.md §Serving layer).
//!
//! This is the `spn-mpc serve` entrypoint's core: the same generic
//! [`MpcSession`] drives training and then serving, so the weight shares
//! never leave the members — the scheduler evaluates client queries over
//! exactly the `DataId` handles training produced. The plan is compiled
//! once ([`EvalPlan::compile`]) and one persistent [`Evaluator`] answers
//! every scheduler tick; per-client [`crate::net::NetStats`] deltas ride
//! back in each response.
//!
//! `--shards S` scales this out through [`train_and_serve_fleet`]: S
//! sessions are **replicated by deterministic replay** — every session is
//! created with the same seed and trained on the same counts, so each
//! member's share store is byte-identical across shards *without any
//! share ever moving between sessions* (exporting shares through the
//! manager would let it reconstruct the secrets). Each shard's evaluator
//! is then confined to its [`TagStripe`] and the fleet front-end
//! ([`crate::net::fleet::serve_fleet`]) routes queries across them.
//!
//! The same replay contract powers **respawn** (DESIGN.md §Fleet): a
//! [`RespawnBuilder`] turns "make me a fresh session for shard s" into a
//! full [`RespawnFactory`] by re-running the identical training schedule
//! on the new session and confining its evaluator to the next
//! *generation* of the shard's tag stripe — so a revived shard's shares
//! match the fleet byte-for-byte while its divpub tags can never collide
//! with the dead generation's burned ones.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::train::{train, SharedModel, TrainConfig, TrainReport};
use crate::net::fault::FaultPlan;
use crate::net::fleet::{
    serve_fleet, FleetOptions, FleetReport, FleetShard, RespawnFactory, RespawnShard, ShardSever,
};
use crate::net::serve::{serve, ServeConfig, ServeReport};
use crate::protocols::session::MpcSession;
use crate::spn::plan::{EvalPlan, Evaluator, TagStripe};
use crate::spn::structure::Structure;

/// How to rebuild one shard of the fleet from scratch: the
/// transport-specific half of respawn. [`train_and_serve_fleet`] supplies
/// the training-replay half, turning this into a [`RespawnFactory`].
pub struct RespawnBuilder<'f, S: MpcSession> {
    /// Build a fresh, untrained session (plus its `kill-shard` transport
    /// switch, if any) for shard `s`. Called on the dead shard's
    /// scheduler thread while survivors keep serving.
    pub build: Box<dyn Fn(usize) -> Result<(S, Option<ShardSever>)> + Send + Sync + 'f>,
    /// Teardown for replacement sessions; `dead = true` means the
    /// replacement itself died, so reap lossily. `Arc` (not `Box`):
    /// one clone rides inside every [`RespawnShard`] as its `reap` hook,
    /// which must own its callee.
    pub reap: Arc<dyn Fn(S, bool) + Send + Sync>,
}

/// Serve an already-trained model: compile its plan, build the persistent
/// [`Evaluator`], and run the scheduler until shutdown. The session stays
/// usable afterwards (TCP callers still own its `shutdown()`).
pub fn serve_model<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    default_leaf_theta: &[f64],
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let plan = EvalPlan::compile(st, default_leaf_theta, model.d);
    let mut ev = Evaluator::new(plan);
    serve(sess, &mut ev, &model.sum_w, model.leaf_theta.as_deref(), listener, cfg)
}

/// Train on the parties' local counts, then serve the learned shares over
/// the same session — the full `spn-mpc serve` pipeline.
#[allow(clippy::too_many_arguments)]
pub fn train_and_serve<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    shard_counts: &[Vec<u64>],
    rows_total: u64,
    tcfg: &TrainConfig,
    default_leaf_theta: &[f64],
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<(ServeReport, TrainReport)> {
    let (model, treport) = train(sess, st, shard_counts, rows_total, tcfg);
    let report = serve_model(sess, st, &model, default_leaf_theta, listener, cfg)?;
    Ok((report, treport))
}

/// Train every session identically (deterministic replay replication),
/// stripe the tag space, and serve the fleet until shutdown.
///
/// `severs[s]`, when present, is installed as shard s's `kill-shard`
/// transport switch (TCP fleets pass `TcpSession::sever_handle` closures;
/// Sim fleets pass an empty vec). The sessions stay alive afterwards: the
/// caller shuts each down, using `TcpSession::shutdown_lossy` for shards
/// the returned [`FleetReport`] marks dead **or respawned** (a respawn
/// orphans the original session's transport).
///
/// `respawn`, when present, arms self-healing: each death triggers a
/// fresh `build(s)` + identical training replay + evaluator confinement
/// to the next generation sub-stripe. `probe_interval` arms idle health
/// probes; `fault_plan` injects a deterministic chaos schedule.
// `S: 'static`: the per-instance reap hook rides inside `RespawnShard`
// as a `Box<dyn FnOnce(S, bool) + Send>` (an owning, `'static` box), so
// the session type itself must not borrow.
#[allow(clippy::too_many_arguments)]
pub fn train_and_serve_fleet<S: MpcSession + Send + 'static>(
    sessions: &mut [S],
    st: &Structure,
    shard_counts: &[Vec<u64>],
    rows_total: u64,
    tcfg: &TrainConfig,
    default_leaf_theta: &[f64],
    listener: TcpListener,
    cfg: &ServeConfig,
    severs: Vec<Option<ShardSever>>,
    respawn: Option<RespawnBuilder<'_, S>>,
    probe_interval: Option<Duration>,
    fault_plan: Option<FaultPlan>,
) -> Result<(FleetReport, TrainReport)> {
    let nshards = sessions.len();
    if nshards == 0 {
        bail!("a fleet needs at least one session");
    }
    let mut severs = severs;
    if severs.is_empty() {
        severs.resize_with(nshards, || None);
    }
    if severs.len() != nshards {
        bail!("got {} sever handles for {nshards} shards", severs.len());
    }
    // identical replay on every session ⇒ byte-identical share stores
    let mut models: Vec<SharedModel> = Vec::with_capacity(nshards);
    let mut treport = None;
    for sess in sessions.iter_mut() {
        let (model, r) = train(sess, st, shard_counts, rows_total, tcfg);
        treport.get_or_insert(r);
        models.push(model);
    }
    let plan = EvalPlan::compile(st, default_leaf_theta, models[0].d);
    let proto = Evaluator::new(plan);
    let mut shards: Vec<FleetShard<'_, S>> = Vec::with_capacity(nshards);
    for (s, ((sess, model), sever)) in
        sessions.iter_mut().zip(&models).zip(severs).enumerate()
    {
        let ev = proto.clone_into_session(sess, TagStripe::new(s, nshards));
        shards.push(FleetShard {
            sess,
            ev,
            sum_w: model.sum_w.clone(),
            learned_theta: model.leaf_theta.clone(),
            sever,
        });
    }
    // The respawn factory: transport-specific build, then the same
    // deterministic replay the gen-0 sessions got, confined to the
    // generation sub-stripe the supervisor hands us.
    let proto_ref = &proto;
    let factory: Option<RespawnFactory<'_, S>> = respawn.map(|rb| {
        let f: RespawnFactory<'_, S> = Box::new(move |s: usize, stripe: TagStripe| {
            let (mut sess, sever) = (rb.build)(s)?;
            let (model, _) = train(&mut sess, st, shard_counts, rows_total, tcfg);
            let ev = proto_ref.clone_into_session(&mut sess, stripe);
            let reap = rb.reap.clone();
            Ok(RespawnShard {
                sess,
                ev,
                sum_w: model.sum_w,
                learned_theta: model.leaf_theta,
                sever,
                reap: Box::new(move |sess, dead| reap(sess, dead)),
            })
        });
        f
    });
    let opts = FleetOptions { probe_interval, respawn: factory, fault_plan };
    let report = serve_fleet(shards, listener, cfg, opts)?;
    Ok((report, treport.expect("nshards ≥ 1")))
}
