//! spn-lint — the protocol-contract source pass (DESIGN.md §Static
//! analysis).
//!
//! The MPC layer has contracts a type checker cannot see: divpub mask
//! discipline, tag-handle hygiene, the dense-store data-plane rule, the
//! no-panic rule in the serve layer, wire-layout agreement across the
//! framing modules, and design-doc references that must keep resolving.
//! `CheckedSession` enforces the dynamic half at run time; this tool is
//! the static half — a dependency-free line scanner (no syn, no crates.io)
//! that runs in CI as a blocking job.
//!
//! Lints:
//!
//! * **L001** — untagged `divpub_vec(` call outside the division/Newton
//!   core (and the session/engine/sanitizer plumbing, and k-means, whose
//!   training-style divisions are stream-ordered by design). Inference
//!   paths must use `divpub_vec_tagged` so the ±1 rounding is a function
//!   of the tag, not of evaluation order.
//! * **L002** — `.reserve_tags(..);` whose returned base is discarded: a
//!   reservation nobody addresses is either dead traffic or an off-by-one
//!   waiting to alias someone else's tags.
//! * **L003** — `HashMap`/`BTreeMap` in the data plane (`protocols/
//!   engine.rs`, `sharing/shamir.rs`, `net/tcp*`): share stores and
//!   hot-path scratch are dense slabs (DESIGN.md §Data plane). Memo
//!   caches may opt out with `lint:allow(L003)`.
//! * **L004** — `.unwrap()`/`.expect(` in `net/serve.rs`/`net/fleet.rs`:
//!   a panicking front-end thread poisons locks for every client. Use the
//!   poison-recovering helpers; invariant-guarded cases take
//!   `lint:allow(L004)` with a justification.
//! * **L005** — the `wire-layout: vN` markers in `net/tcp.rs` and
//!   `net/tcp_session.rs` must agree with each other and with
//!   `WIRE_LAYOUT_VERSION` in `net/wire.rs`, and both framing modules
//!   must carry a marker at all.
//! * **L006** — every `DESIGN.md §X` reference in source comments must
//!   resolve to a heading in DESIGN.md (prefix-tolerant both ways, so
//!   line-wrapped refs and trailing words still match).
//! * **L007** — `PlanStep::` matched or constructed outside `spn/plan.rs`:
//!   the step-dependency DAG (waves, qoffs, pass-through aliases) is
//!   compiled once and executed through the plan's own schedule; code that
//!   re-derives scheduling from raw plan internals elsewhere will silently
//!   disagree with the wave order the round scheduler and the tag ledger
//!   rely on (DESIGN.md §Round scheduler).
//! * **L008** — bare `thread::sleep` in `net/` outside `net/backoff.rs`:
//!   fixed naked sleeps in the transport/serve layer are unbounded stalls
//!   with no jitter and no cap — every wait goes through
//!   `backoff::pause` or a `Backoff` schedule so retry storms stay
//!   deterministic and bounded (DESIGN.md §Fleet).
//! * **L009** — raw `% p` modular reduction (`% p`, `% f.p`, `% self.p`,
//!   …) in `protocols/` or `sharing/` outside `field.rs`: every reduction
//!   routes through the `Field` kernel (`reduce`/`mul`/`dot`/the
//!   Montgomery entry points) so the deferred-reduction and
//!   Montgomery-domain invariants live in exactly one file (DESIGN.md
//!   §Field kernel). Divisor math like `% d` is untouched — the lint only
//!   matches a modulus token that *is* `p` or ends in `.p`.
//!
//! Suppression: `lint:allow(L00X)` on the flagged line or the line
//! immediately above. Lines after a file's literal `#[cfg(test)]` marker
//! are not scanned (test modules exercise forbidden shapes on purpose);
//! `#[cfg(any(test, ...))]` mid-file attributes do NOT end the scan.
//!
//! `spn-lint [--root DIR]` scans `DIR/rust/src` against `DIR/DESIGN.md`
//! and exits 1 on findings. `spn-lint --self-check [--root DIR]` scans
//! the committed fixtures instead and verifies every lint still fires
//! where it must (and nowhere in `clean.rs`) — the linter's own test.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Clone, Debug)]
struct Finding {
    file: String,
    line: usize,
    lint: &'static str,
    msg: String,
}

/// One `wire-layout: vN` marker or `WIRE_LAYOUT_VERSION` definition.
#[derive(Clone, Debug)]
struct WireMark {
    file: String,
    line: usize,
    version: u64,
}

/// One `DESIGN.md §X` reference found in a source comment.
#[derive(Clone, Debug)]
struct DesignRef {
    file: String,
    line: usize,
    section: String,
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

/// Extract `§...` references from a text segment. A reference runs from a
/// `§` to the first structural stop character (or end of line); trailing
/// sentence periods are stripped. Headings are matched prefix-tolerantly,
/// so a reference truncated by a stop char or extended by trailing words
/// still resolves.
fn capture_refs(seg: &str) -> Vec<String> {
    let chars: Vec<char> = seg.chars().collect();
    let mut refs = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] != '§' {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        let mut buf = String::new();
        while j < chars.len() {
            let c = chars[j];
            if matches!(c, ')' | ',' | ';' | '"' | ']' | '(' | '`' | '§') {
                break;
            }
            buf.push(c);
            j += 1;
        }
        let r = buf.trim().trim_end_matches('.').trim();
        if !r.is_empty() {
            refs.push(r.to_string());
        }
        i = j.max(i + 1);
    }
    refs
}

/// Strip a comment prefix (`//!`, `///`, `//`, `*`) from a line, for
/// reading the continuation of a wrapped `DESIGN.md\n§X` reference.
fn strip_comment_prefix(line: &str) -> &str {
    let t = line.trim_start();
    for p in ["//!", "///", "//", "*"] {
        if let Some(rest) = t.strip_prefix(p) {
            return rest.trim_start();
        }
    }
    t
}

/// Parse `DESIGN.md` headings: every markdown heading line containing `§`.
fn design_headings(text: &str) -> Vec<String> {
    text.lines()
        .filter(|l| l.trim_start().starts_with('#'))
        .filter_map(|l| {
            let idx = l.find('§')?;
            let h = l[idx + '§'.len_utf8()..].trim().trim_end_matches('.').trim();
            if h.is_empty() {
                None
            } else {
                Some(h.to_string())
            }
        })
        .collect()
}

fn ref_resolves(r: &str, headings: &[String]) -> bool {
    headings
        .iter()
        .any(|h| r == h || r.starts_with(&format!("{h} ")) || h.starts_with(&format!("{r} ")))
}

fn parse_digits_at(s: &str) -> Option<u64> {
    let digits: String = s.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// L009 matcher: a binary ` % ` whose right operand token is the field
/// modulus — exactly `p`, or a path ending in `.p` (`f.p`, `self.p`,
/// `c.f.p`). Divisors (`% d`), counters (`% n`, `% k.min(..)`) and every
/// other modulus shape pass. The codebase is rustfmt'd, so binary `%`
/// always appears space-padded; `%` in strings/format args never is
/// followed by ` `.
fn raw_mod_p(line: &str) -> bool {
    let mut rest = line;
    while let Some(idx) = rest.find(" % ") {
        rest = &rest[idx + 3..];
        let tok: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_' || *c == '.')
            .collect();
        if tok == "p" || tok.ends_with(".p") {
            return true;
        }
    }
    false
}

/// Scan one file: emit per-line findings and collect the cross-file
/// L005/L006 raw material.
fn scan_file(
    disp: &str,
    text: &str,
    findings: &mut Vec<Finding>,
    wire_marks: &mut Vec<WireMark>,
    design_refs: &mut Vec<DesignRef>,
) {
    let lines: Vec<&str> = text.lines().collect();
    let l001_allowed = ["protocols/division.rs",
        "protocols/newton.rs",
        "protocols/session.rs",
        "protocols/engine.rs",
        "protocols/checked.rs"]
    .iter()
    .any(|s| disp.ends_with(s))
        || disp.contains("kmeans");
    let l003_applies = disp.ends_with("protocols/engine.rs")
        || disp.ends_with("sharing/shamir.rs")
        || disp.contains("net/tcp");
    let l004_applies = disp.ends_with("net/serve.rs") || disp.ends_with("net/fleet.rs");
    let l008_applies = disp.contains("net/") && !disp.ends_with("net/backoff.rs");
    let l009_applies = (disp.contains("protocols/") || disp.contains("sharing/"))
        && !disp.ends_with("field.rs");
    let l007_allowed = disp.ends_with("spn/plan.rs");
    let l005_file = disp.ends_with("net/tcp.rs")
        || disp.ends_with("net/tcp_session.rs")
        || disp.ends_with("net/wire.rs");

    for (i, &line) in lines.iter().enumerate() {
        let trimmed = line.trim();
        if trimmed == "#[cfg(test)]" {
            break; // the rest of the file is its test module
        }
        let lineno = i + 1;
        let allowed = |lint: &str| {
            let marker = format!("lint:allow({lint})");
            line.contains(&marker) || (i > 0 && lines[i - 1].contains(&marker))
        };

        // L005 markers and L006 references live in comments, so collect
        // them before the comment-line skip.
        if l005_file {
            if let Some(p) = line.find("wire-layout: v") {
                if let Some(v) = parse_digits_at(&line[p + "wire-layout: v".len()..]) {
                    wire_marks.push(WireMark { file: disp.to_string(), line: lineno, version: v });
                }
            }
            if let Some(p) = line.find("WIRE_LAYOUT_VERSION: u32 = ") {
                if let Some(v) =
                    parse_digits_at(&line[p + "WIRE_LAYOUT_VERSION: u32 = ".len()..])
                {
                    wire_marks.push(WireMark { file: disp.to_string(), line: lineno, version: v });
                }
            }
        }
        if !allowed("L006") {
            if let Some(p) = line.find("DESIGN.md") {
                for r in capture_refs(&line[p + "DESIGN.md".len()..]) {
                    design_refs.push(DesignRef {
                        file: disp.to_string(),
                        line: lineno,
                        section: r,
                    });
                }
            }
            if trimmed.ends_with("DESIGN.md") && i + 1 < lines.len() {
                let cont = strip_comment_prefix(lines[i + 1]);
                if cont.starts_with('§') {
                    for r in capture_refs(cont) {
                        design_refs.push(DesignRef {
                            file: disp.to_string(),
                            line: lineno + 1,
                            section: r,
                        });
                    }
                }
            }
        }

        if trimmed.starts_with("//") {
            continue; // code lints don't apply to comment lines
        }

        if !l001_allowed
            && line.contains("divpub_vec(")
            && !line.contains("fn divpub_vec")
            && !allowed("L001")
        {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L001",
                msg: "untagged divpub_vec outside the division/newton core — inference \
                      paths must use divpub_vec_tagged (order-invariant masks, \
                      DESIGN.md §Evaluation Plan)"
                    .to_string(),
            });
        }
        if line.contains(".reserve_tags(")
            && trimmed.ends_with(';')
            && !line.contains("let ")
            && !line.contains('=')
            && !allowed("L002")
        {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L002",
                msg: "reserve_tags result discarded — an unaddressed reservation is dead \
                      tag space or an aliasing bug; bind the returned base"
                    .to_string(),
            });
        }
        if l003_applies
            && (line.contains("HashMap") || line.contains("BTreeMap"))
            && !allowed("L003")
        {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L003",
                msg: "HashMap/BTreeMap in the data plane — share stores and hot-path \
                      scratch are dense slabs (DESIGN.md §Data plane); memo caches may \
                      use lint:allow(L003)"
                    .to_string(),
            });
        }
        if !l007_allowed && line.contains("PlanStep::") && !allowed("L007") {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L007",
                msg: "PlanStep internals used outside spn/plan.rs — execute through the \
                      compiled schedule (waves, qoffs, pass-through aliases); re-deriving \
                      scheduling elsewhere desyncs from the round scheduler and the tag \
                      ledger (DESIGN.md §Round scheduler)"
                    .to_string(),
            });
        }
        if l004_applies
            && (line.contains(".unwrap()") || line.contains(".expect("))
            && !allowed("L004")
        {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L004",
                msg: "panicking unwrap/expect in the serve layer — a dead front-end \
                      thread poisons shared state for every client; use the \
                      poison-recovering lock helpers or lint:allow(L004) with an \
                      invariant justification"
                    .to_string(),
            });
        }
        if l008_applies && line.contains("thread::sleep") && !allowed("L008") {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L008",
                msg: "bare thread::sleep in the net layer — waits go through \
                      backoff::pause or a Backoff schedule (capped, jittered, \
                      deterministic; DESIGN.md §Fleet) so a retry loop can never \
                      stall unbounded or stampede"
                    .to_string(),
            });
        }
        if l009_applies && raw_mod_p(line) && !allowed("L009") {
            findings.push(Finding {
                file: disp.to_string(),
                line: lineno,
                lint: "L009",
                msg: "raw `% p` reduction outside the field kernel — route through \
                      Field (reduce / mul / dot / the Montgomery entry points, \
                      DESIGN.md §Field kernel) so reduction invariants live in one \
                      file; divisor math (`% d`) is exempt"
                    .to_string(),
            });
        }
    }
}

/// Cross-file L005: every scanned framing module must carry a wire-layout
/// marker and all markers must agree on one version.
fn check_wire_layout(scanned: &[String], marks: &[WireMark], findings: &mut Vec<Finding>) {
    for suffix in ["net/tcp.rs", "net/tcp_session.rs", "net/wire.rs"] {
        for f in scanned.iter().filter(|f| f.ends_with(suffix)) {
            if !marks.iter().any(|m| &m.file == f) {
                findings.push(Finding {
                    file: f.clone(),
                    line: 1,
                    lint: "L005",
                    msg: "framing module carries no wire-layout marker \
                          (`wire-layout: vN` or WIRE_LAYOUT_VERSION)"
                        .to_string(),
                });
            }
        }
    }
    let versions: BTreeSet<u64> = marks.iter().map(|m| m.version).collect();
    if versions.len() > 1 {
        let all: Vec<String> = versions.iter().map(|v| format!("v{v}")).collect();
        for m in marks {
            findings.push(Finding {
                file: m.file.clone(),
                line: m.line,
                lint: "L005",
                msg: format!(
                    "wire-layout v{} disagrees with other framing modules (saw {}) — \
                     bump every marker and WIRE_LAYOUT_VERSION together",
                    m.version,
                    all.join(", ")
                ),
            });
        }
    }
}

fn check_design_refs(refs: &[DesignRef], headings: &[String], findings: &mut Vec<Finding>) {
    for r in refs {
        if !ref_resolves(&r.section, headings) {
            findings.push(Finding {
                file: r.file.clone(),
                line: r.line,
                lint: "L006",
                msg: format!(
                    "`DESIGN.md §{}` does not resolve to any DESIGN.md heading — \
                     fix the reference or add the section",
                    r.section
                ),
            });
        }
    }
}

/// Lint every `.rs` file under `dir` against the headings of `design_md`.
/// Returns the findings and the number of files scanned.
fn lint_tree(dir: &Path, design_md: &Path) -> (Vec<Finding>, usize) {
    let mut files = Vec::new();
    walk(dir, &mut files);
    let mut findings = Vec::new();
    let mut wire_marks = Vec::new();
    let mut design_refs = Vec::new();
    let mut scanned = Vec::new();
    for p in &files {
        let disp = p.to_string_lossy().replace('\\', "/");
        let Ok(text) = fs::read_to_string(p) else {
            findings.push(Finding {
                file: disp.clone(),
                line: 1,
                lint: "L000",
                msg: "unreadable source file".to_string(),
            });
            continue;
        };
        scanned.push(disp.clone());
        scan_file(&disp, &text, &mut findings, &mut wire_marks, &mut design_refs);
    }
    check_wire_layout(&scanned, &wire_marks, &mut findings);
    match fs::read_to_string(design_md) {
        Ok(text) => check_design_refs(&design_refs, &design_headings(&text), &mut findings),
        Err(_) => {
            if !design_refs.is_empty() {
                findings.push(Finding {
                    file: design_md.to_string_lossy().into_owned(),
                    line: 1,
                    lint: "L006",
                    msg: format!(
                        "{} DESIGN.md §-references found but DESIGN.md is unreadable",
                        design_refs.len()
                    ),
                });
            }
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    (findings, scanned.len())
}

fn print_findings(findings: &[Finding]) {
    for f in findings {
        println!("{}:{}: {} {}", f.file, f.line, f.lint, f.msg);
    }
}

fn run(root: &Path) -> ExitCode {
    let (findings, nfiles) = lint_tree(&root.join("rust/src"), &root.join("DESIGN.md"));
    print_findings(&findings);
    if findings.is_empty() {
        println!("spn-lint: {nfiles} files scanned, clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("spn-lint: {} finding(s) in {nfiles} files", findings.len());
        ExitCode::FAILURE
    }
}

/// Prove every lint still fires on its committed fixture (and that the
/// clean fixture stays clean). The fixture tree mimics the path-suffix
/// rules, so this also pins the applies-to routing.
fn self_check(root: &Path) -> ExitCode {
    let fixtures = root.join("rust/tools/spn-lint/fixtures");
    if !fixtures.is_dir() {
        eprintln!("spn-lint --self-check: no fixtures at {}", fixtures.display());
        return ExitCode::FAILURE;
    }
    let (findings, nfiles) = lint_tree(&fixtures, &root.join("DESIGN.md"));
    let mut failed = false;
    let expect: &[(&str, &str)] = &[
        ("L001", "l001.rs"),
        ("L002", "l002.rs"),
        ("L003", "net/tcp_l003.rs"),
        ("L004", "net/serve.rs"),
        ("L005", "net/tcp_session.rs"),
        ("L006", "l006.rs"),
        ("L007", "l007.rs"),
        ("L008", "net/fleet.rs"),
        ("L009", "protocols/l009.rs"),
    ];
    for (lint, file) in expect {
        if !findings.iter().any(|f| f.lint == *lint && f.file.ends_with(file)) {
            eprintln!("self-check FAIL: {lint} did not fire in fixture {file}");
            failed = true;
        }
    }
    // clean.rs holds decoys (comments, fn defs, suppressed calls, test-module
    // code): any finding there means a skip rule broke.
    for f in findings.iter().filter(|f| f.file.ends_with("clean.rs")) {
        eprintln!("self-check FAIL: clean fixture flagged: {}:{}: {} {}", f.file, f.line, f.lint, f.msg);
        failed = true;
    }
    // l001.rs also carries decoys; exactly one real call may fire.
    let l001 = findings.iter().filter(|f| f.lint == "L001").count();
    if l001 != 1 {
        eprintln!("self-check FAIL: expected exactly 1 L001 finding, got {l001}");
        failed = true;
    }
    // l007.rs carries a comment decoy and a suppressed arm, and
    // fixtures/spn/plan.rs is the allowed path: exactly one L007 total
    // proves both the suppression and the path routing.
    let l007 = findings.iter().filter(|f| f.lint == "L007").count();
    if l007 != 1 {
        eprintln!("self-check FAIL: expected exactly 1 L007 finding, got {l007}");
        failed = true;
    }
    // fixtures/net/fleet.rs carries one firing sleep plus a suppressed
    // decoy, and fixtures/net/backoff.rs is the allowed path: exactly one
    // L008 total pins both the suppression and the path carve-out.
    let l008 = findings.iter().filter(|f| f.lint == "L008").count();
    if l008 != 1 {
        eprintln!("self-check FAIL: expected exactly 1 L008 finding, got {l008}");
        failed = true;
    }
    // fixtures/protocols/l009.rs carries one firing `% f.p` plus a
    // suppressed decoy, a comment decoy, a `% d` divisor decoy and a
    // test-module line; exactly one L009 total pins the token matcher,
    // the suppression and the field.rs/test-module carve-outs.
    let l009 = findings.iter().filter(|f| f.lint == "L009").count();
    if l009 != 1 {
        eprintln!("self-check FAIL: expected exactly 1 L009 finding, got {l009}");
        failed = true;
    }
    if failed {
        print_findings(&findings);
        eprintln!("spn-lint --self-check: FAILED ({nfiles} fixture files)");
        ExitCode::FAILURE
    } else {
        println!(
            "spn-lint --self-check: all {} lints fire on fixtures, clean fixture clean \
             ({nfiles} files, {} findings)",
            expect.len(),
            findings.len()
        );
        ExitCode::SUCCESS
    }
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut selfcheck = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => {
                    eprintln!("--root needs a path");
                    return ExitCode::FAILURE;
                }
            },
            "--self-check" => selfcheck = true,
            "--help" | "-h" => {
                println!(
                    "spn-lint [--root DIR] [--self-check]\n\
                     lints DIR/rust/src (L001–L009) against DIR/DESIGN.md;\n\
                     --self-check runs the linter over its committed fixtures instead"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("unknown flag {other} (try --help)");
                return ExitCode::FAILURE;
            }
        }
    }
    if selfcheck {
        self_check(&root)
    } else {
        run(&root)
    }
}
