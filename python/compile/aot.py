"""AOT entry point: lower the Layer-2 graphs to HLO *text* artifacts.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos and NOT
``.serialize()`` — is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per dataset structure we emit:

  artifacts/<name>.structure.json   — layered structure shared with rust
  artifacts/<name>.counts.hlo.txt   — (X:(B,nv), row_mask:(B,)) -> counts
  artifacts/<name>.eval.hlo.txt     — (X:(B,nv), marg:(nv,), params:(P,)) -> logS
  artifacts/manifest.json           — batch size, shapes, file list

``make artifacts`` is a no-op when inputs are unchanged (mtime-based, via
the Makefile); python never runs on the rust request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, structures

BATCH = 512


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides large constants as
    # `constant({...})`, which xla_extension 0.5.1's text parser silently
    # reads back as zeros — the baked-in structure matrices would vanish.
    # Print a short-parsable form with large constants materialized.
    opts = xc._xla.HloPrintOptions.short_parsable()
    opts.print_large_constants = True
    return comp.get_hlo_module().to_string(opts)


def emit(name: str, outdir: str, batch: int = BATCH) -> dict:
    st = structures.build(name)
    structures.save(st, os.path.join(outdir, f"{name}.structure.json"))

    nv = st["num_vars"]
    counts_fn = model.build_counts_fn(st, batch)
    xs = jax.ShapeDtypeStruct((batch, nv), jnp.float32)
    ms = jax.ShapeDtypeStruct((batch,), jnp.float32)
    low = jax.jit(counts_fn).lower(xs, ms)
    counts_path = os.path.join(outdir, f"{name}.counts.hlo.txt")
    with open(counts_path, "w") as f:
        f.write(to_hlo_text(low))

    eval_fn = model.build_logeval_fn(st, batch)
    mg = jax.ShapeDtypeStruct((nv,), jnp.float32)
    ps = jax.ShapeDtypeStruct((st["num_params"],), jnp.float32)
    low = jax.jit(eval_fn).lower(xs, mg, ps)
    eval_path = os.path.join(outdir, f"{name}.eval.hlo.txt")
    with open(eval_path, "w") as f:
        f.write(to_hlo_text(low))

    return dict(
        name=name,
        batch=batch,
        num_vars=nv,
        num_params=st["num_params"],
        counts_out=st["total_nodes"] + st["layer_widths"][0],
        structure=f"{name}.structure.json",
        counts_hlo=f"{name}.counts.hlo.txt",
        eval_hlo=f"{name}.eval.hlo.txt",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--datasets", default="toy,nltcs,jester,baudio,bnetflix")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest = {"batch": args.batch, "datasets": {}}
    for name in args.datasets.split(","):
        name = name.strip()
        info = emit(name, outdir, args.batch)
        manifest["datasets"][name] = info
        print(f"emitted {name}: params={info['num_params']} "
              f"counts_out={info['counts_out']}")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {outdir}/manifest.json")


if __name__ == "__main__":
    main()
