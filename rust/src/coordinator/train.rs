//! Private parameter learning (§3.1 + §3.4): the exact secret-sharing path.
//!
//! Inputs: each party's *local* counts vector over its data shard (computed
//! by the PJRT runtime from the AOT'd counts artifact, or by the native
//! mirror `spn::eval::counts`).  Horizontal partitioning makes these counts
//! additive contributions to the global counts — exactly Eq. (3).
//!
//! Per sum node i (weights share a denominator):
//!   1. SQ2PQ the parties' local `den_i` and per-edge `num_ij` into
//!      polynomial shares;
//!   2. +1 (Laplace) smoothing of the denominator — public linear op,
//!      guarantees the Newton precondition `b ≥ 1`;
//!   3. one Newton inversion `[I] ≈ d·E/den` (§3.4);
//!   4. per edge: secure multiply `[num]·[I]`, then truncate by E.
//!
//! The coordinator runs those four stages *vectorized across every sum
//! node at once*: one SQ2PQ exercise carries all denominators, one all
//! numerators, and `divide_many` advances every node's Newton inversion in
//! lockstep ([`crate::protocols::newton::newton_inverse_vec`]), so the
//! round count of a training run is one Newton schedule deep — not
//! `#sum-nodes ×` it. Under the paper's `PerOp` accounting the
//! message/byte totals of Tables 2–3 are unchanged by this batching (a
//! k-wide exercise costs exactly k scalar exercises there); the win shows
//! up in rounds and in the `Batched`/TCP deployments.
//!
//! The result is *shares* of the d-scaled weights — the paper's training
//! deliverable. Reveal (for verification/deployment) is a separate step so
//! Tables 2–3 accounting matches training only.

use crate::protocols::division::{divide_many, DivisionConfig};
use crate::protocols::engine::{DataId, Engine};
use crate::protocols::session::{MpcSession, SessionPhase};
use crate::net::NetStats;
use crate::spn::learn::SMOOTH;
use crate::spn::structure::Structure;

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub division: DivisionConfig,
    /// Also learn leaf Bernoulli parameters privately (extension beyond the
    /// paper, which trains sum weights only — §1 "weights for the sum nodes").
    pub learn_leaves: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { division: DivisionConfig::default(), learn_leaves: false }
    }
}

/// Shares of the learned model held by the members.
pub struct SharedModel {
    /// d-scaled sum-edge weights, indexed by param id (0..num_sum_edges).
    pub sum_w: Vec<DataId>,
    /// d-scaled leaf thetas (only when learn_leaves).
    pub leaf_theta: Option<Vec<DataId>>,
    pub d: u128,
}

/// Costs and diagnostics of a training run.
#[derive(Clone, Copy, Debug)]
pub struct TrainReport {
    pub stats: NetStats,
    pub divisions: usize,
    pub sum_edges: usize,
}

/// The shared Eq.-(3) pipeline for a batch of denominator groups:
/// `groups[g]` is `(denominator count index, numerator count indices)`.
/// One SQ2PQ exercise carries every group's denominator, one lin_vec
/// applies the +SMOOTH (Laplace) smoothing — guaranteeing the Newton
/// precondition `b ≥ 1` — one SQ2PQ carries every numerator
/// (group-major), and [`divide_many`] runs all inversions in lockstep.
/// Returns one d-scaled weight vector per group, in group order.
fn batched_count_divide<S: MpcSession>(
    sess: &mut S,
    shard_counts: &[Vec<u64>],
    groups: &[(usize, Vec<usize>)],
    bmax: u128,
    cfg: &DivisionConfig,
) -> Vec<Vec<DataId>> {
    let n = shard_counts.len();
    let den_locals: Vec<Vec<u128>> = (0..n)
        .map(|i| groups.iter().map(|&(di, _)| shard_counts[i][di] as u128).collect())
        .collect();
    let dens_raw = sess.sq2pq_vec(&den_locals);
    let smooth_ops: Vec<(i128, Vec<(i128, DataId)>)> =
        dens_raw.iter().map(|&id| (SMOOTH as i128, vec![(1, id)])).collect();
    let dens = sess.lin_vec(&smooth_ops);

    let num_locals: Vec<Vec<u128>> = (0..n)
        .map(|i| {
            groups
                .iter()
                .flat_map(|(_, nis)| nis.iter().map(move |&ni| shard_counts[i][ni] as u128))
                .collect()
        })
        .collect();
    let nums = sess.sq2pq_vec(&num_locals);

    let mut div_groups: Vec<(DataId, Vec<DataId>)> = Vec::with_capacity(groups.len());
    let mut off = 0;
    for ((_, nis), &den) in groups.iter().zip(&dens) {
        div_groups.push((den, nums[off..off + nis.len()].to_vec()));
        off += nis.len();
    }
    divide_many(sess, &div_groups, bmax, cfg)
}

/// Run private training over any [`MpcSession`] backend — the in-process
/// simulation ([`Engine`]) or real TCP parties. `shard_counts[i]` is party
/// i's local counts vector (length `st.counts_len()`), `rows_total` the
/// public dataset size bound.
pub fn train<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    shard_counts: &[Vec<u64>],
    rows_total: u64,
    cfg: &TrainConfig,
) -> (SharedModel, TrainReport) {
    let n = sess.n();
    assert_eq!(shard_counts.len(), n);
    for c in shard_counts {
        assert_eq!(c.len(), st.counts_len());
    }
    let before = sess.stats();
    // Training uses the stream-order untagged divpub throughout (the Eq. 3
    // pipeline has a fixed call order); tell the sanitizer, if one wraps us.
    sess.declare_phase(SessionPhase::Training);
    let bmax = rows_total as u128 + SMOOTH as u128;

    // Enter the MPC: parties SQ2PQ their local count contributions for every
    // count index the protocol touches — *one* vectorized exercise for all
    // denominators and one for all numerators, then a single divide_many
    // whose vectorized Newton advances every group's inversion in lockstep
    // (rounds scale with the iteration count, not the number of sum nodes).
    let mut sum_w: Vec<Option<DataId>> = vec![None; st.num_sum_edges];

    let sum_groups_idx: Vec<(usize, Vec<usize>)> = st
        .sum_groups
        .iter()
        .map(|g| (st.param_den[g[0]], g.iter().map(|&k| st.param_num[k]).collect()))
        .collect();
    let ws_groups = batched_count_divide(sess, shard_counts, &sum_groups_idx, bmax, &cfg.division);
    let mut divisions = sum_groups_idx.len();
    for (g, ws) in st.sum_groups.iter().zip(ws_groups) {
        for (&k, w) in g.iter().zip(ws) {
            sum_w[k] = Some(w);
        }
    }

    let leaf_theta = if cfg.learn_leaves {
        // the same batching for the leaf extension: every leaf has its own
        // denominator, so this is one divide_many over num_leaves groups
        let w0 = st.num_leaves();
        let leaf_groups: Vec<(usize, Vec<usize>)> = (0..w0)
            .map(|leaf| {
                let k = st.num_sum_edges + leaf;
                (st.param_den[k], vec![st.param_num[k]])
            })
            .collect();
        let ws = batched_count_divide(sess, shard_counts, &leaf_groups, bmax, &cfg.division);
        divisions += w0;
        Some(ws.into_iter().map(|mut v| v.pop().unwrap()).collect())
    } else {
        None
    };

    let model = SharedModel {
        sum_w: sum_w.into_iter().map(Option::unwrap).collect(),
        leaf_theta,
        d: cfg.division.newton.d,
    };
    let stats = sess.stats().delta_since(&before);
    let report = TrainReport { stats, divisions, sum_edges: st.num_sum_edges };
    (model, report)
}

/// Reveal the learned d-scaled sum weights (diagnostic / deployment step;
/// works over any backend and is how the TCP path reads its result out).
pub fn reveal_weights<S: MpcSession>(sess: &mut S, model: &SharedModel) -> Vec<i128> {
    let f = sess.field();
    sess.mark_outputs(&model.sum_w); // the learned weights are the deliverable
    let vals = sess.reveal_vec(&model.sum_w);
    vals.into_iter().map(|v| f.to_i128(v)).collect()
}

/// Peek (no traffic accounting) — simulation-only diagnostics; TCP
/// deployments must use [`reveal_weights`].
pub fn peek_weights(eng: &Engine, model: &SharedModel) -> Vec<i128> {
    model.sum_w.iter().map(|&id| eng.peek_int(id)).collect()
}

pub fn peek_leaf_theta(eng: &Engine, model: &SharedModel) -> Option<Vec<i128>> {
    model
        .leaf_theta
        .as_ref()
        .map(|ids| ids.iter().map(|&id| eng.peek_int(id)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;
    use crate::field::Field;
    use crate::protocols::engine::EngineConfig;
    use crate::spn::{eval, learn};
    use crate::spn::structure::Structure;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    fn setup(n: usize, rows: usize) -> Option<(Structure, Vec<Vec<u64>>, Vec<u64>, u64)> {
        let st = toy()?;
        let gt = datasets::ground_truth_params(&st, 5);
        let data = datasets::sample(&st, &gt, rows, 11);
        let shards = datasets::partition(&data, n);
        let shard_counts: Vec<Vec<u64>> =
            shards.iter().map(|s| eval::counts(&st, s)).collect();
        let global = eval::counts(&st, &data);
        Some((st, shard_counts, global, rows as u64))
    }

    #[test]
    fn private_weights_match_centralized_oracle() {
        let Some((st, shard_counts, global, rows)) = setup(5, 2000) else { return };
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(5));
        let cfg = TrainConfig::default();
        let (model, report) = train(&mut eng, &st, &shard_counts, rows, &cfg);
        let got = peek_weights(&eng, &model);
        let oracle = learn::ml_weights_fixed(&st, &global, 256);
        assert_eq!(report.divisions, st.sum_groups.len());
        for (k, (&g, &o)) in got.iter().zip(&oracle).enumerate() {
            assert!(
                (g - o as i128).abs() <= 4,
                "param {k}: private {g} vs oracle {o}"
            );
        }
    }

    #[test]
    fn weights_per_sum_node_sum_to_d() {
        let Some((st, shard_counts, _, rows)) = setup(3, 1000) else { return };
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(3));
        let (model, _) = train(&mut eng, &st, &shard_counts, rows, &TrainConfig::default());
        let got = peek_weights(&eng, &model);
        for g in &st.sum_groups {
            let tot: i128 = g.iter().map(|&k| got[k]).sum();
            assert!((tot - 256).abs() <= 10, "group sums to {tot}");
        }
    }

    #[test]
    fn learned_leaves_extension() {
        let Some((st, shard_counts, global, rows)) = setup(3, 2000) else { return };
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(3).batched());
        let cfg = TrainConfig { learn_leaves: true, ..Default::default() };
        let (model, report) = train(&mut eng, &st, &shard_counts, rows, &cfg);
        assert_eq!(report.divisions, st.sum_groups.len() + st.num_leaves());
        let thetas = peek_leaf_theta(&eng, &model).unwrap();
        for (leaf, &th) in thetas.iter().enumerate() {
            let k = st.num_sum_edges + leaf;
            let oracle =
                256 * global[st.param_num[k]] as i128 / (global[st.param_den[k]] + 1) as i128;
            assert!((th - oracle).abs() <= 4, "leaf {leaf}: {th} vs {oracle}");
        }
    }

    #[test]
    fn member_shares_differ_from_weights() {
        // Privacy smoke test: no single member's share equals the secret.
        let Some((st, shard_counts, _, rows)) = setup(5, 500) else { return };
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(5));
        let (model, _) = train(&mut eng, &st, &shard_counts, rows, &TrainConfig::default());
        let secrets = peek_weights(&eng, &model);
        // Secrets are small ints; shares should look like random field elems.
        let mut coincidences = 0;
        for (k, &id) in model.sum_w.iter().enumerate() {
            for m in &eng.members {
                let sh = {
                    // members' stores are private; go through peek of single share
                    // via reconstruct_subset of 1 point is impossible — compare raw
                    let shares: Vec<u128> =
                        eng.members.iter().map(|mm| mm_get(mm, id)).collect();
                    shares[m.id - 1]
                };
                if sh == secrets[k].unsigned_abs() {
                    coincidences += 1;
                }
            }
        }
        assert!(coincidences <= 1, "shares leak secrets");
    }

    // test-only accessor (Member::get is private)
    fn mm_get(m: &crate::protocols::engine::Member, id: DataId) -> u128 {
        m.share_for_test(id)
    }
}
