//! Compiled SPN evaluation plans — the private-inference IR.
//!
//! [`EvalPlan::compile`] turns a [`Structure`] into a flat sequence of
//! vectorized steps *once*; [`Evaluator::eval_batch`] then runs any number
//! of queries over it without ever re-deriving the layer wiring. The IR is
//! built around what actually costs money on a real transport — secure
//! rounds, not bytes:
//!
//! * **Leaf step** — one `mul_vec` + `lin_vec` over every *live* (query,
//!   leaf) pair: the Bernoulli affine `x·(2θ−d) + (d−θ)`. Marginalized
//!   leaves read the cached public constant `d`.
//! * **Product step** — chains evaluated breadth-first: depth-k links of
//!   *every* node (and every query in the batch) coalesce into one
//!   `mul_vec` + `divpub_vec` round, so a product layer costs
//!   `max chain length − 1` round-trips, not `Σ (chain length − 1)`.
//! * **Sum step** — one `mul_vec` over all (weight, child) edges, a
//!   `lin_vec` of per-node sums, one `divpub_vec` over the nodes.
//!
//! **Batching invariant.** `eval_batch` over B queries reveals *exactly*
//! the values B sequential single-query evaluations reveal. Every secure
//! primitive except divpub is value-exact (share randomness cancels on
//! reconstruction); divpub's ±1 rounding depends on Alice's mask `r`, so
//! the executor routes every truncation through
//! [`MpcSession::divpub_vec_tagged`] with the tag the *sequential*
//! evaluation would have used: tags are allocated per query via
//! [`MpcSession::reserve_tags`] in blocks of [`EvalPlan::divpubs_per_query`]
//! and addressed by the element's plan-order offset, which is identical
//! under any batching. The cross-backend integration tests pin this
//! bit-identity (Sim = TCP, batch = sequential).
//!
//! **Pipelined scheduling (§Round scheduler).** Compilation also derives a
//! step-dependency DAG over *units* — (product step, chain round) pairs and
//! sum steps — assigning each unit the earliest **wave** its inputs allow
//! ([`EvalPlan::waves`]): chain rounds of disjoint subtrees at the same
//! depth, and steps whose sources are already available, share a wave.
//! [`Evaluator::eval_batch`] launches one coalesced *flight*
//! ([`MpcSession::submit`]/[`MpcSession::complete`]) per wave — all ready
//! muls, then every ready sum's lin-combine, then every unit's tagged
//! divpub — so a batch pays [`EvalPlan::critical_depth`] waves of secure
//! rounds instead of one round-trip per [`EvalPlan::chain_rounds`] step.
//! Message/byte totals are unchanged (coalescing moves latency, not
//! traffic) and revealed values are byte-identical to the stream-order
//! executor because per-element tag assignment is wave-invariant.
//! [`Evaluator::eval_batch_sequential`] keeps the stream-order executor as
//! the pinned parity reference.
//!
//! One [`Evaluator`] is bound to one session and one model: it caches the
//! session-level constants (public `d`, per-leaf θ and the query-independent
//! slope `2θ−d`) on first use — [`DataId`]s from another session would be
//! meaningless.

use crate::net::NetStats;
use crate::protocols::engine::DataId;
use crate::protocols::flight::FlightOp;
use crate::protocols::session::{MpcSession, SessionPhase};
use crate::spn::structure::{LayerKind, Structure};

/// A client query: assignment + which variables are marginalized.
#[derive(Clone, Debug)]
pub struct Query {
    pub x: Vec<u8>,
    pub marg: Vec<bool>,
}

/// One shard *generation*'s slice of the 64-bit divpub-tag space.
///
/// A serve fleet (DESIGN.md §Fleet) runs S independent sessions for one
/// model; shard `s` owns the band `[s·W, (s+1)·W)` with
/// `W = u64::MAX / S`. Within its band each shard subdivides further into
/// [`TagStripe::GENERATIONS`] generation sub-stripes of width
/// `Wg = W / GENERATIONS`: generation 0 is the original session, and every
/// respawned replacement (DESIGN.md §Fleet, shard lifecycle) takes the
/// next generation — so tags burned by a dead incarnation are never
/// reissued to its successor, and the §3.4 freshness invariant holds
/// *per fleet lifetime* without any cross-shard or cross-generation
/// coordination. All (shard, generation) stripes are pairwise disjoint by
/// construction. `TagStripe::new(0, 1)` — shard 0, generation 0 of a
/// one-shard fleet — starts at tag 0, so a fleet of one is tag-for-tag
/// the single-session server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TagStripe {
    shard: usize,
    shards: usize,
    gen: u64,
}

impl TagStripe {
    /// Generation sub-stripes per shard band: enough respawns for any
    /// realistic serve lifetime, while keeping each generation's width
    /// (`u64::MAX / shards / 64`) astronomically larger than any tag
    /// demand a session could meet.
    pub const GENERATIONS: u64 = 64;

    /// Generation 0 of stripe `shard` in a `shards`-way partition
    /// (`shard < shards`).
    pub fn new(shard: usize, shards: usize) -> TagStripe {
        Self::generation(shard, shards, 0)
    }

    /// Generation `gen` of stripe `shard` (`gen < GENERATIONS`): the
    /// sub-stripe handed to the `gen`-th incarnation of the shard.
    pub fn generation(shard: usize, shards: usize, gen: u64) -> TagStripe {
        assert!(shards >= 1, "a fleet has at least one shard");
        assert!(shard < shards, "stripe {shard} of a {shards}-shard fleet");
        assert!(
            gen < Self::GENERATIONS,
            "generation {gen} exhausts the {} sub-stripes of shard {shard}",
            Self::GENERATIONS
        );
        TagStripe { shard, shards, gen }
    }

    /// This stripe's shard index.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Total shards in the partition.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// This stripe's generation within its shard band.
    pub fn gen(&self) -> u64 {
        self.gen
    }

    /// Full shard-band width `W = u64::MAX / shards` (all generations).
    pub fn width(shards: usize) -> u64 {
        u64::MAX / shards as u64
    }

    /// Width of one generation sub-stripe, `W / GENERATIONS`.
    pub fn gen_width(shards: usize) -> u64 {
        Self::width(shards) / Self::GENERATIONS
    }

    /// First tag of the stripe.
    pub fn base(&self) -> u64 {
        self.shard as u64 * Self::width(self.shards) + self.gen * Self::gen_width(self.shards)
    }

    /// One past the last tag of the stripe.
    pub fn limit(&self) -> u64 {
        self.base() + Self::gen_width(self.shards)
    }

    /// Does the half-open tag range `[start, end)` fall inside the stripe?
    pub fn contains(&self, start: u64, end: u64) -> bool {
        start <= end && start >= self.base() && end <= self.limit()
    }
}

/// Where a step input comes from: the previous layer's outputs or a leaf.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    Prev(usize),
    Leaf(usize),
}

/// One vectorized step of a compiled plan.
#[derive(Clone, Debug)]
pub enum PlanStep {
    /// A product layer. `first[i]` seeds node i's accumulator; `rounds[k]`
    /// holds the (node, child) links multiplied in at chain depth k+1 —
    /// one `mul_vec` + `divpub_vec` pair per round, across all nodes (and
    /// all queries in a batch).
    Product { width: usize, first: Vec<Src>, rounds: Vec<Vec<(usize, Src)>> },
    /// A sum layer. `node_edges[i]` lists node i's (sum-weight param id,
    /// child) edges: one `mul_vec` over every edge, per-node `lin_vec`
    /// sums, one `divpub_vec` over the nodes.
    Sum { width: usize, node_edges: Vec<Vec<(usize, Src)>> },
}

/// One schedulable unit of the step-dependency DAG: a single chain round
/// of a product step, or a whole sum step. A unit is the granularity at
/// which traffic coalesces — all of a unit's elements (across every node
/// it covers and every query in the batch) ride one flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DagUnit {
    /// Index into [`EvalPlan::steps`].
    pub step: usize,
    /// Chain-round index for a product step; always 0 for a sum step.
    pub round: usize,
    /// Per-query divpub offset this unit's first element occupies in the
    /// *sequential* plan order — precomputed at compile time so the
    /// pipelined executor hands every divpub the exact tag the stream-order
    /// executor would (`tag0 + b·m + qoff + j`), which is what makes wave
    /// regrouping byte-transparent.
    pub qoff: u64,
}

/// A [`Structure`] compiled for repeated private evaluation.
#[derive(Clone, Debug)]
pub struct EvalPlan {
    /// Source structure name (diagnostics).
    pub name: String,
    /// Fixed-point scale (d = 256 in the paper's setting).
    pub d: u128,
    pub num_vars: usize,
    pub num_leaves: usize,
    /// Variable tested by each leaf.
    pub leaf_var: Vec<usize>,
    /// d-scaled public default θ per leaf (paper mode: leaves are public).
    pub leaf_theta_fixed: Vec<u128>,
    /// Bottom-up layer steps; the last step has width 1 (the root).
    pub steps: Vec<PlanStep>,
    /// Divpub elements one query consumes — the tag stride that keeps
    /// batched and sequential evaluation bit-identical.
    pub divpubs_per_query: u64,
    /// The dependency-DAG schedule: `waves[w]` lists the units whose every
    /// input is available after wave `w` has run (leaf values count as
    /// wave 0). Units within a wave are in plan order — the deterministic
    /// ready-order both backends execute. `waves.len()` is the DAG's
    /// critical-path depth.
    pub waves: Vec<Vec<DagUnit>>,
    /// Per step, per node: `Some(src)` iff the node is a degree-1 product
    /// pass-through — it owns no chain round, its output *is* its `first`
    /// seed. The pipelined executor never materializes such nodes; reads
    /// resolve through the alias (at most one hop: the alias target is a
    /// sum node or a leaf, both always materialized, by the layer-
    /// alternation rule of [`Structure::validate`]).
    pub pass_through: Vec<Vec<Option<Src>>>,
}

impl EvalPlan {
    /// Compile `st` once for scale `d`, quantizing the public per-leaf
    /// default θ exactly as the per-query path used to. A short (even
    /// empty) `default_leaf_theta` is accepted here — the defaults are
    /// only consulted when a model has no learned leaf shares, and the
    /// length is checked at that point.
    pub fn compile(st: &Structure, default_leaf_theta: &[f64], d: u128) -> EvalPlan {
        let w0 = st.num_leaves();
        let leaf_theta_fixed: Vec<u128> = default_leaf_theta
            .iter()
            .map(|&t| ((t * d as f64).round() as u128).min(d))
            .collect();

        let mut steps = Vec::with_capacity(st.layers.len());
        let mut divpubs = 0u64;
        for (li, l) in st.layers.iter().enumerate() {
            let prev_w = if li > 0 { st.layer_widths[li] } else { 0 };
            let src =
                |c: usize| if c < prev_w { Src::Prev(c) } else { Src::Leaf(c - prev_w) };
            // children per node, in COO (edge) order
            let mut children: Vec<Vec<(Src, i64)>> = vec![Vec::new(); l.width];
            for ((&r, &c), &p) in l.rows.iter().zip(&l.cols).zip(&l.param) {
                children[r].push((src(c), p));
            }
            match l.kind {
                LayerKind::Product => {
                    let first: Vec<Src> = children.iter().map(|ch| ch[0].0).collect();
                    let maxlen = children.iter().map(|ch| ch.len()).max().unwrap_or(1);
                    let mut rounds = Vec::with_capacity(maxlen.saturating_sub(1));
                    for k in 1..maxlen {
                        let round: Vec<(usize, Src)> = children
                            .iter()
                            .enumerate()
                            .filter(|(_, ch)| ch.len() > k)
                            .map(|(i, ch)| (i, ch[k].0))
                            .collect();
                        divpubs += round.len() as u64;
                        rounds.push(round);
                    }
                    steps.push(PlanStep::Product { width: l.width, first, rounds });
                }
                LayerKind::Sum => {
                    let node_edges: Vec<Vec<(usize, Src)>> = children
                        .iter()
                        .map(|ch| ch.iter().map(|&(s, p)| (p as usize, s)).collect())
                        .collect();
                    divpubs += l.width as u64;
                    steps.push(PlanStep::Sum { width: l.width, node_edges });
                }
            }
        }
        // ---- dependency-DAG schedule (DESIGN.md §Round scheduler) --------
        // Walk the finished steps in plan order assigning every unit the
        // earliest wave its inputs allow. `node_ready[s][i]` is the wave at
        // which step s's node i output exists (0 = before any wave: leaves
        // and pass-through aliases of leaves).
        let mut node_ready: Vec<Vec<usize>> = Vec::with_capacity(steps.len());
        let mut pass_through: Vec<Vec<Option<Src>>> = Vec::with_capacity(steps.len());
        let mut units: Vec<(DagUnit, usize)> = Vec::new(); // (unit, wave)
        let mut qoff = 0u64;
        for (s, step) in steps.iter().enumerate() {
            // A source is ready when its producing node is; `node_ready`
            // already folds pass-through aliasing in, so one lookup suffices.
            let src_wave = |c: Src, node_ready: &Vec<Vec<usize>>| match c {
                Src::Leaf(_) => 0,
                Src::Prev(i) => node_ready[s - 1][i],
            };
            match step {
                PlanStep::Product { width, first, rounds } => {
                    let mut deg = vec![1usize; *width];
                    for round in rounds {
                        for &(n, _) in round {
                            deg[n] += 1;
                        }
                    }
                    let mut ready = vec![0usize; *width];
                    let mut alias = vec![None; *width];
                    for i in 0..*width {
                        if deg[i] == 1 {
                            // Pass-through: output = the first seed itself.
                            alias[i] = Some(first[i]);
                            ready[i] = src_wave(first[i], &node_ready);
                            if let Src::Prev(j) = first[i] {
                                debug_assert!(
                                    pass_through[s - 1][j].is_none(),
                                    "alias chains longer than one hop need \
                                     non-alternating layers, which validate() rejects"
                                );
                            }
                        }
                    }
                    let mut prev_wave = 0usize;
                    for (k, round) in rounds.iter().enumerate() {
                        // Round k of a chain reads round k-1's accumulators
                        // (round-0 reads the first seeds) plus this round's
                        // children; it runs one wave after the latest.
                        let mut w = if k == 0 {
                            round
                                .iter()
                                .map(|&(n, _)| src_wave(first[n], &node_ready))
                                .max()
                                .unwrap_or(0)
                        } else {
                            prev_wave
                        };
                        for &(_, child) in round {
                            w = w.max(src_wave(child, &node_ready));
                        }
                        let w = w + 1;
                        units.push((DagUnit { step: s, round: k, qoff }, w));
                        qoff += round.len() as u64;
                        prev_wave = w;
                        for &(n, _) in round {
                            // a node's output exists after its last round
                            if deg[n] == k + 2 {
                                ready[n] = w;
                            }
                        }
                    }
                    node_ready.push(ready);
                    pass_through.push(alias);
                }
                PlanStep::Sum { width, node_edges } => {
                    let mut w = 0usize;
                    for edges in node_edges {
                        for &(_, child) in edges {
                            w = w.max(src_wave(child, &node_ready));
                        }
                    }
                    let w = w + 1;
                    units.push((DagUnit { step: s, round: 0, qoff }, w));
                    qoff += *width as u64;
                    node_ready.push(vec![w; *width]);
                    pass_through.push(vec![None; *width]);
                }
            }
        }
        debug_assert_eq!(qoff, divpubs, "unit qoffs must tile the divpub space");
        let depth = units.iter().map(|&(_, w)| w).max().unwrap_or(0);
        let mut waves: Vec<Vec<DagUnit>> = vec![Vec::new(); depth];
        for (u, w) in units {
            waves[w - 1].push(u); // plan order within a wave (stable push)
        }

        EvalPlan {
            name: st.name.clone(),
            d,
            num_vars: st.num_vars,
            num_leaves: w0,
            leaf_var: st.leaf_var.clone(),
            leaf_theta_fixed,
            steps,
            divpubs_per_query: divpubs,
            waves,
            pass_through,
        }
    }

    /// Number of secure round-trip *steps* a single evaluation pays:
    /// the per-query round count is independent of the batch width B, so
    /// rounds per query shrink ~B× under batching.
    pub fn chain_rounds(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                PlanStep::Product { rounds, .. } => rounds.len(),
                PlanStep::Sum { .. } => 1,
            })
            .sum()
    }

    /// Critical-path depth of the step-dependency DAG — the number of
    /// coalesced waves the pipelined executor pays per batch. At most
    /// [`EvalPlan::chain_rounds`] (every unit in its own wave), and
    /// strictly less whenever independent subtrees let units share one.
    pub fn critical_depth(&self) -> usize {
        self.waves.len()
    }

    /// Closed-form secure rounds one **warm** pipelined batch costs under
    /// the Sim accountant, for the non-degenerate case of at least one
    /// live (query, leaf) pair: the client-input star (3) + the leaf
    /// mul+lin flight (3) + 6 per wave (every wave flights a mul, possibly
    /// a lin, and a tagged divpub — `sim_flight_rounds(true, true) = 6`)
    /// + the root reveal star (3). The first batch on a fresh evaluator
    /// adds 2 (the one-time slope `lin_vec` of the constant cache); the
    /// rounds-equal-critical-path tests warm the cache first.
    pub fn pipelined_sim_rounds(&self) -> u64 {
        6 * self.critical_depth() as u64 + 9
    }
}

/// Session-bound constants compiled plans reuse across every query: the
/// public `d`, one θ handle per leaf (learned shares or cached public
/// constants) and the query-independent slope `2θ − d`.
struct PlanCache {
    const_d: DataId,
    theta: Vec<DataId>,
    slope: Vec<DataId>,
    /// The learned-θ handles this cache was built from (`None` = public
    /// θ constants, which are model-independent). Later calls must pass
    /// the same handles — a re-trained model needs a fresh [`Evaluator`].
    learned_src: Option<Vec<DataId>>,
}

/// Executes a compiled [`EvalPlan`] over one session + one model, caching
/// the per-leaf constants on first use (satisfying the one-time-cost
/// contract: B queries pay for the constants once, not B times).
///
/// The evaluator *owns* its plan and carries no per-batch state, so one
/// instance can serve any number of batches of **varying** width over a
/// long-lived session — the standing-server usage of
/// [`crate::net::serve`]. Each call reserves a fresh
/// [`MpcSession::reserve_tags`] range (recorded in
/// [`Evaluator::last_tags`]); ranges from successive calls are disjoint
/// and monotone by the trait contract, which is what keeps tags from ever
/// being reused across scheduler ticks.
pub struct Evaluator {
    plan: EvalPlan,
    cache: Option<PlanCache>,
    /// `[start, end)` of the tag block the most recent batch reserved.
    last_tags: Option<(u64, u64)>,
    /// Batches evaluated so far (scheduler ticks, for a standing server).
    ticks: u64,
    /// The tag stripe this evaluator's session is confined to (`None` =
    /// unsharded: the whole tag space). Installed by
    /// [`Evaluator::clone_into_session`]; every reservation is asserted to
    /// stay inside it.
    stripe: Option<TagStripe>,
}

fn resolve(s: Src, b: usize, prev: &[DataId], leaf_vals: &[DataId], bsz: usize) -> DataId {
    match s {
        Src::Prev(i) => prev[i * bsz + b],
        Src::Leaf(l) => leaf_vals[l * bsz + b],
    }
}

/// Pipelined-executor read of step `step`'s node `i` for query `b` out of
/// the per-step materialized tables, following at most one pass-through
/// hop (see [`EvalPlan::pass_through`] for why one hop suffices).
fn node_out(
    step: usize,
    i: usize,
    b: usize,
    vals: &[Vec<DataId>],
    leaf_vals: &[DataId],
    pass_through: &[Vec<Option<Src>>],
    bsz: usize,
) -> DataId {
    match pass_through[step][i] {
        None => vals[step][i * bsz + b],
        Some(Src::Leaf(l)) => leaf_vals[l * bsz + b],
        Some(Src::Prev(j)) => vals[step - 1][j * bsz + b],
    }
}

/// [`node_out`] through a step-input [`Src`] of `consuming_step`.
fn resolve_dag(
    s: Src,
    consuming_step: usize,
    b: usize,
    vals: &[Vec<DataId>],
    leaf_vals: &[DataId],
    pass_through: &[Vec<Option<Src>>],
    bsz: usize,
) -> DataId {
    match s {
        Src::Leaf(l) => leaf_vals[l * bsz + b],
        Src::Prev(i) => node_out(consuming_step - 1, i, b, vals, leaf_vals, pass_through, bsz),
    }
}

impl Evaluator {
    pub fn new(plan: EvalPlan) -> Self {
        Evaluator { plan, cache: None, last_tags: None, ticks: 0, stripe: None }
    }

    /// The compiled plan this evaluator executes.
    pub fn plan(&self) -> &EvalPlan {
        &self.plan
    }

    /// The tag stripe this evaluator is confined to (`None` = unsharded).
    pub fn stripe(&self) -> Option<TagStripe> {
        self.stripe
    }

    /// The fleet replication path: bind a copy of this evaluator's compiled
    /// plan to another session and confine it to `stripe` of the partitioned
    /// tag space.
    ///
    /// The session-bound cache is *not* cloned — [`DataId`]s are meaningless
    /// across sessions; `sess` rebuilds its own constants on first use. The
    /// stripe is installed by advancing `sess`'s monotone tag counter to the
    /// stripe base, which is only sound on a session that has never reserved
    /// a tag (training and k-means use untagged divpub, so a freshly trained
    /// replica qualifies); a session with tag history is rejected. With
    /// stripe 0 of 1 this is byte-for-byte the unsharded evaluator.
    pub fn clone_into_session<S: MpcSession>(
        &self,
        sess: &mut S,
        stripe: TagStripe,
    ) -> Evaluator {
        let start = sess.reserve_tags(stripe.base());
        assert_eq!(
            start, 0,
            "fleet replication needs a session with a fresh tag space \
             (tag counter was {start}, not 0)"
        );
        // Hand the stripe bounds to the session's sanitizer (if one is
        // wrapped around it): from here on, a reservation escaping the
        // stripe is a contract violation, not silent cross-shard reuse.
        sess.confine_tags(stripe.base(), stripe.limit());
        Evaluator {
            plan: self.plan.clone(),
            cache: None,
            last_tags: None,
            ticks: 0,
            stripe: Some(stripe),
        }
    }

    /// `[start, end)` of the divpub-tag block reserved by the most recent
    /// [`Evaluator::eval_batch`] call (`None` before the first call). The
    /// tag-freshness tests assert these ranges are pairwise disjoint and
    /// strictly monotone across scheduler ticks.
    pub fn last_tags(&self) -> Option<(u64, u64)> {
        self.last_tags
    }

    /// Number of batches evaluated so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    fn ensure_cache<S: MpcSession>(
        &mut self,
        sess: &mut S,
        learned_theta: Option<&[DataId]>,
    ) {
        if let Some(c) = &self.cache {
            // The cached θ/slope handles embed the model they were built
            // from; silently mixing them with a different model's sum
            // weights would produce wrong posteriors with no error.
            assert_eq!(
                c.learned_src.as_deref(),
                learned_theta,
                "Evaluator is bound to one model; build a new Evaluator for a new model"
            );
        } else {
            let d = self.plan.d;
            let const_d = sess.constant(d);
            let theta: Vec<DataId> = match learned_theta {
                Some(t) => {
                    assert_eq!(t.len(), self.plan.num_leaves, "one learned θ per leaf");
                    t.to_vec()
                }
                None => {
                    assert_eq!(
                        self.plan.leaf_theta_fixed.len(),
                        self.plan.num_leaves,
                        "the plan was compiled without one default θ per leaf, \
                         and this model has no learned leaf shares"
                    );
                    self.plan.leaf_theta_fixed.iter().map(|&th| sess.constant(th)).collect()
                }
            };
            let slope_ops: Vec<(i128, Vec<(i128, DataId)>)> =
                theta.iter().map(|&th| (-(d as i128), vec![(2, th)])).collect();
            let slope = sess.lin_vec(&slope_ops); // 2θ − d, query-independent
            let learned_src = learned_theta.map(|t| t.to_vec());
            self.cache = Some(PlanCache { const_d, theta, slope, learned_src });
        }
    }

    /// Shared front half of both executors: phase/tag bookkeeping, the
    /// constant cache, the client-input star and the leaf layer. Returns
    /// the batch's tag-block base and the (leaf × query) value table. With
    /// `pipelined` the leaf mul+lin ride one coalesced flight (3 rounds
    /// instead of 5); either way the values and the tag ledger are
    /// identical.
    fn batch_prologue<S: MpcSession>(
        &mut self,
        sess: &mut S,
        queries: &[Query],
        learned_theta: Option<&[DataId]>,
        pipelined: bool,
    ) -> (u64, Vec<DataId>) {
        let bsz = queries.len();
        for q in queries {
            assert_eq!(q.x.len(), self.plan.num_vars, "query width");
            assert_eq!(q.marg.len(), self.plan.num_vars, "marginal mask width");
        }
        // Batch evaluation is inference by definition: every truncation
        // below goes through the tagged divpub, and the sanitizer (when
        // wrapped) may hold us to that.
        sess.declare_phase(SessionPhase::Inference);
        let m = self.plan.divpubs_per_query;
        // One tag block per query: query b's divpub at plan-order offset o
        // gets tag0 + b·m + o — exactly what b prior single-query calls
        // would have reserved, hence the bit-identity (and, for a standing
        // server, partition-invariance: however the scheduler slices an
        // arrival sequence into ticks, overall query j always lands on tag
        // block j·m).
        let tag0 = sess.reserve_tags(m * bsz as u64);
        if let Some(stripe) = self.stripe {
            // Escaping the stripe would collide with another shard's tag
            // namespace; at W = u64::MAX / S tags per stripe this cannot
            // happen before the heat death of the counter, but a violated
            // invariant here must never reach the wire.
            assert!(
                stripe.contains(tag0, tag0 + m * bsz as u64),
                "tag block [{tag0}, {}) escapes stripe {} of {}",
                tag0 + m * bsz as u64,
                stripe.shard(),
                stripe.shards(),
            );
        }
        self.last_tags = Some((tag0, tag0 + m * bsz as u64));
        self.ticks += 1;
        self.ensure_cache(sess, learned_theta);
        let p = &self.plan;
        let cache = self.cache.as_ref().unwrap();

        // --- client input: every query's assignment, query-major ----------
        let xvals: Vec<u128> =
            queries.iter().flat_map(|q| q.x.iter().map(|&b| b as u128)).collect();
        let x_ids = sess.input_vec(1, &xvals);

        // --- leaf values over the live (leaf, query) pairs -----------------
        let mut leaf_vals: Vec<DataId> = vec![cache.const_d; p.num_leaves * bsz];
        let mut live: Vec<(usize, usize)> = Vec::new(); // (leaf, query)
        for leaf in 0..p.num_leaves {
            let v = p.leaf_var[leaf];
            for (b, q) in queries.iter().enumerate() {
                if !q.marg[v] {
                    live.push((leaf, b));
                }
            }
        }
        if !live.is_empty() {
            let pairs: Vec<(DataId, DataId)> = live
                .iter()
                .map(|&(leaf, b)| (x_ids[b * p.num_vars + p.leaf_var[leaf]], cache.slope[leaf]))
                .collect();
            let prods =
                if pipelined { sess.submit(FlightOp::Mul(pairs)) } else { sess.mul_vec(&pairs) };
            let val_ops: Vec<(i128, Vec<(i128, DataId)>)> = live
                .iter()
                .zip(&prods)
                .map(|(&(leaf, _), &pr)| {
                    (p.d as i128, vec![(1, pr), (-1, cache.theta[leaf])])
                })
                .collect();
            let vals = if pipelined {
                let v = sess.submit(FlightOp::Lin(val_ops));
                sess.complete();
                v
            } else {
                sess.lin_vec(&val_ops)
            };
            for (&(leaf, b), &val) in live.iter().zip(&vals) {
                leaf_vals[leaf * bsz + b] = val;
            }
        }
        (tag0, leaf_vals)
    }

    /// Evaluate all `queries` simultaneously over the compiled dependency
    /// DAG, one coalesced flight per wave: each wave stages every ready
    /// unit's multiplications, then every ready sum's lin-combines, then
    /// every unit's tagged truncation, and launches the lot as one framed
    /// message per member per physical round. Returns the revealed d-scaled
    /// root value per query (same order) and the traffic spent.
    ///
    /// Byte-identical to [`Evaluator::eval_batch_sequential`] (and to
    /// evaluating the queries one `eval_batch(&[q])` at a time): mul/lin
    /// are value-exact on reconstruction, and every divpub carries the
    /// exact tag the stream-order executor assigns (the precomputed
    /// [`DagUnit::qoff`]), so its ±1 rounding is identical. Message, byte
    /// and exercise totals match the sequential path under the per-op
    /// accounting schedule; only rounds (and therefore virtual latency)
    /// shrink — to [`EvalPlan::critical_depth`] waves
    /// ([`EvalPlan::pipelined_sim_rounds`] in total).
    pub fn eval_batch<S: MpcSession>(
        &mut self,
        sess: &mut S,
        queries: &[Query],
        sum_w: &[DataId],
        learned_theta: Option<&[DataId]>,
    ) -> (Vec<i128>, NetStats) {
        let before = sess.stats();
        let bsz = queries.len();
        if bsz == 0 {
            return (Vec::new(), sess.stats().delta_since(&before));
        }
        let (tag0, leaf_vals) = self.batch_prologue(sess, queries, learned_theta, true);
        let p = &self.plan;
        let m = p.divpubs_per_query;

        // Materialized (node × query) values per step; pass-through nodes
        // stay unmaterialized (reads alias through `p.pass_through`). The
        // placeholder id is never handed to the session: the wave order
        // guarantees every slot a unit reads was scattered by an earlier
        // wave (or earlier unit of the same flight).
        let mut vals: Vec<Vec<DataId>> = p
            .steps
            .iter()
            .map(|s| match s {
                PlanStep::Product { width, .. } | PlanStep::Sum { width, .. } => {
                    vec![DataId(u64::MAX); width * bsz]
                }
            })
            .collect();

        // Per-wave scratch: the offset tables are pure staging state, so
        // they hoist across waves (clear, don't reallocate). The staged op
        // vectors (`pairs`/`ops`/`us`/`tags`) are consumed by value by
        // `FlightOp`, so those are instead pre-sized from the previous
        // waves' high-water marks — after the first wave, staging performs
        // no growth reallocation.
        let mut mul_offs: Vec<usize> = Vec::new();
        let mut lin_offs: Vec<usize> = Vec::new();
        let mut div_offs: Vec<usize> = Vec::new();
        let (mut pairs_hint, mut ops_hint, mut us_hint) = (0usize, 0usize, 0usize);
        for wave in &p.waves {
            // Pass 1 — stage every unit's multiplications, wave-unit order.
            mul_offs.clear();
            mul_offs.reserve(wave.len());
            let mut pairs: Vec<(DataId, DataId)> = Vec::with_capacity(pairs_hint);
            for u in wave {
                mul_offs.push(pairs.len());
                match &p.steps[u.step] {
                    PlanStep::Product { first, rounds, .. } => {
                        for &(node, child) in &rounds[u.round] {
                            for b in 0..bsz {
                                let acc = if u.round == 0 {
                                    resolve_dag(
                                        first[node], u.step, b, &vals, &leaf_vals,
                                        &p.pass_through, bsz,
                                    )
                                } else {
                                    vals[u.step][node * bsz + b]
                                };
                                let ch = resolve_dag(
                                    child, u.step, b, &vals, &leaf_vals, &p.pass_through, bsz,
                                );
                                pairs.push((acc, ch));
                            }
                        }
                    }
                    PlanStep::Sum { node_edges, .. } => {
                        for edges in node_edges {
                            for &(pidx, child) in edges {
                                for b in 0..bsz {
                                    let ch = resolve_dag(
                                        child, u.step, b, &vals, &leaf_vals, &p.pass_through,
                                        bsz,
                                    );
                                    pairs.push((sum_w[pidx], ch));
                                }
                            }
                        }
                    }
                }
            }
            // Every wave multiplies: product rounds by definition, sum
            // units on their (≥ 1 by validate()) weight×child edges.
            pairs_hint = pairs_hint.max(pairs.len());
            let prods = sess.submit(FlightOp::Mul(pairs));

            // Pass 2 — stage the per-node lin sums of the wave's sum units.
            lin_offs.clear();
            lin_offs.reserve(wave.len());
            let mut ops: Vec<(i128, Vec<(i128, DataId)>)> = Vec::with_capacity(ops_hint);
            for (ui, u) in wave.iter().enumerate() {
                lin_offs.push(ops.len());
                if let PlanStep::Sum { node_edges, .. } = &p.steps[u.step] {
                    let mut off = mul_offs[ui];
                    for edges in node_edges {
                        for b in 0..bsz {
                            let terms: Vec<(i128, DataId)> = (0..edges.len())
                                .map(|e| (1, prods[off + e * bsz + b]))
                                .collect();
                            ops.push((0, terms));
                        }
                        off += edges.len() * bsz;
                    }
                }
            }
            ops_hint = ops_hint.max(ops.len());
            let sums = if ops.is_empty() { Vec::new() } else { sess.submit(FlightOp::Lin(ops)) };

            // Pass 3 — stage every unit's tagged truncation with the exact
            // sequential tag (`tag0 + b·m + qoff + element`).
            div_offs.clear();
            div_offs.reserve(wave.len());
            let mut us: Vec<DataId> = Vec::with_capacity(us_hint);
            let mut tags: Vec<u64> = Vec::with_capacity(us_hint);
            for (ui, u) in wave.iter().enumerate() {
                div_offs.push(us.len());
                match &p.steps[u.step] {
                    PlanStep::Product { rounds, .. } => {
                        for j in 0..rounds[u.round].len() {
                            for b in 0..bsz {
                                us.push(prods[mul_offs[ui] + j * bsz + b]);
                                tags.push(tag0 + b as u64 * m + u.qoff + j as u64);
                            }
                        }
                    }
                    PlanStep::Sum { width, .. } => {
                        for i in 0..*width {
                            for b in 0..bsz {
                                us.push(sums[lin_offs[ui] + i * bsz + b]);
                                tags.push(tag0 + b as u64 * m + u.qoff + i as u64);
                            }
                        }
                    }
                }
            }
            us_hint = us_hint.max(us.len());
            let outs = sess.submit(FlightOp::DivpubTagged { us, d: p.d, tags });
            sess.complete();

            // Pass 4 — scatter the truncated values into the step tables.
            for (ui, u) in wave.iter().enumerate() {
                match &p.steps[u.step] {
                    PlanStep::Product { rounds, .. } => {
                        for (j, &(node, _)) in rounds[u.round].iter().enumerate() {
                            for b in 0..bsz {
                                vals[u.step][node * bsz + b] = outs[div_offs[ui] + j * bsz + b];
                            }
                        }
                    }
                    PlanStep::Sum { width, .. } => {
                        for i in 0..*width {
                            for b in 0..bsz {
                                vals[u.step][i * bsz + b] = outs[div_offs[ui] + i * bsz + b];
                            }
                        }
                    }
                }
            }
        }

        // --- reveal every root to the client -------------------------------
        let last = p.steps.len() - 1;
        let roots: Vec<DataId> = (0..bsz)
            .map(|b| node_out(last, 0, b, &vals, &leaf_vals, &p.pass_through, bsz))
            .collect();
        sess.mark_outputs(&roots); // the posteriors ARE the functionality
        let revealed = sess.reveal_vec(&roots);
        let f = sess.field();
        let out: Vec<i128> = revealed.into_iter().map(|v| f.to_i128(v)).collect();
        (out, sess.stats().delta_since(&before))
    }

    /// The stream-order reference executor: one `mul_vec`/`lin_vec`/
    /// `divpub_vec_tagged` round-trip per plan step, exactly as every
    /// backend ran before the round scheduler existed. Kept (not as a
    /// fallback but as a *pinned contract*) so the cross-backend tests can
    /// assert the pipelined path reveals byte-identical values while
    /// spending the same messages under per-op accounting — and as the
    /// honest baseline the §Perf round-count tables compare against.
    pub fn eval_batch_sequential<S: MpcSession>(
        &mut self,
        sess: &mut S,
        queries: &[Query],
        sum_w: &[DataId],
        learned_theta: Option<&[DataId]>,
    ) -> (Vec<i128>, NetStats) {
        let before = sess.stats();
        let bsz = queries.len();
        if bsz == 0 {
            return (Vec::new(), sess.stats().delta_since(&before));
        }
        let (tag0, leaf_vals) = self.batch_prologue(sess, queries, learned_theta, false);
        let p = &self.plan;
        let m = p.divpubs_per_query;

        // --- layered steps (node-major × query-inner layout) ---------------
        let mut prev: Vec<DataId> = Vec::new();
        let mut qoff = 0u64; // per-query divpub offset consumed so far
        for step in &p.steps {
            match step {
                PlanStep::Product { width, first, rounds } => {
                    let w = *width;
                    let mut acc: Vec<DataId> = Vec::with_capacity(w * bsz);
                    for &f in first {
                        for b in 0..bsz {
                            acc.push(resolve(f, b, &prev, &leaf_vals, bsz));
                        }
                    }
                    for round in rounds {
                        let mut pairs = Vec::with_capacity(round.len() * bsz);
                        let mut tags = Vec::with_capacity(round.len() * bsz);
                        for (j, &(node, child)) in round.iter().enumerate() {
                            for b in 0..bsz {
                                pairs.push((
                                    acc[node * bsz + b],
                                    resolve(child, b, &prev, &leaf_vals, bsz),
                                ));
                                tags.push(tag0 + b as u64 * m + qoff + j as u64);
                            }
                        }
                        let prods = sess.mul_vec(&pairs);
                        let outs = sess.divpub_vec_tagged(&prods, p.d, &tags);
                        for (j, &(node, _)) in round.iter().enumerate() {
                            for b in 0..bsz {
                                acc[node * bsz + b] = outs[j * bsz + b];
                            }
                        }
                        qoff += round.len() as u64;
                    }
                    prev = acc;
                }
                PlanStep::Sum { width, node_edges } => {
                    let w = *width;
                    let mut pairs = Vec::new();
                    for edges in node_edges {
                        for &(pidx, child) in edges {
                            for b in 0..bsz {
                                pairs.push((
                                    sum_w[pidx],
                                    resolve(child, b, &prev, &leaf_vals, bsz),
                                ));
                            }
                        }
                    }
                    let prods = sess.mul_vec(&pairs);
                    let mut ops: Vec<(i128, Vec<(i128, DataId)>)> =
                        Vec::with_capacity(w * bsz);
                    let mut tags = Vec::with_capacity(w * bsz);
                    let mut off = 0usize;
                    for (i, edges) in node_edges.iter().enumerate() {
                        for b in 0..bsz {
                            let terms: Vec<(i128, DataId)> =
                                (0..edges.len()).map(|e| (1, prods[off + e * bsz + b])).collect();
                            ops.push((0, terms));
                            tags.push(tag0 + b as u64 * m + qoff + i as u64);
                        }
                        off += edges.len() * bsz;
                    }
                    let sums = sess.lin_vec(&ops);
                    prev = sess.divpub_vec_tagged(&sums, p.d, &tags);
                    qoff += w as u64;
                }
            }
        }
        debug_assert_eq!(qoff, m, "plan divpub count must match execution");

        // --- reveal every root to the client -------------------------------
        let roots: Vec<DataId> = prev[..bsz].to_vec(); // root layer width 1
        sess.mark_outputs(&roots); // the posteriors ARE the functionality
        let vals = sess.reveal_vec(&roots);
        let f = sess.field();
        let out: Vec<i128> = vals.into_iter().map(|v| f.to_i128(v)).collect();
        (out, sess.stats().delta_since(&before))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spn::structure::Structure;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    #[test]
    fn compile_mini_demo_shapes() {
        let st = Structure::mini_demo();
        let theta = vec![0.5; st.num_leaves()];
        let plan = EvalPlan::compile(&st, &theta, 256);
        assert_eq!(plan.num_vars, 2);
        assert_eq!(plan.num_leaves, 4);
        assert_eq!(plan.steps.len(), 2);
        // product layer: chains of length 2 → one chain round of 2 links
        match &plan.steps[0] {
            PlanStep::Product { width, first, rounds } => {
                assert_eq!(*width, 2);
                assert_eq!(first, &[Src::Leaf(0), Src::Leaf(2)]);
                assert_eq!(rounds.len(), 1);
                assert_eq!(rounds[0], vec![(0, Src::Leaf(1)), (1, Src::Leaf(3))]);
            }
            s => panic!("expected product step, got {s:?}"),
        }
        match &plan.steps[1] {
            PlanStep::Sum { width, node_edges } => {
                assert_eq!(*width, 1);
                assert_eq!(node_edges[0], vec![(0, Src::Prev(0)), (1, Src::Prev(1))]);
            }
            s => panic!("expected sum step, got {s:?}"),
        }
        // 2 chain-link divpubs + 1 sum divpub per query
        assert_eq!(plan.divpubs_per_query, 3);
        assert_eq!(plan.chain_rounds(), 2);
        // dependency DAG: the product round (qoff 0) must finish before the
        // sum that consumes it (qoff 2) — two waves, no pass-throughs
        assert_eq!(plan.critical_depth(), 2);
        assert_eq!(plan.waves[0], vec![DagUnit { step: 0, round: 0, qoff: 0 }]);
        assert_eq!(plan.waves[1], vec![DagUnit { step: 1, round: 0, qoff: 2 }]);
        assert!(plan.pass_through.iter().flatten().all(|a| a.is_none()));
        assert_eq!(plan.pipelined_sim_rounds(), 6 * 2 + 9);
    }

    #[test]
    fn waves_tile_the_divpub_space_on_toy() {
        let Some(st) = toy() else { return };
        let theta = crate::spn::learn::default_leaf_theta(&st);
        let plan = EvalPlan::compile(&st, &theta, 256);
        // every unit appears in exactly one wave, in plan (= qoff) order,
        // and unit element counts tile [0, divpubs_per_query) exactly
        let mut units: Vec<DagUnit> = plan.waves.iter().flatten().copied().collect();
        units.sort_by_key(|u| u.qoff);
        let mut expect = 0u64;
        for u in &units {
            assert_eq!(u.qoff, expect, "units must tile the sequential tag layout");
            expect += match &plan.steps[u.step] {
                PlanStep::Product { rounds, .. } => rounds[u.round].len() as u64,
                PlanStep::Sum { width, .. } => *width as u64,
            };
        }
        assert_eq!(expect, plan.divpubs_per_query);
        // the critical path can never exceed the sequential step count and
        // every plan has at least one wave (the root sum)
        assert!(plan.critical_depth() >= 1);
        assert!(plan.critical_depth() <= plan.chain_rounds());
        // Causality: a unit may only read node values materialized by a
        // *strictly earlier* wave. Replay the schedule against a defined-
        // set, checking every read against the state as of the previous
        // wave's end (writes of the current wave are invisible to reads —
        // exactly how the executor's vals tables behave).
        let mut defined: Vec<Vec<bool>> = plan
            .steps
            .iter()
            .map(|s| match s {
                PlanStep::Product { width, .. } | PlanStep::Sum { width, .. } => {
                    vec![false; *width]
                }
            })
            .collect();
        let mut acc_rounds: Vec<Vec<usize>> = defined.iter().map(|d| vec![0; d.len()]).collect();
        // chain degree per product node: output exists after the LAST round
        let deg: Vec<Vec<usize>> = plan
            .steps
            .iter()
            .map(|s| match s {
                PlanStep::Product { width, rounds, .. } => {
                    let mut d = vec![1usize; *width];
                    for round in rounds {
                        for &(n, _) in round {
                            d[n] += 1;
                        }
                    }
                    d
                }
                PlanStep::Sum { .. } => Vec::new(),
            })
            .collect();
        for wave in &plan.waves {
            let snap = defined.clone();
            let snap_acc = acc_rounds.clone();
            let avail = |s: usize, c: Src| match c {
                Src::Leaf(_) => true,
                Src::Prev(i) => match plan.pass_through[s - 1][i] {
                    None => snap[s - 1][i],
                    Some(Src::Leaf(_)) => true,
                    Some(Src::Prev(j)) => snap[s - 2][j],
                },
            };
            for u in wave {
                match &plan.steps[u.step] {
                    PlanStep::Product { first, rounds, .. } => {
                        for &(node, child) in &rounds[u.round] {
                            assert!(avail(u.step, child), "child read before materialized");
                            if u.round == 0 {
                                assert!(avail(u.step, first[node]), "seed read early");
                            } else {
                                assert_eq!(
                                    snap_acc[u.step][node],
                                    u.round,
                                    "accumulator must hold exactly the prior rounds"
                                );
                            }
                        }
                        for &(node, _) in &rounds[u.round] {
                            acc_rounds[u.step][node] = u.round + 1;
                            if u.round + 2 == deg[u.step][node] {
                                defined[u.step][node] = true;
                            }
                        }
                    }
                    PlanStep::Sum { width, node_edges } => {
                        for edges in node_edges {
                            for &(_, child) in edges {
                                assert!(avail(u.step, child), "sum child read early");
                            }
                        }
                        for i in 0..*width {
                            defined[u.step][i] = true;
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn tag_stripes_partition_the_space() {
        for shards in [1usize, 2, 3, 4, 7] {
            let gen0: Vec<TagStripe> =
                (0..shards).map(|s| TagStripe::new(s, shards)).collect();
            assert_eq!(gen0[0].base(), 0, "stripe 0 gen 0 starts at tag 0");
            for (s, st) in gen0.iter().enumerate() {
                assert_eq!(
                    st.base(),
                    s as u64 * TagStripe::width(shards),
                    "gen 0 starts at its shard band"
                );
                assert!(st.contains(st.base(), st.base() + 1000));
                assert!(!st.contains(st.limit(), st.limit() + 1));
                assert_eq!(st.limit() - st.base(), TagStripe::gen_width(shards));
            }
            // generations tile each shard band gap-free and stay inside it
            for s in 0..shards {
                let band_lo = s as u64 * TagStripe::width(shards);
                let band_hi = band_lo + TagStripe::width(shards);
                let gens: Vec<TagStripe> = (0..TagStripe::GENERATIONS)
                    .map(|g| TagStripe::generation(s, shards, g))
                    .collect();
                assert_eq!(gens[0].base(), band_lo);
                for w in gens.windows(2) {
                    assert_eq!(w[0].limit(), w[1].base(), "generations tile without gaps");
                }
                assert!(gens.last().expect("GENERATIONS >= 1").limit() <= band_hi);
            }
            // all (shard, generation) stripes are pairwise disjoint
            let all: Vec<TagStripe> = (0..shards)
                .flat_map(|s| {
                    (0..TagStripe::GENERATIONS).map(move |g| TagStripe::generation(s, shards, g))
                })
                .collect();
            for (i, a) in all.iter().enumerate() {
                for b in &all[i + 1..] {
                    assert!(
                        a.limit() <= b.base() || b.limit() <= a.base(),
                        "{a:?} and {b:?} overlap"
                    );
                }
            }
        }
        // generation 0 of a fleet of one starts at tag 0 — the unsharded
        // server's stripe — and a later generation never reaches back
        let whole = TagStripe::new(0, 1);
        assert_eq!(whole.base(), 0);
        assert!(whole.contains(0, TagStripe::gen_width(1)));
        let respawned = TagStripe::generation(0, 1, 1);
        assert_eq!(respawned.base(), whole.limit(), "gen 1 starts where gen 0 ends");
        assert!(!respawned.contains(whole.base(), whole.base() + 1));
    }

    #[test]
    fn compile_counts_divpubs_on_toy() {
        let Some(st) = toy() else { return };
        let theta = crate::spn::learn::default_leaf_theta(&st);
        let plan = EvalPlan::compile(&st, &theta, 256);
        // every non-first product link and every sum node truncates once
        let mut want = 0u64;
        for l in &st.layers {
            match l.kind {
                LayerKind::Product => {
                    let mut deg = vec![0u64; l.width];
                    for &r in &l.rows {
                        deg[r] += 1;
                    }
                    want += deg.iter().map(|&d| d - 1).sum::<u64>();
                }
                LayerKind::Sum => want += l.width as u64,
            }
        }
        assert_eq!(plan.divpubs_per_query, want);
        assert!(plan.leaf_theta_fixed.iter().all(|&t| t <= 256));
    }
}
