//! spn-mpc — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train   --dataset <name> [--members N] [--latency MS] [--batched]
//!           [--learn-leaves] [--native-counts] [--backend sim|tcp]
//!           [--checked] — private parameter learning
//!
//! The `--checked` flag (train/infer/serve/kmeans) wraps the session in
//! the [`CheckedSession`] protocol sanitizer: tag freshness, reveal
//! discipline, phase rules and (sim backend) Tables 2–3 accounting
//! conservation are enforced on every call (DESIGN.md §Static analysis).
//!   infer   --dataset <name> [--members N] [--evidence v=b,...]
//!           [--target v=b,...] [--batch queries.jsonl] [--backend sim|tcp]
//!           — private inference (one query, or a whole batch through the
//!           compiled evaluation plan)
//!   serve   [--dataset <name>] [--members N] [--backend sim|tcp] [--port P]
//!           [--shards S] [--max-batch B] [--max-wait-ms T] [--max-queries Q]
//!           [--respawn] [--probe-interval-ms T] [--fault-plan SPEC]
//!           — train, then run the persistent private-inference service:
//!           concurrent TCP clients, micro-batched over one MPC session
//!           (or a fleet of S sessions with `--shards S`; `--respawn`
//!           revives dead shards into fresh tag-stripe generations,
//!           `--probe-interval-ms` arms idle health probes, and
//!           `--fault-plan` injects a deterministic chaos schedule)
//!   client  --addr host:port [--queries FILE.jsonl | --evidence v=b,...]
//!           [--repeat R] [--concurrency C] [--kill-shard N] [--shutdown]
//!           [--no-retry] — drive (or stop) a running serve instance
//!   kmeans  [--members N] [--k K] [--points P] [--backend sim|tcp]
//!           — private clustering demo
//!   tables  [--members N] — reproduce the paper's Tables 1–3 rows
//!   info    — artifact / runtime status
//!
//! (The vendored crate set has no clap; flags are parsed by hand.)

use std::collections::HashMap;
use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use spn_mpc::coordinator::infer::{private_conditional, private_eval_batch, Query};
use spn_mpc::coordinator::serve::{train_and_serve, train_and_serve_fleet, RespawnBuilder};
use spn_mpc::net::fault::FaultPlan;
use spn_mpc::net::fleet::ShardSever;
use spn_mpc::json::Json;
use spn_mpc::net::serve::{query_from_json, Response, ServeClient, ServeConfig};
use spn_mpc::coordinator::train::{peek_weights, reveal_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::kmeans::{plain_kmeans, private_kmeans, KmeansConfig, PartyData};
use spn_mpc::metrics::{group_thousands, render_table, stats_row};
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::net::NetConfig;
use spn_mpc::protocols::checked::CheckedSession;
use spn_mpc::protocols::division::DivisionConfig;
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::runtime;
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::{eval, learn};

struct Args {
    flags: HashMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.push(name.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { flags, bools }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|s| s.parse().expect("bad number")).unwrap_or(default)
    }

    fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|s| s.parse().expect("bad number")).unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

fn engine_config(args: &Args, n: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new(n);
    cfg.net = NetConfig {
        latency_s: args.f64_or("latency", 10.0) / 1000.0,
        ..NetConfig::default()
    };
    if args.has("batched") {
        cfg.schedule = Schedule::Batched;
    }
    if let Some(t) = args.get("threshold") {
        cfg.threshold = Some(t.parse().expect("bad threshold"));
    }
    // Member-side worker-pool width; results are byte-identical for any
    // value (DESIGN.md §Field kernel).
    cfg.threads = args.usize_or("threads", 1);
    cfg
}

fn tcp_config(args: &Args, n: usize) -> TcpSessionConfig {
    let mut cfg = TcpSessionConfig::new(n);
    if let Some(t) = args.get("threshold") {
        cfg.threshold = Some(t.parse().expect("bad threshold"));
    }
    cfg.threads = args.usize_or("threads", 1);
    // Simulation-only flags have no meaning on real sockets; say so rather
    // than silently ignoring them.
    if args.get("latency").is_some() {
        eprintln!("[backend] note: --latency models the simulation only; tcp runs real links");
    }
    if args.has("batched") {
        eprintln!("[backend] note: --batched selects a simulation schedule; tcp always packs vectors");
    }
    cfg
}

/// The `--backend` flag shared by train/infer/kmeans: `sim` (default, the
/// accounted in-process simulation) or `tcp` (real member threads over
/// loopback sockets; same seed → byte-identical results).
fn backend(args: &Args) -> Result<&str> {
    match args.get("backend").unwrap_or("sim") {
        b @ ("sim" | "tcp") => Ok(b),
        other => bail!("unknown --backend {other} (expected sim|tcp)"),
    }
}

fn load_structure(name: &str) -> Result<Structure> {
    if name == "mini" {
        // The in-code demo structure shared with tests and benches:
        // artifact-free, so serve/infer smoke runs work on a fresh
        // checkout with no python toolchain.
        return Ok(Structure::mini_demo());
    }
    let dir = runtime::default_artifacts_dir();
    Structure::load(dir.join(format!("{name}.structure.json")))
        .map_err(|e| e.context(format!("structure for {name} — run `make artifacts`")))
}

/// Per-party counts: via the PJRT runtime (AOT artifacts) by default, or
/// the native mirror with --native-counts.
fn shard_counts(
    name: &str,
    st: &Structure,
    shards: &[Vec<Vec<u8>>],
    native: bool,
) -> Result<Vec<Vec<u64>>> {
    if native {
        return Ok(shards.iter().map(|s| eval::counts(st, s)).collect());
    }
    let rt = runtime::Runtime::cpu()?;
    let ds = runtime::load_dataset(&rt, runtime::default_artifacts_dir(), name)?;
    eprintln!("[runtime] counts via artifact runtime ({})", rt.platform());
    shards.iter().map(|s| ds.counts.counts(s)).collect()
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("toy");
    let n = args.usize_or("members", 5);
    let st = load_structure(name)?;
    let rows = args.usize_or("rows", st.rows);
    println!("dataset {name}: {:?}", st.stats);

    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, rows, 42);
    let shards = datasets::partition(&data, n);
    let counts = shard_counts(name, &st, &shards, args.has("native-counts"))?;

    let cfg = TrainConfig {
        division: DivisionConfig::default(),
        learn_leaves: args.has("learn-leaves"),
    };
    let t0 = std::time::Instant::now();
    let checked = args.has("checked");
    let (d, got, report) = match backend(args)? {
        "tcp" => {
            let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
            let out = if checked {
                let mut cs = CheckedSession::new(sess);
                let (model, report) = train(&mut cs, &st, &counts, rows as u64, &cfg);
                let got = reveal_weights(&mut cs, &model);
                cs.into_inner().shutdown()?;
                (model.d, got, report)
            } else {
                let mut sess = sess;
                let (model, report) = train(&mut sess, &st, &counts, rows as u64, &cfg);
                let got = reveal_weights(&mut sess, &model);
                sess.shutdown()?;
                (model.d, got, report)
            };
            println!("[backend] tcp: {n} member threads over loopback");
            out
        }
        _ => {
            let ec = engine_config(args, n);
            let eng = Engine::new(Field::paper(), ec);
            if checked {
                let mut cs = CheckedSession::with_sim_accounting(eng, ec.schedule);
                let (model, report) = train(&mut cs, &st, &counts, rows as u64, &cfg);
                (model.d, peek_weights(cs.inner(), &model), report)
            } else {
                let mut eng = eng;
                let (model, report) = train(&mut eng, &st, &counts, rows as u64, &cfg);
                (model.d, peek_weights(&eng, &model), report)
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    let wall = t0.elapsed().as_secs_f64();

    // verification vs centralized oracle
    let global = eval::counts(&st, &data);
    let oracle = learn::ml_weights_fixed(&st, &global, d);
    let max_err = got
        .iter()
        .zip(&oracle)
        .map(|(&g, &o)| (g - o as i128).abs())
        .max()
        .unwrap_or(0);

    println!("members={n} divisions={} sum_edges={}", report.divisions, report.sum_edges);
    println!(
        "messages={} traffic={:.1} MB rounds={} virtual_time={:.0} s (wall {:.2} s)",
        group_thousands(report.stats.messages),
        report.stats.megabytes(),
        report.stats.rounds,
        report.stats.virtual_time_s,
        wall,
    );
    println!("max |private - oracle| over d-scaled sum weights: {max_err} (d={d})");

    // model quality
    let theta = learn::default_leaf_theta(&st);
    let params = learn::params_from_fixed(&st, &got, &theta, d);
    let ml = learn::ml_params(&st, &global);
    println!(
        "mean log-likelihood: private {:.4} vs centralized {:.4} vs ground-truth {:.4}",
        eval::mean_loglik(&st, &data, &params),
        eval::mean_loglik(&st, &data, &ml),
        eval::mean_loglik(&st, &data, &gt),
    );
    Ok(())
}

fn parse_assign(s: &str) -> Result<Vec<(usize, u8)>> {
    s.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| {
            let (v, b) = t.split_once('=').ok_or_else(|| anyhow!("bad assignment {t}"))?;
            Ok((v.parse()?, b.parse()?))
        })
        .collect()
}

/// Parse a JSONL batch-query file: one object per line with `"x"` (0/1
/// assignment) and `"marg"` (true = marginalized) arrays of `num_vars`
/// entries each — the same object schema the serve wire protocol speaks
/// ([`query_from_json`]). Blank lines and `#` comments are skipped.
fn parse_batch_queries(path: &str, num_vars: usize) -> Result<Vec<Query>> {
    let txt = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading batch file {path}: {e}"))?;
    let mut out = Vec::new();
    for (ln, line) in txt.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow!("{path}:{}: {e}", ln + 1))?;
        let q = query_from_json(&j, num_vars)
            .map_err(|e| e.context(format!("{path}:{}", ln + 1)))?;
        out.push(q);
    }
    if out.is_empty() {
        bail!("{path}: no queries");
    }
    Ok(out)
}

/// `infer --batch <jsonl>`: evaluate every query in the file in one
/// compiled-plan batch — the cross-query amortized path (rounds per query
/// shrink ~B×; results are bit-identical to sequential evaluation).
fn cmd_infer_batch(
    args: &Args,
    st: &Structure,
    counts: &[Vec<u64>],
    rows: usize,
    theta: &[f64],
    path: &str,
) -> Result<()> {
    let n = args.usize_or("members", 5);
    // Say so rather than silently ignoring them (same policy as tcp_config).
    if args.get("target").is_some() || args.get("evidence").is_some() {
        eprintln!(
            "[infer] note: --target/--evidence apply to single-query mode; \
             --batch evaluates the file's queries as marginals"
        );
    }
    let queries = parse_batch_queries(path, st.num_vars)?;
    let bsz = queries.len();
    let checked = args.has("checked");
    let (roots, stats, d) = match backend(args)? {
        "tcp" => {
            let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
            let out = if checked {
                let mut cs = CheckedSession::new(sess);
                let (model, _) = train(&mut cs, st, counts, rows as u64, &TrainConfig::default());
                let (roots, stats) = private_eval_batch(&mut cs, st, &model, &queries, theta);
                let dd = model.d;
                cs.into_inner().shutdown()?;
                (roots, stats, dd)
            } else {
                let mut sess = sess;
                let (model, _) = train(&mut sess, st, counts, rows as u64, &TrainConfig::default());
                let (roots, stats) = private_eval_batch(&mut sess, st, &model, &queries, theta);
                let dd = model.d;
                sess.shutdown()?;
                (roots, stats, dd)
            };
            println!("[backend] tcp: {n} member threads over loopback");
            out
        }
        _ => {
            let mut cfg = engine_config(args, n);
            cfg.schedule = Schedule::Batched; // amortization is the point
            let eng = Engine::new(Field::paper(), cfg);
            if checked {
                let mut cs = CheckedSession::with_sim_accounting(eng, cfg.schedule);
                let (model, _) = train(&mut cs, st, counts, rows as u64, &TrainConfig::default());
                let (roots, stats) = private_eval_batch(&mut cs, st, &model, &queries, theta);
                (roots, stats, model.d)
            } else {
                let mut eng = eng;
                let (model, _) = train(&mut eng, st, counts, rows as u64, &TrainConfig::default());
                let (roots, stats) = private_eval_batch(&mut eng, st, &model, &queries, theta);
                (roots, stats, model.d)
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    for (i, (q, &root)) in queries.iter().zip(&roots).enumerate() {
        let ev: Vec<String> = (0..st.num_vars)
            .filter(|&v| !q.marg[v])
            .map(|v| format!("{v}={}", q.x[v]))
            .collect();
        println!(
            "query {i:>3} [{}]: S = {:.4}",
            ev.join(","),
            root.max(0) as f64 / d as f64
        );
    }
    println!(
        "batch of {bsz}: {} messages, {} rounds ({:.1} rounds/query), {:.2} MB, {:.1} s virtual",
        group_thousands(stats.messages),
        stats.rounds,
        stats.rounds as f64 / bsz as f64,
        stats.megabytes(),
        stats.virtual_time_s
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("toy");
    let n = args.usize_or("members", 5);
    let st = load_structure(name)?;
    let rows = args.usize_or("rows", 2000.min(st.rows));

    // train first (quick, batched) to get weight shares
    let counts = synth_shard_counts(&st, n, rows);

    let theta = learn::default_leaf_theta(&st);
    if let Some(path) = args.get("batch") {
        return cmd_infer_batch(args, &st, &counts, rows, &theta, path);
    }
    let target = parse_assign(args.get("target").unwrap_or("0=1"))?;
    let evidence = parse_assign(args.get("evidence").unwrap_or(""))?;

    let checked = args.has("checked");
    let (p, stats, fixed, d) = match backend(args)? {
        "tcp" => {
            let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
            let out = if checked {
                let mut cs = CheckedSession::new(sess);
                let (model, _) = train(&mut cs, &st, &counts, rows as u64, &TrainConfig::default());
                let (p, stats) =
                    private_conditional(&mut cs, &st, &model, &target, &evidence, &theta);
                let fixed = reveal_weights(&mut cs, &model);
                cs.into_inner().shutdown()?;
                (p, stats, fixed, model.d)
            } else {
                let mut sess = sess;
                let (model, _) = train(&mut sess, &st, &counts, rows as u64, &TrainConfig::default());
                let (p, stats) =
                    private_conditional(&mut sess, &st, &model, &target, &evidence, &theta);
                let fixed = reveal_weights(&mut sess, &model);
                sess.shutdown()?;
                (p, stats, fixed, model.d)
            };
            println!("[backend] tcp: {n} member threads over loopback");
            out
        }
        _ => {
            let mut eng_cfg = engine_config(args, n);
            eng_cfg.schedule = Schedule::Batched;
            let eng = Engine::new(Field::paper(), eng_cfg);
            // switch to per-op accounting for the inference cost report
            let infer_schedule =
                if args.has("batched") { Schedule::Batched } else { Schedule::PerOp };
            if checked {
                let mut cs = CheckedSession::with_sim_accounting(eng, eng_cfg.schedule);
                let (model, _) = train(&mut cs, &st, &counts, rows as u64, &TrainConfig::default());
                cs.inner_mut().cfg.schedule = infer_schedule;
                cs.set_sim_schedule(infer_schedule);
                let (p, stats) =
                    private_conditional(&mut cs, &st, &model, &target, &evidence, &theta);
                let fixed = peek_weights(cs.inner(), &model);
                (p, stats, fixed, model.d)
            } else {
                let mut eng = eng;
                let (model, _) = train(&mut eng, &st, &counts, rows as u64, &TrainConfig::default());
                eng.cfg.schedule = infer_schedule;
                let (p, stats) =
                    private_conditional(&mut eng, &st, &model, &target, &evidence, &theta);
                let fixed = peek_weights(&eng, &model);
                (p, stats, fixed, model.d)
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    println!("Pr({target:?} | {evidence:?}) = {p:.4}");

    // oracle comparison
    let params = learn::params_from_fixed(&st, &fixed, &theta, d);
    let mut x = vec![0u8; st.num_vars];
    let mut m_xe = vec![true; st.num_vars];
    let mut m_e = vec![true; st.num_vars];
    for &(v, b) in target.iter().chain(&evidence) {
        x[v] = b;
        m_xe[v] = false;
    }
    for &(v, _) in &evidence {
        m_e[v] = false;
    }
    let want = eval::logeval(&st, &x, &m_xe, &params).exp()
        / eval::logeval(&st, &x, &m_e, &params).exp();
    println!("float oracle: {want:.4}   (fixed-point d = {d})");
    println!(
        "inference cost: {} messages, {:.2} MB, {:.1} s virtual",
        group_thousands(stats.messages),
        stats.megabytes(),
        stats.virtual_time_s
    );
    Ok(())
}

/// The deterministic synthetic training shards `infer` and `serve` share
/// (ground truth seed 7, sample seed 42) — one definition, because the
/// served-vs-direct byte-identity story depends on every command training
/// the same way.
fn synth_shard_counts(st: &Structure, n: usize, rows: usize) -> Vec<Vec<u64>> {
    datasets::synth_shard_counts(st, n, rows, 7, 42)
}

/// `serve`: train, then run the persistent private-inference service —
/// one long-lived MPC session (or, with `--shards S`, a fleet of S
/// sessions behind one front-end), many concurrent TCP clients, a
/// micro-batching scheduler coalescing their queries per tick.
fn cmd_serve(args: &Args) -> Result<()> {
    let name = args.get("dataset").unwrap_or("mini");
    let n = args.usize_or("members", 3);
    let shards = args.usize_or("shards", 1).max(1);
    let st = load_structure(name)?;
    let rows = args.usize_or("rows", 2000.min(st.rows));
    let port = args.usize_or("port", 0);
    if port > u16::MAX as usize {
        bail!("--port {port} out of range (max 65535)");
    }
    let port = port as u16;
    let cfg = ServeConfig {
        max_batch: args.usize_or("max-batch", 16).max(1),
        max_wait: Duration::from_millis(args.usize_or("max-wait-ms", 5) as u64),
        max_queries: args.get("max-queries").map(|s| s.parse().expect("bad --max-queries")),
    };

    let counts = synth_shard_counts(&st, n, rows);
    let theta = learn::default_leaf_theta(&st);
    let tcfg = TrainConfig::default();

    let listener = std::net::TcpListener::bind(("127.0.0.1", port))?;
    let addr = listener.local_addr()?;
    let b = backend(args)?;
    // One parseable line for drivers (tests, CI, scripts) — flushed
    // explicitly because stdout is block-buffered when piped.
    println!(
        "SERVE listening on {addr} dataset={name} num_vars={} members={n} backend={b} \
         max_batch={} max_wait_ms={} shards={shards}",
        st.num_vars,
        cfg.max_batch,
        cfg.max_wait.as_millis()
    );
    std::io::stdout().flush()?;

    // Self-healing knobs force the fleet path even at --shards 1: a
    // single-shard fleet with respawn is the minimal self-healing server.
    let fleet_mode = shards > 1
        || args.has("respawn")
        || args.usize_or("probe-interval-ms", 0) > 0
        || args.get("fault-plan").is_some();
    if fleet_mode {
        return serve_fleet_cli(args, &st, n, shards, &counts, rows, &tcfg, &theta, listener, &cfg);
    }
    let checked = args.has("checked");
    let report = match b {
        "tcp" => {
            let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
            let report = if checked {
                let mut cs = CheckedSession::new(sess);
                let (report, _) =
                    train_and_serve(&mut cs, &st, &counts, rows as u64, &tcfg, &theta, listener, &cfg)?;
                cs.into_inner().shutdown()?;
                report
            } else {
                let mut sess = sess;
                let (report, _) =
                    train_and_serve(&mut sess, &st, &counts, rows as u64, &tcfg, &theta, listener, &cfg)?;
                sess.shutdown()?;
                report
            };
            println!("[backend] tcp: {n} member threads joined");
            report
        }
        _ => {
            let mut ec = engine_config(args, n);
            ec.schedule = Schedule::Batched; // a standing service amortizes
            let eng = Engine::new(Field::paper(), ec);
            if checked {
                let mut cs = CheckedSession::with_sim_accounting(eng, ec.schedule);
                let (report, _) =
                    train_and_serve(&mut cs, &st, &counts, rows as u64, &tcfg, &theta, listener, &cfg)?;
                report
            } else {
                let mut eng = eng;
                let (report, _) =
                    train_and_serve(&mut eng, &st, &counts, rows as u64, &tcfg, &theta, listener, &cfg)?;
                report
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    println!(
        "serve: clean shutdown — {} queries from {} client(s) in {} batches (max tick {}), \
         {} messages / {} rounds total",
        report.queries,
        report.clients,
        report.batches,
        report.max_tick,
        group_thousands(report.stats.messages),
        report.stats.rounds
    );
    Ok(())
}

/// The `--shards S` arm of `serve`: S replicated sessions behind the
/// fleet front-end. Dead shards (chaos kills, member failures) are torn
/// down lossily; the clean-shutdown line still prints.
#[allow(clippy::too_many_arguments)]
fn serve_fleet_cli(
    args: &Args,
    st: &Structure,
    n: usize,
    shards: usize,
    counts: &[Vec<u64>],
    rows: usize,
    tcfg: &TrainConfig,
    theta: &[f64],
    listener: std::net::TcpListener,
    cfg: &ServeConfig,
) -> Result<()> {
    let checked = args.has("checked");
    let want_respawn = args.has("respawn");
    let probe_ms = args.usize_or("probe-interval-ms", 0);
    let probe = (probe_ms > 0).then(|| Duration::from_millis(probe_ms as u64));
    let fault_plan = match args.get("fault-plan") {
        Some(spec) => {
            let plan = FaultPlan::parse(spec, shards)?;
            eprintln!("[fleet] fault plan armed: {}", plan.summary());
            Some(plan)
        }
        None => None,
    };
    let report = match backend(args)? {
        "tcp" => {
            let mut raw = Vec::with_capacity(shards);
            let mut severs: Vec<Option<ShardSever>> = Vec::with_capacity(shards);
            for _ in 0..shards {
                let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
                // Sever handles are taken BEFORE any sanitizer wrapping:
                // they cut the transport underneath the session and do not
                // go through the MpcSession vocabulary.
                let h = sess.sever_handle()?;
                severs.push(Some(Box::new(move || h.sever())));
                raw.push(sess);
            }
            let (report, shutdowns) = if checked {
                let mut sessions: Vec<CheckedSession<TcpSession>> =
                    raw.into_iter().map(CheckedSession::new).collect();
                let respawn = want_respawn.then(|| RespawnBuilder {
                    build: Box::new(move |_s| {
                        let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
                        let h = sess.sever_handle()?;
                        let sever: ShardSever = Box::new(move || h.sever());
                        Ok((CheckedSession::new(sess), Some(sever)))
                    }),
                    reap: Arc::new(|cs: CheckedSession<TcpSession>, dead: bool| {
                        let sess = cs.into_inner();
                        if dead {
                            sess.shutdown_lossy();
                        } else if let Err(e) = sess.shutdown() {
                            eprintln!("[fleet] replacement shutdown: {e}");
                        }
                    }),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, st, counts, rows as u64, tcfg, theta, listener, cfg, severs,
                    respawn, probe, fault_plan,
                )?;
                let inner: Vec<TcpSession> =
                    sessions.into_iter().map(CheckedSession::into_inner).collect();
                (report, inner)
            } else {
                let mut sessions = raw;
                let respawn = want_respawn.then(|| RespawnBuilder {
                    build: Box::new(move |_s| {
                        let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
                        let h = sess.sever_handle()?;
                        let sever: ShardSever = Box::new(move || h.sever());
                        Ok((sess, Some(sever)))
                    }),
                    reap: Arc::new(|sess: TcpSession, dead: bool| {
                        if dead {
                            sess.shutdown_lossy();
                        } else if let Err(e) = sess.shutdown() {
                            eprintln!("[fleet] replacement shutdown: {e}");
                        }
                    }),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, st, counts, rows as u64, tcfg, theta, listener, cfg, severs,
                    respawn, probe, fault_plan,
                )?;
                (report, sessions)
            };
            for (s, sess) in shutdowns.into_iter().enumerate() {
                // A shard that died OR respawned orphaned its gen-0
                // transport — only the lossy teardown is safe for it.
                let ps = &report.per_shard[s];
                if ps.dead || ps.respawns > 0 {
                    sess.shutdown_lossy();
                } else {
                    sess.shutdown()?;
                }
            }
            println!("[backend] tcp: {shards}×{n} member threads joined");
            report
        }
        _ => {
            let build = move |_: usize| {
                let mut ec = engine_config(args, n);
                ec.schedule = Schedule::Batched;
                (Engine::new(Field::paper(), ec), ec.schedule)
            };
            if checked {
                let mut sessions: Vec<CheckedSession<Engine>> = (0..shards)
                    .map(|s| {
                        let (eng, sched) = build(s);
                        CheckedSession::with_sim_accounting(eng, sched)
                    })
                    .collect();
                let respawn = want_respawn.then(|| RespawnBuilder {
                    build: Box::new(move |s| {
                        let (eng, sched) = build(s);
                        Ok((CheckedSession::with_sim_accounting(eng, sched), None))
                    }),
                    reap: Arc::new(|_sess: CheckedSession<Engine>, _dead: bool| {}),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, st, counts, rows as u64, tcfg, theta, listener, cfg, Vec::new(),
                    respawn, probe, fault_plan,
                )?;
                report
            } else {
                let mut sessions: Vec<Engine> = (0..shards).map(|s| build(s).0).collect();
                let respawn = want_respawn.then(|| RespawnBuilder {
                    build: Box::new(move |s| Ok((build(s).0, None))),
                    reap: Arc::new(|_sess: Engine, _dead: bool| {}),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, st, counts, rows as u64, tcfg, theta, listener, cfg, Vec::new(),
                    respawn, probe, fault_plan,
                )?;
                report
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    let probes: u64 = report.per_shard.iter().map(|r| r.probes).sum();
    println!(
        "serve: clean shutdown — {} queries from {} client(s) in {} batches (max tick {}), \
         {} messages / {} rounds total, {} shard(s) ({} dead, {} re-dispatched), \
         {} respawn(s), {} probe(s)",
        report.queries,
        report.clients,
        report.batches,
        report.max_tick,
        group_thousands(report.stats.messages),
        report.stats.rounds,
        report.shards,
        report.dead_shards,
        report.redispatched,
        report.respawns,
        probes
    );
    for (s, ps) in report.per_shard.iter().enumerate() {
        if ps.dead || ps.respawns > 0 || ps.panic_msg.is_some() {
            println!(
                "  shard {s}: {}, {} respawn(s){}{}",
                if ps.dead { "dead" } else { "revived" },
                ps.respawns,
                match &ps.panic_msg {
                    Some(m) => format!(" — last death: {m}"),
                    None => String::new(),
                },
                if ps.links.is_empty() {
                    String::new()
                } else {
                    format!(" — links {:?}", ps.links)
                }
            );
        }
    }
    Ok(())
}

/// Is this error reply a transient fleet condition — a shard died with
/// the query aboard, or a respawn window briefly left no live shard —
/// that a retry can outwait? Transport errors (connection gone) are NOT
/// transient: the fleet front-end outlives its shards, so a dead socket
/// means the server itself went away.
fn is_transient_fleet_error(e: &anyhow::Error) -> bool {
    let s = e.to_string();
    s.contains("server error")
        && (s.contains("died") || s.contains("no live shards") || s.contains("no surviving shards"))
}

/// One query with capped doubling backoff on transient fleet errors
/// (shard death, respawn in progress) — the `client` default; `--no-retry`
/// restores fail-fast. Worst case ~20 attempts over ~6 s, which covers a
/// mini-demo respawn retrain with generous margin.
fn query_with_retry(c: &mut ServeClient, q: &Query, retry: bool) -> Result<Response> {
    let mut delay = Duration::from_millis(10);
    for _ in 0..20 {
        match c.query(q) {
            Ok(r) => return Ok(r),
            Err(e) if retry && is_transient_fleet_error(&e) => {
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(400));
            }
            Err(e) => return Err(e),
        }
    }
    c.query(q)
}

/// `client`: drive a running `serve` instance — single queries from
/// `--evidence`, whole JSONL files, repeated and spread over concurrent
/// connections, or `--shutdown` to stop the server. Transient fleet
/// errors (a shard died holding the query) are retried with backoff
/// unless `--no-retry` is given.
fn cmd_client(args: &Args) -> Result<()> {
    let addr =
        args.get("addr").ok_or_else(|| anyhow!("client needs --addr host:port"))?.to_string();
    if args.has("shutdown") {
        ServeClient::connect(&addr)?.shutdown_server()?;
        println!("client: server acknowledged shutdown");
        return Ok(());
    }
    if let Some(ks) = args.get("kill-shard") {
        let shard: usize = ks.parse().map_err(|_| anyhow!("bad --kill-shard {ks}"))?;
        ServeClient::connect(&addr)?.kill_shard(shard)?;
        println!("client: server acknowledged kill-shard {shard}");
        return Ok(());
    }
    let probe = ServeClient::connect(&addr)?;
    let num_vars = probe.hello.num_vars;
    println!(
        "client: connected to {addr} (model {}, {} vars, d={}, server max_batch {})",
        probe.hello.name, num_vars, probe.hello.d, probe.hello.max_batch
    );

    let base: Vec<Query> = if let Some(path) = args.get("queries") {
        parse_batch_queries(path, num_vars)?
    } else {
        let evidence = parse_assign(args.get("evidence").unwrap_or("0=1"))?;
        let mut x = vec![0u8; num_vars];
        let mut marg = vec![true; num_vars];
        for &(v, bit) in &evidence {
            if v >= num_vars {
                bail!("--evidence variable {v} out of range (model has {num_vars} vars)");
            }
            x[v] = bit;
            marg[v] = false;
        }
        vec![Query { x, marg }]
    };
    let repeat = args.usize_or("repeat", 1).max(1);
    let queries: Vec<Query> = (0..repeat).flat_map(|_| base.iter().cloned()).collect();
    let conc = args.usize_or("concurrency", 1).clamp(1, queries.len());
    let retry = !args.has("no-retry");

    let t0 = Instant::now();
    let mut results: Vec<(usize, Response, f64)> = Vec::with_capacity(queries.len());
    if conc == 1 {
        let mut c = probe;
        for (i, q) in queries.iter().enumerate() {
            let tq = Instant::now();
            let resp = query_with_retry(&mut c, q, retry)?;
            results.push((i, resp, tq.elapsed().as_secs_f64()));
        }
    } else {
        drop(probe); // each worker owns its own connection
        let queries = Arc::new(queries);
        let mut handles = Vec::new();
        for t in 0..conc {
            let addr = addr.clone();
            let queries = queries.clone();
            handles.push(std::thread::spawn(move || -> Result<Vec<(usize, Response, f64)>> {
                let mut c = ServeClient::connect(&addr)?;
                let mut out = Vec::new();
                let mut i = t;
                while i < queries.len() {
                    let tq = Instant::now();
                    let resp = query_with_retry(&mut c, &queries[i], retry)?;
                    out.push((i, resp, tq.elapsed().as_secs_f64()));
                    i += conc;
                }
                Ok(out)
            }));
        }
        for h in handles {
            results.extend(h.join().map_err(|_| anyhow!("client thread panicked"))??);
        }
        results.sort_by_key(|r| r.0);
    }
    let wall = t0.elapsed().as_secs_f64();
    for (i, r, lat) in &results {
        println!(
            "q{i:04} p={:.6} root={} batch={} seq={} latency_ms={:.2}",
            r.p,
            r.root,
            r.batch,
            r.seq,
            lat * 1e3
        );
    }
    let mut lats: Vec<f64> = results.iter().map(|r| r.2).collect();
    lats.sort_by(f64::total_cmp);
    let pct = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize] * 1e3;
    let max_tick = results.iter().map(|r| r.1.batch).max().unwrap_or(0);
    println!(
        "client: {} queries over {conc} connection(s) in {:.3} s ({:.1} q/s), \
         p50 {:.2} ms, p99 {:.2} ms, max served batch {max_tick}",
        results.len(),
        wall,
        results.len() as f64 / wall,
        pct(0.50),
        pct(0.99)
    );
    Ok(())
}

fn cmd_kmeans(args: &Args) -> Result<()> {
    let n = args.usize_or("members", 3);
    let k = args.usize_or("k", 3);
    let pts = args.usize_or("points", 300);
    use spn_mpc::rng::{Prng, Rng};
    let mut rng = Prng::seed_from_u64(9);
    let centers = [(100i64, 200i64), (800, 300), (400, 900)];
    let all: Vec<Vec<i64>> = (0..pts)
        .map(|i| {
            let (cx, cy) = centers[i % k.min(3)];
            vec![
                cx + rng.gen_range_u64(120) as i64 - 60,
                cy + rng.gen_range_u64(120) as i64 - 60,
            ]
        })
        .collect();
    let mut parties = vec![PartyData { points: vec![] }; n];
    for (i, p) in all.iter().enumerate() {
        parties[i % n].points.push(p.clone());
    }
    let init: Vec<Vec<i64>> =
        (0..k).map(|i| vec![500 + 13 * i as i64, 500 - 17 * i as i64]).collect();

    let cfg = KmeansConfig { k, iters: 10, division: DivisionConfig::default() };
    let checked = args.has("checked");
    let out = match backend(args)? {
        "tcp" => {
            let sess = TcpSession::spawn_local(Field::paper(), tcp_config(args, n))?;
            let out = if checked {
                let mut cs = CheckedSession::new(sess);
                let out = private_kmeans(&mut cs, &parties, &init, &cfg);
                cs.into_inner().shutdown()?;
                out
            } else {
                let mut sess = sess;
                let out = private_kmeans(&mut sess, &parties, &init, &cfg);
                sess.shutdown()?;
                out
            };
            println!("[backend] tcp: {n} member threads over loopback");
            out
        }
        _ => {
            let ec = engine_config(args, n);
            let eng = Engine::new(Field::paper(), ec);
            if checked {
                let mut cs = CheckedSession::with_sim_accounting(eng, ec.schedule);
                private_kmeans(&mut cs, &parties, &init, &cfg)
            } else {
                let mut eng = eng;
                private_kmeans(&mut eng, &parties, &init, &cfg)
            }
        }
    };
    if checked {
        println!("[checked] CheckedSession sanitizer active: no contract violations");
    }
    let plain = plain_kmeans(&all, &init, 10);
    println!("private centroids: {:?}", out.centroids);
    println!("plain   centroids: {plain:?}");
    println!(
        "iterations {} | {} messages, {:.2} MB, {:.1} s virtual",
        out.iterations_run,
        group_thousands(out.stats.messages),
        out.stats.megabytes(),
        out.stats.virtual_time_s
    );
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let datasets_ = ["nltcs", "jester", "baudio", "bnetflix"];
    // Table 1
    let mut rows1 = Vec::new();
    for name in datasets_ {
        let st = load_structure(name)?;
        rows1.push(vec![
            name.to_string(),
            st.stats.sum.to_string(),
            st.stats.product.to_string(),
            st.stats.leaf.to_string(),
            st.stats.params.to_string(),
            st.stats.edges.to_string(),
            st.stats.layers.to_string(),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Table 1: structure statistics (generated; matches paper exactly)",
            &["Dataset", "sum", "product", "leaf", "params", "edges", "layers"],
            &rows1
        )
    );

    for &n in &[13usize, 5] {
        if let Some(only) = args.get("members") {
            if only.parse::<usize>().ok() != Some(n) {
                continue;
            }
        }
        let mut rows = Vec::new();
        for name in datasets_ {
            let st = load_structure(name)?;
            let gt = datasets::ground_truth_params(&st, 7);
            let data = datasets::sample(&st, &gt, st.rows, 42);
            let shards = datasets::partition(&data, n);
            let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
            let mut eng = Engine::new(Field::paper(), engine_config(args, n));
            let (_, report) =
                train(&mut eng, &st, &counts, st.rows as u64, &TrainConfig::default());
            rows.push(stats_row(name, &report.stats));
        }
        println!(
            "{}",
            render_table(
                &format!(
                    "Table {}: training cost, {n} members + manager, latency 10 ms",
                    if n == 13 { 2 } else { 3 }
                ),
                &["Dataset", "Amount messages", "size (MB)", "time (s)"],
                &rows
            )
        );
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = runtime::default_artifacts_dir();
    println!("artifacts dir: {dir:?}");
    match runtime::read_manifest(&dir) {
        Ok(infos) => {
            for i in infos {
                println!(
                    "  {}: vars={} params={} batch={} counts_out={}",
                    i.name, i.num_vars, i.num_params, i.batch, i.counts_out
                );
            }
        }
        Err(e) => println!("  no manifest: {e}"),
    }
    match runtime::Runtime::cpu() {
        Ok(rt) => println!("runtime platform: {}", rt.platform()),
        Err(e) => println!("runtime unavailable: {e}"),
    }
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    match cmd {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "kmeans" => cmd_kmeans(&args),
        "tables" => cmd_tables(&args),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            println!(
                "spn-mpc — private SPN parameter learning & inference (paper reproduction)\n\
                 usage: spn-mpc <train|infer|serve|client|kmeans|tables|info> [flags]\n\
                 common flags: --dataset <mini|toy|nltcs|jester|baudio|bnetflix> --members N\n\
                 \t--latency MS --batched --learn-leaves --native-counts --rows N\n\
                 \t--threads T (worker-pool width per party for the k-loops;\n\
                 \t    byte-identical results for any T, default 1)\n\
                 \t--backend sim|tcp (train/infer/serve/kmeans; default sim = accounted\n\
                 \t    simulation, tcp = real member threads over loopback sockets\n\
                 \t    running the same protocol byte-identically)\n\
                 \t--checked (train/infer/serve/kmeans: wrap the session in the\n\
                 \t    CheckedSession protocol sanitizer — tag freshness, reveal\n\
                 \t    discipline, phase rules, accounting conservation)\n\
                 \t(--dataset mini is the in-code demo structure: no artifacts needed)\n\
                 infer flags: --target v=b,... --evidence v=b,...\n\
                 \t--batch FILE.jsonl (one {{\"x\": [...], \"marg\": [...]}} per line:\n\
                 \t    all queries evaluate in ONE compiled-plan batch — rounds per\n\
                 \t    query shrink ~B×, results identical to sequential evaluation)\n\
                 serve flags: --port P (0 = ephemeral, printed) --max-batch B\n\
                 \t--max-wait-ms T --max-queries Q (trains, then serves concurrent\n\
                 \t    clients from one persistent MPC session: queued queries\n\
                 \t    coalesce into one compiled-plan batch per scheduler tick)\n\
                 \t--shards S (fleet of S replicated sessions behind one front-end)\n\
                 \t--respawn (self-heal: a dead shard is retrained by deterministic\n\
                 \t    replay into a fresh tag-stripe generation and re-admitted)\n\
                 \t--probe-interval-ms T (idle health probes: a no-op secure round\n\
                 \t    quarantines a dead shard before real queries reach it; 0 = off)\n\
                 \t--fault-plan SPEC (deterministic chaos schedule, comma-separated:\n\
                 \t    sever:S@W | delay:S@W:MS | panic:S@W | seeded:SEED[:HORIZON])\n\
                 client flags: --addr host:port [--queries FILE.jsonl | --evidence v=b,...]\n\
                 \t--repeat R --concurrency C --shutdown (stop the server)\n\
                 \t--kill-shard N (chaos: sever shard N) --no-retry (fail fast\n\
                 \t    instead of backing off on shard-death error replies)\n\
                 kmeans flags: --k K --points P"
            );
            Ok(())
        }
        other => bail!("unknown command {other}; try `spn-mpc help`"),
    }
}
