//! Sum-product network substrate (§2.3 of the paper).
//!
//! * [`graph`]     — node-based SPN DAG with validation (completeness,
//!   decomposability, selectivity) and exact evaluation; includes the
//!   paper's Figure-1 network as a constructor.
//! * [`structure`] — the layered dense structure format shared with the
//!   python compile path (`artifacts/<name>.structure.json`).
//! * [`eval`]      — batched layered evaluation in rust: bottom-up
//!   positivity, top-down activation, counts (the plaintext mirror of the
//!   AOT'd counts artifact) and log-domain evaluation.
//! * [`learn`]     — the closed-form ML weights of Eq. (2) from counts,
//!   plus dataset log-likelihood.

pub mod eval;
pub mod graph;
pub mod learn;
pub mod structure;

pub use structure::{Layer, LayerKind, ParamKind, Structure};
