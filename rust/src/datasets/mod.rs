//! Synthetic DEBD-equivalent datasets and the horizontal partitioner.
//!
//! The paper trains on four DEBD binary datasets (nltcs, jester, baudio,
//! bnetflix) which are not available in this environment; per the
//! substitution rule (DESIGN.md) we generate synthetic binary data with the
//! same dimensions and row counts.  To make parameter learning meaningful
//! (not just uniform noise), rows are sampled *from a ground-truth SPN* over
//! the same structure via ancestral sampling — so the ML weights the
//! protocol recovers have a known target and the e2e driver can report
//! recovery error and held-out log-likelihood.

use crate::rng::{Prng, Rng};
use crate::spn::structure::{LayerKind, Structure};

/// Ground-truth parameters for sampling: random Dirichlet-ish sum weights,
/// claim-consistent gate thetas, uniform-ish plain-leaf thetas.
pub fn ground_truth_params(st: &Structure, seed: u64) -> Vec<f64> {
    let mut rng = Prng::seed_from_u64(seed ^ 0x9a5c_93d1);
    let mut p = vec![0.0f64; st.num_params];
    for g in &st.sum_groups {
        let mut tot = 0.0;
        for &i in g {
            p[i] = 0.1 + rng.gen_f64();
            tot += p[i];
        }
        for &i in g {
            p[i] /= tot;
        }
    }
    for i in 0..st.num_leaves() {
        p[st.num_sum_edges + i] = match st.leaf_claim[i] {
            1 => 0.97,
            0 => 0.03,
            _ => 0.15 + 0.7 * rng.gen_f64(),
        };
    }
    p
}

/// Ancestral sampling from the (tree-structured, selective) SPN: walk the
/// tree from the root; at a sum node pick a child by weight; at a product
/// node descend into all children; at a leaf sample its Bernoulli. Gate
/// leaves force their claimed value, so the sampled instance activates
/// exactly the chosen branch — matching the counting semantics.
pub fn sample(st: &Structure, params: &[f64], n: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut rng = Prng::seed_from_u64(seed);
    let nl = st.layers.len();
    // Pre-index children per (layer, node).
    let mut children: Vec<Vec<Vec<(usize, i64)>>> = Vec::with_capacity(nl);
    for l in &st.layers {
        let mut per = vec![Vec::new(); l.width];
        for ((&r, &c), &p) in l.rows.iter().zip(&l.cols).zip(&l.param) {
            per[r].push((c, p));
        }
        children.push(per);
    }

    (0..n)
        .map(|_| {
            let mut x: Vec<u8> =
                (0..st.num_vars).map(|_| rng.gen_bool(0.5) as u8).collect();
            // visit stack of (layer, node); layer == 0 means leaf index space
            let mut stack = vec![(nl, 0usize)];
            while let Some((li, node)) = stack.pop() {
                if li == 0 {
                    // leaf: sample/force its variable
                    let v = st.leaf_var[node];
                    x[v] = match st.leaf_claim[node] {
                        1 => 1,
                        0 => 0,
                        _ => rng.gen_bool(params[st.num_sum_edges + node]) as u8,
                    };
                    continue;
                }
                let l = &st.layers[li - 1];
                let prev_w = if li - 1 > 0 { st.layer_widths[li - 1] } else { 0 };
                match l.kind {
                    LayerKind::Sum => {
                        // weighted choice among children
                        let ch = &children[li - 1][node];
                        let mut u = rng.gen_f64();
                        let mut pick = ch[ch.len() - 1].0;
                        for &(c, pid) in ch {
                            let w = params[pid as usize];
                            if u < w {
                                pick = c;
                                break;
                            }
                            u -= w;
                        }
                        if pick < prev_w {
                            stack.push((li - 1, pick));
                        } else {
                            stack.push((0, pick - prev_w));
                        }
                    }
                    LayerKind::Product => {
                        for &(c, _) in &children[li - 1][node] {
                            if c < prev_w {
                                stack.push((li - 1, c));
                            } else {
                                stack.push((0, c - prev_w));
                            }
                        }
                    }
                }
            }
            x
        })
        .collect()
}

/// Horizontal partition of a dataset into `n` near-equal shards — the
/// paper's data distribution model (§1: each party owns a subset of rows).
/// Deterministic synthetic per-party training shards in one step:
/// ground-truth params from `gt_seed`, `rows` rows sampled with
/// `sample_seed`, an `n`-way partition, native counts per shard. This is
/// the single definition behind every oracle-vs-served byte-identity
/// comparison (serve tests, cross-backend tests, the `serve_throughput`
/// bench, the CLI) — divergent copies would silently train different
/// models and break those comparisons.
pub fn synth_shard_counts(
    st: &Structure,
    n: usize,
    rows: usize,
    gt_seed: u64,
    sample_seed: u64,
) -> Vec<Vec<u64>> {
    let gt = ground_truth_params(st, gt_seed);
    let data = sample(st, &gt, rows, sample_seed);
    let shards = partition(&data, n);
    shards.iter().map(|s| crate::spn::eval::counts(st, s)).collect()
}

pub fn partition(data: &[Vec<u8>], n: usize) -> Vec<Vec<Vec<u8>>> {
    let mut shards: Vec<Vec<Vec<u8>>> = vec![Vec::new(); n];
    for (i, row) in data.iter().enumerate() {
        shards[i % n].push(row.clone());
    }
    shards
}

/// Skewed partition (party 0 gets `frac` of the rows): ablation for the
/// approximate path's iid assumption (§3.2).
pub fn partition_skewed(data: &[Vec<u8>], n: usize, frac: f64) -> Vec<Vec<Vec<u8>>> {
    assert!(n >= 2);
    let head = ((data.len() as f64) * frac) as usize;
    let mut shards: Vec<Vec<Vec<u8>>> = vec![Vec::new(); n];
    shards[0] = data[..head].to_vec();
    for (i, row) in data[head..].iter().enumerate() {
        shards[1 + i % (n - 1)].push(row.clone());
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spn::{eval, learn};

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    #[test]
    fn sampling_recovers_generator_weights() {
        let Some(st) = toy() else { return };
        let gt = ground_truth_params(&st, 7);
        let data = sample(&st, &gt, 20_000, 42);
        let cnt = eval::counts(&st, &data);
        let ml = learn::ml_params(&st, &cnt);
        for g in &st.sum_groups {
            // only groups with enough mass are statistically testable
            let den = cnt[st.param_den[g[0]]];
            if den < 2000 {
                continue;
            }
            for &k in g {
                assert!(
                    (ml[k] - gt[k]).abs() < 0.03,
                    "param {k}: ml {} vs gt {}",
                    ml[k],
                    gt[k]
                );
            }
        }
    }

    #[test]
    fn sample_is_deterministic_per_seed() {
        let Some(st) = toy() else { return };
        let gt = ground_truth_params(&st, 1);
        assert_eq!(sample(&st, &gt, 50, 9), sample(&st, &gt, 50, 9));
        assert_ne!(sample(&st, &gt, 50, 9), sample(&st, &gt, 50, 10));
    }

    #[test]
    fn partition_covers_all_rows() {
        let Some(st) = toy() else { return };
        let gt = ground_truth_params(&st, 2);
        let data = sample(&st, &gt, 101, 3);
        let shards = partition(&data, 5);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 101);
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn skewed_partition_respects_fraction() {
        let Some(st) = toy() else { return };
        let gt = ground_truth_params(&st, 2);
        let data = sample(&st, &gt, 1000, 3);
        let shards = partition_skewed(&data, 4, 0.7);
        assert_eq!(shards[0].len(), 700);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 1000);
    }
}
