//! Artifact runtime: load and execute the AOT'd counts/eval graphs from
//! rust.
//!
//! This is the Layer-3 ↔ Layer-2 bridge. `make artifacts` lowers the JAX
//! counts/eval graphs (which call the Pallas layer kernels) to HLO *text*
//! plus a structure/manifest JSON bundle; this module loads that bundle and
//! executes the graphs so python never runs on the request path.
//!
//! Two execution backends (see DESIGN.md §Hardware-Adaptation):
//!
//! * **native** (default) — a rust interpreter with the exact semantics of
//!   the artifacts: the counts graph's fixed-batch chunking + tail row
//!   masking, and the eval graph's shape contract, over the structure
//!   matrices baked into the artifact. The kernel math is shared with
//!   [`crate::spn::eval`], which the python side's reference tests pin to
//!   the Pallas kernels — so the two backends are cross-checked by
//!   construction and the integration tests assert their counts agree.
//! * **pjrt** (feature `pjrt`) — compiles the HLO text through a PJRT CPU
//!   client via a vendored `xla` crate. That crate is not present in this
//!   image (no crates.io access), so enabling the feature is a guarded
//!   compile error until the vendor drop lands; the text interchange
//!   format is chosen for it (jax ≥ 0.5 protos carry 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; text round-trips cleanly).
//!
//! Artifact contract (what `python/compile/aot.py` emits per dataset):
//!
//! * `<name>.structure.json` — the layered structure shared with rust;
//! * `<name>.counts.hlo.txt` — `(X:(B,nv) f32, row_mask:(B,) f32) → counts`;
//! * `<name>.eval.hlo.txt` — `(X:(B,nv), marg:(nv,), params:(P,)) → logS`;
//! * `manifest.json` — batch size, shapes, file list.

#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate (PJRT CPU client), \
     which is not present in this build environment; see DESIGN.md \
     §Hardware-Adaptation for the backend plan"
);

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;
use crate::spn::eval;
use crate::spn::structure::Structure;

/// Artifact bundle for one dataset structure, as listed in `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Dataset name (`toy`, `nltcs`, ...).
    pub name: String,
    /// Fixed batch size the graphs were lowered with.
    pub batch: usize,
    /// Number of input variables.
    pub num_vars: usize,
    /// Total parameter count (sum-edge weights then leaf thetas).
    pub num_params: usize,
    /// Length of the counts output vector.
    pub counts_out: usize,
    /// Path to the structure JSON.
    pub structure_path: PathBuf,
    /// Path to the counts-graph HLO text.
    pub counts_hlo: PathBuf,
    /// Path to the eval-graph HLO text.
    pub eval_hlo: PathBuf,
}

/// Parse `artifacts/manifest.json` into the per-dataset artifact list.
pub fn read_manifest(dir: impl AsRef<Path>) -> Result<Vec<ArtifactInfo>> {
    let dir = dir.as_ref();
    let txt = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {:?}/manifest.json — run `make artifacts`", dir))?;
    let j = Json::parse(&txt).map_err(|e| anyhow!("{e}"))?;
    let mut out = Vec::new();
    if let Json::Obj(ds) = j.get("datasets") {
        for (name, info) in ds {
            out.push(ArtifactInfo {
                name: name.clone(),
                batch: info.get("batch").as_usize(),
                num_vars: info.get("num_vars").as_usize(),
                num_params: info.get("num_params").as_usize(),
                counts_out: info.get("counts_out").as_usize(),
                structure_path: dir.join(info.get("structure").as_str()),
                counts_hlo: dir.join(info.get("counts_hlo").as_str()),
                eval_hlo: dir.join(info.get("eval_hlo").as_str()),
            });
        }
    }
    Ok(out)
}

/// The execution client. On the native backend this is a stateless handle;
/// the `pjrt` backend owns the PJRT client here.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Create a CPU execution client.
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { _private: () })
    }

    /// Human-readable backend/platform name.
    pub fn platform(&self) -> String {
        "cpu (native interpreter; `pjrt` feature off)".to_string()
    }

    /// Load the counts graph for one dataset.
    pub fn load_counts(&self, info: &ArtifactInfo) -> Result<CountsExe> {
        let structure = Structure::load(&info.structure_path)?;
        self.counts_from(info, structure)
    }

    /// Load the eval graph for one dataset.
    pub fn load_eval(&self, info: &ArtifactInfo) -> Result<EvalExe> {
        let structure = Structure::load(&info.structure_path)?;
        self.eval_from(info, structure)
    }

    fn counts_from(&self, info: &ArtifactInfo, structure: Structure) -> Result<CountsExe> {
        anyhow::ensure!(
            structure.counts_len() == info.counts_out,
            "manifest counts_out {} disagrees with structure ({})",
            info.counts_out,
            structure.counts_len()
        );
        anyhow::ensure!(
            structure.num_vars == info.num_vars,
            "manifest num_vars disagrees with structure"
        );
        Ok(CountsExe {
            structure,
            batch: info.batch,
            num_vars: info.num_vars,
            out_len: info.counts_out,
        })
    }

    fn eval_from(&self, info: &ArtifactInfo, structure: Structure) -> Result<EvalExe> {
        anyhow::ensure!(
            structure.num_params == info.num_params,
            "manifest num_params disagrees with structure"
        );
        anyhow::ensure!(
            structure.num_vars == info.num_vars,
            "manifest num_vars disagrees with structure"
        );
        Ok(EvalExe {
            structure,
            batch: info.batch,
            num_vars: info.num_vars,
            num_params: info.num_params,
        })
    }
}

/// Loaded counts graph: `(X:(B,nv) f32, row_mask:(B,) f32) → (counts,)`.
pub struct CountsExe {
    structure: Structure,
    /// Fixed batch size of the lowered graph.
    pub batch: usize,
    /// Number of input variables per row.
    pub num_vars: usize,
    /// Length of the counts output vector.
    pub out_len: usize,
}

impl CountsExe {
    /// Counts over a shard of any size. The shard is fed through the
    /// graph's contract — fixed-size batches, tail rows masked out — and
    /// the per-batch count vectors are accumulated. The chunk loop below
    /// deliberately mirrors that PJRT fixed-batch executable contract
    /// (one call per `batch` rows) even though the native interpreter
    /// could evaluate the whole shard at once, so the call pattern and
    /// the `chunked == whole` invariant stay pinned for the `pjrt`
    /// backend to drop into.
    pub fn counts(&self, shard: &[Vec<u8>]) -> Result<Vec<u64>> {
        let mut acc = vec![0u64; self.out_len];
        for chunk in shard.chunks(self.batch) {
            for row in chunk {
                anyhow::ensure!(row.len() == self.num_vars, "row width mismatch");
            }
            // Masked rows contribute zero to every count, so the per-chunk
            // result equals the native counts of the chunk alone.
            let vals = eval::counts(&self.structure, chunk);
            anyhow::ensure!(vals.len() == self.out_len, "counts output length mismatch");
            for (a, v) in acc.iter_mut().zip(vals) {
                *a += v;
            }
        }
        Ok(acc)
    }
}

/// Loaded eval graph: `(X, marg, params) → (log S per row,)`.
pub struct EvalExe {
    structure: Structure,
    /// Fixed batch size of the lowered graph.
    pub batch: usize,
    /// Number of input variables per row.
    pub num_vars: usize,
    /// Expected parameter vector length.
    pub num_params: usize,
}

impl EvalExe {
    /// Log-likelihoods for up to `batch` rows — the graph's fixed-batch
    /// contract (the `pjrt` backend pads to `batch` and slices the result;
    /// the native interpreter evaluates exactly the rows given, which is
    /// equivalent). Returns one `log S(x)` per input row.
    pub fn logeval(&self, rows: &[Vec<u8>], marg: &[bool], params: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(rows.len() <= self.batch, "eval chunk too large");
        anyhow::ensure!(params.len() == self.num_params, "params length mismatch");
        anyhow::ensure!(marg.len() == self.num_vars, "marg length mismatch");
        Ok(rows.iter().map(|row| eval::logeval(&self.structure, row, marg, params)).collect())
    }

    /// Mean log-likelihood over an arbitrary-size dataset (chunked).
    pub fn mean_loglik(&self, data: &[Vec<u8>], params: &[f64]) -> Result<f64> {
        let marg = vec![false; self.num_vars];
        let mut tot = 0.0;
        for chunk in data.chunks(self.batch) {
            tot += self.logeval(chunk, &marg, params)?.iter().sum::<f64>();
        }
        Ok(tot / data.len() as f64)
    }
}

/// Convenience bundle: structure + counts + eval graphs for one dataset.
pub struct DatasetRuntime {
    /// The parsed, validated structure.
    pub structure: Structure,
    /// The loaded counts graph.
    pub counts: CountsExe,
    /// The loaded eval graph.
    pub eval: EvalExe,
}

/// Load structure + counts + eval for one dataset name from `dir`. The
/// structure JSON is parsed once and shared with both graphs.
pub fn load_dataset(rt: &Runtime, dir: impl AsRef<Path>, name: &str) -> Result<DatasetRuntime> {
    let infos = read_manifest(&dir)?;
    let info = infos
        .iter()
        .find(|i| i.name == name)
        .ok_or_else(|| anyhow!("dataset {name} not in manifest"))?;
    let structure = Structure::load(&info.structure_path)?;
    let counts = rt.counts_from(info, structure.clone())?;
    let eval = rt.eval_from(info, structure.clone())?;
    Ok(DatasetRuntime { structure, counts, eval })
}

/// Default artifacts directory (crate root / `artifacts`).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_if_present() {
        let Ok(infos) = read_manifest(default_artifacts_dir()) else { return };
        assert!(infos.iter().any(|i| i.name == "toy"));
        for i in &infos {
            assert!(i.batch > 0 && i.counts_out > 0);
        }
    }

    #[test]
    fn missing_manifest_is_an_error_not_a_panic() {
        let err = read_manifest("/definitely/not/a/real/dir").unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn chunked_counts_equal_whole_shard_counts() {
        // The fixed-batch chunking + masking contract: counts must not
        // depend on the batch split. Exercised against the native mirror
        // whenever artifacts are present.
        let Ok(infos) = read_manifest(default_artifacts_dir()) else { return };
        let Some(info) = infos.iter().find(|i| i.name == "toy") else { return };
        let rt = Runtime::cpu().unwrap();
        let exe = rt.load_counts(info).unwrap();
        let st = Structure::load(&info.structure_path).unwrap();
        let gt = crate::datasets::ground_truth_params(&st, 3);
        // 700 rows: not a multiple of the 512 batch → exercises tail masking
        let data = crate::datasets::sample(&st, &gt, 700, 99);
        let chunked = exe.counts(&data).unwrap();
        let whole = crate::spn::eval::counts(&st, &data);
        assert_eq!(chunked, whole);
    }
}
