"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps the kernel over shapes, sparsity patterns and modes;
every case asserts allclose against kernels/ref.py.  On images without
`hypothesis` the sweep tests skip and the deterministic cases still run
(same degrade-gracefully contract as the rust artifact tests).
"""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline image without hypothesis: skip the sweeps only

    class _St:
        """Stand-in for hypothesis.strategies: arguments are ignored."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _St()

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    def given(*_args, **_kwargs):
        def deco(fn):
            def wrapper():
                pytest.skip("hypothesis not installed")

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

from compile.kernels import ref
from compile.kernels import spn_layer as K

MODES = [K.MODE_NONE, K.MODE_OR, K.MODE_AND, K.MODE_GATE]


def _case(rng, b, in_w, out_w, density):
    x = (rng.random((b, in_w)) < 0.5).astype(np.float32)
    m = (rng.random((out_w, in_w)) < density).astype(np.float32)
    # ensure no empty rows (real layers always have >= 1 child)
    for r in range(out_w):
        if m[r].sum() == 0:
            m[r, rng.integers(0, in_w)] = 1.0
    deg = m.sum(axis=1).astype(np.float32)
    gate = (rng.random((b, out_w)) < 0.5).astype(np.float32)
    return x, m, deg, gate


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("b,in_w,out_w", [(128, 16, 8), (256, 74, 26), (128, 301, 40)])
def test_layer_apply_matches_ref(mode, b, in_w, out_w):
    rng = np.random.default_rng(42 + mode)
    x, m, deg, gate = _case(rng, b, in_w, out_w, 0.2)
    got = K.layer_apply(jnp.asarray(x), jnp.asarray(m.T), jnp.asarray(deg),
                        jnp.asarray(gate), mode)
    want = ref.layer_apply_ref(jnp.asarray(x), jnp.asarray(m.T),
                               jnp.asarray(deg), jnp.asarray(gate), mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    b_blocks=st.integers(1, 3),
    in_w=st.integers(1, 96),
    out_w=st.integers(1, 48),
    density=st.floats(0.05, 0.9),
    mode=st.sampled_from(MODES),
    seed=st.integers(0, 2**31 - 1),
)
def test_layer_apply_hypothesis(b_blocks, in_w, out_w, density, mode, seed):
    rng = np.random.default_rng(seed)
    b = 128 * b_blocks
    x, m, deg, gate = _case(rng, b, in_w, out_w, density)
    got = K.layer_apply(jnp.asarray(x), jnp.asarray(m.T), jnp.asarray(deg),
                        jnp.asarray(gate), mode)
    want = ref.layer_apply_ref(jnp.asarray(x), jnp.asarray(m.T),
                               jnp.asarray(deg), jnp.asarray(gate), mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b_blocks=st.integers(1, 4),
    w=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_count_hypothesis(b_blocks, w, seed):
    rng = np.random.default_rng(seed)
    b = 128 * b_blocks
    a = rng.random((b, w)).astype(np.float32)
    mask = (rng.random(b) < 0.7).astype(np.float32)
    got = K.masked_count(jnp.asarray(a), jnp.asarray(mask))
    want = ref.masked_count_ref(jnp.asarray(a), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_masked_count_accumulates_across_grid():
    """Multiple batch tiles must accumulate, not overwrite."""
    b, w = 512, 5
    a = np.ones((b, w), dtype=np.float32)
    mask = np.ones(b, dtype=np.float32)
    got = np.asarray(K.masked_count(jnp.asarray(a), jnp.asarray(mask), block_b=128))
    np.testing.assert_allclose(got, np.full(w, b, dtype=np.float32))


def test_mode_and_requires_all_children():
    b, out_w, in_w = 128, 1, 3
    m = np.ones((out_w, in_w), dtype=np.float32)
    deg = m.sum(axis=1).astype(np.float32)
    x = np.zeros((b, in_w), dtype=np.float32)
    x[:, :2] = 1.0    # 2 of 3 children active -> AND is false
    gate = np.zeros((b, out_w), dtype=np.float32)
    got = np.asarray(K.layer_apply(jnp.asarray(x), jnp.asarray(m.T),
                                   jnp.asarray(deg), jnp.asarray(gate), K.MODE_AND))
    assert (got == 0).all()
    x[:, 2] = 1.0
    got = np.asarray(K.layer_apply(jnp.asarray(x), jnp.asarray(m.T),
                                   jnp.asarray(deg), jnp.asarray(gate), K.MODE_AND))
    assert (got == 1).all()


def test_mode_or_any_child():
    b, out_w, in_w = 128, 2, 4
    m = np.zeros((out_w, in_w), dtype=np.float32)
    m[0, 0] = 1.0
    m[1, 2] = m[1, 3] = 1.0
    deg = m.sum(axis=1).astype(np.float32)
    x = np.zeros((b, in_w), dtype=np.float32)
    x[:, 3] = 1.0
    gate = np.zeros((b, out_w), dtype=np.float32)
    got = np.asarray(K.layer_apply(jnp.asarray(x), jnp.asarray(m.T),
                                   jnp.asarray(deg), jnp.asarray(gate), K.MODE_OR))
    assert (got[:, 0] == 0).all() and (got[:, 1] == 1).all()


def test_vmem_footprint_reported():
    bt, in_w, out_w = 128, 320, 64
    fb = K.vmem_footprint_bytes(bt, in_w, out_w)
    # must fit a 16 MiB VMEM budget comfortably for Table-1 sized layers
    assert fb < 16 * 2**20
