//! MPC data-plane throughput — the §Perf iteration-3 instrument
//! (EXPERIMENTS.md).
//!
//! Measures elements/sec for the vectorized session primitives over both
//! backends at k ∈ {1, 64, 4096} and n ∈ {3, 5, 13}:
//!
//! * `share_batch` — raw flat-buffer dealing ([`ShamirCtx::share_batch_into`]),
//!   no session around it: the data-plane kernel in isolation;
//! * `mul_vec` / `divpub_vec` — the full secure primitives through the
//!   `Batched` simulated engine (`sim`) and through real loopback TCP
//!   member threads (`tcp`);
//! * `pipelined mul+div` — the same work coalesced into one flight
//!   (`submit`/`complete`, DESIGN.md §Round scheduler): identical traffic,
//!   fewer lockstep synchronization points per call.
//!
//! Never skips (no artifacts needed). `--json <path>` writes the
//! `{bench, metric, value}` rows `make bench-json` commits as
//! BENCH_mpc_throughput.json — the data-plane perf trajectory baseline.
//! `--smoke` shrinks to k ∈ {1, 8}, n = 3 with 3 iterations: CI runs this
//! mode so the bench binary and its JSON schema cannot rot.
//!
//! Threads dimension (§Perf iteration 7): every session/dealing shape runs
//! at threads ∈ {1, 4}. `thr1` keeps the legacy metric names; pooled rows
//! append `_thr4` *before* the unit suffix, keeping the backend token at
//! split index 2 for the CI schema check. Before anything is timed, a
//! byte-identity anchor asserts the pooled paths reproduce the serial
//! bytes exactly.
//!
//! `--gate <baseline.json>` compares the `mul_vec_sim_*` and
//! `share_batch_local_*` elems/s rows just measured against a committed
//! baseline and exits nonzero on a >3× regression — the CI perf-smoke
//! tripwire (thresholded loosely: CI runners are noisy, 3× is rot, not
//! jitter).

use spn_mpc::bench::{throughput, time_it, JsonSink};
use spn_mpc::field::Field;
use spn_mpc::json::Json;
use spn_mpc::metrics::render_table;
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::parallel::Pool;
use spn_mpc::protocols::engine::{DataId, Engine, EngineConfig};
use spn_mpc::protocols::flight::FlightOp;
use spn_mpc::protocols::session::MpcSession;
use spn_mpc::rng::Prng;
use spn_mpc::sharing::shamir::ShamirCtx;

/// (warmup, measured) iteration counts, scaled down as k grows so the
/// whole sweep stays in bench-budget territory.
fn iters_for(k: usize, smoke: bool) -> (u32, u32) {
    if smoke {
        (1, 3)
    } else if k >= 4096 {
        (2, 10)
    } else if k >= 64 {
        (2, 50)
    } else {
        (3, 200)
    }
}

fn fmt_eps(eps: f64) -> String {
    if eps >= 1e6 {
        format!("{:.2} M elems/s", eps / 1e6)
    } else if eps >= 1e3 {
        format!("{:.1} k elems/s", eps / 1e3)
    } else {
        format!("{eps:.0} elems/s")
    }
}

/// Time `mul_vec` and `divpub_vec` at width k on one session backend.
/// `suffix` is the threads-dimension tag (`""` for the serial legacy rows,
/// `"_thr4"` for the pooled ones); it sits before the unit suffix so the
/// backend token stays at metric-name split index 2. Gate-relevant
/// measurements are mirrored into `measured` (the JsonSink drops rows
/// when `--json` is absent, the gate must not).
fn bench_session<S: MpcSession>(
    backend: &str,
    suffix: &str,
    sess: &mut S,
    n: usize,
    k: usize,
    smoke: bool,
    json: &mut JsonSink,
    rows: &mut Vec<Vec<String>>,
    measured: &mut Vec<(String, f64)>,
) {
    let avals: Vec<u128> = (0..k as u128).map(|i| i * 7 + 3).collect();
    let bvals: Vec<u128> = (0..k as u128).map(|i| i * 11 + 1).collect();
    let a = sess.input_vec(1, &avals);
    let b = sess.input_vec(2, &bvals);
    let pairs: Vec<(DataId, DataId)> =
        a.iter().copied().zip(b.iter().copied()).collect();
    let (wu, it) = iters_for(k, smoke);

    let s = time_it(wu, it, || sess.mul_vec(&pairs));
    let eps = throughput(&s, k as u64);
    let metric = format!("mul_vec_{backend}_n{n}_k{k}{suffix}_elems_per_s");
    json.push("mpc_throughput", &metric, eps);
    measured.push((metric, eps));
    rows.push(vec![
        format!("mul_vec (n={n})"),
        format!("{backend}{suffix}"),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    let s = time_it(wu, it, || sess.divpub_vec(&a, 256));
    let eps = throughput(&s, k as u64);
    json.push("mpc_throughput", &format!("divpub_vec_{backend}_n{n}_k{k}{suffix}_elems_per_s"), eps);
    rows.push(vec![
        format!("divpub_vec (n={n})"),
        format!("{backend}{suffix}"),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    // Pipelined dimension (DESIGN.md §Round scheduler): the same mul +
    // truncation work coalesced into ONE flight — one schedule broadcast,
    // one ordered relay pass — instead of two standalone round-trips. The
    // traffic is identical; what this row measures is the wall-clock win
    // of halving the lockstep synchronization points.
    let s = time_it(wu, it, || {
        let t0 = sess.reserve_tags(k as u64);
        let prods = sess.submit(FlightOp::Mul(pairs.clone()));
        let tags: Vec<u64> = (0..k as u64).map(|i| t0 + i).collect();
        let outs = sess.submit(FlightOp::DivpubTagged { us: prods, d: 256, tags });
        sess.complete();
        outs[0]
    });
    let eps = throughput(&s, k as u64);
    json.push(
        "mpc_throughput",
        &format!("pipelined_mul_div_{backend}_n{n}_k{k}{suffix}_elems_per_s"),
        eps,
    );
    rows.push(vec![
        format!("pipelined mul+div (n={n})"),
        format!("{backend}{suffix}"),
        k.to_string(),
        fmt_eps(eps),
        s.per_iter_str(),
    ]);

    // Correctness anchor: the path we just timed must still reveal the
    // right values (mul is exact; divpub is ±1 around avals[0]·bvals[0]/d).
    let prod = sess.mul_vec(&pairs[..1])[0];
    assert_eq!(sess.reveal_vec(&[prod]), vec![avals[0] * bvals[0]], "{backend} n={n} k={k}");
    let q = sess.divpub(prod, 256);
    let got = sess.reveal_int(q);
    let want = (avals[0] * bvals[0] / 256) as i128;
    assert!((got - want).abs() <= 1, "{backend} n={n} k={k}: divpub {got} vs {want}");

    // ... and so must the flight path it raced against.
    let t0 = sess.reserve_tags(1);
    let fp = sess.submit(FlightOp::Mul(pairs[..1].to_vec()));
    let fq = sess.submit(FlightOp::DivpubTagged { us: fp.clone(), d: 256, tags: vec![t0] });
    sess.complete();
    assert_eq!(sess.reveal_vec(&fp), vec![avals[0] * bvals[0]], "{backend} n={n} k={k} flight");
    let got = sess.reveal_int(fq[0]);
    assert!((got - want).abs() <= 1, "{backend} n={n} k={k}: flight divpub {got} vs {want}");
}

/// The threads-dimension sweep: serial first (legacy metric names), then
/// the 4-wide pool with a `_thr4` metric tag.
const THREADS: [usize; 2] = [1, 4];

fn thr_suffix(thr: usize) -> String {
    if thr == 1 {
        String::new()
    } else {
        format!("_thr{thr}")
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let mut json = JsonSink::from_env_args();
    let ks: Vec<usize> = if smoke { vec![1, 8] } else { vec![1, 64, 4096] };
    let ns: Vec<usize> = if smoke { vec![3] } else { vec![3, 5, 13] };
    let f = Field::paper();
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut measured: Vec<(String, f64)> = Vec::new();

    // Correctness anchor for the threads dimension: before timing anything,
    // the pooled engine must reproduce the serial engine's bytes exactly
    // (input → mul_vec → reveal over a pool-sized batch).
    {
        let run = |threads: usize| -> Vec<u128> {
            let mut e = Engine::new(f, EngineConfig::new(3).batched().with_threads(threads));
            let avals: Vec<u128> = (0..1500u128).map(|i| i * 3 + 1).collect();
            let bvals: Vec<u128> = (0..1500u128).map(|i| i * 5 + 2).collect();
            let a = e.input_vec(1, &avals);
            let b = e.input_vec(2, &bvals);
            let pairs: Vec<(DataId, DataId)> =
                a.iter().copied().zip(b.iter().copied()).collect();
            let prods = e.mul_vec(&pairs);
            e.reveal_vec(&prods)
        };
        assert_eq!(run(1), run(4), "threads=4 engine must be byte-identical to serial");
    }

    // --- raw flat-buffer dealing, no session ------------------------------
    for &n in &ns {
        let ctx = ShamirCtx::new(f, n);
        for &k in &ks {
            let secrets: Vec<u128> = (0..k as u128).map(|i| i * 97 + 5).collect();
            let (wu, it) = iters_for(k, smoke);
            for &thr in &THREADS {
                let suffix = thr_suffix(thr);
                let pool = Pool::new(thr);
                let mut rng = Prng::seed_from_u64(7);
                let mut out = vec![0u128; n * k];
                let mut coeffs: Vec<u128> = Vec::new();
                if thr > 1 {
                    // Byte-identity anchor for the pooled dealer: same
                    // seed, same flat buffer as a serial deal.
                    let mut r_ref = Prng::seed_from_u64(7);
                    let mut want = vec![0u128; n * k];
                    ctx.share_batch_into(&secrets, ctx.t, &mut r_ref, &mut want);
                    ctx.share_batch_into_pooled(
                        &secrets, ctx.t, &mut rng, &mut out, &mut coeffs, pool,
                    );
                    assert_eq!(out, want, "pooled dealing must match serial bytes (n={n} k={k})");
                    rng = Prng::seed_from_u64(7);
                }
                let s = time_it(wu, it, || {
                    ctx.share_batch_into_pooled(
                        &secrets, ctx.t, &mut rng, &mut out, &mut coeffs, pool,
                    );
                    out[0]
                });
                let eps = throughput(&s, k as u64);
                let metric = format!("share_batch_local_n{n}_k{k}{suffix}_elems_per_s");
                json.push("mpc_throughput", &metric, eps);
                measured.push((metric, eps));
                json.push(
                    "mpc_throughput",
                    &format!("share_batch_local_n{n}_k{k}{suffix}_ns_per_dealt_share"),
                    s.mean_s * 1e9 / (n * k) as f64,
                );
                rows.push(vec![
                    format!("share_batch (n={n})"),
                    format!("local{suffix}"),
                    k.to_string(),
                    fmt_eps(eps),
                    s.per_iter_str(),
                ]);
            }
        }
    }

    // --- full secure primitives, both backends ----------------------------
    for &n in &ns {
        for &k in &ks {
            for &thr in &THREADS {
                let suffix = thr_suffix(thr);
                let mut eng =
                    Engine::new(f, EngineConfig::new(n).batched().with_threads(thr));
                bench_session(
                    "sim", &suffix, &mut eng, n, k, smoke, &mut json, &mut rows, &mut measured,
                );

                let mut tcp =
                    TcpSession::spawn_local(f, TcpSessionConfig::new(n).with_threads(thr))
                        .expect("spawn tcp session");
                bench_session(
                    "tcp", &suffix, &mut tcp, n, k, smoke, &mut json, &mut rows, &mut measured,
                );
                tcp.shutdown().expect("tcp shutdown");
            }
        }
    }

    println!(
        "{}",
        render_table(
            "MPC data-plane throughput (flat-buffer dealing, dense stores, buffered TCP)",
            &["primitive", "backend", "k", "throughput", "latency/call"],
            &rows
        )
    );
    json.finish().expect("write --json output");

    // --- perf gate (CI tripwire) ------------------------------------------
    // `--gate <baseline.json>`: for every `mul_vec_sim_*` / `share_batch_local_*`
    // elems/s metric present in BOTH the baseline and this run, fail on a
    // >3× regression. Metrics only one side has (different k sweep, new
    // thr rows) and the provenance marker row are skipped.
    if let Some(gi) = args.iter().position(|a| a == "--gate") {
        let path = args.get(gi + 1).expect("--gate needs a baseline path");
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("--gate {path}: {e}"));
        let base = Json::parse(&text).unwrap_or_else(|e| panic!("--gate {path}: {e:?}"));
        let mut checked = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for row in base.as_arr() {
            let metric = row.get("metric").as_str().to_string();
            let gated = (metric.starts_with("mul_vec_sim_")
                || metric.starts_with("share_batch_local_"))
                && metric.ends_with("_elems_per_s");
            if !gated {
                continue;
            }
            let Some((_, got)) = measured.iter().find(|(m, _)| *m == metric) else {
                continue;
            };
            let want = row.get("value").as_f64();
            checked += 1;
            if *got < want / 3.0 {
                failures.push(format!(
                    "{metric}: measured {got:.1} elems/s < baseline {want:.1} / 3"
                ));
            }
        }
        if !failures.is_empty() {
            for f in &failures {
                eprintln!("[gate] REGRESSION {f}");
            }
            eprintln!("[gate] {} of {checked} gated metrics regressed >3×", failures.len());
            std::process::exit(1);
        }
        println!("[gate] {checked} gated metrics within 3× of {path}");
    }
    println!("mpc_throughput OK");
}
