//! The transport-agnostic MPC session API (DESIGN.md §Session API).
//!
//! Every protocol in this crate — training (Eq. (3)/§3.4), inference (§4),
//! k-means (§6), the Newton inverse — is written against [`MpcSession`],
//! the vectorized primitive vocabulary the coordinators actually use:
//! `input_vec`, local affine ops (`lin_vec`), `mul_vec`, `divpub_vec` (and
//! its order-invariant `divpub_vec_tagged` + `reserve_tags` pair, used by
//! the compiled-plan batch evaluator), `reveal_vec`, `sq2pq_vec`, plus
//! [`MpcSession::stats`] for cost accounting. Two first-class
//! implementations exist:
//!
//! * [`SimSession`] (= [`Engine`]) — the in-process Manager/Member
//!   simulation with the paper-exact message/byte/round accounting of
//!   Tables 2–3. **Authoritative for all reported numbers.**
//! * [`crate::net::tcp_session::TcpSession`] — a Manager-side driver plus
//!   one OS thread per member speaking the framed TCP protocol of
//!   [`crate::net::tcp`]. The deployment path: the same coordinator code
//!   runs unchanged over real sockets and, under the same seed, produces
//!   **byte-identical** shares, weights and posteriors (asserted by the
//!   cross-backend integration tests).
//!
//! The scalar operations (`mul`, `divpub`, `lin`, …) are provided methods
//! that delegate to their `_vec` counterparts, exactly like the engine's
//! inherent wrappers do — so generic protocol code has the same accounting
//! as code written directly against [`Engine`].

use crate::field::Field;
use crate::net::NetStats;

use super::engine::{DataId, Engine};
use super::flight::FlightOp;

/// Protocol phase a session is operating in, declared by the coordinator
/// via [`MpcSession::declare_phase`]. Raw backends ignore it; the
/// [`CheckedSession`](super::checked::CheckedSession) sanitizer uses it to
/// enforce the divpub mode discipline: **Inference** permits tagged
/// divpubs only (the order-invariance contract of the compiled-plan batch
/// evaluator), while **Training** also admits the stream-order untagged
/// `divpub_vec` the Eq.-(3)/k-means paths use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionPhase {
    /// Parameter learning / k-means: untagged stream-order divpub allowed.
    Training,
    /// Compiled-plan inference: every divpub must carry fresh tags.
    Inference,
}

/// The in-process simulation backend is the engine itself; the alias makes
/// call sites explicit about which side of the Sim/Tcp pair they are on.
pub type SimSession = Engine;

/// A live MPC session: one Manager (the caller) driving `n` members that
/// each hold a private share store and RNG.
///
/// Semantics contract (shared by both implementations, and what the
/// byte-identical cross-backend tests pin): member `i ∈ 1..=n` holds
/// Shamir evaluation point `i`, deals with an RNG seeded
/// `seed ^ i·0x9E3779B97F4A7C15`, and each primitive draws randomness in
/// the same per-member order. Transport failures in a remote backend abort
/// the session via panic — the session API mirrors the engine's infallible
/// signatures; see `net::tcp_session` for the rationale.
pub trait MpcSession {
    /// Number of computing members (the Manager is not a member).
    fn n(&self) -> usize;

    /// The prime field all shares live in.
    fn field(&self) -> Field;

    /// Party `owner` (1-based) Shamir-deals its private values.
    fn input_vec(&mut self, owner: usize, values: &[u128]) -> Vec<DataId>;

    /// A public constant as a (constant-polynomial) shared value. Local.
    fn constant(&mut self, c: u128) -> DataId;

    /// Vectorized affine exercise: each entry is `(c0, [(ck, ak), ...])`
    /// computing `c0 + Σ ck·[ak]`. Local math, but a scheduled exercise.
    fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId>;

    /// Secure multiplication (BGW resharing) for all pairs.
    fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId>;

    /// Division by a public `d` (§3.4) for all values.
    fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId>;

    /// Order-invariant [`MpcSession::divpub_vec`]: element `e`'s mask is
    /// derived as `PRF(session seed, tags[e])`
    /// ([`crate::protocols::divpub::tagged_r`]) instead of the next draw of
    /// Alice's RNG stream. Same wire shape and accounting; the revealed ±1
    /// rounding of each element becomes a function of its *tag* rather than
    /// of global evaluation order — which is what lets the compiled-plan
    /// batch evaluator coalesce many queries' divisions into one call while
    /// staying bit-identical to sequential evaluation (DESIGN.md
    /// §Evaluation Plan). Tags must never be reused for different inputs
    /// (mask reuse would let Bob difference two openings); allocate them
    /// via [`MpcSession::reserve_tags`].
    fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId>;

    /// Allocate `count` fresh divpub tags and return the first: a monotone
    /// per-session counter, so every reservation is disjoint from every
    /// earlier one. Local bookkeeping — no traffic.
    fn reserve_tags(&mut self, count: u64) -> u64;

    /// Reveal to the manager; returns the reconstructions.
    fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128>;

    /// SQ2PQ: convert per-party additive contributions (`local_values[i]`
    /// is member i's vector) into polynomial shares of the sums.
    fn sq2pq_vec(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId>;

    /// Running cost totals; diff two snapshots (see
    /// [`NetStats::delta_since`]) to cost a protocol. For [`SimSession`]
    /// this is the paper-exact Tables 2–3 accounting; for the TCP backend
    /// it counts the actual relayed frames.
    fn stats(&self) -> NetStats;

    /// Transport health per member link
    /// ([`MemberLinkState`](crate::net::MemberLinkState)), for fleet
    /// monitoring. Backends without real links (the Sim engine) report an
    /// empty vector; [`crate::net::tcp_session::TcpSession`] reports one
    /// state per member.
    fn link_states(&self) -> Vec<crate::net::MemberLinkState> {
        Vec::new()
    }

    // --- sanitizer hooks (default no-ops; bookkeeping only) --------------
    // CheckedSession overrides these three to enforce the protocol
    // contracts; raw backends inherit the no-ops, so calling them costs
    // nothing and changes nothing — bit-identity by construction.

    /// Declare the protocol phase ([`SessionPhase`]) the following calls
    /// belong to. Pure bookkeeping: no traffic, no accounting, and raw
    /// backends ignore it entirely.
    fn declare_phase(&mut self, _phase: SessionPhase) {}

    /// Mark `ids` as protocol **outputs** — values whose reveal is part of
    /// the functionality (learned weights, batch roots, centroids). The
    /// sanitizer only permits revealing marked ids (the paper's §4
    /// security argument needs intermediates to stay shared). No-op on raw
    /// backends.
    fn mark_outputs(&mut self, _ids: &[DataId]) {}

    /// Confine every future tag reservation to `lo..hi` — the fleet's
    /// per-shard [`crate::spn::plan::TagStripe`] handoff. No-op on raw
    /// backends (stripes are already disjoint by construction; the
    /// sanitizer turns an escape into a panic instead of silent reuse).
    fn confine_tags(&mut self, _lo: u64, _hi: u64) {}

    // --- the flight surface (pipelined round engine) ---------------------
    // DESIGN.md §Round scheduler. Defaults make every backend correct out
    // of the box: `submit` executes the op immediately through the trait's
    // own vectorized methods and `complete` is a no-op, so a backend
    // without a coalescing transport pays exactly the sequential cost.
    // Engine and TcpSession override the pair to coalesce the staged ops'
    // traffic into one flight per round (Engine: rounds re-attributed to
    // `flight::sim_flight_rounds`; TCP: one instruction frame per member
    // for the whole flight, relays driven back-to-back).

    /// Stage one operation into the current flight and return its output
    /// ids immediately. Ids are Manager-assigned, so a later `submit` in
    /// the same flight may reference an earlier one's outputs; values are
    /// only guaranteed computed after [`MpcSession::complete`]. Ops must
    /// be non-empty.
    fn submit(&mut self, op: FlightOp) -> Vec<DataId> {
        match op {
            FlightOp::Mul(pairs) => self.mul_vec(&pairs),
            FlightOp::Lin(ops) => self.lin_vec(&ops),
            FlightOp::DivpubTagged { us, d, tags } => self.divpub_vec_tagged(&us, d, &tags),
        }
    }

    /// Launch and drain the current flight: after this returns, every
    /// staged op's outputs are materialized shares. A barrier — the next
    /// `submit` starts a new flight. No-op when nothing is staged.
    fn complete(&mut self) {}

    // --- provided scalar conveniences (same delegation as the engine) ----

    /// Scalar [`MpcSession::lin_vec`].
    fn lin(&mut self, c0: i128, terms: &[(i128, DataId)]) -> DataId {
        self.lin_vec(&[(c0, terms.to_vec())])[0]
    }

    /// `[a] + [b]` (local affine exercise).
    fn add(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (1, b)])
    }

    /// `[a] - [b]` (local affine exercise).
    fn sub(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (-1, b)])
    }

    /// Scalar [`MpcSession::mul_vec`].
    fn mul(&mut self, a: DataId, b: DataId) -> DataId {
        self.mul_vec(&[(a, b)])[0]
    }

    /// Scalar [`MpcSession::divpub_vec`].
    fn divpub(&mut self, u: DataId, d: u128) -> DataId {
        self.divpub_vec(&[u], d)[0]
    }

    /// Scalar [`MpcSession::reveal_vec`].
    fn reveal(&mut self, a: DataId) -> u128 {
        self.reveal_vec(&[a])[0]
    }

    /// Reveal interpreted as a signed small integer (protocol outputs are).
    fn reveal_int(&mut self, a: DataId) -> i128 {
        let f = self.field();
        let v = self.reveal(a);
        f.to_i128(v)
    }
}

impl MpcSession for Engine {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn field(&self) -> Field {
        self.field
    }

    fn input_vec(&mut self, owner: usize, values: &[u128]) -> Vec<DataId> {
        Engine::input(self, owner, values)
    }

    fn constant(&mut self, c: u128) -> DataId {
        Engine::constant(self, c)
    }

    fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId> {
        Engine::lin_vec(self, ops)
    }

    fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId> {
        Engine::mul_vec(self, pairs)
    }

    fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId> {
        Engine::divpub_vec(self, us, d)
    }

    fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId> {
        Engine::divpub_vec_tagged(self, us, d, tags)
    }

    fn reserve_tags(&mut self, count: u64) -> u64 {
        Engine::reserve_tags(self, count)
    }

    fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128> {
        Engine::reveal_vec(self, ids)
    }

    fn sq2pq_vec(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId> {
        Engine::sq2pq_inputs(self, local_values)
    }

    fn stats(&self) -> NetStats {
        self.net.stats
    }

    fn submit(&mut self, op: FlightOp) -> Vec<DataId> {
        Engine::flight_submit(self, op)
    }

    fn complete(&mut self) {
        Engine::flight_complete(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::protocols::engine::EngineConfig;

    /// A protocol written only against the trait must behave exactly like
    /// the same calls made on the engine's inherent API (same values, same
    /// accounting) — the redesign's compatibility contract.
    fn generic_mad<S: MpcSession>(sess: &mut S, a: u128, b: u128, d: u128) -> i128 {
        let ia = sess.input_vec(1, &[a])[0];
        let ib = sess.input_vec(2, &[b])[0];
        let prod = sess.mul(ia, ib);
        let q = sess.divpub(prod, d);
        sess.reveal_int(q)
    }

    #[test]
    fn engine_behind_trait_matches_inherent_api() {
        let mut via_trait = Engine::new(Field::paper(), EngineConfig::new(5));
        let got = generic_mad(&mut via_trait, 123, 45, 256);
        assert!((got - 21).abs() <= 1, "⌊123·45/256⌋ = 21 ± 1, got {got}");

        let mut inherent = Engine::new(Field::paper(), EngineConfig::new(5));
        let ia = inherent.input(1, &[123])[0];
        let ib = inherent.input(2, &[45])[0];
        let prod = inherent.mul(ia, ib);
        let q = inherent.divpub(prod, 256);
        let r = inherent.reveal(q);
        assert_eq!(inherent.field.to_i128(r), got, "trait and inherent paths agree");
        assert_eq!(
            via_trait.net.stats, inherent.net.stats,
            "trait delegation must not change the accounting"
        );
    }

    #[test]
    fn provided_scalar_ops_compose() {
        let mut e = Engine::new(Field::paper(), EngineConfig::new(3));
        let a = MpcSession::input_vec(&mut e, 1, &[10])[0];
        let b = MpcSession::input_vec(&mut e, 2, &[4])[0];
        let sum = MpcSession::add(&mut e, a, b);
        let dif = MpcSession::sub(&mut e, a, b);
        assert_eq!(e.peek(sum), 14);
        assert_eq!(e.peek(dif), 6);
    }
}
