//! Shamir polynomial secret sharing over `Z_p` [13] with the degree-reduction
//! machinery for BGW-style secure multiplication.
//!
//! Party `i ∈ 1..=n` holds `f(i)` for a random degree-`t` polynomial with
//! `f(0) = secret`.  The paper states `k = n` (§2.2.2) but also multiplies
//! polynomial shares, which requires `2t + 1 ≤ n` evaluation points; we
//! therefore default to the BGW honest-majority threshold `t = ⌊(n-1)/2⌋`
//! and document the deviation in DESIGN.md §4 (the `--threshold` CLI flag
//! exposes it).

use crate::rng::Rng;

use crate::field::Field;
use crate::parallel::Pool;

/// Shamir context for a fixed party set `1..=n` and degree `t`.
#[derive(Clone, Debug)]
pub struct ShamirCtx {
    /// The field all polynomials live in.
    pub f: Field,
    /// Number of parties; party `i ∈ 1..=n` holds evaluation point `i`.
    pub n: usize,
    /// Polynomial degree (threshold): any `t` shares reveal nothing,
    /// `t + 1` reconstruct. Secure multiplication requires `2t < n`.
    pub t: usize,
    /// Lagrange coefficients at 0 for interpolating from all n points
    /// (valid for any polynomial of degree ≤ n-1, in particular degree 2t).
    lagrange0: Vec<u128>,
    /// Row-major n×n Vandermonde power table: `vander[(i-1)·n + j] = iʲ mod
    /// p` for party `i ∈ 1..=n`, exponent `j ∈ 0..n`. Precomputed once so a
    /// deal is a coefficient/power dot product instead of a per-party Horner
    /// chain — the flat-buffer data plane's kernel (DESIGN.md §Data plane).
    /// Covers every legal polynomial degree (`deg ≤ 2t < n`).
    vander: Vec<u128>,
    /// Montgomery-domain images of the two constant tables (`x·2^128 mod
    /// p`), built once at context construction (DESIGN.md §Field kernel).
    /// The dealing and reconstruction dot products pair *canonical*
    /// coefficients/shares against these via `Field::mont_mul_add`, whose
    /// R factors cancel — division-free kernels with canonical, hence
    /// bit-identical, outputs. Shares themselves never live in the
    /// Montgomery domain.
    vander_mont: Vec<u128>,
    lagrange0_mont: Vec<u128>,
}

impl ShamirCtx {
    /// Standard honest-majority threshold.
    pub fn new(f: Field, n: usize) -> Self {
        Self::with_threshold(f, n, (n - 1) / 2)
    }

    /// Explicit threshold; rejects `2t ≥ n` (which would break secure
    /// multiplication — the §4 deviation documented in DESIGN.md §4).
    pub fn with_threshold(f: Field, n: usize, t: usize) -> Self {
        assert!(n >= 1 && (n as u128) < f.p, "party ids must be distinct mod p");
        assert!(2 * t < n, "secure multiplication needs 2t+1 <= n (got n={n}, t={t})");
        let lagrange0 = Self::lagrange_at_zero(&f, &(1..=n as u128).collect::<Vec<_>>());
        let mut vander = Vec::with_capacity(n * n);
        for x in 1..=n as u128 {
            let mut pw = 1u128;
            for _ in 0..n {
                vander.push(pw);
                pw = f.mul(pw, x);
            }
        }
        let vander_mont: Vec<u128> = vander.iter().map(|&x| f.to_mont(x)).collect();
        let lagrange0_mont: Vec<u128> = lagrange0.iter().map(|&x| f.to_mont(x)).collect();
        ShamirCtx { f, n, t, lagrange0, vander, vander_mont, lagrange0_mont }
    }

    /// λ_j such that g(0) = Σ λ_j·g(x_j) for any g with deg g < |xs|.
    pub fn lagrange_at_zero(f: &Field, xs: &[u128]) -> Vec<u128> {
        let mut out = Vec::with_capacity(xs.len());
        for (j, &xj) in xs.iter().enumerate() {
            let mut num = 1u128;
            let mut den = 1u128;
            for (m, &xm) in xs.iter().enumerate() {
                if m == j {
                    continue;
                }
                num = f.mul(num, f.sub(0, xm)); // (0 - x_m)
                den = f.mul(den, f.sub(xj, xm));
            }
            out.push(f.mul(num, f.inv(den)));
        }
        out
    }

    /// Share `secret` with a fresh degree-`t` polynomial; returns `n` shares
    /// where index `i` is party `i+1`'s share `f(i+1)`.
    pub fn share<R: Rng + ?Sized>(&self, secret: u128, rng: &mut R) -> Vec<u128> {
        self.share_deg(secret, self.t, rng)
    }

    /// Share with an explicit polynomial degree (used by tests to build
    /// degree-2t sharings directly).
    pub fn share_deg<R: Rng + ?Sized>(&self, secret: u128, deg: usize, rng: &mut R) -> Vec<u128> {
        let mut out = vec![0u128; self.n];
        self.share_batch_into(&[secret], deg, rng, &mut out);
        out
    }

    /// Deal `k = secrets.len()` secrets with fresh degree-`deg` polynomials
    /// into the flat **party-major** buffer `out`: `out[(i-1)·k + e]` is
    /// party i's share of secret `e`. `out.len()` must be exactly `n·k`.
    ///
    /// Coefficients are drawn from `rng` in *exactly* the order a loop of
    /// scalar [`ShamirCtx::share_deg`] calls draws them — secret 0's `deg`
    /// random coefficients first, then secret 1's, and so on — so a batched
    /// deal is draw-for-draw (and therefore share-for-share) identical to
    /// the scalar path. The cross-backend byte-identity contract of
    /// [`MpcSession`](crate::protocols::session::MpcSession) rests on this
    /// order; `tests::batch_share_matches_scalar_draw_for_draw` pins it
    /// against an independent Horner reference.
    ///
    /// Polynomial evaluation reads the precomputed Vandermonde power table,
    /// so dealing performs **zero heap allocation per element** (one
    /// reusable coefficient buffer per call) — the §Perf iteration-3 hot
    /// path (EXPERIMENTS.md). The per-party dot product itself is the
    /// division-free Montgomery kernel of §Perf iteration 7
    /// ([`Self::eval_row`]).
    pub fn share_batch_into<R: Rng + ?Sized>(
        &self,
        secrets: &[u128],
        deg: usize,
        rng: &mut R,
        out: &mut [u128],
    ) {
        let f = &self.f;
        let n = self.n;
        let k = secrets.len();
        assert_eq!(out.len(), n * k, "out must hold n·k = {}·{} shares", n, k);
        assert!(deg < n, "power table covers degrees < n (got deg={deg}, n={n})");
        let mut coeffs: Vec<u128> = Vec::with_capacity(deg + 1);
        for (e, &secret) in secrets.iter().enumerate() {
            coeffs.clear();
            coeffs.push(f.reduce(secret));
            for _ in 0..deg {
                coeffs.push(f.rand(rng));
            }
            for i in 0..n {
                out[i * k + e] =
                    Self::eval_row(f, &coeffs, &self.vander_mont[i * n..i * n + deg + 1]);
            }
        }
    }

    /// [`ShamirCtx::share_batch_into`] with the polynomial evaluations
    /// fanned out over a worker [`Pool`] — the parallel member compute
    /// plane's dealing kernel (DESIGN.md §Field kernel).
    ///
    /// Draw-order byte-identity holds **by construction**: *all* `k·deg`
    /// random coefficients are pre-drawn serially into `coeffs_scratch`
    /// (one `deg+1` row per secret, in exactly the scalar order) *before*
    /// any fan-out, and the parallel phase is pure indexed evaluation into
    /// disjoint chunks of `out`. Serial pools take the same pre-draw path,
    /// so `pool.threads() == 1` output, parallel output, and
    /// [`ShamirCtx::share_batch_into`] output are all bit-identical
    /// (pinned by `tests::pooled_batch_share_is_bit_identical`).
    pub fn share_batch_into_pooled<R: Rng + ?Sized>(
        &self,
        secrets: &[u128],
        deg: usize,
        rng: &mut R,
        out: &mut [u128],
        coeffs_scratch: &mut Vec<u128>,
        pool: Pool,
    ) {
        let f = self.f;
        let n = self.n;
        let k = secrets.len();
        assert_eq!(out.len(), n * k, "out must hold n·k = {}·{} shares", n, k);
        assert!(deg < n, "power table covers degrees < n (got deg={deg}, n={n})");
        let w = deg + 1;
        coeffs_scratch.clear();
        coeffs_scratch.reserve(k * w);
        for &secret in secrets {
            coeffs_scratch.push(f.reduce(secret));
            for _ in 0..deg {
                coeffs_scratch.push(f.rand(rng));
            }
        }
        let coeffs = &coeffs_scratch[..];
        let vander_mont = &self.vander_mont[..];
        pool.run_chunks(out, crate::parallel::MIN_CHUNK, |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                let (i, e) = ((start + off) / k, (start + off) % k);
                *slot =
                    Self::eval_row(&f, &coeffs[e * w..(e + 1) * w], &vander_mont[i * n..i * n + w]);
            }
        });
    }

    /// Coefficient/power dot product in the **Montgomery kernel** (§Perf
    /// iteration 7, DESIGN.md §Field kernel). Canonical coefficients are
    /// paired against the Montgomery-domain power table, so each term is
    /// one division-free two-round REDC and the running total is restored
    /// to canonical form with two branch-free conditional subtracts —
    /// no `u128 %` anywhere on the dealing hot path. (Iteration 6's
    /// deferred-reduction chunk kernel, which this replaces, still paid
    /// one `u128` division per 8-term chunk; for the common `deg+1 ∈ 2..8`
    /// row widths that was one division per dealt share.)
    ///
    /// Only the *representation of the constants* changes, never the value
    /// mod p: the result is canonical at every step, so outputs are
    /// bit-identical to `f.dot` on the canonical table and the draw-order
    /// contract above is untouched
    /// (`tests::batch_share_matches_scalar_draw_for_draw` still pins the
    /// whole path against the legacy Horner reference).
    #[inline]
    fn eval_row(f: &Field, coeffs: &[u128], powers_mont: &[u128]) -> u128 {
        f.dot_mont(coeffs, powers_mont)
    }

    /// Deal one secret into `out` (`out[i-1]` = party i's share): the k = 1
    /// case of [`ShamirCtx::share_batch_into`], for protocol phases whose
    /// draw order interleaves several logical values per element (§3.4's
    /// r/q pairs) and therefore cannot batch across elements.
    pub fn share_into<R: Rng + ?Sized>(
        &self,
        secret: u128,
        deg: usize,
        rng: &mut R,
        out: &mut [u128],
    ) {
        self.share_batch_into(&[secret], deg, rng, out);
    }

    /// Reconstruct from all `n` shares (degree up to n-1, so also 2t).
    /// Canonical shares against the Montgomery λ table: division-free and
    /// bit-identical to the canonical dot (DESIGN.md §Field kernel).
    pub fn reconstruct(&self, shares: &[u128]) -> u128 {
        assert_eq!(shares.len(), self.n);
        self.f.dot_mont(shares, &self.lagrange0_mont)
    }

    /// Reconstruct from a subset of `(party_id, share)` pairs; needs at
    /// least `deg+1` points for a degree-`deg` polynomial.
    pub fn reconstruct_subset(&self, points: &[(usize, u128)], deg: usize) -> u128 {
        assert!(points.len() > deg, "not enough shares for degree {deg}");
        let xs: Vec<u128> = points.iter().map(|&(i, _)| i as u128).collect();
        let lam = Self::lagrange_at_zero(&self.f, &xs);
        let ys: Vec<u128> = points.iter().map(|&(_, y)| y).collect();
        self.f.dot(&lam, &ys)
    }

    /// The λ vector for full-set reconstruction (used by the degree-reduction
    /// step of secure multiplication: new_share_j = Σ_i λ_i · subshare_{i→j}).
    pub fn lambda(&self) -> &[u128] {
        &self.lagrange0
    }

    /// Montgomery-domain image of [`ShamirCtx::lambda`], for the engines'
    /// division-free λ-recombination loops (`Field::mont_mul_add` against
    /// canonical sub-shares).
    pub fn lambda_mont(&self) -> &[u128] {
        &self.lagrange0_mont
    }

    /// A "public constant" share: the constant polynomial, share = c for all.
    pub fn const_share(&self, c: u128) -> u128 {
        self.f.reduce(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, EXAMPLE_P};
    use crate::rng::Prng;

    fn ctx(n: usize) -> ShamirCtx {
        ShamirCtx::new(Field::paper(), n)
    }

    #[test]
    fn roundtrip_various_n() {
        let mut rng = Prng::seed_from_u64(1);
        for n in [1, 2, 3, 5, 13] {
            let c = ctx(n);
            for _ in 0..20 {
                let x = c.f.rand(&mut rng);
                let sh = c.share(x, &mut rng);
                assert_eq!(c.reconstruct(&sh), x, "n={n}");
            }
        }
    }

    #[test]
    fn reconstruct_from_t_plus_1_subset() {
        let mut rng = Prng::seed_from_u64(2);
        let c = ctx(7); // t = 3
        let x = 123456u128;
        let sh = c.share(x, &mut rng);
        let pts: Vec<(usize, u128)> = [2usize, 4, 5, 7].iter().map(|&i| (i, sh[i - 1])).collect();
        assert_eq!(c.reconstruct_subset(&pts, c.t), x);
    }

    #[test]
    fn t_shares_reveal_nothing_statistically() {
        // With t=2, any 2 shares of two different secrets are identically
        // distributed; smoke-test by bucketing share 1 of fixed secrets.
        let mut rng = Prng::seed_from_u64(3);
        let c = ShamirCtx::new(Field::new(EXAMPLE_P), 5);
        let mut b0 = [0u32; 8];
        let mut b1 = [0u32; 8];
        for _ in 0..4096 {
            b0[(c.share(0, &mut rng)[0] % 8) as usize] += 1;
            b1[(c.share(EXAMPLE_P - 1, &mut rng)[0] % 8) as usize] += 1;
        }
        for i in 0..8 {
            let (a, b) = (b0[i] as f64, b1[i] as f64);
            assert!((a - b).abs() / (a + b) < 0.2, "{b0:?} vs {b1:?}");
        }
    }

    #[test]
    fn linear_homomorphism() {
        let mut rng = Prng::seed_from_u64(4);
        let c = ctx(5);
        let f = &c.f;
        let (x, y) = (f.rand(&mut rng), f.rand(&mut rng));
        let sx = c.share(x, &mut rng);
        let sy = c.share(y, &mut rng);
        let alpha = 7u128;
        let sz: Vec<u128> = sx
            .iter()
            .zip(&sy)
            .map(|(&a, &b)| f.add(f.mul(alpha, a), b))
            .collect();
        assert_eq!(c.reconstruct(&sz), f.add(f.mul(alpha, x), y));
    }

    #[test]
    fn share_products_reconstruct_with_degree_2t() {
        let mut rng = Prng::seed_from_u64(5);
        let c = ctx(5); // t=2, 2t=4 < 5
        let f = &c.f;
        let (x, y) = (12345u128, 9999u128);
        let sx = c.share(x, &mut rng);
        let sy = c.share(y, &mut rng);
        let prod: Vec<u128> = sx.iter().zip(&sy).map(|(&a, &b)| f.mul(a, b)).collect();
        assert_eq!(c.reconstruct(&prod), f.mul(x, y));
    }

    #[test]
    fn const_share_reconstructs() {
        let c = ctx(5);
        let sh = vec![c.const_share(42); 5];
        assert_eq!(c.reconstruct(&sh), 42);
    }

    #[test]
    #[should_panic]
    fn rejects_threshold_too_high_for_mult() {
        ShamirCtx::with_threshold(Field::paper(), 4, 2); // 2t = 4 >= n
    }

    /// The seed implementation of `share_deg` (per-secret coefficient Vec +
    /// per-party Horner chain), kept verbatim as the reference the batched
    /// Vandermonde path must match draw-for-draw and share-for-share.
    fn share_deg_reference(
        c: &ShamirCtx,
        secret: u128,
        deg: usize,
        rng: &mut Prng,
    ) -> Vec<u128> {
        let f = &c.f;
        let mut coeffs = Vec::with_capacity(deg + 1);
        coeffs.push(secret % f.p);
        for _ in 0..deg {
            coeffs.push(f.rand(rng));
        }
        (1..=c.n as u128)
            .map(|x| coeffs.iter().rev().fold(0u128, |acc, &cf| f.add(f.mul(acc, x), cf)))
            .collect()
    }

    #[test]
    fn batch_share_matches_scalar_draw_for_draw() {
        // share_batch_into ≡ a loop of scalar share calls: same Prng seed →
        // identical flat buffer AND identical post-call RNG position (so a
        // protocol step after a batched deal sees the same stream a scalar
        // deal would leave). Checked against the legacy Horner reference,
        // not against share_deg (which now delegates to the batch path).
        crate::rng::property(64, |rng| {
            let n = 1 + rng.gen_range_u64(13) as usize;
            let c = ctx(n);
            let k = rng.gen_range_u64(9) as usize;
            let deg = if rng.gen_bool(0.5) { c.t } else { 2 * c.t };
            let secrets: Vec<u128> = (0..k).map(|_| c.f.rand(rng)).collect();

            let mut r_batch = Prng::seed_from_u64(0xBA7C4 + n as u64);
            let mut r_scalar = r_batch.clone();
            let mut flat = vec![0u128; n * k];
            c.share_batch_into(&secrets, deg, &mut r_batch, &mut flat);
            for (e, &s) in secrets.iter().enumerate() {
                let want = share_deg_reference(&c, s, deg, &mut r_scalar);
                for i in 0..n {
                    assert_eq!(flat[i * k + e], want[i], "n={n} k={k} deg={deg} e={e} i={i}");
                }
                assert_eq!(c.reconstruct(&want), s % c.f.p);
            }
            assert_eq!(
                r_batch.next_u64(),
                r_scalar.next_u64(),
                "batch and scalar dealing must consume the same number of draws"
            );
        });
    }

    #[test]
    fn eval_row_matches_field_dot_exactly() {
        // The Montgomery kernel is an optimization seam only: for every
        // length and random operands, canonical coefficients against the
        // mont-lifted power table must reproduce the canonical Field::dot
        // bit-for-bit (on both built-in primes).
        for f in [Field::paper(), Field::new(EXAMPLE_P)] {
            crate::rng::property(128, |rng| {
                let len = 1 + rng.gen_range_u64(20) as usize;
                let cs: Vec<u128> = (0..len).map(|_| f.rand(rng)).collect();
                let ps: Vec<u128> = (0..len).map(|_| f.rand(rng)).collect();
                let ps_mont: Vec<u128> = ps.iter().map(|&x| f.to_mont(x)).collect();
                assert_eq!(ShamirCtx::eval_row(&f, &cs, &ps_mont), f.dot(&cs, &ps), "len={len}");
            });
        }
    }

    #[test]
    fn pooled_batch_share_is_bit_identical() {
        // share_batch_into_pooled ≡ share_batch_into for any thread count:
        // same flat buffer AND same post-call RNG position (the pre-draw
        // phase consumes exactly the scalar draw stream). Large k crosses
        // the pool's fan-out floor so the parallel path really runs.
        use crate::parallel::Pool;
        for threads in [1usize, 4] {
            crate::rng::property(12, |rng| {
                let n = 2 + rng.gen_range_u64(6) as usize;
                let c = ctx(n);
                let k = 1500 + rng.gen_range_u64(600) as usize;
                let deg = if rng.gen_bool(0.5) { c.t } else { 2 * c.t };
                let secrets: Vec<u128> = (0..k).map(|_| c.f.rand(rng)).collect();

                let mut r_serial = Prng::seed_from_u64(0x9001ED + n as u64);
                let mut r_pooled = r_serial.clone();
                let mut want = vec![0u128; n * k];
                c.share_batch_into(&secrets, deg, &mut r_serial, &mut want);
                let mut got = vec![0u128; n * k];
                let mut scratch = Vec::new();
                c.share_batch_into_pooled(
                    &secrets,
                    deg,
                    &mut r_pooled,
                    &mut got,
                    &mut scratch,
                    Pool::new(threads),
                );
                assert_eq!(got, want, "threads={threads} n={n} k={k} deg={deg}");
                assert_eq!(
                    r_serial.next_u64(),
                    r_pooled.next_u64(),
                    "pooled dealing must consume the same draw stream"
                );
            });
        }
    }

    #[test]
    fn share_into_is_the_k1_batch() {
        let c = ctx(5);
        let mut r1 = Prng::seed_from_u64(42);
        let mut r2 = Prng::seed_from_u64(42);
        let mut out = vec![0u128; 5];
        c.share_into(9999, c.t, &mut r1, &mut out);
        assert_eq!(out, c.share_deg(9999, c.t, &mut r2));
        assert_eq!(c.reconstruct(&out), 9999);
    }

    #[test]
    #[should_panic]
    fn batch_share_rejects_wrong_buffer_size() {
        let c = ctx(5);
        let mut rng = Prng::seed_from_u64(7);
        let mut out = vec![0u128; 9]; // needs 5·2 = 10
        c.share_batch_into(&[1, 2], c.t, &mut rng, &mut out);
    }

    #[test]
    fn prop_roundtrip_deg_t_and_2t() {
        crate::rng::property(128, |rng| {
            let n = 1 + rng.gen_range_u64(13) as usize;
            let c = ctx(n);
            let x = c.f.rand(rng);
            let sh = c.share_deg(x, c.t, rng);
            assert_eq!(c.reconstruct(&sh), x);
            let sh2 = c.share_deg(x, 2 * c.t, rng);
            assert_eq!(c.reconstruct(&sh2), x);
        });
    }
}
