//! Private marginal inference (§4) vs the CryptoSPN cost model (claim 2(d)).
//!
//! Trains weight shares on the toy structure, answers marginal/conditional
//! queries privately (secure mul ladder over the layered SPN, only the root
//! revealed to the client), checks accuracy against the float oracle, and
//! prints the CryptoSPN garbled-circuit cost estimate for the same query on
//! the same structure.
//!
//! Run: `cargo run --release --example private_inference [-- dataset]`

use spn_mpc::coordinator::infer::{private_conditional, private_eval, Query};
use spn_mpc::coordinator::train::{peek_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::gc;
use spn_mpc::metrics::group_thousands;
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::runtime;
use spn_mpc::spn::{eval, learn};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("toy");
    let members = 5;

    let dir = runtime::default_artifacts_dir();
    let st = spn_mpc::spn::structure::Structure::load(
        dir.join(format!("{dataset}.structure.json")),
    )?;
    println!("dataset {dataset}: {:?}", st.stats);

    // train shares (batched schedule: fast path)
    let gt = datasets::ground_truth_params(&st, 7);
    let rows = 4000.min(st.rows);
    let data = datasets::sample(&st, &gt, rows, 42);
    let shards = datasets::partition(&data, members);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(members).batched());
    let (model, _) = train(&mut eng, &st, &counts, rows as u64, &TrainConfig::default());
    let theta = learn::default_leaf_theta(&st);
    let fixed = peek_weights(&eng, &model);
    let params = learn::params_from_fixed(&st, &fixed, &theta, model.d);

    // --- single-evidence marginals across all variables ----------------------
    eng.cfg.schedule = Schedule::PerOp; // per-op accounting, like the paper
    println!("\nmarginal queries Pr(Xv = 1), one at a time:");
    let mut worst = 0.0f64;
    let mut total_stats = None;
    for v in 0..st.num_vars.min(8) {
        let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        q.x[v] = 1;
        q.marg[v] = false;
        let (got, stats) = private_eval(&mut eng, &st, &model, &q, &theta);
        let want = eval::logeval(&st, &q.x, &q.marg, &params).exp();
        let got_f = got.max(0) as f64 / model.d as f64;
        worst = worst.max((got_f - want).abs());
        if total_stats.is_none() {
            total_stats = Some(stats);
        }
        println!("  v={v}: private {got_f:.3} oracle {want:.3}");
    }
    println!("worst abs error: {worst:.3} (fixed point d = {})", model.d);

    // --- a conditional -------------------------------------------------------
    let (p, _) = private_conditional(&mut eng, &st, &model, &[(0, 1)], &[(1, 1)], &theta);
    println!("\nPr(X0=1 | X1=1) = {p:.4}");

    // --- CryptoSPN comparison -------------------------------------------------
    let stats = total_stats.unwrap();
    let cost = gc::inference_cost(&st);
    let aes = gc::measure_aes_per_sec(3_000_000);
    let gc_time = gc::estimate_seconds(&cost, aes, 125e6, 0.010);
    println!("\n— one private inference: this work vs CryptoSPN (GC/ABY cost model) —");
    println!(
        "  this work : {} messages, {:.3} MB, {:.2} s virtual (10 ms links)",
        group_thousands(stats.messages),
        stats.megabytes(),
        stats.virtual_time_s
    );
    println!(
        "  CryptoSPN : {} AND gates, {:.3} MB garbled tables + OT, est. {:.2} s \
         ({:.1}M AES-equiv/s measured)",
        group_thousands(cost.and_gates),
        cost.bytes as f64 / 1e6,
        gc_time,
        aes / 1e6
    );
    println!(
        "  traffic ratio (GC / secret sharing): {:.1}x",
        cost.bytes as f64 / stats.bytes as f64
    );
    println!("\nprivate_inference OK");
    Ok(())
}
