//! Acceptance pins of the sharded serve fleet (DESIGN.md §Fleet):
//!
//! * **Cross-shard byte-identity** — a query pinned to any shard (Sim and
//!   TCP backends) reveals the bit-identical `root`/`p` of its
//!   single-session oracle: a fresh identically-seeded session, identical
//!   training replay, the shard's tag stripe installed, one direct
//!   `Evaluator::eval_batch` in served order. Stripe 0 starts at tag 0,
//!   so shard 0 is additionally bit-identical to the *unsharded* oracle.
//! * **Tag-stripe discipline** — mixed-width ticks on S shards reserve
//!   ranges that are monotone, pairwise disjoint within the shard, and
//!   confined to the shard's stripe (the PR 5 freshness test, fleetized).
//! * **Chaos** — under 8-client concurrent load, killing a shard mid-run
//!   loses no query: every in-flight and queued query is answered by a
//!   survivor, post-kill queries pinned at the corpse are served
//!   elsewhere, and the server drains through a clean shutdown. The TCP
//!   variant severs real member sockets via the kill-shard command.
//! * **Dispatch** — unpinned pipelined load spreads over multiple live
//!   shards (least-loaded routing), with exact report totals.
//! * **Self-healing** — a seeded fault plan kills every shard of a
//!   respawning fleet once under 8-client load: every query is still
//!   answered, every answer is byte-identical to its (shard, generation)
//!   oracle in served (`snum`) order, every shard revives (`0 dead`), and
//!   the divpub-tag blocks consumed across all generations are pairwise
//!   disjoint — burned tags are never reissued. Health probes quarantine
//!   a severed TCP shard before any client query reaches it.
//!
//! Everything runs on `Structure::mini_demo()` — artifact-free, CI-safe.

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use spn_mpc::coordinator::infer::private_eval_batch;
use spn_mpc::coordinator::serve::{train_and_serve_fleet, RespawnBuilder};
use spn_mpc::coordinator::train::{train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::net::fault::{FaultEvent, FaultKind, FaultPlan};
use spn_mpc::net::fleet::{FleetReport, ShardSever};
use spn_mpc::net::serve::{render_query_json, Response, ServeClient, ServeConfig};
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::net::MemberLinkState;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::learn;
use spn_mpc::spn::plan::{EvalPlan, Evaluator, Query, TagStripe};
use spn_mpc::spn::structure::Structure;

const MEMBERS: usize = 3;

fn mini_counts(st: &Structure, n: usize) -> (Vec<Vec<u64>>, u64) {
    // seeds 5/21: the same shards as serve.rs / integration.rs
    (datasets::synth_shard_counts(st, n, st.rows, 5, 21), st.rows as u64)
}

// Under `--features checked-session` every *fleet* session runs wrapped in
// the CheckedSession sanitizer while the oracles stay raw (see serve.rs);
// by default wrap() is the identity. Sever handles are always taken from
// the raw TcpSession BEFORE wrapping — severing is transport surgery, not
// a protocol call, and must bypass the sanitizer.
#[cfg(feature = "checked-session")]
use spn_mpc::protocols::checked::CheckedSession;
#[cfg(feature = "checked-session")]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> CheckedSession<S> {
    CheckedSession::new(s)
}
#[cfg(not(feature = "checked-session"))]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}
#[cfg(feature = "checked-session")]
fn wrap_engine(e: Engine) -> CheckedSession<Engine> {
    let schedule = e.cfg.schedule;
    CheckedSession::with_sim_accounting(e, schedule)
}
#[cfg(not(feature = "checked-session"))]
fn wrap_engine(e: Engine) -> Engine {
    e
}
#[cfg(feature = "checked-session")]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: CheckedSession<S>) -> S {
    s.into_inner()
}
#[cfg(not(feature = "checked-session"))]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}

/// A deterministic mixed stream (same shape as serve.rs): mostly
/// single-evidence marginals, every fifth query fully marginalized.
fn arrival_queries(st: &Structure, total: usize) -> Vec<Query> {
    (0..total)
        .map(|i| {
            let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
            if i % 5 != 0 {
                let v = i % st.num_vars;
                q.x[v] = ((i / 2) % 2) as u8;
                q.marg[v] = false;
            }
            q
        })
        .collect()
}

/// A stripe's single-session oracle: a fresh identically-seeded Sim
/// session, identical training replay, the given [`TagStripe`] (any
/// shard, any generation) installed, one direct eval_batch over the
/// queries that generation served, in served order. (TCP ≡ Sim
/// byte-identically under one seed, so this is the oracle for both
/// backends.)
fn generation_oracle(st: &Structure, n: usize, stripe: TagStripe, queries: &[Query]) -> Vec<i128> {
    let (counts, rows) = mini_counts(st, n);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    let plan = EvalPlan::compile(st, &theta, model.d);
    let mut ev = Evaluator::new(plan).clone_into_session(&mut eng, stripe);
    let (roots, _) = ev.eval_batch(&mut eng, queries, &model.sum_w, model.leaf_theta.as_deref());
    roots
}

/// Shard s's generation-0 oracle (the original fleet byte-identity pin).
fn shard_oracle(
    st: &Structure,
    n: usize,
    s: usize,
    shards: usize,
    queries: &[Query],
) -> Vec<i128> {
    generation_oracle(st, n, TagStripe::new(s, shards), queries)
}

/// Divpub tags per query of the mini-demo plan — the stride that turns a
/// response's `(gen, snum)` into the exact tag block it consumed.
fn divpubs_per_query(st: &Structure) -> u64 {
    let (counts, rows) = mini_counts(st, MEMBERS);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    EvalPlan::compile(st, &theta, model.d).divpubs_per_query
}

/// The unsharded oracle of serve.rs, for the shard-0 ≡ single-session pin.
fn plain_oracle(st: &Structure, n: usize, queries: &[Query]) -> Vec<i128> {
    let (counts, rows) = mini_counts(st, n);
    let theta = learn::default_leaf_theta(st);
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let (model, _) = train(&mut eng, st, &counts, rows, &TrainConfig::default());
    let (roots, _) = private_eval_batch(&mut eng, st, &model, queries, &theta);
    roots
}

/// Bind an ephemeral listener, then train + serve a fleet of `shards`
/// sessions on a background thread. TCP fleets get real sever handles so
/// `kill-shard` cuts member sockets; dead or respawned TCP shards are
/// torn down lossily after the drain (a leak would hang the test).
///
/// `respawn` arms self-healing (deterministic retrain replay onto the
/// next generation sub-stripe), `probe_ms > 0` arms idle health probes,
/// `fault` injects a seeded chaos schedule.
fn spawn_healing_fleet(
    backend: &'static str,
    st: Structure,
    shards: usize,
    cfg: ServeConfig,
    respawn: bool,
    probe_ms: u64,
    fault: Option<FaultPlan>,
) -> (std::net::SocketAddr, thread::JoinHandle<FleetReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = thread::spawn(move || {
        let (counts, rows) = mini_counts(&st, MEMBERS);
        let theta = learn::default_leaf_theta(&st);
        let tcfg = TrainConfig::default();
        let probe = (probe_ms > 0).then(|| Duration::from_millis(probe_ms));
        match backend {
            "tcp" => {
                let mut sessions = Vec::with_capacity(shards);
                let mut severs: Vec<Option<ShardSever>> = Vec::with_capacity(shards);
                for _ in 0..shards {
                    let sess =
                        TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(MEMBERS))
                            .unwrap();
                    // sever handle from the raw session, BEFORE wrapping
                    let sever = sess.sever_handle().unwrap();
                    severs.push(Some(Box::new(move || sever.sever())));
                    sessions.push(wrap(sess));
                }
                let rb = respawn.then(|| RespawnBuilder {
                    build: Box::new(|_s| {
                        let sess = TcpSession::spawn_local(
                            Field::paper(),
                            TcpSessionConfig::new(MEMBERS),
                        )?;
                        let sever = sess.sever_handle()?;
                        let sever: ShardSever = Box::new(move || sever.sever());
                        Ok((wrap(sess), Some(sever)))
                    }),
                    reap: Arc::new(|sess, dead: bool| {
                        let raw = unwrap_session(sess);
                        if dead {
                            raw.shutdown_lossy();
                        } else {
                            let _ = raw.shutdown();
                        }
                    }),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, &st, &counts, rows, &tcfg, &theta, listener, &cfg, severs,
                    rb, probe, fault,
                )
                .unwrap();
                for (s, sess) in sessions.into_iter().enumerate() {
                    let sess = unwrap_session(sess);
                    if report.per_shard[s].dead || report.per_shard[s].respawns > 0 {
                        sess.shutdown_lossy();
                    } else {
                        sess.shutdown().unwrap();
                    }
                }
                report
            }
            _ => {
                let mut sessions: Vec<_> = (0..shards)
                    .map(|_| {
                        wrap_engine(Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()))
                    })
                    .collect();
                let rb = respawn.then(|| RespawnBuilder {
                    build: Box::new(|_s| {
                        Ok((
                            wrap_engine(Engine::new(
                                Field::paper(),
                                EngineConfig::new(MEMBERS).batched(),
                            )),
                            None,
                        ))
                    }),
                    reap: Arc::new(|_sess, _dead: bool| {}),
                });
                let (report, _) = train_and_serve_fleet(
                    &mut sessions, &st, &counts, rows, &tcfg, &theta, listener, &cfg,
                    Vec::new(), rb, probe, fault,
                )
                .unwrap();
                report
            }
        }
    });
    (addr, h)
}

/// The pre-healing entry point: no respawn, no probes, no faults.
fn spawn_fleet(
    backend: &'static str,
    st: Structure,
    shards: usize,
    cfg: ServeConfig,
) -> (std::net::SocketAddr, thread::JoinHandle<FleetReport>) {
    spawn_healing_fleet(backend, st, shards, cfg, false, 0, None)
}

/// Drive one query to an answer through transient fleet errors (the shard
/// holding it died, or a respawn window briefly left no live shard) — the
/// test mirror of the CLI client's retry loop. Transport-level failures
/// abort the test: the fleet front-end must outlive its shards.
fn query_until_served(c: &mut ServeClient, q: &Query) -> Response {
    for _ in 0..400 {
        match c.query(q) {
            Ok(r) => return r,
            Err(e) => {
                let msg = e.to_string();
                assert!(
                    msg.contains("server error"),
                    "fleet front-end must outlive its shards: {msg}"
                );
                thread::sleep(Duration::from_millis(10));
            }
        }
    }
    panic!("query not served after 400 attempts");
}

/// A query frame carrying the `"shard"` routing pin.
fn pinned_query_json(q: &Query, shard: usize) -> String {
    let mut s = render_query_json(q);
    s.truncate(s.len() - 1); // drop the closing brace
    format!("{s},\"shard\":{shard}}}")
}

#[test]
fn any_shard_matches_its_single_session_oracle_marginal_and_conditional() {
    let st = Structure::mini_demo();
    let shards = 3usize;
    // one marginal plus the two components of Pr(x0=1 | x1=1) — the
    // conditional is served as two queries; the client forms the ratio
    let marginal = Query { x: vec![1, 0], marg: vec![false, true] };
    let q_xe = Query { x: vec![1, 1], marg: vec![false, false] };
    let q_e = Query { x: vec![0, 1], marg: vec![true, false] };
    let served: Vec<Query> = vec![marginal, q_xe, q_e];
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    for backend in ["sim", "tcp"] {
        let (addr, h) = spawn_fleet(backend, st.clone(), shards, cfg);
        let mut c = ServeClient::connect(&addr.to_string()).unwrap();
        assert_eq!(c.hello.shards, shards, "{backend}: hello reports the fleet width");
        let mut roots_by_shard: Vec<Vec<i128>> = Vec::new();
        for s in 0..shards {
            // closed loop, pinned: shard s serves exactly these three
            // queries, in this order
            let mut got = Vec::new();
            for q in &served {
                c.send_raw(&pinned_query_json(q, s)).unwrap();
                let r = c.recv().unwrap();
                assert_eq!(r.shard, Some(s), "{backend}: pin to live shard {s} is honored");
                // p is the shortest-roundtrip rendering of root.max(0)/d
                assert_eq!(r.p, r.root.max(0) as f64 / 256.0);
                got.push(r.root);
            }
            let want = shard_oracle(&st, MEMBERS, s, shards, &served);
            assert_eq!(
                got, want,
                "{backend} shard {s}: served roots must be bit-identical to the \
                 single-session oracle with stripe {s} of {shards}"
            );
            // conditional: the served ratio equals the oracle ratio exactly
            let ratio = |v: &[i128]| {
                if v[2] <= 0 {
                    0.0
                } else {
                    (v[1].max(0) as f64 / v[2] as f64).min(1.0)
                }
            };
            assert_eq!(ratio(&got), ratio(&want), "{backend} shard {s}: conditional p");
            roots_by_shard.push(got);
        }
        // stripe 0 starts at tag 0 → shard 0 ≡ the unsharded single session
        assert_eq!(
            roots_by_shard[0],
            plain_oracle(&st, MEMBERS, &served),
            "{backend}: shard 0 must equal the unsharded oracle bit-for-bit"
        );
        // across shards the masks differ (different tag stripes), so roots
        // may differ by the ±1-per-divpub rounding — never more
        for s in 1..shards {
            for (a, b) in roots_by_shard[0].iter().zip(&roots_by_shard[s]) {
                assert!((a - b).abs() <= 8, "shard {s} root {b} vs shard 0 root {a}");
            }
        }
        ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
        let report = h.join().unwrap();
        assert_eq!(report.queries, (shards * served.len()) as u64);
        assert_eq!(report.shards, shards);
        assert_eq!(report.dead_shards, 0);
        assert_eq!(report.redispatched, 0);
    }
}

#[test]
fn mixed_width_ticks_stay_confined_to_each_shards_stripe() {
    // The PR 5 tag-freshness pin, fleetized: on every shard of a 3-way
    // fleet, mixed-width ticks reserve monotone, pairwise-disjoint ranges
    // that never leave the shard's stripe — and the stripes themselves
    // are disjoint across shards by construction.
    let st = Structure::mini_demo();
    let shards = 3usize;
    let (counts, rows) = mini_counts(&st, MEMBERS);
    let theta = learn::default_leaf_theta(&st);
    let widths = [1usize, 3, 2, 7, 1, 5, 4, 2, 6, 1]; // mixed traffic
    let mut all_ranges: Vec<Vec<(u64, u64)>> = Vec::new();
    for s in 0..shards {
        let stripe = TagStripe::new(s, shards);
        let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()));
        let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
        let plan = EvalPlan::compile(&st, &theta, model.d);
        let m = plan.divpubs_per_query;
        let mut ev = Evaluator::new(plan).clone_into_session(&mut eng, stripe);
        assert_eq!(ev.stripe(), Some(stripe));
        let mut ranges: Vec<(u64, u64)> = Vec::new();
        for (t, &w) in widths.iter().enumerate() {
            let batch = arrival_queries(&st, w);
            let (roots, _) =
                ev.eval_batch(&mut eng, &batch, &model.sum_w, model.leaf_theta.as_deref());
            assert_eq!(roots.len(), w);
            let (start, end) = ev.last_tags().unwrap();
            assert_eq!(end - start, m * w as u64, "shard {s} tick {t}: width must be m·B");
            assert!(
                start >= stripe.base() && end <= stripe.limit(),
                "shard {s} tick {t}: range [{start}, {end}) escapes its stripe"
            );
            if let Some(&(_, prev_end)) = ranges.last() {
                assert!(start >= prev_end, "shard {s} tick {t}: ranges must be monotone");
            }
            ranges.push((start, end));
        }
        for i in 0..ranges.len() {
            for j in i + 1..ranges.len() {
                let (a, b) = ranges[i];
                let (c, d) = ranges[j];
                assert!(b <= c || d <= a, "shard {s}: tick ranges {i}/{j} overlap");
            }
        }
        all_ranges.push(ranges);
    }
    for i in 0..shards {
        for j in i + 1..shards {
            for &(a, b) in &all_ranges[i] {
                for &(c, d) in &all_ranges[j] {
                    assert!(b <= c || d <= a, "shards {i}/{j} share tags — stripes broken");
                }
            }
        }
    }
}

#[test]
fn killing_a_shard_under_load_degrades_without_losing_queries() {
    // The chaos pin: 8 concurrent clients, one kills shard 0 mid-run.
    // Every query — in flight, queued on the corpse, or sent afterwards —
    // still gets a correct answer from a survivor, and the fleet drains
    // through a clean shutdown.
    let st = Structure::mini_demo();
    let shards = 2usize;
    let clients = 8usize;
    let per = 6usize;
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let (addr, h) = spawn_fleet("sim", st.clone(), shards, cfg);
    let all_marg = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
    let mut workers = Vec::new();
    for t in 0..clients {
        let a = addr.to_string();
        let q = all_marg.clone();
        workers.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            let mut out = Vec::new();
            for i in 0..per {
                if t == 0 && i == per / 2 {
                    // mid-run, with the other 7 clients still loading
                    let mut killer = ServeClient::connect(&a).unwrap();
                    killer.kill_shard(0).unwrap();
                }
                let r = c.query(&q).unwrap();
                out.push((r.root, r.shard));
            }
            out
        }));
    }
    let answered: Vec<(i128, Option<usize>)> =
        workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    assert_eq!(answered.len(), clients * per, "no query may be lost to the kill");
    for &(root, shard) in &answered {
        // S(∅)·d ≈ d on every shard (masks differ per stripe, value doesn't)
        assert!((root - 256).abs() <= 32, "root {root} from shard {shard:?}");
        assert!(matches!(shard, Some(0) | Some(1)));
    }
    // the kill has long landed: queries pinned at the corpse must be
    // served by the survivor
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    let post = 4usize;
    for _ in 0..post {
        c.send_raw(&pinned_query_json(&all_marg, 0)).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.shard, Some(1), "a dead pin falls back to the survivor");
        assert!((r.root - 256).abs() <= 32);
    }
    drop(c);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap();
    assert_eq!(report.queries, (clients * per + post) as u64, "exact accounting");
    assert_eq!(report.dead_shards, 1);
    assert!(report.per_shard[0].dead, "shard 0 is the corpse");
    assert!(!report.per_shard[1].dead);
    assert_eq!(
        report.per_shard[0].queries + report.per_shard[1].queries,
        report.queries,
        "per-shard counts partition the total"
    );
    // 8 workers + 1 killer + 1 post-kill client + 1 shutdown connection
    assert_eq!(report.clients, clients as u64 + 3);
}

#[test]
fn tcp_fleet_kill_severs_member_sockets_and_survivors_serve() {
    // The TCP chaos variant: kill-shard cuts shard 0's real member
    // sockets out from under its session; the fleet degrades and the
    // dead member set is torn down lossily.
    let st = Structure::mini_demo();
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let (addr, h) = spawn_fleet("tcp", st.clone(), 2, cfg);
    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    let before = {
        c.send_raw(&pinned_query_json(&q, 0)).unwrap();
        let r = c.recv().unwrap();
        assert_eq!(r.shard, Some(0), "shard 0 serves while alive");
        r.root
    };
    let mut killer = ServeClient::connect(&addr.to_string()).unwrap();
    killer.kill_shard(0).unwrap();
    for _ in 0..3 {
        let r = c.query(&q).unwrap();
        assert_eq!(r.shard, Some(1), "only the survivor serves after the kill");
        assert!((r.root - before).abs() <= 8, "same query, rounding-close root");
    }
    drop(c);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap(); // member threads joined in spawn_fleet
    assert_eq!(report.queries, 4);
    assert_eq!(report.dead_shards, 1);
    assert!(report.per_shard[0].dead);
}

#[test]
fn unpinned_pipelined_load_spreads_over_live_shards() {
    // Least-loaded dispatch: one client pipelining a burst must light up
    // both shards (while a shard evaluates, new arrivals route to the
    // other), with exact totals and no deaths.
    let st = Structure::mini_demo();
    let total = 12usize;
    let queries = arrival_queries(&st, total);
    let cfg = ServeConfig {
        max_batch: 2,
        max_wait: Duration::from_millis(1),
        max_queries: Some(total as u64),
    };
    let (addr, h) = spawn_fleet("sim", st.clone(), 2, cfg);
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    for q in &queries {
        c.send(q).unwrap();
    }
    let mut used = [0u64; 2];
    for _ in 0..total {
        let r = c.recv().unwrap();
        let s = r.shard.expect("fleet responses name their shard");
        used[s] += 1;
        assert!(r.batch >= 1 && r.batch <= 2);
    }
    let report = h.join().unwrap(); // max_queries reached → self-shutdown
    assert_eq!(report.queries, total as u64);
    assert_eq!(report.dead_shards, 0);
    assert!(used[0] > 0 && used[1] > 0, "both shards must serve ({used:?})");
    assert_eq!(report.per_shard[0].queries, used[0]);
    assert_eq!(report.per_shard[1].queries, used[1]);
}

#[test]
fn seeded_chaos_kills_every_shard_and_the_fleet_self_heals_byte_identically() {
    // The acceptance chaos run: a seeded fault plan kills each shard once
    // (a scheduled Sever degrades to a panic kill on Sim shards) while 8
    // clients stream queries through retry loops. Every query must be
    // answered, every answer must be byte-identical to its (shard,
    // generation) oracle replayed in served (`snum`) order, every shard
    // must respawn (`0 dead`), and the divpub-tag blocks consumed across
    // all generations must be pairwise disjoint — no burned tag reused.
    let st = Structure::mini_demo();
    let shards = 2usize;
    let clients = 8usize;
    let per = 4usize;
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let fault = FaultPlan::seeded(7, shards, 4);
    let (addr, h) = spawn_healing_fleet("sim", st.clone(), shards, cfg, true, 5, Some(fault));
    let queries = arrival_queries(&st, clients * per);
    let mut workers = Vec::new();
    for t in 0..clients {
        let a = addr.to_string();
        let mine: Vec<Query> = queries[t * per..(t + 1) * per].to_vec();
        workers.push(thread::spawn(move || {
            let mut c = ServeClient::connect(&a).unwrap();
            let mut out = Vec::new();
            for q in &mine {
                let r = query_until_served(&mut c, q);
                out.push((q.clone(), r));
            }
            out
        }));
    }
    let answered: Vec<(Query, Response)> =
        workers.into_iter().flat_map(|w| w.join().unwrap()).collect();
    assert_eq!(answered.len(), clients * per, "every query eventually answered");
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap();
    // every shard died once and was revived — nobody stayed dead
    assert_eq!(report.dead_shards, 0, "respawn must revive every kill: {report:?}");
    assert_eq!(report.respawns, shards as u64);
    for (s, ps) in report.per_shard.iter().enumerate() {
        assert_eq!(ps.respawns, 1, "shard {s}: the seeded plan kills each shard once");
        assert!(!ps.dead, "shard {s} ends the run alive");
        assert!(ps.panic_msg.is_some(), "shard {s}: the death cause is preserved");
    }
    // byte-identity: replay each (shard, generation) group on its striped
    // oracle, in served order
    let m = divpubs_per_query(&st);
    let mut groups: HashMap<(usize, u64), Vec<(u64, Query, i128)>> = HashMap::new();
    for (q, r) in &answered {
        let s = r.shard.expect("fleet responses name their shard");
        let gen = r.gen.expect("fleet responses name their generation");
        let snum = r.snum.expect("fleet responses carry their serve index");
        groups.entry((s, gen)).or_default().push((snum, q.clone(), r.root));
    }
    let mut blocks: Vec<(u64, u64)> = Vec::new();
    for ((s, gen), mut grp) in groups {
        grp.sort_by_key(|e| e.0);
        for (k, e) in grp.iter().enumerate() {
            // served snums are gap-free within a generation: an
            // interrupted tick never reports, and its burned tags sit
            // after every served block
            assert_eq!(e.0, k as u64, "shard {s} gen {gen}: snums must be contiguous");
        }
        let stripe = TagStripe::generation(s, shards, gen);
        let qs: Vec<Query> = grp.iter().map(|e| e.1.clone()).collect();
        let want = generation_oracle(&st, MEMBERS, stripe, &qs);
        let got: Vec<i128> = grp.iter().map(|e| e.2).collect();
        assert_eq!(got, want, "shard {s} gen {gen}: byte-identity to its oracle");
        for e in &grp {
            let b = stripe.base() + e.0 * m;
            assert!(b + m <= stripe.limit(), "block escapes the generation sub-stripe");
            blocks.push((b, b + m));
        }
    }
    // freshness, observably: no tag block is ever consumed twice
    blocks.sort_unstable();
    for w in blocks.windows(2) {
        assert!(w[0].1 <= w[1].0, "tag blocks {w:?} overlap — freshness broken");
    }
}

#[test]
fn respawned_generation_never_reuses_burned_tags() {
    // Kill a 1-shard healing fleet mid-stream, then keep querying: the
    // revived generation's divpub-tag blocks must lie strictly inside its
    // own sub-stripe. Generation g+1 starts exactly at generation g's
    // limit, so even the killed tick's burned, never-revealed tags can
    // never be reissued — which this makes observable by reconstructing
    // every consumed block from the responses' (gen, snum).
    let st = Structure::mini_demo();
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let m = divpubs_per_query(&st);
    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    for backend in ["sim", "tcp"] {
        let (addr, h) = spawn_healing_fleet(backend, st.clone(), 1, cfg, true, 0, None);
        let mut c = ServeClient::connect(&addr.to_string()).unwrap();
        let mut blocks: Vec<(u64, u64)> = Vec::new();
        let mut note = |r: &Response| -> u64 {
            let gen = r.gen.unwrap();
            let stripe = TagStripe::generation(0, 1, gen);
            let b = stripe.base() + r.snum.unwrap() * m;
            assert!(b + m <= stripe.limit(), "{backend}: block escapes its sub-stripe");
            blocks.push((b, b + m));
            gen
        };
        for _ in 0..3 {
            let r = c.query(&q).unwrap();
            assert_eq!(note(&r), 0, "{backend}: generation 0 serves before the kill");
        }
        ServeClient::connect(&addr.to_string()).unwrap().kill_shard(0).unwrap();
        // queries during the respawn window bounce with a retryable
        // "no live shards" error until the supervisor re-admits shard 0
        let mut revived_gen = 0;
        for _ in 0..6 {
            let r = query_until_served(&mut c, &q);
            revived_gen = note(&r);
        }
        assert!(revived_gen >= 1, "{backend}: revival serves from a fresh generation");
        drop(c);
        ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
        let report = h.join().unwrap();
        assert_eq!(report.dead_shards, 0, "{backend}: the fleet healed");
        assert!(report.respawns >= 1, "{backend}: the kill triggered a respawn");
        assert_eq!(report.queries, 9, "{backend}: all nine queries served");
        blocks.sort_unstable();
        for w in blocks.windows(2) {
            assert!(w[0].1 <= w[1].0, "{backend}: tag blocks {w:?} overlap");
        }
    }
}

#[test]
fn probes_quarantine_a_severed_shard_before_queries_reach_it() {
    // Acceptance: with probes armed, a shard whose member sockets are
    // severed while the fleet is IDLE is detected and quarantined by the
    // probe round itself — no client query is ever dispatched to the
    // corpse, so nothing needs rescuing.
    let st = Structure::mini_demo();
    let cfg =
        ServeConfig { max_batch: 4, max_wait: Duration::from_millis(2), max_queries: None };
    let fault = FaultPlan::new(vec![FaultEvent { shard: 0, wake: 0, kind: FaultKind::Sever }]);
    let (addr, h) = spawn_healing_fleet("tcp", st.clone(), 2, cfg, false, 5, Some(fault));
    // idle fleet ⇒ the only wakes are probes; the wake-0 sever cuts shard
    // 0's member sockets and its first probe dies on them
    thread::sleep(Duration::from_millis(400));
    let mut c = ServeClient::connect(&addr.to_string()).unwrap();
    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    for _ in 0..4 {
        let r = c.query(&q).unwrap();
        assert_eq!(r.shard, Some(1), "only the healthy shard may serve");
    }
    drop(c);
    ServeClient::connect(&addr.to_string()).unwrap().shutdown_server().unwrap();
    let report = h.join().unwrap();
    assert!(report.per_shard[0].dead, "the severed shard was quarantined");
    assert_eq!(report.per_shard[0].queries, 0, "no query ever reached the corpse");
    assert_eq!(report.redispatched, 0, "quarantine beat dispatch — nothing to rescue");
    assert!(report.per_shard[1].probes > 0, "the healthy shard kept probing");
    assert!(
        report.per_shard[0].links.iter().any(|l| *l == MemberLinkState::Down),
        "the death snapshot records the downed member link: {:?}",
        report.per_shard[0].links
    );
    assert!(report.per_shard[0].panic_msg.is_some(), "the probe death is attributed");
}
