//! Vendored offline subset of the `anyhow` error-handling crate.
//!
//! The build environment for this repository has no crates.io access, so
//! this workspace path crate implements the (small) API surface `spn_mpc`
//! actually uses, with the same names and semantics as the real crate:
//!
//! * [`Error`] — a boxed, `Display`-able error value carrying an optional
//!   source chain; any `std::error::Error + Send + Sync + 'static` converts
//!   into it via `?`.
//! * [`Result<T>`](Result) — `std::result::Result<T, Error>` with a
//!   defaulted error type.
//! * [`Context`] — `.context(..)` / `.with_context(..)` adapters on
//!   `Result` and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros
//!   (including the message-less `ensure!(cond)` form, which reports the
//!   stringified condition).
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` itself (that would conflict with the blanket
//! `From<E: Error>` conversion); it implements `Display` and a
//! chain-printing `Debug`, which is what `fn main() -> anyhow::Result<()>`
//! needs.

use std::fmt;

/// A dynamic error value with an optional chain of sources.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error value, preserving it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap this error with an outer context message (`{context}: {self}`
    /// when displayed; the chain is kept for `Debug`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The deepest available source message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> + '_ {
        let mut next: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_deref().map(|e| e as &(dyn std::error::Error + 'static));
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut sources = self.chain().peekable();
        if sources.peek().is_some() {
            write!(f, "\n\nCaused by:")?;
            for (i, s) in sources.enumerate() {
                write!(f, "\n    {i}: {s}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `std::result::Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching adapters for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message, eagerly evaluated.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Attach a context message, lazily evaluated only on the error path.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)+))
    };
}

/// Return early with an [`Error`] unless a condition holds. The
/// message-less form reports the stringified condition.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_wraps_messages() {
        let e = io_fail().with_context(|| "reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let e = io_fail().context("ctx").unwrap_err();
        assert!(e.to_string().starts_with("ctx: "));
        assert_eq!(e.chain().count(), 1);
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u8).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 7);
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("x too big: 12"));
        assert!(f(7).unwrap_err().to_string().contains("x != 7"));
        assert!(f(3).unwrap_err().to_string().contains("three"));
        let name = "toy";
        let e: Error = anyhow!("dataset {name} missing");
        assert_eq!(e.to_string(), "dataset toy missing");
        let e: Error = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e: Error = anyhow!(format!("from {}", "expr"));
        assert_eq!(e.to_string(), "from expr");
    }

    #[test]
    fn debug_prints_chain() {
        let e = io_fail().context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
    }
}
