//! §3.3 baseline: homomorphic encryption vs secret sharing for the weight
//! aggregation + division.
//!
//! Measures real Paillier keygen/encrypt/add/decrypt at 512/1024/2048-bit
//! moduli (in-tree bignum), charges the §3.3 flow (N parties encrypt
//! 2·params values, leader aggregates, division circuit per [17]), and puts
//! it against the measured secret-sharing division from §3.4.  The shape to
//! reproduce: HE is orders of magnitude more compute even before its
//! division circuit.

mod common;

use spn_mpc::bench::time_it;
use spn_mpc::field::Field;
use spn_mpc::he::bigint::BigUint;
use spn_mpc::he::{Keypair, Paillier};
use spn_mpc::metrics::render_table;
use spn_mpc::protocols::division::{private_divide, DivisionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::rng::Prng;

fn paillier_row(bits: usize, rng: &mut Prng) -> (Keypair, Vec<String>) {
    let t_kg = time_it(0, 1, || Paillier::keygen(rng, bits));
    let kp = Paillier::keygen(rng, bits);
    let m = BigUint::from_u128(123456);
    let mut rng2 = Prng::seed_from_u64(1);
    let t_enc = time_it(1, 5, || Paillier::encrypt(&kp, &m, &mut rng2));
    let c = Paillier::encrypt(&kp, &m, &mut rng2);
    let t_add = time_it(2, 20, || Paillier::add(&kp, &c, &c));
    let t_dec = time_it(1, 5, || Paillier::decrypt(&kp, &c));
    let row = vec![
        format!("{bits}"),
        format!("{:.1} ms", t_kg.mean_s * 1e3),
        format!("{:.2} ms", t_enc.mean_s * 1e3),
        format!("{:.3} ms", t_add.mean_s * 1e3),
        format!("{:.2} ms", t_dec.mean_s * 1e3),
    ];
    (kp, row)
}

fn main() {
    let mut rng = Prng::seed_from_u64(42);
    let mut rows = Vec::new();
    let mut enc_1024 = 0.0;
    for bits in [512usize, 1024, 2048] {
        let (kp, row) = paillier_row(bits, &mut rng);
        if bits == 1024 {
            let m = BigUint::from_u128(7);
            let mut r2 = Prng::seed_from_u64(2);
            enc_1024 = time_it(1, 5, || Paillier::encrypt(&kp, &m, &mut r2)).mean_s;
        }
        rows.push(row);
    }
    println!(
        "{}",
        render_table(
            "Paillier primitive costs (in-tree bignum, this machine)",
            &["modulus bits", "keygen", "encrypt", "hom. add", "decrypt"],
            &rows
        )
    );

    // §3.3 flow for nltcs at 1024-bit: N=5 parties, 2 ciphertexts per sum
    // node + edge numerators.
    if !common::guard("baseline_he (nltcs flow)", &["nltcs"]) {
        return;
    }
    let st = common::load("nltcs").expect("guarded above");
    let n_cts = 2 * st.num_sum_edges + st.sum_groups.len();
    let he_aggregate_s = n_cts as f64 * 5.0 * enc_1024; // encrypt dominates
    // division per [17]: word-wise FHE division needs thousands of
    // homomorphic mults; we charge only 1000x an encryption as a *lower*
    // bound per division.
    let he_division_s = st.sum_groups.len() as f64 * 1000.0 * enc_1024;

    // secret-sharing division measured end to end (wall time + accounting)
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(5));
    let num = eng.input(1, &[600])[0];
    let den = eng.input(1, &[2169])[0];
    let ss = time_it(1, 3, || {
        private_divide(&mut eng, num, den, 4096, &DivisionConfig::default())
    });

    println!("§3.3 HE path (1024-bit, nltcs, 5 parties, lower bounds):");
    println!("  aggregation (encrypt {n_cts} values x 5 parties): {he_aggregate_s:.2} s");
    println!("  division circuit [17] (>= 1000 hom. ops / division): {he_division_s:.1} s");
    println!("§3.4 secret-sharing path:");
    println!(
        "  one full private division (36 Newton iterations): {:.2} ms wall compute",
        ss.mean_s * 1e3
    );
    let ratio = (he_aggregate_s + he_division_s) / (ss.mean_s * st.sum_groups.len() as f64);
    println!("compute ratio (HE / secret sharing), whole training: {ratio:.0}x");
    assert!(ratio > 10.0, "HE must be at least an order of magnitude slower");
    println!("baseline_he OK");
}
