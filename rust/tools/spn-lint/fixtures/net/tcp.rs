//! L005 fixture, framing module A.
//! wire-layout: v2 (agrees with wire.rs)
