//! Tiny benchmarking harness (criterion is not in the vendored crate set).
//!
//! `cargo bench` targets use [`time_it`] / [`Bench`] for wall-clock
//! measurements with warmup and repetition, reporting min/mean like
//! criterion's terse output.  Deterministic protocol *accounting* (message
//! counts, virtual time) needs no repetition and is printed directly.

use std::time::Instant;

/// Measurement summary for one benchmark case.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Sample {
    pub fn per_iter_str(&self) -> String {
        fn fmt(s: f64) -> String {
            if s < 1e-6 {
                format!("{:.1} ns", s * 1e9)
            } else if s < 1e-3 {
                format!("{:.2} µs", s * 1e6)
            } else if s < 1.0 {
                format!("{:.2} ms", s * 1e3)
            } else {
                format!("{:.3} s", s)
            }
        }
        format!("mean {} (min {}, max {}, n={})", fmt(self.mean_s), fmt(self.min_s), fmt(self.max_s), self.iters)
    }
}

/// Run `f` repeatedly: `warmup` unmeasured + `iters` measured runs.
pub fn time_it<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    Sample { iters, mean_s: mean, min_s: min, max_s: max }
}

/// Throughput helper: ops/sec given a per-call op count.
pub fn throughput(sample: &Sample, ops_per_iter: u64) -> f64 {
    ops_per_iter as f64 / sample.mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let s = time_it(1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(s.mean_s > 0.0 && s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert!(throughput(&s, 10_000) > 0.0);
    }

    #[test]
    fn formats_units() {
        let s = Sample { iters: 3, mean_s: 2.5e-7, min_s: 1e-7, max_s: 5e-7 };
        assert!(s.per_iter_str().contains("ns"));
        let s = Sample { iters: 3, mean_s: 2.5e-3, min_s: 1e-3, max_s: 5e-3 };
        assert!(s.per_iter_str().contains("ms"));
    }
}
