//! The sharded serve fleet (DESIGN.md §Serving layer, §Fleet): S
//! independent MPC sessions for one trained model behind a single TCP
//! front-end.
//!
//! [`crate::net::serve::serve`] owns exactly one session, so every client
//! serializes through one secure-round pipeline. The fleet scales out
//! horizontally: each **shard** is a full session (Sim engine or TCP
//! member set) holding its own replica of the trained weight shares
//! (deterministic replay under the shared seed — see
//! [`crate::coordinator::serve::train_and_serve_fleet`]) and its own
//! [`Evaluator`] confined to stripe s of the partitioned divpub-tag space
//! ([`TagStripe`]). Tag freshness is a *per-session* invariant, so the
//! stripes need no cross-shard coordination, and a shard's answers are
//! byte-identical to a direct `private_eval_batch` on that shard's
//! session.
//!
//! ## Dispatch
//!
//! One FIFO queue per shard; readers route each arriving query to the
//! least-loaded live shard (queue depth + in-flight tick width, ties to
//! the lowest index). A query may pin itself to a shard with an optional
//! `"shard":s` field — honored while that shard is live (the byte-identity
//! and chaos tests use this), otherwise it falls back to least-loaded.
//! A shard whose own queue is empty **steals** the back half of the
//! longest live queue (skipping entries pinned to the victim), so one hot
//! queue cannot idle the rest of the fleet. Per-shard scheduling keeps
//! the single-session flush rules ([`ServeConfig::max_batch`] /
//! [`ServeConfig::max_wait`]) per shard.
//!
//! Responses carry a `"shard"` field and can interleave across shards on
//! one connection — fleet clients attribute replies by `seq`.
//!
//! ## Degrade, don't crash
//!
//! Each tick's evaluation runs under `catch_unwind`: a session whose
//! transport dies (TCP members gone) or that is killed by the
//! `{"cmd":"kill-shard","shard":s}` chaos command panics mid-op, the
//! shard is marked **dead**, and every query it owed — the interrupted
//! tick plus its queue — is re-dispatched to surviving shards. The
//! interrupted tick's reserved tags are burned unrevealed, which is safe:
//! freshness only forbids *reuse*, and survivors evaluate with their own
//! stripe-local tags. With zero survivors the front-end answers errors
//! but keeps accepting connections, so `{"cmd":"shutdown"}` still drains
//! and the clean-shutdown teardown still runs.
//!
//! ## Self-healing (DESIGN.md §Fleet)
//!
//! With [`FleetOptions::respawn`] set, death is not final: the dead
//! shard's scheduler thread doubles as its supervisor. It calls the
//! respawn factory, which trains a replacement session by deterministic
//! replay (same seed, same training schedule — the
//! [`Evaluator::clone_into_session`] contract) confined to the **next
//! generation** of the shard's tag stripe ([`TagStripe::generation`]):
//! tags burned by the dead generation are never reissued, so divpub
//! freshness survives any number of respawns. The revived shard is
//! re-admitted to dispatch (survivors keep answering throughout), and
//! each replacement session is handed back to the factory's `reap` hook
//! when it in turn dies or the fleet drains. A `kill-shard` that lands
//! inside the respawn window may be absorbed by the revival — the chaos
//! command guarantees at least one death, not a permanent one.
//!
//! [`FleetOptions::probe_interval`] arms a per-shard health probe: an
//! idle scheduler periodically runs a one-element `mul_vec` over two
//! dummy constants (defined once per generation, never revealed, no
//! divpub tags — CheckedSession-legal) so a shard whose members died is
//! quarantined *before* a real client query is dispatched to it.
//! [`FleetOptions::fault_plan`] injects a seeded, deterministic schedule
//! of transport severs, stalls, and panics keyed on per-shard wake
//! counters ([`FaultPlan`]), so the chaos tests replay identical failure
//! schedules across engines and runs.

use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::backoff;
use super::fault::{FaultKind, FaultPlan};
use super::serve::{
    cv_wait, cv_wait_timeout, json_escape, lock, query_from_json, read_json_msg,
    render_response, reply, reply_error, ConnShared, ServeConfig,
};
use super::{MemberLinkState, NetStats};
use crate::json::Json;
use crate::protocols::engine::DataId;
use crate::protocols::session::MpcSession;
use crate::spn::plan::{Evaluator, Query, TagStripe};

/// Out-of-band shard kill switch: severs the shard's transport so its
/// next secure op aborts. TCP shards install
/// `TcpSession::sever_handle`; Sim shards have no transport to cut and
/// rely on the killed flag alone.
pub type ShardSever = Box<dyn Fn() + Send + Sync>;

/// One shard of a serve fleet: a session, its striped evaluator, and its
/// replica of the model's weight shares.
pub struct FleetShard<'a, S: MpcSession> {
    /// The shard's MPC session (exclusively owned by its scheduler
    /// thread for the lifetime of [`serve_fleet`]).
    pub sess: &'a mut S,
    /// Plan evaluator confined to this shard's [`TagStripe`] (built via
    /// `Evaluator::clone_into_session`).
    pub ev: Evaluator,
    /// Sum-weight share handles in `sess`.
    pub sum_w: Vec<DataId>,
    /// Learned leaf-θ share handles in `sess` (None = public defaults).
    pub learned_theta: Option<Vec<DataId>>,
    /// Optional transport kill switch for `kill-shard` (TCP shards).
    pub sever: Option<ShardSever>,
}

/// A replacement shard built by a [`RespawnFactory`]: the same shape as
/// [`FleetShard`] but *owning* its session (the scheduler thread that
/// revives a shard keeps the replacement alive until the next death or
/// the drain), plus a `reap` hook that takes the session back for
/// teardown — `reap(sess, dead)` with `dead = true` when the replacement
/// itself died (its transport may be gone, so reap lossily).
pub struct RespawnShard<S: MpcSession> {
    /// The replacement session (trained by deterministic replay).
    pub sess: S,
    /// Evaluator confined to the replacement's *generation* sub-stripe.
    pub ev: Evaluator,
    /// Sum-weight share handles in `sess`.
    pub sum_w: Vec<DataId>,
    /// Learned leaf-θ share handles in `sess` (None = public defaults).
    pub learned_theta: Option<Vec<DataId>>,
    /// Transport kill switch for the replacement (installed fleet-wide so
    /// `kill-shard` keeps working across generations).
    pub sever: Option<ShardSever>,
    /// Teardown hook: `reap(sess, dead)`.
    pub reap: Box<dyn FnOnce(S, bool) + Send>,
}

/// Builds generation `gen ≥ 1` of shard `s`: called as
/// `factory(s, TagStripe::generation(s, nshards, gen))` on the dead
/// shard's scheduler thread. Must reproduce the fleet's trained model by
/// deterministic replay into a fresh session confined to the given
/// stripe (see [`crate::coordinator::serve::RespawnBuilder`]).
pub type RespawnFactory<'f, S> =
    Box<dyn Fn(usize, TagStripe) -> Result<RespawnShard<S>> + Send + Sync + 'f>;

/// Self-healing knobs for [`serve_fleet`]. The default (`None`
/// everywhere) reproduces the degrade-don't-crash fleet exactly: no
/// probes, no respawn, no injected faults.
pub struct FleetOptions<'f, S: MpcSession> {
    /// Probe an idle shard with a no-op secure round at this interval.
    pub probe_interval: Option<Duration>,
    /// Revive dead shards into fresh tag-stripe generations.
    pub respawn: Option<RespawnFactory<'f, S>>,
    /// Deterministic fault schedule (chaos testing).
    pub fault_plan: Option<FaultPlan>,
}

impl<S: MpcSession> Default for FleetOptions<'_, S> {
    fn default() -> Self {
        FleetOptions { probe_interval: None, respawn: None, fault_plan: None }
    }
}

/// What one shard did, inside a [`FleetReport`].
#[derive(Clone, Debug, Default)]
pub struct ShardReport {
    /// Queries this shard answered.
    pub queries: u64,
    /// Scheduler ticks this shard ran.
    pub batches: u64,
    /// Widest tick this shard served.
    pub max_tick: usize,
    /// Σ of this shard's per-tick [`NetStats`] deltas.
    pub stats: NetStats,
    /// Health probes this shard answered (idle no-op secure rounds).
    pub probes: u64,
    /// Times this shard died and was revived into a fresh generation.
    pub respawns: u64,
    /// Did this shard die (session panic or kill-shard) and *stay* dead?
    pub dead: bool,
    /// Panic payload of this shard's most recent death (kept even when a
    /// respawn revived it), or the reason a respawn was refused.
    pub panic_msg: Option<String>,
    /// Last observed per-member transport link states (empty for Sim
    /// shards — they have no transport).
    pub links: Vec<MemberLinkState>,
}

/// What a fleet did, returned by [`serve_fleet`] after the drain.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    /// Queries answered across all shards.
    pub queries: u64,
    /// Scheduler ticks across all shards.
    pub batches: u64,
    /// Client connections accepted over the fleet's lifetime.
    pub clients: u64,
    /// Σ of every shard's stats.
    pub stats: NetStats,
    /// Widest tick any shard served.
    pub max_tick: usize,
    /// Number of shards the fleet started with.
    pub shards: usize,
    /// Shards dead by the end of the run.
    pub dead_shards: usize,
    /// Queries moved off a dying shard onto survivors.
    pub redispatched: u64,
    /// Shard revivals across the fleet (Σ per-shard `respawns`).
    pub respawns: u64,
    /// Per-shard breakdown, indexed by shard.
    pub per_shard: Vec<ShardReport>,
}

// --- shared front-end state ------------------------------------------------

struct FPending {
    conn: Arc<ConnShared>,
    seq: u64,
    query: Query,
    enqueued: Instant,
    /// Client-requested shard, if any (kept so stealing never moves a
    /// pinned query off its live shard).
    pin: Option<usize>,
}

#[derive(Default)]
struct ShardQueue {
    queue: VecDeque<FPending>,
    /// Width of the tick the shard is currently evaluating (load signal
    /// for least-loaded dispatch).
    in_flight: usize,
    /// Session gone; never routed to again.
    dead: bool,
    /// kill-shard received; the scheduler turns this into `dead` on its
    /// next wake-up.
    killed: bool,
}

#[derive(Default)]
struct FleetState {
    shards: Vec<ShardQueue>,
    shutdown: bool,
    /// Queries answered fleet-wide (drives `max_queries`).
    answered: u64,
    redispatched: u64,
    conns: Vec<Arc<ConnShared>>,
    reader_handles: Vec<JoinHandle<()>>,
    clients_seen: u64,
}

struct FleetShared {
    state: Mutex<FleetState>,
    cvar: Condvar,
    /// Per-shard transport kill switches (`None` for Sim shards). Behind
    /// its own lock (never nested with `state`) because a respawned
    /// generation installs its replacement's sever.
    severs: Mutex<Vec<Option<ShardSever>>>,
    nshards: usize,
}

/// Least-loaded live shard, honoring a live pin. `None` = no live shard.
fn route(st: &FleetState, pin: Option<usize>) -> Option<usize> {
    if let Some(p) = pin {
        let sq = &st.shards[p];
        if !sq.dead && !sq.killed {
            return Some(p);
        }
    }
    st.shards
        .iter()
        .enumerate()
        .filter(|(_, q)| !q.dead && !q.killed)
        .min_by_key(|(i, q)| (q.queue.len() + q.in_flight, *i))
        .map(|(i, _)| i)
}

/// The longest live queue worth stealing from (≥ 2 entries, not `thief`).
fn steal_victim(st: &FleetState, thief: usize) -> Option<usize> {
    st.shards
        .iter()
        .enumerate()
        .filter(|&(i, q)| i != thief && !q.dead && !q.killed && q.queue.len() >= 2)
        .max_by_key(|(_, q)| q.queue.len())
        .map(|(i, _)| i)
}

/// Take up to half of `victim`'s queue (capped at `max_batch`) from the
/// back, skipping entries pinned to the victim; the stolen run keeps its
/// FIFO order.
fn steal_from(q: &mut VecDeque<FPending>, max_batch: usize, victim: usize) -> Vec<FPending> {
    let want = (q.len() / 2).min(max_batch);
    let mut got = Vec::new();
    while got.len() < want {
        match q.pop_back() {
            Some(p) if p.pin != Some(victim) => got.push(p),
            Some(pinned) => {
                q.push_back(pinned);
                break;
            }
            None => break,
        }
    }
    got.reverse();
    got
}

/// What a shard scheduler woke up to do.
enum Wake {
    /// A coalesced tick of queries to evaluate.
    Tick(Vec<FPending>),
    /// Idle past the probe interval: run a health probe round.
    Probe,
    /// `kill-shard` pending: take the death path.
    Killed,
    /// Drained shutdown (or the shard is marked dead): stop serving.
    Drained,
}

/// Next wake-up for shard `s`: its own queue under the single-session
/// flush rules, else stolen work, else block — with a probe timeout when
/// the fleet runs health probes.
fn next_wake(
    shared: &FleetShared,
    s: usize,
    cfg: &ServeConfig,
    probe_interval: Option<Duration>,
) -> Wake {
    let mut st = lock(&shared.state);
    loop {
        if st.shards[s].dead {
            return Wake::Drained;
        }
        if st.shards[s].killed {
            return Wake::Killed;
        }
        if !st.shards[s].queue.is_empty() {
            break;
        }
        if let Some(v) = steal_victim(&st, s) {
            let stolen = steal_from(&mut st.shards[v].queue, cfg.max_batch, v);
            if !stolen.is_empty() {
                st.shards[s].in_flight = stolen.len();
                return Wake::Tick(stolen);
            }
        }
        if st.shutdown {
            return Wake::Drained;
        }
        match probe_interval {
            Some(iv) => {
                let (g, to) = cv_wait_timeout(&shared.cvar, st, iv);
                st = g;
                if to.timed_out() {
                    return Wake::Probe;
                }
            }
            None => st = cv_wait(&shared.cvar, st),
        }
    }
    // coalesce arrivals exactly like the single-session scheduler
    // lint:allow(L004) — the loop above guarantees the queue is non-empty
    let deadline = st.shards[s].queue.front().unwrap().enqueued + cfg.max_wait;
    while st.shards[s].queue.len() < cfg.max_batch && !st.shutdown && !st.shards[s].killed {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (g, to) = cv_wait_timeout(&shared.cvar, st, deadline - now);
        st = g;
        if to.timed_out() {
            break;
        }
    }
    let take = st.shards[s].queue.len().min(cfg.max_batch);
    let tick: Vec<FPending> = st.shards[s].queue.drain(..take).collect();
    st.shards[s].in_flight = tick.len();
    Wake::Tick(tick)
}

/// Best-effort text of a panic payload (`&str` and `String` payloads,
/// which is what `panic!` produces; anything else gets a placeholder).
fn panic_payload_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Apply a scheduled fault before a wake executes. `Sever` cuts the
/// shard's transport via its installed sever; a Sim shard has none, so
/// the sever degrades to an injected panic (the shard must still die on
/// schedule for chaos plans to be engine-agnostic). `Delay` stalls the
/// scheduler in place. `Panic` (and a degraded sever) is returned as a
/// flag so the caller fires it *inside* its unwind region.
fn apply_fault(s: usize, fault: Option<FaultKind>, shared: &FleetShared) -> bool {
    match fault {
        None => false,
        Some(FaultKind::Sever) => {
            let sv = lock(&shared.severs);
            match &sv[s] {
                Some(f) => {
                    f();
                    false
                }
                None => true,
            }
        }
        Some(FaultKind::Delay(ms)) => {
            backoff::pause(Duration::from_millis(ms));
            false
        }
        Some(FaultKind::Panic) => true,
    }
}

/// The shard-death path: mark shard `s` dead (quarantined from routing)
/// and move every query it owed — the interrupted tick plus its queue —
/// to survivors. The tick's reserved tags are burned unrevealed
/// (freshness only forbids reuse); survivors answer with their own
/// stripe-local tags. Queries with no surviving shard to run on get an
/// error reply (retryable — see `client --repeat`, which backs off and
/// resends while a respawn is in flight).
fn shard_death(s: usize, tick: Vec<FPending>, shared: &FleetShared) {
    let mut lost = Vec::new();
    {
        let mut st = lock(&shared.state);
        st.shards[s].dead = true;
        st.shards[s].in_flight = 0;
        let mut orphans = tick;
        orphans.extend(st.shards[s].queue.drain(..));
        st.redispatched += orphans.len() as u64;
        for mut p in orphans {
            if p.pin == Some(s) {
                p.pin = None;
            }
            match route(&st, p.pin) {
                Some(t) => st.shards[t].queue.push_back(p),
                None => lost.push(p),
            }
        }
        shared.cvar.notify_all();
    }
    for p in lost {
        reply_error(&p.conn, Some(p.seq), &format!("shard {s} died with no surviving shards"));
    }
}

/// How one generation of a shard ended.
enum GenEnd {
    /// Drained shutdown: the generation served to completion.
    Drained,
    /// The session died (transport gone, kill-shard, or injected fault).
    Died,
}

/// Serve one *generation* of shard `s` — one session's lifetime — until
/// drained shutdown or death. Responses carry `(shard, gen, snum)` where
/// `snum` is the query's index in this generation's served order: with
/// the per-query divpub-tag layout of `Evaluator::batch_prologue`, snum
/// alone pins the tag block a query consumed, so the byte-identity
/// oracle can replay any generation independently of tick boundaries.
#[allow(clippy::too_many_arguments)]
fn serve_generation<S: MpcSession>(
    s: usize,
    gen: u64,
    sess: &mut S,
    ev: &mut Evaluator,
    sum_w: &[DataId],
    learned_theta: Option<&[DataId]>,
    shared: &FleetShared,
    cfg: &ServeConfig,
    d: u128,
    opts: &FleetOptions<'_, S>,
    rep: &mut ShardReport,
    wake_no: &mut u64,
) -> GenEnd {
    let mut snum: u64 = 0;
    // Probe operands, built lazily once per generation: two public
    // constants whose product is computed (a real secure round through
    // every member) but never revealed and never tagged.
    let mut probe_ids: Option<(DataId, DataId)> = None;
    loop {
        match next_wake(shared, s, cfg, opts.probe_interval) {
            Wake::Drained => {
                rep.links = sess.link_states();
                return GenEnd::Drained;
            }
            Wake::Killed => {
                rep.panic_msg = Some(format!("shard {s} killed by command"));
                rep.links = sess.link_states();
                shard_death(s, Vec::new(), shared);
                return GenEnd::Died;
            }
            Wake::Probe => {
                let fault = opts.fault_plan.as_ref().and_then(|p| p.take(s, *wake_no));
                let wake = *wake_no;
                *wake_no += 1;
                let inject_panic = apply_fault(s, fault, shared);
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if inject_panic {
                        panic!("shard {s} gen {gen}: injected fault at wake {wake}");
                    }
                    let (a, b) = match probe_ids {
                        Some(ids) => ids,
                        None => {
                            let ids = (sess.constant(1), sess.constant(1));
                            probe_ids = Some(ids);
                            ids
                        }
                    };
                    let _ = sess.mul_vec(&[(a, b)]);
                }));
                match outcome {
                    Ok(()) => rep.probes += 1,
                    Err(e) => {
                        rep.panic_msg = Some(panic_payload_msg(&*e));
                        rep.links = sess.link_states();
                        shard_death(s, Vec::new(), shared);
                        return GenEnd::Died;
                    }
                }
            }
            Wake::Tick(tick) => {
                let fault = opts.fault_plan.as_ref().and_then(|p| p.take(s, *wake_no));
                let wake = *wake_no;
                *wake_no += 1;
                let inject_panic = apply_fault(s, fault, shared);
                let queries: Vec<Query> = tick.iter().map(|p| p.query.clone()).collect();
                // Read the kill flag *outside* the unwind region: panicking
                // while holding the state lock would poison it fleet-wide.
                let killed = { lock(&shared.state).shards[s].killed };
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    if killed {
                        panic!("shard {s} killed by command");
                    }
                    if inject_panic {
                        panic!("shard {s} gen {gen}: injected fault at wake {wake}");
                    }
                    ev.eval_batch(sess, &queries, sum_w, learned_theta)
                }));
                match outcome {
                    Ok((roots, delta)) => {
                        rep.queries += tick.len() as u64;
                        rep.batches += 1;
                        rep.stats = rep.stats + delta;
                        rep.max_tick = rep.max_tick.max(tick.len());
                        // bill the tick delta once per distinct client
                        let mut seen: Vec<u64> = Vec::new();
                        for p in &tick {
                            if !seen.contains(&p.conn.id) {
                                seen.push(p.conn.id);
                                let mut t = lock(&p.conn.total);
                                *t = *t + delta;
                            }
                        }
                        for (i, (p, &root)) in tick.iter().zip(&roots).enumerate() {
                            let total = *lock(&p.conn.total);
                            let msg = render_response(
                                p.seq,
                                root,
                                d,
                                tick.len(),
                                &delta,
                                &total,
                                Some((s, gen, snum + i as u64)),
                            );
                            reply(&p.conn, &msg);
                        }
                        snum += tick.len() as u64;
                        let mut st = lock(&shared.state);
                        st.shards[s].in_flight = 0;
                        st.answered += tick.len() as u64;
                        if let Some(maxq) = cfg.max_queries {
                            if st.answered >= maxq {
                                st.shutdown = true;
                            }
                        }
                        shared.cvar.notify_all();
                    }
                    Err(e) => {
                        rep.panic_msg = Some(panic_payload_msg(&*e));
                        rep.links = sess.link_states();
                        shard_death(s, tick, shared);
                        return GenEnd::Died;
                    }
                }
            }
        }
    }
}

/// One shard's scheduler: serves its gen-0 session to death or drain,
/// and — when a respawn factory is armed — doubles as the shard's
/// supervisor, reviving it into successive tag-stripe generations. Runs
/// on a scoped thread inside [`serve_fleet`].
fn shard_scheduler<S: MpcSession>(
    s: usize,
    shard: &mut FleetShard<'_, S>,
    shared: &FleetShared,
    cfg: &ServeConfig,
    d: u128,
    opts: &FleetOptions<'_, S>,
) -> ShardReport {
    let mut rep = ShardReport::default();
    // The fault-plan wake counter spans generations: a plan can schedule
    // a second fault for the respawned shard.
    let mut wake_no: u64 = 0;
    let mut gen: u64 = 0;
    // Replacement sessions are owned here; `None` while serving the
    // caller's borrowed gen-0 session.
    let mut owned: Option<RespawnShard<S>> = None;
    loop {
        let end = match owned.as_mut() {
            None => serve_generation(
                s,
                gen,
                &mut *shard.sess,
                &mut shard.ev,
                &shard.sum_w,
                shard.learned_theta.as_deref(),
                shared,
                cfg,
                d,
                opts,
                &mut rep,
                &mut wake_no,
            ),
            Some(r) => serve_generation(
                s,
                gen,
                &mut r.sess,
                &mut r.ev,
                &r.sum_w,
                r.learned_theta.as_deref(),
                shared,
                cfg,
                d,
                opts,
                &mut rep,
                &mut wake_no,
            ),
        };
        if matches!(end, GenEnd::Drained) {
            break;
        }
        // Death. Without a factory this is final (degrade, don't crash);
        // with one, train a replacement and re-admit the shard.
        let Some(factory) = &opts.respawn else {
            rep.dead = true;
            break;
        };
        if gen + 1 >= TagStripe::GENERATIONS {
            rep.dead = true;
            rep.panic_msg = Some(format!(
                "shard {s} exhausted its {} tag-stripe generations",
                TagStripe::GENERATIONS
            ));
            break;
        }
        match factory(s, TagStripe::generation(s, shared.nshards, gen + 1)) {
            Ok(mut fresh) => {
                // Hand the kill switch over to the new transport before
                // re-admission, so `kill-shard` targets the live session.
                {
                    let mut sv = lock(&shared.severs);
                    sv[s] = fresh.sever.take();
                }
                if let Some(prev) = owned.take() {
                    (prev.reap)(prev.sess, true);
                }
                owned = Some(fresh);
                gen += 1;
                rep.respawns += 1;
                let mut st = lock(&shared.state);
                st.shards[s].dead = false;
                st.shards[s].killed = false;
                shared.cvar.notify_all();
            }
            Err(e) => {
                rep.dead = true;
                rep.panic_msg = Some(format!("shard {s} respawn failed: {e}"));
                break;
            }
        }
    }
    if let Some(r) = owned.take() {
        (r.reap)(r.sess, rep.dead);
    }
    rep
}

// --- front-end (readers + accept loop) -------------------------------------

/// Parse an optional integer `"shard"` routing hint in `0..nshards`.
/// `Ok(None)` = unpinned; `Err` = present but unusable.
fn parse_pin(j: &Json, nshards: usize) -> Result<Option<usize>> {
    match j.opt("shard") {
        None => Ok(None),
        Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 && (*n as usize) < nshards => {
            Ok(Some(*n as usize))
        }
        Some(_) => bail!("\"shard\" must be an integer in 0..{nshards}"),
    }
}

/// Per-connection reader: hello, then frames → routed queue entries.
/// Extends the single-session reader with the `"shard"` pin and the
/// `kill-shard` chaos command. Never touches any MPC session.
fn fleet_reader_session(conn: &Arc<ConnShared>, shared: &FleetShared, hello: &str, num_vars: usize) {
    if !reply(conn, hello) {
        return;
    }
    let Ok(rstream) = conn.stream.try_clone() else { return };
    let mut r = BufReader::with_capacity(8192, rstream);
    let nshards = shared.nshards;
    loop {
        let Ok(txt) = read_json_msg(&mut r) else { return }; // disconnect
        let j = match Json::parse(&txt) {
            Ok(j) => j,
            Err(e) => {
                let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
                if !reply_error(conn, Some(seq), &format!("request is not JSON: {e}")) {
                    return;
                }
                continue;
            }
        };
        if let Some(cmd) = j.opt("cmd") {
            if matches!(cmd, Json::Str(c) if c.as_str() == "shutdown") {
                reply(conn, "{\"ok\":true}");
                let mut st = lock(&shared.state);
                st.shutdown = true;
                shared.cvar.notify_all();
                return;
            }
            if matches!(cmd, Json::Str(c) if c.as_str() == "kill-shard") {
                match parse_pin(&j, nshards) {
                    Ok(Some(t)) => {
                        {
                            let mut st = lock(&shared.state);
                            st.shards[t].killed = true;
                            shared.cvar.notify_all();
                        }
                        // sever outside the state lock: closing sockets
                        // can block (the severs lock is leaf-level)
                        {
                            let sv = lock(&shared.severs);
                            if let Some(f) = &sv[t] {
                                f();
                            }
                        }
                        if !reply(conn, &format!("{{\"ok\":true,\"killed\":{t}}}")) {
                            return;
                        }
                    }
                    _ => {
                        if !reply_error(
                            conn,
                            None,
                            &format!("kill-shard needs \"shard\" in 0..{nshards}"),
                        ) {
                            return;
                        }
                    }
                }
                continue;
            }
            if !reply_error(conn, None, &format!("unknown cmd {cmd:?}")) {
                return;
            }
            continue;
        }
        let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
        let pin = match parse_pin(&j, nshards) {
            Ok(p) => p,
            Err(e) => {
                if !reply_error(conn, Some(seq), &e.to_string()) {
                    return;
                }
                continue;
            }
        };
        match query_from_json(&j, num_vars) {
            Ok(query) => {
                let mut st = lock(&shared.state);
                if st.shutdown {
                    drop(st);
                    if !reply_error(conn, Some(seq), "server is shutting down") {
                        return;
                    }
                    continue;
                }
                match route(&st, pin) {
                    Some(t) => {
                        st.shards[t].queue.push_back(FPending {
                            conn: conn.clone(),
                            seq,
                            query,
                            enqueued: Instant::now(),
                            pin,
                        });
                        shared.cvar.notify_all();
                    }
                    None => {
                        drop(st);
                        if !reply_error(conn, Some(seq), "no live shards") {
                            return;
                        }
                    }
                }
            }
            Err(e) => {
                if !reply_error(conn, Some(seq), &e.to_string()) {
                    return;
                }
            }
        }
    }
}

fn fleet_reader_loop(
    conn: Arc<ConnShared>,
    shared: Arc<FleetShared>,
    hello: Arc<String>,
    num_vars: usize,
) {
    fleet_reader_session(&conn, &shared, &hello, num_vars);
    // prune, exactly like the single-session reader (queued FPendings hold
    // their own Arc, so in-flight responses still go out)
    let mut st = lock(&shared.state);
    st.conns.retain(|c| c.id != conn.id);
    st.reader_handles.retain(|h| !h.is_finished());
}

/// Accept loop: register connections, spawn readers, exit on shutdown
/// (woken by a dummy self-connection, as in the single-session server).
fn fleet_listener_loop(
    listener: TcpListener,
    shared: Arc<FleetShared>,
    hello: Arc<String>,
    num_vars: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if lock(&shared.state).shutdown {
                    return;
                }
                backoff::pause(Duration::from_millis(50));
                continue;
            }
        };
        let mut st = lock(&shared.state);
        if st.shutdown {
            return;
        }
        st.clients_seen += 1;
        let Some(conn) = ConnShared::register(st.clients_seen, stream) else { continue };
        st.conns.push(conn.clone());
        let rs = shared.clone();
        let h = hello.clone();
        st.reader_handles
            .push(std::thread::spawn(move || fleet_reader_loop(conn, rs, h, num_vars)));
    }
}

/// Run a serve fleet: accept clients on `listener` and micro-batch their
/// queries across the `shards` — one scheduler thread per shard, each
/// exclusively owning its session. Returns after a drained shutdown with
/// every spawned thread joined; the gen-0 sessions outlive the call (the
/// caller shuts them down, using their lossy path for shards that died
/// **or respawned** — a respawn orphans the gen-0 transport). Replacement
/// sessions built by `opts.respawn` are reaped inside the fleet.
///
/// Every shard must serve the same compiled plan; each generation's
/// answers are byte-identical to a direct `private_eval_batch` of the
/// queries it served, in its served (`snum`) order, on a session with the
/// same seed, training replay, and generation [`TagStripe`] (pinned by
/// `rust/tests/fleet.rs`).
pub fn serve_fleet<S: MpcSession + Send>(
    mut shards: Vec<FleetShard<'_, S>>,
    listener: TcpListener,
    cfg: &ServeConfig,
    opts: FleetOptions<'_, S>,
) -> Result<FleetReport> {
    if cfg.max_batch == 0 {
        bail!("serve_fleet needs max_batch ≥ 1");
    }
    if shards.is_empty() {
        bail!("serve_fleet needs at least one shard");
    }
    let (num_vars, d) = (shards[0].ev.plan().num_vars, shards[0].ev.plan().d);
    for sh in &shards {
        let p = sh.ev.plan();
        if p.num_vars != num_vars || p.d != d {
            bail!("every fleet shard must serve the same compiled plan");
        }
        let stripe = sh.ev.stripe();
        if stripe.map(|st| st.shards()) != Some(shards.len()) {
            bail!(
                "shard evaluator stripe {stripe:?} does not match a {}-shard fleet \
                 (build shards via Evaluator::clone_into_session)",
                shards.len()
            );
        }
    }
    let nshards = shards.len();
    let addr = listener.local_addr()?;
    let hello = Arc::new(format!(
        "{{\"proto\":1,\"name\":\"{}\",\"num_vars\":{},\"d\":{},\"max_batch\":{},\"shards\":{}}}",
        json_escape(&shards[0].ev.plan().name),
        num_vars,
        d,
        cfg.max_batch,
        nshards
    ));
    let severs: Vec<Option<ShardSever>> = shards.iter_mut().map(|sh| sh.sever.take()).collect();
    let shared = Arc::new(FleetShared {
        state: Mutex::new(FleetState {
            shards: (0..nshards).map(|_| ShardQueue::default()).collect(),
            ..FleetState::default()
        }),
        cvar: Condvar::new(),
        severs: Mutex::new(severs),
        nshards,
    });
    let ls = shared.clone();
    let lhello = hello.clone();
    let lh = std::thread::spawn(move || fleet_listener_loop(listener, ls, lhello, num_vars));

    let mut per_shard: Vec<ShardReport> = Vec::with_capacity(nshards);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nshards);
        for (s, shard) in shards.iter_mut().enumerate() {
            let sh: &FleetShared = &shared;
            let op: &FleetOptions<'_, S> = &opts;
            handles.push(scope.spawn(move || shard_scheduler(s, shard, sh, cfg, d, op)));
        }
        // Hold the front door open until shutdown even if every scheduler
        // died: readers keep answering errors and the shutdown command
        // must still drain cleanly.
        {
            let mut st = lock(&shared.state);
            while !st.shutdown {
                st = cv_wait(&shared.cvar, st);
            }
        }
        for h in handles {
            // A scheduler that panicked outside its unwind regions still
            // reports: dead, with the panic payload preserved (not
            // silently swallowed into a default report).
            per_shard.push(h.join().unwrap_or_else(|e| ShardReport {
                dead: true,
                panic_msg: Some(panic_payload_msg(&*e)),
                ..ShardReport::default()
            }));
        }
    });
    // graceful teardown, exactly like the single-session server
    let _ = TcpStream::connect(addr);
    lh.join().map_err(|_| anyhow!("fleet listener thread panicked"))?;
    let (conns, readers, clients, redispatched) = {
        let mut st = lock(&shared.state);
        (
            std::mem::take(&mut st.conns),
            std::mem::take(&mut st.reader_handles),
            st.clients_seen,
            st.redispatched,
        )
    };
    for c in &conns {
        let _ = c.stream.shutdown(Shutdown::Both);
    }
    for h in readers {
        h.join().map_err(|_| anyhow!("fleet reader thread panicked"))?;
    }

    let mut report = FleetReport {
        clients,
        shards: nshards,
        redispatched,
        per_shard: per_shard.clone(),
        ..FleetReport::default()
    };
    for r in &per_shard {
        report.queries += r.queries;
        report.batches += r.batches;
        report.stats = report.stats + r.stats;
        report.max_tick = report.max_tick.max(r.max_tick);
        report.dead_shards += r.dead as usize;
        report.respawns += r.respawns;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(pin: Option<usize>) -> FPending {
        // a connected TCP pair so ConnShared::register has a real socket
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let conn = ConnShared::register(1, a).unwrap();
        FPending {
            conn,
            seq: 0,
            query: Query { x: vec![0], marg: vec![true] },
            enqueued: Instant::now(),
            pin,
        }
    }

    fn state(loads: &[(usize, usize, bool)]) -> FleetState {
        // (queued, in_flight, dead) per shard
        let mut st = FleetState::default();
        for &(queued, in_flight, dead) in loads {
            let mut q = ShardQueue { in_flight, dead, ..ShardQueue::default() };
            for _ in 0..queued {
                q.queue.push_back(pend(None));
            }
            st.shards.push(q);
        }
        st
    }

    #[test]
    fn routing_is_least_loaded_with_live_pins() {
        let st = state(&[(3, 0, false), (0, 2, false), (1, 0, false)]);
        assert_eq!(route(&st, None), Some(2), "lowest queue+in_flight wins");
        assert_eq!(route(&st, Some(0)), Some(0), "a live pin is honored");
        let st = state(&[(0, 0, true), (5, 0, false)]);
        assert_eq!(route(&st, Some(0)), Some(1), "a dead pin falls back");
        let st = state(&[(0, 0, true), (0, 0, true)]);
        assert_eq!(route(&st, None), None, "no live shard → no route");
    }

    #[test]
    fn stealing_takes_the_unpinned_back_half_in_order() {
        let mut q: VecDeque<FPending> = VecDeque::new();
        for seq in 0..6 {
            let mut p = pend(None);
            p.seq = seq;
            q.push_back(p);
        }
        let got = steal_from(&mut q, 16, 0);
        assert_eq!(got.len(), 3, "half of six");
        assert_eq!(got.iter().map(|p| p.seq).collect::<Vec<_>>(), vec![3, 4, 5], "FIFO kept");
        assert_eq!(q.len(), 3);

        // entries pinned to the victim are never stolen
        let mut q: VecDeque<FPending> = VecDeque::new();
        for seq in 0..4 {
            let mut p = pend(Some(7));
            p.seq = seq;
            q.push_back(p);
        }
        assert!(steal_from(&mut q, 16, 7).is_empty());
        assert_eq!(q.len(), 4);
    }
}
