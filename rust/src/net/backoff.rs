//! Capped, deterministically-jittered backoff — the one sanctioned sleep
//! site in the `net/` layer (DESIGN.md §Fleet).
//!
//! Every retry loop in the transport and fleet code (member reconnects,
//! accept-loop breathers, fault-plan delays) waits through this module
//! instead of calling `thread::sleep` directly, for two reasons:
//!
//! * **Thundering-herd hygiene.** A fleet that loses a member loses every
//!   shard's connection to it at once; naked fixed-interval retries then
//!   hammer the listener in lockstep. [`Backoff`] doubles the wait per
//!   attempt up to a cap and adds *deterministic* jitter (a [`Prng`] draw
//!   keyed by seed and attempt number) so retries spread out — yet two
//!   runs with the same seed wait the same schedule, keeping chaos tests
//!   reproducible.
//! * **Lintability.** spn-lint L008 flags any bare `thread::sleep` in
//!   `net/` outside this file, so un-jittered waits cannot creep back in.
//!
//! [`pause`] is the raw escape hatch for fixed waits that are genuinely
//! not retries (e.g. a fault-plan's scheduled frame delay); it exists so
//! callers go through a named, greppable chokepoint rather than an
//! anonymous sleep.

use std::time::Duration;

use crate::rng::{Prng, Rng};

/// Exponential backoff with a cap and deterministic jitter.
///
/// The wait before attempt `k` (0-based) is drawn uniformly from
/// `[base·2^k / 2, base·2^k)`, clamped to `cap` — the standard
/// "equal jitter" scheme, with the jitter coming from a seeded [`Prng`]
/// so the schedule is a pure function of `(seed, attempt)`.
#[derive(Debug)]
pub struct Backoff {
    attempt: u32,
    base: Duration,
    cap: Duration,
    seed: u64,
}

impl Backoff {
    /// A schedule starting at `base` and never exceeding `cap`.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { attempt: 0, base, cap, seed }
    }

    /// How many waits this schedule has served so far.
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// The next wait in the schedule, without sleeping. Advances the
    /// attempt counter.
    pub fn next_delay(&mut self) -> Duration {
        let shift = self.attempt.min(20); // 2^20 · base already dwarfs any cap
        self.attempt += 1;
        let full = self
            .base
            .saturating_mul(1u32 << shift)
            .min(self.cap)
            .max(Duration::from_micros(1));
        let full_us = full.as_micros() as u64;
        // equal jitter: [full/2, full), deterministic in (seed, attempt)
        let mut rng = Prng::seed_from_u64(self.seed ^ (self.attempt as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let jittered_us = full_us / 2 + rng.gen_range_u64((full_us / 2).max(1));
        Duration::from_micros(jittered_us)
    }

    /// Sleep for the next wait in the schedule.
    pub fn wait(&mut self) {
        let d = self.next_delay();
        pause(d);
    }

    /// Restart the schedule (after a success).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }
}

/// The `net/` layer's single raw sleep: a named chokepoint for fixed,
/// non-retry waits (fault-plan delays, accept-loop breathers). Everything
/// retry-shaped should use [`Backoff`] instead.
pub fn pause(d: Duration) {
    std::thread::sleep(d);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(400);
        let mut a = Backoff::new(base, cap, 7);
        let mut b = Backoff::new(base, cap, 7);
        let delays: Vec<Duration> = (0..12).map(|_| a.next_delay()).collect();
        let again: Vec<Duration> = (0..12).map(|_| b.next_delay()).collect();
        assert_eq!(delays, again, "same seed, same schedule");
        for (k, d) in delays.iter().enumerate() {
            assert!(*d < cap, "attempt {k} exceeds the cap: {d:?}");
            assert!(*d >= base / 2, "attempt {k} under the jitter floor: {d:?}");
        }
        // the tail is cap-bound: jitter keeps it in [cap/2, cap)
        assert!(delays[11] >= cap / 2);
        // a different seed gives a different schedule (jitter is live)
        let mut c = Backoff::new(base, cap, 8);
        let other: Vec<Duration> = (0..12).map(|_| c.next_delay()).collect();
        assert_ne!(delays, other, "jitter must depend on the seed");
        // reset restarts from the base
        a.reset();
        assert_eq!(a.attempts(), 0);
        assert!(a.next_delay() <= base);
    }
}
