//! [`TcpSession`] — the real-socket implementation of
//! [`MpcSession`](crate::protocols::session::MpcSession): a Manager-side
//! driver plus one OS thread per member, speaking the framed protocol of
//! [`super::tcp`] over loopback (or any reachable address).
//!
//! This replaces the former `net::distributed` module's standalone 4-opcode
//! interpreter: the member event loop below executes the *same*
//! share-store / [`ShamirCtx`] semantics as the engine's `Member`, opcode
//! by opcode, for the full vectorized session vocabulary — so full private
//! training, inference and k-means run end-to-end through the generic
//! coordinators over real TCP parties, and (under the same seed) produce
//! **byte-identical** results to the simulated engine. The cross-backend
//! integration tests pin that equality; the RNG contract that makes it
//! hold is documented on the trait.
//!
//! Topology: all traffic relays through the Manager (the paper's WebSocket
//! deployment also stars at the Manager, §5.2). The relay only ever sees
//! Shamir sub-shares and the §3.4 masked opening `z' = u + r`; each
//! member's private inputs travel only on the manager↔owner link during
//! provisioning (a production deployment loads them party-locally instead
//! — the wire vocabulary is unchanged either way).
//!
//! Data plane (DESIGN.md §Data plane): every socket runs `TCP_NODELAY`
//! with `BufReader`/`BufWriter` framing (one flush per frame, so a frame
//! is one syscall instead of one per element); the member loop keeps a
//! dense `ShareStore` slab plus reusable frame/scratch buffers
//! ([`read_frame_into`]) and deals through
//! [`ShamirCtx::share_batch_into_pooled`] (Montgomery-domain Vandermonde
//! dot, coefficients pre-drawn serially, evaluation fanned over
//! [`TcpSessionConfig::threads`] scoped workers), so steady-state
//! exercises perform no per-element heap allocation and wire bytes are
//! identical for every pool width. Dealer→manager frames for `input`/`mul`/
//! `sq2pq` are **party-major** (`dealt[(j−1)·k + e]` = member j's
//! sub-share of element e) to match the flat batch-dealing layout;
//! divpub's Alice/Bob frames stay element-major because §3.4 interleaves
//! two deals per element (the draw-order contract). Manager→member frames
//! are element-major with dealer-inner stride, unchanged from the seed
//! protocol.
//!
//! Error handling: the session trait mirrors the engine's infallible
//! signatures, so transport failures abort via panic with the failing
//! operation named. The fallible building blocks ([`TcpSession::spawn_local`],
//! [`TcpSession::shutdown`], the internal op drivers) use `Result`.
//!
//! Transport hardening (DESIGN.md §Fleet): the manager's member sockets
//! carry read/write deadlines ([`TcpSessionConfig::io_deadline_ms`]), so a
//! hung or killed member turns into a timely error instead of a silent
//! stall; members reconnect with capped jittered backoff
//! ([`super::backoff::Backoff`]) during session setup. Per-member link
//! health ([`MemberLinkState`](super::MemberLinkState)) is tracked from
//! observed reply latency and surfaced through
//! [`MpcSession::link_states`]. Deterministic member-side faults for chaos
//! tests inject via [`TcpSessionConfig::fault`].
//!
//! Accounting: [`TcpSession`] counts the frames and bytes it actually
//! relays and accumulates real elapsed seconds in `virtual_time_s`. The
//! simulated engine remains **authoritative** for the Tables 2–3 numbers
//! (DESIGN.md §2, §Session API); this module's stats describe the star
//! deployment as wired.
//!
//! Flights (DESIGN.md §Round scheduler): `MpcSession::submit` stages
//! mul/lin/tagged-divpub runs into one `OP_FLIGHT` frame;
//! `MpcSession::complete` broadcasts it once and drives each run's relay
//! phases in order. Members execute the runs in submission order against
//! the same share slab, so later runs may read earlier runs' outputs
//! within one flight; with buffered framing on both sides the instruction
//! frame and the first run's sub-share replies cross the wire
//! back-to-back (double-buffered send/recv) instead of paying one
//! broadcast round-trip per op. Traffic accounting stays per-op — a
//! flight moves latency, not bytes.
//!
//! wire-layout: v3 (opcodes, frame geometry and stride math live in
//! [`super::wire`], shared with `tcp.rs` — the compiler keeps both sides
//! of the socket in lockstep, and spn-lint L005 keeps these markers
//! paired; v3 added the `OP_FLIGHT` container frame).

use std::collections::HashMap; // lint:allow(L003) — d⁻¹ memo, not a share store
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Error, Result};

use super::backoff::{self, Backoff};
use super::tcp::{read_frame, read_frame_into, set_io_deadlines, write_frame_parts, Frame};
use super::wire::{
    divpub_q_slot, divpub_r_slot, element_major, flight_run_len, party_major, wire_bytes_for,
    OP_CONST, OP_DIVPUB, OP_DIVPUB_TAGGED, OP_FLIGHT, OP_INPUT, OP_LIN, OP_MUL, OP_REVEAL,
    OP_SHUTDOWN, OP_SQ2PQ,
};
use super::{MemberLinkState, NetStats};
use crate::field::Field;
use crate::parallel::{Pool, MIN_CHUNK};
use crate::protocols::divpub::{sample_r, tagged_r_many};
use crate::protocols::engine::{reset_scratch, DataId, ShareStore};
use crate::protocols::flight::FlightOp;
use crate::protocols::session::MpcSession;
use crate::rng::Prng;
use crate::sharing::shamir::ShamirCtx;

/// Buffered-framing capacity on both sides of every socket: large enough
/// that a typical vectorized exercise frame flushes in one write.
const FRAME_BUF: usize = 1 << 16;

/// A reply slower than this marks its link [`MemberLinkState::Degraded`]:
/// loopback/LAN relay phases complete in microseconds, so hundreds of
/// milliseconds means the member (or its path) is struggling even if the
/// hard deadline hasn't tripped yet.
const DEGRADED_AFTER: Duration = Duration::from_millis(500);

/// How a deterministically-injected member fault manifests
/// ([`TcpSessionConfig::fault`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberFaultKind {
    /// The member thread panics — the manager's next read on that link
    /// blocks until the io deadline trips (how deadlines + probes detect
    /// member death).
    Panic,
    /// The member stalls this long before processing the frame, driving
    /// the link to `Degraded` (or `Down` if it exceeds the deadline).
    DelayMs(u64),
}

/// A chaos-test fault injected into one member's event loop after it has
/// processed `after_frames` exercise frames. Deterministic: frame counts,
/// not wall clocks, decide when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemberFault {
    /// 1-based member id the fault targets.
    pub member: usize,
    /// Fire when this many exercise frames have been processed.
    pub after_frames: u64,
    pub kind: MemberFaultKind,
}

/// Session parameters, mirroring the protocol-relevant subset of
/// `EngineConfig` (no schedule — the wire protocol is always vectorized —
/// and no simulated-latency model).
#[derive(Clone, Copy, Debug)]
pub struct TcpSessionConfig {
    /// Number of computing members (≥ 2: §3.4 needs distinct Alice/Bob).
    pub n: usize,
    /// Shamir degree; defaults to ⌊(n-1)/2⌋ like the engine.
    pub threshold: Option<usize>,
    /// Security parameter ρ for division-by-public (§3.4).
    pub rho_bits: u32,
    /// Seed for the per-member RNGs. Members derive their stream exactly
    /// like `Engine::new` (`seed ^ id·0x9E3779B97F4A7C15`), which is what
    /// makes a TCP run byte-identical to a simulated run.
    pub seed: u64,
    /// Manager-side read/write deadline per member socket, in
    /// milliseconds; `0` keeps the old fully-blocking behavior. A tripped
    /// deadline errors the op (the fleet catches it as shard death) and
    /// marks the link [`MemberLinkState::Down`].
    pub io_deadline_ms: u64,
    /// Deterministic member-side fault for chaos tests; `None` in
    /// production.
    pub fault: Option<MemberFault>,
    /// Worker-pool width inside each member thread (DESIGN.md §Field
    /// kernel): the k-loops of products, dealing evaluations and
    /// λ-recombination chunk over up to this many scoped threads. `1`
    /// (default) is strictly serial; wire bytes are identical for any
    /// value (RNG draws are pre-drawn serially before fan-out).
    pub threads: usize,
}

impl TcpSessionConfig {
    /// Defaults matching `EngineConfig::new(n)`: honest-majority
    /// threshold, ρ = 64, the same fixed seed, a 10 s io deadline and no
    /// injected fault.
    pub fn new(n: usize) -> Self {
        TcpSessionConfig {
            n,
            threshold: None,
            rho_bits: 64,
            seed: 0xC0FFEE,
            io_deadline_ms: 10_000,
            fault: None,
            threads: 1,
        }
    }

    /// Set the member-side worker-pool width.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// The configured deadline as the `Option<Duration>` the socket API
    /// wants (`None` = blocking).
    fn io_deadline(&self) -> Option<Duration> {
        (self.io_deadline_ms > 0).then(|| Duration::from_millis(self.io_deadline_ms))
    }
}

fn shamir_for(field: Field, cfg: &TcpSessionConfig) -> ShamirCtx {
    match cfg.threshold {
        Some(t) => ShamirCtx::with_threshold(field, cfg.n, t),
        None => ShamirCtx::new(field, cfg.n),
    }
}

/// One member's event loop: connect, say hello, then serve exercises until
/// shutdown. Owns the member's private share store and RNG — the exact
/// counterpart of the engine's `Member`, with the same per-exercise
/// randomness order — plus the reusable frame/scratch buffers and the
/// memoized `d⁻¹` table of the flat-buffer data plane.
fn member_loop(addr: String, id: usize, field: Field, cfg: TcpSessionConfig) -> Result<()> {
    let shamir = shamir_for(field, &cfg);
    let deg = shamir.t;
    let mut rng = Prng::seed_from_u64(cfg.seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let n = cfg.n;
    let f = field;
    let mut store = ShareStore::new();
    // Per-divisor d⁻¹ memo (a handful of entries), not a per-element
    // data-plane store; the share slab stays dense.
    let mut dinv_cache: HashMap<u128, u128> = HashMap::new(); // lint:allow(L003)
    // Connect with capped jittered backoff: during a fleet respawn the
    // manager's accept loop may lag the member spawns, and a fixed retry
    // interval would have every member of the new generation hammering
    // the listener in lockstep. Deterministic per (seed, member, attempt).
    let mut retry = Backoff::new(
        Duration::from_millis(10),
        Duration::from_millis(500),
        cfg.seed ^ (id as u64).rotate_left(17),
    );
    let stream = loop {
        match TcpStream::connect(&addr) {
            Ok(s) => break s,
            Err(e) if retry.attempts() < 12 => {
                let _ = e; // transient: refused/unreachable while spawning
                retry.wait();
            }
            Err(e) => return Err(e.into()),
        }
    };
    stream.set_nodelay(true)?;
    // Members keep blocking *reads* (an idle serve legitimately leaves
    // them waiting for the next exercise indefinitely) but bound writes:
    // a wedged manager must not absorb a member thread forever.
    stream.set_write_timeout(cfg.io_deadline())?;
    let mut w = BufWriter::with_capacity(FRAME_BUF, stream.try_clone()?);
    let mut r = BufReader::with_capacity(FRAME_BUF, stream);
    write_frame_parts(&mut w, 0, id as u32, &[])?;
    w.flush()?;
    let mut frames_seen: u64 = 0;
    let mut fault_armed = cfg.fault;

    // Reusable buffers: the event loop performs no per-frame heap
    // allocation once these reach their steady-state sizes.
    let mut ex = Frame::empty(); // current exercise broadcast
    let mut body = Frame::empty(); // first relayed read of a phase
    let mut body2 = Frame::empty(); // second relayed read (divpub z'/w)
    let mut dealt: Vec<u128> = Vec::new(); // outbound sub-share scratch
    let mut vals: Vec<u128> = Vec::new(); // local products / z' shares
    let mut runs: Vec<(usize, usize)> = Vec::new(); // flight run bounds
    let mut tag_buf: Vec<u64> = Vec::new(); // Alice: a divpub's tag slice
    let mut mask_buf: Vec<u128> = Vec::new(); // Alice: its batched PRF masks
    let mut coeffs: Vec<u128> = Vec::new(); // pooled dealing: pre-drawn coefficients

    // Member-side worker pool (DESIGN.md §Field kernel). `pool_for` keeps
    // small batches strictly serial so thread spawn never dominates; with
    // `threads == 1` every path below degenerates to the seed's serial
    // loops. RNG draws never happen inside a pooled closure — dealing
    // pre-draws coefficients serially — so wire bytes are identical for
    // any width.
    let pool = Pool::new(cfg.threads);
    let pool_for = move |work: usize| if work >= MIN_CHUNK { pool } else { Pool::serial() };

    let get = |store: &ShareStore, a: u128| -> Result<u128> {
        store.get(a as u64).ok_or_else(|| anyhow!("member {id} missing id {a}"))
    };

    loop {
        read_frame_into(&mut r, &mut ex)?;
        // Injected chaos fault: fires once, when the frame counter
        // matures. Frame counts (not wall clocks) decide, so runs replay
        // exactly.
        if let Some(fault) = fault_armed {
            if fault.member == id && frames_seen >= fault.after_frames {
                fault_armed = None;
                match fault.kind {
                    MemberFaultKind::Panic => {
                        panic!("member {id}: injected fault after {frames_seen} frames")
                    }
                    MemberFaultKind::DelayMs(ms) => backoff::pause(Duration::from_millis(ms)),
                }
            }
        }
        frames_seen += 1;
        // Split an OP_FLIGHT container (wire-layout v3) into its runs; a
        // plain exercise is one run covering the whole frame. Runs execute
        // in order against the same share slab, which is what lets a later
        // run read an earlier run's outputs within one flight.
        let elems = std::mem::take(&mut ex.elems);
        runs.clear();
        if elems[0] == OP_FLIGHT {
            let n_runs = elems[1] as usize;
            let mut i = 2;
            for _ in 0..n_runs {
                let len = flight_run_len(&elems[i..]).ok_or_else(|| {
                    anyhow!("member {id}: unflightable opcode {} inside a flight", elems[i])
                })?;
                runs.push((i, i + len));
                i += len;
            }
            if i != elems.len() {
                bail!("member {id}: flight frame length {} != runs end {i}", elems.len());
            }
        } else {
            runs.push((0, elems.len()));
        }
        for &(lo, hi) in &runs {
        let e = &elems[lo..hi];
        match e[0] {
            OP_SHUTDOWN => return Ok(()),
            OP_INPUT => {
                // [op, owner, k, out₀..] — owner deals its provisioned
                // values, party-major on the wire.
                let owner = e[1] as usize;
                let k = e[2] as usize;
                let outs = &e[3..3 + k];
                if owner == id {
                    read_frame_into(&mut r, &mut body)?;
                    reset_scratch(&mut dealt, k * n);
                    shamir.share_batch_into_pooled(
                        &body.elems,
                        deg,
                        &mut rng,
                        &mut dealt,
                        &mut coeffs,
                        pool_for(k * n),
                    );
                    write_frame_parts(&mut w, ex.exercise_id, id as u32, &dealt)?;
                    w.flush()?;
                }
                read_frame_into(&mut r, &mut body)?; // my k shares
                for (i, &o) in outs.iter().enumerate() {
                    store.put(o as u64, body.elems[i]);
                }
            }
            OP_CONST => {
                // [op, out, c] — constant polynomial share. Local.
                store.put(e[1] as u64, f.reduce(e[2]));
            }
            OP_LIN => {
                // [op, k, (out, c0, t, (c, a)×t)×k] — coefficients arrive
                // pre-embedded as field elements (manager runs from_i128).
                let k = e[1] as usize;
                let mut i = 2;
                for _ in 0..k {
                    let out = e[i] as u64;
                    let mut acc = e[i + 1];
                    let t = e[i + 2] as usize;
                    i += 3;
                    for _ in 0..t {
                        let c = e[i];
                        let a = get(&store, e[i + 1])?;
                        acc = f.add(acc, f.mul(c, a));
                        i += 2;
                    }
                    store.put(out, acc);
                }
            }
            OP_MUL => {
                // [op, k, out₀.., a₀.., b₀..]: local products → one flat
                // batch deal (party-major) → combine.
                let k = e[1] as usize;
                let outs = &e[2..2 + k];
                let avs = &e[2 + k..2 + 2 * k];
                let bvs = &e[2 + 2 * k..2 + 3 * k];
                // Local products, chunked over the member pool. Missing
                // ids surface as a `u128::MAX` sentinel (never a valid
                // product: p < 2⁷⁴) checked after the fan-in, keeping the
                // pooled closure infallible and the error path intact.
                reset_scratch(&mut vals, k);
                {
                    let store = &store;
                    pool_for(k).run_chunks(&mut vals, MIN_CHUNK, |start, chunk| {
                        for (off, slot) in chunk.iter_mut().enumerate() {
                            let ei = start + off;
                            *slot = match (
                                store.get(avs[ei] as u64),
                                store.get(bvs[ei] as u64),
                            ) {
                                (Some(a), Some(b)) => f.mul(a, b),
                                _ => u128::MAX,
                            };
                        }
                    });
                }
                if let Some(ei) = vals.iter().position(|&v| v == u128::MAX) {
                    bail!(
                        "member {id} missing id {} or {} (mul element {ei})",
                        avs[ei],
                        bvs[ei]
                    );
                }
                reset_scratch(&mut dealt, k * n);
                shamir.share_batch_into_pooled(
                    &vals,
                    deg,
                    &mut rng,
                    &mut dealt,
                    &mut coeffs,
                    pool_for(k * n),
                );
                write_frame_parts(&mut w, ex.exercise_id, id as u32, &dealt)?;
                w.flush()?;
                // relay returns, per element, the n sub-shares destined to me
                read_frame_into(&mut r, &mut body)?;
                let sub = &body.elems;
                // λ-recombination in the Montgomery kernel: λ lives in the
                // mont domain once (precomputed), each sub-share stays
                // canonical, `mont_mul_add` yields the canonical λ·share
                // product — division-free (DESIGN.md §Field kernel).
                let lambda_mont = shamir.lambda_mont();
                reset_scratch(&mut vals, k);
                pool_for(k).run_chunks(&mut vals, MIN_CHUNK, |start, chunk| {
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        let ei = start + off;
                        let mut acc = 0u128;
                        for (i, &lm) in lambda_mont.iter().enumerate() {
                            acc = f.mont_mul_add(acc, sub[element_major(ei, n, i)], lm);
                        }
                        *slot = acc;
                    }
                });
                for (ei, &o) in outs.iter().enumerate() {
                    store.put(o as u64, vals[ei]);
                }
            }
            OP_DIVPUB | OP_DIVPUB_TAGGED => {
                // [op, k, d, out₀.., u₀.., (tag₀.. when tagged)];
                // Alice = member 1, Bob = member 2.
                let k = e[1] as usize;
                let d = e[2];
                let outs = &e[3..3 + k];
                let us = &e[3 + k..3 + 2 * k];
                let tags = (e[0] == OP_DIVPUB_TAGGED).then(|| &e[3 + 2 * k..3 + 3 * k]);
                if id == 1 {
                    // Phase 1: Alice deals [r], [q = r mod d] per element,
                    // element-major on the wire ([e][r×n][q×n]) — the §3.4
                    // draw order (r, r's coefficients, q's coefficients)
                    // interleaves two deals per element and must match the
                    // engine's divpub_vec / divpub_vec_tagged draw-for-draw.
                    reset_scratch(&mut dealt, 2 * k * n);
                    if let Some(t) = tags {
                        // One streamed PRF derivation for the whole tag
                        // range (bit-identical to the per-element scalar
                        // calls — see `tagged_r_many`'s contract).
                        tag_buf.clear();
                        tag_buf.extend(t.iter().map(|&x| x as u64));
                        mask_buf.clear();
                        tagged_r_many(cfg.seed, &tag_buf, cfg.rho_bits, &mut mask_buf);
                    }
                    for ei in 0..k {
                        let rm = match tags {
                            Some(_) => mask_buf[ei],
                            None => sample_r(&mut rng, cfg.rho_bits),
                        };
                        let q = rm % d;
                        shamir.share_into(
                            rm,
                            deg,
                            &mut rng,
                            &mut dealt[ei * 2 * n..ei * 2 * n + n],
                        );
                        shamir.share_into(
                            q,
                            deg,
                            &mut rng,
                            &mut dealt[ei * 2 * n + n..(ei + 1) * 2 * n],
                        );
                    }
                    write_frame_parts(&mut w, ex.exercise_id, id as u32, &dealt)?;
                    w.flush()?;
                }
                read_frame_into(&mut r, &mut body)?; // per element: (rᵢ, qᵢ)
                // Phase 2: [z'] = [u] + [r], opened to Bob via the relay.
                vals.clear();
                for ei in 0..k {
                    vals.push(f.add(get(&store, us[ei])?, body.elems[2 * ei]));
                }
                write_frame_parts(&mut w, ex.exercise_id, id as u32, &vals)?;
                w.flush()?;
                if id == 2 {
                    // Phase 3: Bob reconstructs z', deals [w = z' mod d]
                    // (element-major, as the manager's scatter expects).
                    read_frame_into(&mut r, &mut body2)?;
                    reset_scratch(&mut dealt, k * n);
                    for ei in 0..k {
                        let z = shamir.reconstruct(&body2.elems[ei * n..(ei + 1) * n]);
                        let wv = z % d;
                        shamir.share_into(wv, deg, &mut rng, &mut dealt[ei * n..(ei + 1) * n]);
                    }
                    write_frame_parts(&mut w, ex.exercise_id, id as u32, &dealt)?;
                    w.flush()?;
                }
                read_frame_into(&mut r, &mut body2)?; // my k [w] shares
                // Phase 4 (local, corrected sign — DESIGN.md §4, the sign erratum):
                // [v] = ([u] + [q] − [w]) · d⁻¹, with d⁻¹ memoized per
                // divisor (Fermat inversion is ~74 squarings) and held in
                // the Montgomery domain so the per-element multiply is a
                // division-free `mont_mul` with a canonical result.
                let dinv_mont =
                    *dinv_cache.entry(d).or_insert_with(|| f.to_mont(f.inv(f.reduce(d))));
                for (ei, &o) in outs.iter().enumerate() {
                    let u_sh = get(&store, us[ei])?;
                    let v = f.mont_mul(
                        f.sub(f.add(u_sh, body.elems[2 * ei + 1]), body2.elems[ei]),
                        dinv_mont,
                    );
                    store.put(o as u64, v);
                }
            }
            OP_REVEAL => {
                // [op, k, a₀..]: send my shares to the manager.
                let k = e[1] as usize;
                vals.clear();
                for &a in &e[2..2 + k] {
                    vals.push(get(&store, a)?);
                }
                write_frame_parts(&mut w, ex.exercise_id, id as u32, &vals)?;
                w.flush()?;
            }
            OP_SQ2PQ => {
                // [op, k, out₀..]: deal my provisioned additive
                // contributions (party-major), then sum everyone's
                // sub-shares (no λ).
                let k = e[1] as usize;
                let outs = &e[2..2 + k];
                read_frame_into(&mut r, &mut body)?;
                reset_scratch(&mut dealt, k * n);
                shamir.share_batch_into_pooled(
                    &body.elems,
                    deg,
                    &mut rng,
                    &mut dealt,
                    &mut coeffs,
                    pool_for(k * n),
                );
                write_frame_parts(&mut w, ex.exercise_id, id as u32, &dealt)?;
                w.flush()?;
                read_frame_into(&mut r, &mut body)?;
                let sub = &body.elems;
                for (ei, &o) in outs.iter().enumerate() {
                    // Deferred reduction: n raw adds stay below u128 range
                    // (n·p ≪ 2¹²⁸), one reduce at the end.
                    let mut acc = 0u128;
                    for i in 0..n {
                        acc += sub[element_major(ei, n, i)];
                    }
                    store.put(o as u64, f.reduce(acc));
                }
            }
            op => bail!("member {id}: unknown opcode {op}"),
        }
        }
        ex.elems = elems; // hand the buffer back for the next read
    }
}

/// One manager↔member connection: buffered reader/writer halves of the
/// same `TCP_NODELAY` stream.
struct Conn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

/// The relay obligation one staged flight run leaves behind: after the
/// single `OP_FLIGHT` broadcast, the manager drives these in submission
/// order — exactly the order members execute the runs in.
enum FlightRelay {
    Mul { k: usize },
    Lin, // broadcast-only: no relay phases
    Divpub { k: usize },
}

/// A flight being staged between `submit` calls and `complete`:
/// `elems` accumulates `[OP_FLIGHT, n_runs, run₀.., run₁..]` (the run
/// count is patched in at launch) and `relays` remembers each run's
/// relay obligation.
struct TcpFlight {
    elems: Vec<u128>,
    relays: Vec<FlightRelay>,
}

/// Duplicated handles to a live session's member sockets, obtained via
/// [`TcpSession::sever_handle`]: [`SessionSever::sever`] shuts every
/// socket down from outside the session, aborting its next op. Chaos
/// tooling only — there is no way back to a healthy session.
pub struct SessionSever {
    streams: Vec<TcpStream>,
}

impl SessionSever {
    /// Cut every manager↔member connection (both directions). Idempotent;
    /// errors are ignored (the sockets may already be gone).
    pub fn sever(&self) {
        for s in &self.streams {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// The Manager end of a TCP session: owns the member connections,
/// schedules exercises, relays sub-shares, accounts frames.
pub struct TcpSession {
    cfg: TcpSessionConfig,
    field: Field,
    shamir: ShamirCtx,
    conns: Vec<Conn>, // index i = member i+1
    next_ex: u64,
    next_id: u64,
    next_tag: u64,
    flight: Option<TcpFlight>,
    stats: NetStats,
    /// Observed health per member link (index j = member j+1), updated by
    /// every `tx`/`rx` — see [`MemberLinkState`].
    links: Vec<MemberLinkState>,
    handles: Vec<JoinHandle<Result<()>>>,
}

impl TcpSession {
    /// Spawn `n` member threads against an ephemeral loopback port and
    /// connect them. The members are empty-handed: private inputs are
    /// provisioned per `input_vec`/`sq2pq_vec` call over the owner's link.
    pub fn spawn_local(field: Field, cfg: TcpSessionConfig) -> Result<Self> {
        if cfg.n < 2 {
            bail!("TcpSession needs n ≥ 2 members (distinct Alice/Bob for §3.4)");
        }
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let mut handles = Vec::new();
        for id in 1..=cfg.n {
            let a = addr.clone();
            handles.push(std::thread::spawn(move || member_loop(a, id, field, cfg)));
        }
        let mut conns_by_id: Vec<Option<Conn>> = (0..cfg.n).map(|_| None).collect();
        for _ in 0..cfg.n {
            let (s, _) = listener.accept()?;
            s.set_nodelay(true)?;
            // Read/write deadlines replace silent blocking I/O: a member
            // that dies mid-exercise turns into a timely op error here
            // instead of wedging the manager (and its shard) forever.
            set_io_deadlines(&s, cfg.io_deadline())?;
            let mut r = BufReader::with_capacity(FRAME_BUF, s.try_clone()?);
            let hello = read_frame(&mut r)?;
            let w = BufWriter::with_capacity(FRAME_BUF, s);
            conns_by_id[hello.from as usize - 1] = Some(Conn { r, w });
        }
        let conns: Vec<Conn> = conns_by_id.into_iter().map(|c| c.unwrap()).collect();
        Ok(TcpSession {
            cfg,
            field,
            shamir: shamir_for(field, &cfg),
            conns,
            next_ex: 0,
            next_id: 0,
            next_tag: 0,
            flight: None,
            stats: NetStats::default(),
            links: vec![MemberLinkState::Up; cfg.n],
            handles,
        })
    }

    /// Stop all members and join their threads.
    pub fn shutdown(mut self) -> Result<()> {
        self.broadcast(&[OP_SHUTDOWN])?;
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow!("member thread panicked"))??;
        }
        Ok(())
    }

    /// Best-effort shutdown for a session whose transport may already be
    /// severed (a dead fleet shard): try the OP_SHUTDOWN broadcast, then
    /// join member threads ignoring transport errors — a severed member
    /// exits with a read error rather than a clean opcode.
    pub fn shutdown_lossy(mut self) {
        let _ = self.broadcast(&[OP_SHUTDOWN]);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Duplicate handles to every member connection for out-of-band
    /// severing — the chaos switch behind the serve fleet's `kill-shard`
    /// command. [`SessionSever::sever`] may be called from any thread
    /// while the session is in use; the manager's next op then fails and
    /// the [`MpcSession`] impl panics, which a fleet catches as shard
    /// death. After severing, tear the session down with
    /// [`TcpSession::shutdown_lossy`].
    pub fn sever_handle(&self) -> Result<SessionSever> {
        let mut streams = Vec::with_capacity(self.conns.len());
        for c in &self.conns {
            streams.push(c.r.get_ref().try_clone()?);
        }
        Ok(SessionSever { streams })
    }

    /// Current per-member link health (index j = member j+1) — the data
    /// behind [`MpcSession::link_states`].
    pub fn link_states_snapshot(&self) -> &[MemberLinkState] {
        &self.links
    }

    // --- relay plumbing ---------------------------------------------------

    fn alloc_vec(&mut self, k: usize) -> Vec<DataId> {
        (0..k)
            .map(|_| {
                self.next_id += 1;
                DataId(self.next_id)
            })
            .collect()
    }

    /// Send one frame to member j+1 (write + flush: with `TCP_NODELAY` the
    /// frame leaves as one segment train immediately). A failed or
    /// deadline-expired write marks the link [`MemberLinkState::Down`].
    fn tx(&mut self, j: usize, elems: &[u128]) -> Result<()> {
        self.stats.messages += 1;
        self.stats.bytes += wire_bytes_for(elems.len()) as u64;
        let ex = self.next_ex;
        let c = &mut self.conns[j];
        let res = write_frame_parts(&mut c.w, ex, u32::MAX, elems)
            .and_then(|()| c.w.flush().map_err(Error::from))
            .map_err(|e| e.context(format!("send to member {}", j + 1)));
        if res.is_err() {
            self.links[j] = MemberLinkState::Down;
        }
        res
    }

    /// Receive one frame from member j+1, grading the link from the
    /// observed wait: error/deadline → `Down`, slower than
    /// [`DEGRADED_AFTER`] → `Degraded`, otherwise back to `Up`.
    fn rx(&mut self, j: usize) -> Result<Vec<u128>> {
        let t0 = Instant::now();
        let fr = match read_frame(&mut self.conns[j].r) {
            Ok(fr) => fr,
            Err(e) => {
                self.links[j] = MemberLinkState::Down;
                return Err(e.context(format!("recv from member {}", j + 1)));
            }
        };
        self.links[j] = if t0.elapsed() >= DEGRADED_AFTER {
            MemberLinkState::Degraded
        } else {
            MemberLinkState::Up
        };
        self.stats.messages += 1;
        self.stats.bytes += fr.wire_bytes() as u64;
        Ok(fr.elems)
    }

    fn round(&mut self) {
        self.stats.rounds += 1;
    }

    fn broadcast(&mut self, elems: &[u128]) -> Result<()> {
        // A staged-but-unlaunched flight interleaved with other exercises
        // would desync the members' run order from the manager's relays.
        // (`flight_complete` takes the flight out before broadcasting, so
        // the launch itself passes this guard.)
        assert!(
            self.flight.is_none(),
            "staged flight never launched: call complete() before other exercises"
        );
        self.next_ex += 1;
        self.stats.exercises += 1;
        for j in 0..self.cfg.n {
            self.tx(j, elems)?;
        }
        self.round();
        Ok(())
    }

    /// Collect one frame from every member, in member order.
    fn gather(&mut self) -> Result<Vec<Vec<u128>>> {
        let mut out = Vec::with_capacity(self.cfg.n);
        for j in 0..self.cfg.n {
            out.push(self.rx(j)?);
        }
        self.round();
        Ok(out)
    }

    /// Redistribute dealt sub-shares: member j receives, per element, the
    /// sub-shares from every dealer i. Dealer frames are party-major
    /// (`dealt[i][j·k + e]`, the flat batch-deal layout); the outgoing
    /// frames keep the seed protocol's element-major, dealer-inner order
    /// (`out[e·n + i]`).
    fn scatter_transposed(&mut self, dealt: &[Vec<u128>], k: usize) -> Result<()> {
        let n = self.cfg.n;
        let mut mine = Vec::with_capacity(k * n);
        for j in 0..n {
            mine.clear();
            for e in 0..k {
                for di in dealt.iter() {
                    mine.push(di[party_major(j, k, e)]);
                }
            }
            self.tx(j, &mine)?;
        }
        self.round();
        Ok(())
    }

    // --- op drivers (fallible core; the trait impl panics on Err) ---------

    fn op_input(&mut self, owner: usize, values: &[u128]) -> Result<Vec<DataId>> {
        let t0 = Instant::now();
        let n = self.cfg.n;
        let k = values.len();
        let ids = self.alloc_vec(k);
        let mut msg = vec![OP_INPUT, owner as u128, k as u128];
        msg.extend(ids.iter().map(|id| id.0 as u128));
        self.broadcast(&msg)?;
        // provisioning: the owner's values travel only on its own link
        self.tx(owner - 1, values)?;
        self.round();
        let dealt = self.rx(owner - 1)?; // k·n, party-major
        self.round();
        for j in 0..n {
            self.tx(j, &dealt[j * k..(j + 1) * k])?;
        }
        self.round();
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    fn op_constant(&mut self, c: u128) -> Result<DataId> {
        let t0 = Instant::now();
        let id = self.alloc_vec(1)[0];
        self.broadcast(&[OP_CONST, id.0 as u128, self.field.reduce(c)])?;
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(id)
    }

    fn op_lin(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Result<Vec<DataId>> {
        let t0 = Instant::now();
        let f = self.field;
        let ids = self.alloc_vec(ops.len());
        let mut msg = vec![OP_LIN, ops.len() as u128];
        for ((c0, terms), id) in ops.iter().zip(&ids) {
            msg.push(id.0 as u128);
            msg.push(f.from_i128(*c0));
            msg.push(terms.len() as u128);
            for &(c, a) in terms {
                msg.push(f.from_i128(c));
                msg.push(a.0 as u128);
            }
        }
        self.broadcast(&msg)?;
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    fn op_mul(&mut self, pairs: &[(DataId, DataId)]) -> Result<Vec<DataId>> {
        let t0 = Instant::now();
        let k = pairs.len();
        let ids = self.alloc_vec(k);
        let mut msg = vec![OP_MUL, k as u128];
        msg.extend(ids.iter().map(|id| id.0 as u128));
        msg.extend(pairs.iter().map(|p| p.0 .0 as u128));
        msg.extend(pairs.iter().map(|p| p.1 .0 as u128));
        self.broadcast(&msg)?;
        self.relay_mul(k)?;
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    /// Relay phases of one width-`k` mul (everything after the schedule
    /// broadcast): gather the flat party-major deals, scatter transposed.
    fn relay_mul(&mut self, k: usize) -> Result<()> {
        let dealt = self.gather()?;
        self.scatter_transposed(&dealt, k)
    }

    fn op_divpub(&mut self, us: &[DataId], d: u128, tags: Option<&[u64]>) -> Result<Vec<DataId>> {
        if d == 0 {
            bail!("divpub by zero");
        }
        let t0 = Instant::now();
        let n = self.cfg.n;
        let k = us.len();
        let ids = self.alloc_vec(k);
        let op = if tags.is_some() { OP_DIVPUB_TAGGED } else { OP_DIVPUB };
        let mut msg = vec![op, k as u128, d];
        msg.extend(ids.iter().map(|id| id.0 as u128));
        msg.extend(us.iter().map(|u| u.0 as u128));
        if let Some(t) = tags {
            msg.extend(t.iter().map(|&x| x as u128));
        }
        self.broadcast(&msg)?;
        self.relay_divpub(k)?;
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    /// Relay phases of one width-`k` §3.4 divpub (everything after the
    /// schedule broadcast): Alice's r‖q deal, the z' opening to Bob, and
    /// Bob's w deal.
    fn relay_divpub(&mut self, k: usize) -> Result<()> {
        let n = self.cfg.n;
        // Phase 1: Alice's dealt [r]‖[q] per element → (rⱼ, qⱼ) per member.
        let alice = self.rx(0)?;
        self.round();
        let mut mine = Vec::with_capacity(2 * k);
        for j in 0..n {
            mine.clear();
            for e in 0..k {
                mine.push(alice[divpub_r_slot(e, n, j)]);
                mine.push(alice[divpub_q_slot(e, n, j)]);
            }
            self.tx(j, &mine)?;
        }
        self.round();
        // Phase 2: everyone's z' shares → Bob (element-major, party-inner).
        let zs = self.gather()?;
        let mut to_bob = Vec::with_capacity(k * n);
        for e in 0..k {
            for zi in zs.iter() {
                to_bob.push(zi[e]);
            }
        }
        self.tx(1, &to_bob)?;
        self.round();
        // Phase 3: Bob's dealt [w] per element → wⱼ per member.
        let bob = self.rx(1)?;
        self.round();
        for j in 0..n {
            mine.clear();
            for e in 0..k {
                mine.push(bob[element_major(e, n, j)]);
            }
            self.tx(j, &mine)?;
        }
        self.round();
        Ok(())
    }

    fn op_reveal(&mut self, ids: &[DataId]) -> Result<Vec<u128>> {
        let t0 = Instant::now();
        let n = self.cfg.n;
        let k = ids.len();
        let mut msg = vec![OP_REVEAL, k as u128];
        msg.extend(ids.iter().map(|id| id.0 as u128));
        self.broadcast(&msg)?;
        let shares = self.gather()?;
        let mut out = Vec::with_capacity(k);
        for e in 0..k {
            let col: Vec<u128> = (0..n).map(|j| shares[j][e]).collect();
            out.push(self.shamir.reconstruct(&col));
        }
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn op_sq2pq(&mut self, local_values: &[Vec<u128>]) -> Result<Vec<DataId>> {
        let t0 = Instant::now();
        let n = self.cfg.n;
        if local_values.len() != n {
            bail!("sq2pq needs one contribution vector per member");
        }
        let k = local_values[0].len();
        // Same guard as the engine: with party-major stride-k dealer
        // frames, a ragged vector would silently address the wrong
        // party's region instead of erroring.
        if local_values.iter().any(|v| v.len() != k) {
            bail!("sq2pq contribution vectors must all have length {k}");
        }
        let ids = self.alloc_vec(k);
        let mut msg = vec![OP_SQ2PQ, k as u128];
        msg.extend(ids.iter().map(|id| id.0 as u128));
        self.broadcast(&msg)?;
        // provisioning: each member's contributions on its own link only
        for (i, vals) in local_values.iter().enumerate() {
            self.tx(i, vals)?;
        }
        self.round();
        let dealt = self.gather()?;
        self.scatter_transposed(&dealt, k)?;
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    // --- flights (DESIGN.md §Round scheduler) -----------------------------

    /// Stage one run into the pending flight, allocating its output ids
    /// immediately so later same-flight runs can reference them. The run
    /// body appended to the flight frame is byte-for-byte the standalone
    /// broadcast body of the op.
    fn flight_submit(&mut self, op: FlightOp) -> Result<Vec<DataId>> {
        assert!(!op.is_empty(), "flights stage only non-empty ops");
        let f = self.field;
        let fl = self
            .flight
            .get_or_insert_with(|| TcpFlight { elems: vec![OP_FLIGHT, 0], relays: Vec::new() });
        // alloc_vec inlined: `fl` already borrows self mutably.
        let mut alloc = |next_id: &mut u64, k: usize| -> Vec<DataId> {
            (0..k)
                .map(|_| {
                    *next_id += 1;
                    DataId(*next_id)
                })
                .collect()
        };
        match op {
            FlightOp::Mul(pairs) => {
                let k = pairs.len();
                let ids = alloc(&mut self.next_id, k);
                fl.elems.push(OP_MUL);
                fl.elems.push(k as u128);
                fl.elems.extend(ids.iter().map(|id| id.0 as u128));
                fl.elems.extend(pairs.iter().map(|p| p.0 .0 as u128));
                fl.elems.extend(pairs.iter().map(|p| p.1 .0 as u128));
                fl.relays.push(FlightRelay::Mul { k });
                Ok(ids)
            }
            FlightOp::Lin(ops) => {
                let ids = alloc(&mut self.next_id, ops.len());
                fl.elems.push(OP_LIN);
                fl.elems.push(ops.len() as u128);
                for ((c0, terms), id) in ops.iter().zip(&ids) {
                    fl.elems.push(id.0 as u128);
                    fl.elems.push(f.from_i128(*c0));
                    fl.elems.push(terms.len() as u128);
                    for &(c, a) in terms {
                        fl.elems.push(f.from_i128(c));
                        fl.elems.push(a.0 as u128);
                    }
                }
                fl.relays.push(FlightRelay::Lin);
                Ok(ids)
            }
            FlightOp::DivpubTagged { us, d, tags } => {
                if d == 0 {
                    bail!("divpub by zero");
                }
                assert_eq!(us.len(), tags.len());
                let k = us.len();
                let ids = alloc(&mut self.next_id, k);
                fl.elems.push(OP_DIVPUB_TAGGED);
                fl.elems.push(k as u128);
                fl.elems.push(d);
                fl.elems.extend(ids.iter().map(|id| id.0 as u128));
                fl.elems.extend(us.iter().map(|u| u.0 as u128));
                fl.elems.extend(tags.iter().map(|&t| t as u128));
                fl.relays.push(FlightRelay::Divpub { k });
                Ok(ids)
            }
        }
    }

    /// Launch the pending flight: one `OP_FLIGHT` broadcast, then each
    /// run's relay phases in submission order (the order members execute
    /// in). No pending flight is a no-op, so `complete()` is always safe
    /// to call. Each run still counts as one exercise — coalescing moves
    /// latency, not the accounting unit.
    fn flight_complete(&mut self) -> Result<()> {
        let Some(mut fl) = self.flight.take() else { return Ok(()) };
        let t0 = Instant::now();
        fl.elems[1] = fl.relays.len() as u128;
        self.broadcast(&fl.elems)?;
        self.stats.exercises += fl.relays.len() as u64 - 1;
        for relay in &fl.relays {
            match *relay {
                FlightRelay::Mul { k } => self.relay_mul(k)?,
                FlightRelay::Lin => {}
                FlightRelay::Divpub { k } => self.relay_divpub(k)?,
            }
        }
        self.stats.virtual_time_s += t0.elapsed().as_secs_f64();
        Ok(())
    }
}

impl MpcSession for TcpSession {
    fn n(&self) -> usize {
        self.cfg.n
    }

    fn field(&self) -> Field {
        self.field
    }

    fn input_vec(&mut self, owner: usize, values: &[u128]) -> Vec<DataId> {
        self.op_input(owner, values).expect("TcpSession input_vec")
    }

    fn constant(&mut self, c: u128) -> DataId {
        self.op_constant(c).expect("TcpSession constant")
    }

    fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId> {
        self.op_lin(ops).expect("TcpSession lin_vec")
    }

    fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId> {
        self.op_mul(pairs).expect("TcpSession mul_vec")
    }

    fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId> {
        self.op_divpub(us, d, None).expect("TcpSession divpub_vec")
    }

    fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId> {
        assert_eq!(us.len(), tags.len());
        self.op_divpub(us, d, Some(tags)).expect("TcpSession divpub_vec_tagged")
    }

    fn reserve_tags(&mut self, count: u64) -> u64 {
        let base = self.next_tag;
        self.next_tag += count;
        base
    }

    fn submit(&mut self, op: FlightOp) -> Vec<DataId> {
        self.flight_submit(op).expect("TcpSession submit")
    }

    fn complete(&mut self) {
        self.flight_complete().expect("TcpSession complete")
    }

    fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128> {
        self.op_reveal(ids).expect("TcpSession reveal_vec")
    }

    fn sq2pq_vec(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId> {
        self.op_sq2pq(local_values).expect("TcpSession sq2pq_vec")
    }

    fn stats(&self) -> NetStats {
        self.stats
    }

    fn link_states(&self) -> Vec<MemberLinkState> {
        self.links.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocols::engine::{Engine, EngineConfig};

    /// The generic division pipeline, written once against the trait.
    fn pipeline<S: MpcSession>(sess: &mut S) -> Vec<u128> {
        let a = sess.input_vec(1, &[123, 7])[0];
        let b = sess.input_vec(2, &[45])[0];
        let ab = sess.mul(a, b);
        let q = sess.divpub(ab, 256);
        let lin = sess.lin(5, &[(3, a), (-1, b)]);
        let c = sess.constant(1000);
        let s = sess.add(lin, c);
        let locals: Vec<Vec<u128>> = (0..sess.n()).map(|i| vec![(i + 1) as u128]).collect();
        let sq = sess.sq2pq_vec(&locals)[0];
        let base = sess.reserve_tags(2);
        let qt = sess.divpub_vec_tagged(&[ab, s], 100, &[base, base + 1]);
        sess.reveal_vec(&[ab, q, s, sq, qt[0], qt[1]])
    }

    #[test]
    fn tcp_session_matches_sim_session_byte_for_byte() {
        for n in [2usize, 3, 5] {
            let field = Field::paper();
            let mut sim = Engine::new(field, EngineConfig::new(n));
            let want = pipeline(&mut sim);

            let mut tcp = TcpSession::spawn_local(field, TcpSessionConfig::new(n)).unwrap();
            let got = pipeline(&mut tcp);
            tcp.shutdown().unwrap();

            assert_eq!(got, want, "n={n}: TCP and Sim must agree byte-for-byte");
            assert_eq!(want[0], 123 * 45);
            let q = field.to_i128(want[1]);
            assert!((q - 21).abs() <= 1, "⌊123·45/256⌋ = 21 ± 1, got {q}");
            assert_eq!(want[2], 5 + 3 * 123 - 45 + 1000);
            assert_eq!(want[3], (n * (n + 1) / 2) as u128);
        }
    }

    #[test]
    fn rejects_single_member_session() {
        assert!(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(1)).is_err());
    }

    #[test]
    fn tcp_session_counts_traffic() {
        let mut tcp = TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(3)).unwrap();
        let before = tcp.stats();
        let a = tcp.input_vec(1, &[9])[0];
        let _ = tcp.mul(a, a);
        let after = tcp.stats().delta_since(&before);
        tcp.shutdown().unwrap();
        assert!(after.messages > 0 && after.bytes > 0 && after.rounds > 0);
        assert_eq!(after.exercises, 2);
    }

    #[test]
    fn one_tcp_flight_matches_sequential_sim_ops() {
        let field = Field::paper();
        // Sequential reference on the simulated engine: mul, lin, then a
        // tagged divpub over the mul outputs.
        let mut sim = Engine::new(field, EngineConfig::new(3));
        let want = {
            let s = &mut sim;
            let a = s.input_vec(1, &[123, 456]);
            let b = s.input_vec(2, &[789, 12]);
            let prods = s.mul_vec(&[(a[0], b[0]), (a[1], b[1])]);
            let lins = s.lin_vec(&[(7, vec![(2, a[0]), (1, b[1])])]);
            let base = s.reserve_tags(2);
            let qs = s.divpub_vec_tagged(&prods, 256, &[base, base + 1]);
            s.reveal_vec(&[prods[0], prods[1], lins[0], qs[0], qs[1]])
        };

        // The same three ops as ONE coalesced flight over TCP — the divpub
        // run reads the mul run's outputs within the same flight.
        let mut tcp = TcpSession::spawn_local(field, TcpSessionConfig::new(3)).unwrap();
        let a = tcp.input_vec(1, &[123, 456]);
        let b = tcp.input_vec(2, &[789, 12]);
        let before = tcp.stats();
        let prods = tcp.submit(FlightOp::Mul(vec![(a[0], b[0]), (a[1], b[1])]));
        let lins = tcp.submit(FlightOp::Lin(vec![(7, vec![(2, a[0]), (1, b[1])])]));
        let base = tcp.reserve_tags(2);
        let qs = tcp.submit(FlightOp::DivpubTagged {
            us: prods.clone(),
            d: 256,
            tags: vec![base, base + 1],
        });
        tcp.complete();
        let mid = tcp.stats().delta_since(&before);
        let got = tcp.reveal_vec(&[prods[0], prods[1], lins[0], qs[0], qs[1]]);
        tcp.shutdown().unwrap();

        assert_eq!(got, want, "a TCP flight must match sequential sim ops byte-for-byte");
        assert_eq!(want[0], 123 * 789);
        // Per-op accounting survives coalescing: 3 exercises. Latency does
        // not: 1 broadcast round + mul's 2 relay rounds + divpub's 6.
        assert_eq!(mid.exercises, 3);
        assert_eq!(mid.rounds, 1 + 2 + 6);
    }

    #[test]
    fn wide_vector_ops_over_tcp_match_sim() {
        // A k ≫ 1 exercise stresses the flat party-major dealer frames and
        // the buffered framing path end to end, on both backends.
        let k = 257usize; // non-power-of-two, larger than any internal chunk
        let avals: Vec<u128> = (0..k as u128).map(|i| i * 3 + 1).collect();
        let bvals: Vec<u128> = (0..k as u128).map(|i| i * 5 + 2).collect();

        fn wide<S: MpcSession>(sess: &mut S, avals: &[u128], bvals: &[u128]) -> Vec<u128> {
            let a = sess.input_vec(1, avals);
            let b = sess.input_vec(2, bvals);
            let pairs: Vec<_> = a.iter().copied().zip(b.iter().copied()).collect();
            let prods = sess.mul_vec(&pairs);
            let qs = sess.divpub_vec(&prods, 256);
            let mut ids = prods;
            ids.extend(qs);
            sess.reveal_vec(&ids)
        }

        let field = Field::paper();
        let mut sim = Engine::new(field, EngineConfig::new(3));
        let want = wide(&mut sim, &avals, &bvals);
        let mut tcp = TcpSession::spawn_local(field, TcpSessionConfig::new(3)).unwrap();
        let got = wide(&mut tcp, &avals, &bvals);
        tcp.shutdown().unwrap();
        assert_eq!(got, want, "wide mul/divpub must be byte-identical across backends");
        for i in 0..k {
            assert_eq!(want[i], avals[i] * bvals[i]);
        }
    }

    #[test]
    fn threaded_tcp_members_match_serial_sim_byte_for_byte() {
        // k large enough to clear the pool's MIN_CHUNK work floor, so the
        // member-side fan-outs (products, dealing, λ-recombination)
        // actually run parallel — and must still produce the exact bytes
        // of the serial single-threaded sim engine.
        let k = 1500usize;
        let avals: Vec<u128> = (0..k as u128).map(|i| i * 3 + 1).collect();
        let bvals: Vec<u128> = (0..k as u128).map(|i| i * 5 + 2).collect();

        fn wide<S: MpcSession>(sess: &mut S, avals: &[u128], bvals: &[u128]) -> Vec<u128> {
            let a = sess.input_vec(1, avals);
            let b = sess.input_vec(2, bvals);
            let pairs: Vec<_> = a.iter().copied().zip(b.iter().copied()).collect();
            let prods = sess.mul_vec(&pairs);
            let qs = sess.divpub_vec(&prods[..8], 256);
            let locals: Vec<Vec<u128>> =
                (0..sess.n()).map(|i| vec![(i + 1) as u128; 4]).collect();
            let sq = sess.sq2pq_vec(&locals);
            let mut ids = prods;
            ids.extend(qs);
            ids.extend(sq);
            sess.reveal_vec(&ids)
        }

        let field = Field::paper();
        let mut sim = Engine::new(field, EngineConfig::new(3));
        let want = wide(&mut sim, &avals, &bvals);
        let mut tcp =
            TcpSession::spawn_local(field, TcpSessionConfig::new(3).with_threads(4)).unwrap();
        let got = wide(&mut tcp, &avals, &bvals);
        tcp.shutdown().unwrap();
        assert_eq!(got, want, "threads=4 TCP members must match the serial sim bytes");
        for i in 0..k {
            assert_eq!(want[i], avals[i] * bvals[i]);
        }
    }

    #[test]
    fn slow_member_grades_its_link_degraded_then_recovers() {
        let mut cfg = TcpSessionConfig::new(3);
        // Member 3 stalls 1.5 s (≫ DEGRADED_AFTER, < the deadline) before
        // its first exercise frame.
        cfg.fault = Some(MemberFault {
            member: 3,
            after_frames: 0,
            kind: MemberFaultKind::DelayMs(1500),
        });
        let mut tcp = TcpSession::spawn_local(Field::paper(), cfg).unwrap();
        assert_eq!(tcp.link_states(), vec![MemberLinkState::Up; 3]);
        let a = tcp.input_vec(1, &[5])[0]; // member 3 sleeping: no rx from it here
        let vals = tcp.reveal_vec(&[a]); // gather waits ~1.5 s on member 3
        assert_eq!(vals[0], 5);
        assert_eq!(tcp.link_states()[2], MemberLinkState::Degraded, "slow reply noticed");
        let vals = tcp.reveal_vec(&[a]); // prompt now: the link recovers
        assert_eq!(vals[0], 5);
        assert_eq!(tcp.link_states(), vec![MemberLinkState::Up; 3]);
        tcp.shutdown().unwrap();
    }

    #[test]
    fn dead_member_downs_its_link_and_errors_the_op() {
        let mut cfg = TcpSessionConfig::new(3);
        // Fire after the input frame, on the reveal frame: the input's
        // provisioning writes all land before the member dies, so only
        // the reveal's gather observes the closed socket.
        cfg.fault =
            Some(MemberFault { member: 3, after_frames: 1, kind: MemberFaultKind::Panic });
        let mut tcp = TcpSession::spawn_local(Field::paper(), cfg).unwrap();
        let a = tcp.input_vec(1, &[7])[0];
        // Member 3 panics on the reveal exercise frame; the gather hits a
        // closed socket and the infallible trait surface aborts via panic
        // — which a fleet catches as shard death.
        let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = tcp.reveal_vec(&[a]);
        }));
        assert!(died.is_err(), "an op over a dead member must abort");
        assert_eq!(tcp.link_states()[2], MemberLinkState::Down, "dead link graded Down");
        tcp.shutdown_lossy();
    }
}
