//! Private marginal inference (§4): servers hold shares of the learned
//! weights; a client shares its query; the network is evaluated bottom-up
//! with secure sums and products; only the root value is revealed (to the
//! client).
//!
//! Fixed-point convention: every node value is an integer ≈ d·(true value)
//! with d = 256 (§5.3); each secure multiplication of two d-scaled values
//! is followed by a truncation by d (divpub).  Like the paper's setting,
//! deep conjunctive queries underflow at this precision — marginal queries
//! over a handful of evidence variables (CryptoSPN's use case) are the
//! intended workload; the `infer` tests quantify accuracy against the
//! float oracle.

use crate::protocols::engine::DataId;
use crate::protocols::session::MpcSession;
use crate::coordinator::train::SharedModel;
use crate::net::NetStats;
use crate::spn::structure::{LayerKind, Structure};

/// A client query: assignment + which variables are marginalized.
#[derive(Clone, Debug)]
pub struct Query {
    pub x: Vec<u8>,
    pub marg: Vec<bool>,
}

/// Evaluate S(query) over shares on any [`MpcSession`] backend; returns
/// the revealed d-scaled root value and the traffic spent.
pub fn private_eval<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    q: &Query,
    default_leaf_theta: &[f64],
) -> (i128, NetStats) {
    let before = sess.stats();
    let d = model.d;
    let w0 = st.num_leaves();

    // --- client shares its input: one bit per variable --------------------
    let xvals: Vec<u128> = q.x.iter().map(|&b| b as u128).collect();
    let x_ids = sess.input_vec(1, &xvals);

    // --- leaf values -------------------------------------------------------
    // marginalized leaf → public d; else Bernoulli: x·θ + (1-x)·(d-θ)
    //   = [x]·(2θ - d) + (d - θ), one secure mul per live leaf.
    let mut leaf_vals: Vec<DataId> = Vec::with_capacity(w0);
    let const_d = sess.constant(d);
    for leaf in 0..w0 {
        let v = st.leaf_var[leaf];
        if q.marg[v] {
            leaf_vals.push(const_d);
            continue;
        }
        let theta: DataId = match &model.leaf_theta {
            Some(t) => t[leaf],
            None => {
                // public default θ (paper mode): d-scaled constant
                let th = (default_leaf_theta[leaf] * d as f64).round() as u128;
                sess.constant(th.min(d))
            }
        };
        let slope = sess.lin(-(d as i128), &[(2, theta)]); // 2θ - d
        let prod = sess.mul(x_ids[v], slope);
        let val = sess.lin(d as i128, &[(1, prod), (-1, theta)]); // d - θ + x(2θ-d)
        leaf_vals.push(val);
    }

    // --- layered evaluation -------------------------------------------------
    let mut prev: Vec<DataId> = Vec::new();
    for (li, l) in st.layers.iter().enumerate() {
        let prev_w = if li > 0 { st.layer_widths[li] } else { 0 };
        let mut children: Vec<Vec<(usize, i64)>> = vec![Vec::new(); l.width];
        for ((&r, &c), &p) in l.rows.iter().zip(&l.cols).zip(&l.param) {
            children[r].push((c, p));
        }
        let mut out: Vec<DataId> = Vec::with_capacity(l.width);
        for ch in &children {
            let get = |c: usize| -> DataId {
                if c < prev_w {
                    prev[c]
                } else {
                    leaf_vals[c - prev_w]
                }
            };
            match l.kind {
                LayerKind::Product => {
                    // sequential secure mult + truncate to stay d-scaled
                    let mut acc = get(ch[0].0);
                    for &(c, _) in &ch[1..] {
                        let m = sess.mul(acc, get(c));
                        acc = sess.divpub(m, d);
                    }
                    out.push(acc);
                }
                LayerKind::Sum => {
                    // Σ_j w_j · v_j / d — pairwise muls then one truncate
                    let pairs: Vec<(DataId, DataId)> =
                        ch.iter().map(|&(c, p)| (model.sum_w[p as usize], get(c))).collect();
                    let prods = sess.mul_vec(&pairs);
                    let terms: Vec<(i128, DataId)> = prods.iter().map(|&p| (1, p)).collect();
                    let sum = sess.lin(0, &terms);
                    out.push(sess.divpub(sum, d));
                }
            }
        }
        prev = out;
    }

    // --- reveal root to the client ------------------------------------------
    let val = sess.reveal_int(prev[0]);
    let stats = sess.stats().delta_since(&before);
    (val, stats)
}

/// Conditional Pr(x | e) = S(x∧e)/S(e) — two private evaluations, client
/// divides the revealed d-scaled values (§4).
pub fn private_conditional<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    x_assign: &[(usize, u8)],
    e_assign: &[(usize, u8)],
    default_leaf_theta: &[f64],
) -> (f64, NetStats) {
    let nv = st.num_vars;
    let mut x = vec![0u8; nv];
    let mut marg_xe = vec![true; nv];
    for &(v, b) in x_assign.iter().chain(e_assign) {
        x[v] = b;
        marg_xe[v] = false;
    }
    let mut marg_e = vec![true; nv];
    for &(v, b) in e_assign {
        x[v] = b;
        marg_e[v] = false;
    }
    let (sxe, st1) = private_eval(
        sess,
        st,
        model,
        &Query { x: x.clone(), marg: marg_xe },
        default_leaf_theta,
    );
    let (se, st2) = private_eval(sess, st, model, &Query { x, marg: marg_e }, default_leaf_theta);
    let p = if se <= 0 { 0.0 } else { (sxe.max(0) as f64) / (se as f64) };
    let stats = NetStats {
        messages: st1.messages + st2.messages,
        bytes: st1.bytes + st2.bytes,
        rounds: st1.rounds + st2.rounds,
        exercises: st1.exercises + st2.exercises,
        virtual_time_s: st1.virtual_time_s + st2.virtual_time_s,
    };
    (p.min(1.0), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::train::{train, TrainConfig};
    use crate::datasets;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig};
    use crate::spn::{eval, learn};
    use crate::spn::structure::Structure;

    fn toy() -> Option<Structure> {
        let p = format!("{}/artifacts/toy.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    fn trained(n: usize) -> Option<(Structure, Engine, SharedModel, Vec<f64>)> {
        let st = toy()?;
        let gt = datasets::ground_truth_params(&st, 5);
        let data = datasets::sample(&st, &gt, 3000, 11);
        let shards = datasets::partition(&data, n);
        let shard_counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
        let (model, _) = train(&mut eng, &st, &shard_counts, 3000, &TrainConfig::default());
        // float oracle params from the revealed weights (same quantization)
        let fixed = super::super::train::peek_weights(&eng, &model);
        let theta = learn::default_leaf_theta(&st);
        let params = learn::params_from_fixed(&st, &fixed, &theta, 256);
        Some((st, eng, model, params))
    }

    #[test]
    fn private_eval_matches_float_oracle_marginal() {
        let Some((st, mut eng, model, params)) = trained(5) else { return };
        let theta = learn::default_leaf_theta(&st);
        // evidence on one variable, rest marginalized: shallow, no underflow
        for v in 0..st.num_vars {
            for b in [0u8, 1] {
                let mut q =
                    Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
                q.x[v] = b;
                q.marg[v] = false;
                let (got, _) = private_eval(&mut eng, &st, &model, &q, &theta);
                let marg: Vec<bool> = q.marg.clone();
                let want = eval::logeval(&st, &q.x, &marg, &params).exp();
                let got_f = got.max(0) as f64 / 256.0;
                assert!(
                    (got_f - want).abs() < 0.08,
                    "v={v} b={b}: private {got_f} vs oracle {want}"
                );
            }
        }
    }

    #[test]
    fn private_conditional_close_to_oracle() {
        let Some((st, mut eng, model, params)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let (p, stats) =
            private_conditional(&mut eng, &st, &model, &[(0, 1)], &[(1, 1)], &theta);
        // oracle
        let mut x = vec![0u8; st.num_vars];
        x[0] = 1;
        x[1] = 1;
        let mut m_xe = vec![true; st.num_vars];
        m_xe[0] = false;
        m_xe[1] = false;
        let mut m_e = vec![true; st.num_vars];
        m_e[1] = false;
        let want = eval::logeval(&st, &x, &m_xe, &params).exp()
            / eval::logeval(&st, &x, &m_e, &params).exp();
        assert!((p - want).abs() < 0.25, "private {p} vs oracle {want}");
        assert!(stats.messages > 0);
    }

    #[test]
    fn all_marginal_query_gives_d() {
        // S(∅) = 1 → d-scaled root ≈ d.
        let Some((st, mut eng, model, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        let (got, _) = private_eval(&mut eng, &st, &model, &q, &theta);
        assert!((got - 256).abs() <= 26, "S(∅)·d = {got}");
    }

    #[test]
    fn inference_cost_scales_with_edges() {
        let Some((st, mut eng, model, _)) = trained(3) else { return };
        let theta = learn::default_leaf_theta(&st);
        let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        let (_, stats) = private_eval(&mut eng, &st, &model, &q, &theta);
        // at least one secure op per edge
        assert!(stats.exercises as usize >= st.stats.edges / 2);
    }
}
