//! End-to-end driver (the EXPERIMENTS.md run): full-system private training
//! on the synthetic-nltcs workload with all three layers composing:
//!
//!   Pallas layer kernels → JAX counts graph → HLO artifact → rust PJRT
//!   runtime (per-party local counts) → SQ2PQ → Newton division protocol
//!   over the simulated 10 ms Manager/Member network → shared weights →
//!   verification against the centralized ML oracle + held-out
//!   log-likelihood.
//!
//! Run: `cargo run --release --example private_training [-- dataset members rows]`

use spn_mpc::coordinator::train::{peek_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::metrics::group_thousands;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::runtime;
use spn_mpc::spn::{eval, learn};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(|s| s.as_str()).unwrap_or("nltcs");
    let members: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(5);

    // ---- load structure + artifacts ----------------------------------------
    let rt = runtime::Runtime::cpu()?;
    let ds = runtime::load_dataset(&rt, runtime::default_artifacts_dir(), dataset)?;
    let st = &ds.structure;
    let rows: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(st.rows);
    println!("[1/5] dataset {dataset}: {:?}, rows {rows}, members {members}", st.stats);
    println!("      runtime platform: {}", rt.platform());

    // ---- synthetic data from a ground-truth SPN ----------------------------
    let gt = datasets::ground_truth_params(st, 7);
    let train_data = datasets::sample(st, &gt, rows, 42);
    let heldout = datasets::sample(st, &gt, 2048, 4242);
    let shards = datasets::partition(&train_data, members);

    // ---- Layer 1+2: per-party local counts through the AOT artifact --------
    let t0 = std::time::Instant::now();
    let counts: anyhow::Result<Vec<Vec<u64>>> =
        shards.iter().map(|s| ds.counts.counts(s)).collect();
    let counts = counts?;
    let counts_wall = t0.elapsed().as_secs_f64();
    println!(
        "[2/5] local counts via PJRT artifact: {} rows in {:.2}s ({:.0} rows/s/party avg)",
        rows,
        counts_wall,
        rows as f64 / counts_wall
    );
    // cross-check against the native mirror
    let native: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(st, s)).collect();
    assert_eq!(counts, native, "PJRT artifact and native mirror disagree");
    println!("      artifact counts == native rust mirror ✓");

    // ---- Layer 3: the private protocol --------------------------------------
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(members));
    let cfg = TrainConfig::default();
    let t0 = std::time::Instant::now();
    let (model, report) = train(&mut eng, st, &counts, rows as u64, &cfg);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "[3/5] private training: {} Newton divisions ({} sum edges)",
        report.divisions, report.sum_edges
    );
    println!(
        "      {} messages, {:.1} MB, {} rounds, {:.0} s virtual (10 ms links), {:.2} s wall",
        group_thousands(report.stats.messages),
        report.stats.megabytes(),
        report.stats.rounds,
        report.stats.virtual_time_s,
        wall
    );

    // ---- verification vs centralized oracle ---------------------------------
    let global = eval::counts(st, &train_data);
    let oracle = learn::ml_weights_fixed(st, &global, model.d);
    let got = peek_weights(&eng, &model);
    let mut max_err = 0i128;
    let mut sum_err = 0i128;
    for (&g, &o) in got.iter().zip(&oracle) {
        let e = (g - o as i128).abs();
        max_err = max_err.max(e);
        sum_err += e;
    }
    println!(
        "[4/5] vs centralized Eq.(2) oracle (d = {}): max |err| = {max_err}, mean |err| = {:.3}",
        model.d,
        sum_err as f64 / got.len() as f64
    );
    assert!(max_err <= 4, "private weights must match the oracle within rounding");

    // ---- model quality on held-out data -------------------------------------
    let theta = learn::default_leaf_theta(st);
    let private_params = learn::params_from_fixed(st, &got, &theta, model.d);
    let ml = learn::ml_params(st, &global);
    let ll_priv = ds.eval.mean_loglik(&heldout, &private_params)?;
    let ll_ml = ds.eval.mean_loglik(&heldout, &ml)?;
    let ll_gt = ds.eval.mean_loglik(&heldout, &gt)?;
    println!("[5/5] held-out mean log-likelihood (PJRT eval artifact):");
    println!("      private (sum weights @ d=256, default leaves): {ll_priv:.4}");
    println!("      centralized ML (float, incl. ML leaves):       {ll_ml:.4}");
    println!("      ground truth:                                  {ll_gt:.4}");
    println!("\nprivate_training OK");
    Ok(())
}
