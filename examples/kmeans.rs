//! Private k-means (§6 / Eq. (7)): the paper's division primitive applied to
//! the Jha–Kruger–McDaniel clustering functionality.
//!
//! Three parties hold disjoint point sets; each Lloyd iteration assigns
//! points locally and updates every centroid coordinate with one private
//! division ((Σ sums)/(Σ counts)) over the exercise engine.  The result is
//! checked against plaintext Lloyd's.
//!
//! Run: `cargo run --release --example kmeans`

use spn_mpc::field::Field;
use spn_mpc::kmeans::{plain_kmeans, private_kmeans, KmeansConfig, PartyData};
use spn_mpc::metrics::group_thousands;
use spn_mpc::protocols::division::DivisionConfig;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::rng::{Prng, Rng};

fn main() -> anyhow::Result<()> {
    let mut rng = Prng::seed_from_u64(2024);
    let centers = [(150i64, 250i64), (850, 300), (450, 900)];
    let n_points = 360;
    let all: Vec<Vec<i64>> = (0..n_points)
        .map(|i| {
            let (cx, cy) = centers[i % 3];
            vec![
                cx + rng.gen_range_u64(140) as i64 - 70,
                cy + rng.gen_range_u64(140) as i64 - 70,
            ]
        })
        .collect();

    let members = 3;
    let mut parties = vec![PartyData { points: vec![] }; members];
    for (i, p) in all.iter().enumerate() {
        parties[i % members].points.push(p.clone());
    }
    let init = vec![vec![500, 500], vec![520, 480], vec![480, 520]];

    println!("{n_points} points, {members} parties, k = 3, 10 ms links");
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(members));
    let cfg = KmeansConfig { k: 3, iters: 12, division: DivisionConfig::default() };
    let t0 = std::time::Instant::now();
    let out = private_kmeans(&mut eng, &parties, &init, &cfg);
    let plain = plain_kmeans(&all, &init, 12);

    println!("converged after {} iterations ({:.2} s wall)", out.iterations_run, t0.elapsed().as_secs_f64());
    println!("cluster sizes: {:?}", out.assignments_counts);
    for (c, (priv_c, plain_c)) in out.centroids.iter().zip(&plain).enumerate() {
        println!("  centroid {c}: private {priv_c:?} | plaintext {plain_c:?}");
        for (a, b) in priv_c.iter().zip(plain_c) {
            assert!((a - b).abs() <= 8, "private centroid deviates");
        }
    }
    println!(
        "traffic: {} messages, {:.2} MB, {:.1} s virtual",
        group_thousands(out.stats.messages),
        out.stats.megabytes(),
        out.stats.virtual_time_s
    );
    println!("\nkmeans OK");
    Ok(())
}
