//! Cross-layer integration tests: PJRT runtime ⇄ native mirror ⇄ MPC
//! protocols ⇄ coordinators, plus the cross-backend session tests.
//!
//! The artifact-driven tests need `make artifacts` to have run; each skips
//! gracefully if the artifacts directory is absent so `cargo test` stays
//! green on a fresh checkout (CI runs `make test` which builds artifacts
//! first). The `cross_backend_*` tests build a miniature in-code structure
//! instead, so they run everywhere — including artifact-less CI — and pin
//! the session redesign's core contract: the same coordinator code over
//! `SimSession` (PerOp and Batched) and `TcpSession` produces
//! byte-identical weights, posteriors and centroids under the same seed.

use spn_mpc::coordinator::infer::{private_conditional, private_eval, private_eval_batch, Query};
use spn_mpc::coordinator::train::{peek_weights, reveal_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::protocols::newton::{newton_inverse, NewtonConfig};
use spn_mpc::runtime;
use spn_mpc::spn::structure::Structure;
use spn_mpc::spn::{eval, learn};

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn runtime_counts_match_native_mirror_all_datasets() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    for name in ["toy", "nltcs", "jester", "baudio", "bnetflix"] {
        let ds = runtime::load_dataset(&rt, &dir, name).unwrap();
        let st = &ds.structure;
        let gt = datasets::ground_truth_params(st, 3);
        let data = datasets::sample(st, &gt, 700, 99); // non-multiple of 512: tail masking
        let native = eval::counts(st, &data);
        let pjrt = ds.counts.counts(&data).unwrap();
        assert_eq!(native, pjrt, "{name}: artifact and native counts diverge");
    }
}

#[test]
fn runtime_eval_matches_native_logeval() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    let ds = runtime::load_dataset(&rt, &dir, "nltcs").unwrap();
    let st = &ds.structure;
    let gt = datasets::ground_truth_params(st, 4);
    let data = datasets::sample(st, &gt, 64, 5);
    let marg = vec![false; st.num_vars];
    let got = ds.eval.logeval(&data, &marg, &gt).unwrap();
    for (i, row) in data.iter().enumerate() {
        let want = eval::logeval(st, row, &marg, &gt);
        assert!(
            (got[i] - want).abs() < 1e-3,
            "row {i}: pjrt {} vs native {want}",
            got[i]
        );
    }
}

#[test]
fn full_pipeline_pjrt_counts_into_private_training() {
    let Some(dir) = artifacts() else { return };
    let rt = runtime::Runtime::cpu().unwrap();
    let ds = runtime::load_dataset(&rt, &dir, "toy").unwrap();
    let st = &ds.structure;
    let gt = datasets::ground_truth_params(st, 7);
    let data = datasets::sample(st, &gt, 1500, 42);
    let shards = datasets::partition(&data, 4);
    let counts: Vec<Vec<u64>> =
        shards.iter().map(|s| ds.counts.counts(s).unwrap()).collect();

    let mut eng = Engine::new(Field::paper(), EngineConfig::new(4));
    let (model, report) = train(&mut eng, st, &counts, 1500, &TrainConfig::default());
    assert_eq!(report.divisions, st.sum_groups.len());

    let oracle = learn::ml_weights_fixed(st, &eval::counts(st, &data), model.d);
    for (k, (&g, &o)) in peek_weights(&eng, &model).iter().zip(&oracle).enumerate() {
        assert!((g - o as i128).abs() <= 3, "param {k}");
    }
}

#[test]
fn training_then_inference_shares_flow() {
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, 2000, 11);
    let shards = datasets::partition(&data, 5);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
    let mut eng = Engine::new(Field::paper(), EngineConfig::new(5).batched());
    let (model, _) = train(&mut eng, &st, &counts, 2000, &TrainConfig::default());
    let theta = learn::default_leaf_theta(&st);
    let q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
    let (root, _) = private_eval(&mut eng, &st, &model, &q, &theta);
    assert!((root - model.d as i128).abs() <= model.d as i128 / 8, "S(∅) ≈ 1");
}

#[test]
fn skewed_partition_still_exact() {
    // Eq. (3) holds for ANY horizontal partition — exactness is the paper's
    // core claim vs the §3.2 approximation.
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 8);
    let data = datasets::sample(&st, &gt, 3000, 12);
    let oracle = learn::ml_weights_fixed(&st, &eval::counts(&st, &data), 256);
    for skew in [0.5, 0.9] {
        let shards = datasets::partition_skewed(&data, 4, skew);
        let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(4).batched());
        let (model, _) = train(&mut eng, &st, &counts, 3000, &TrainConfig::default());
        for (k, (&g, &o)) in peek_weights(&eng, &model).iter().zip(&oracle).enumerate() {
            assert!((g - o as i128).abs() <= 3, "skew {skew} param {k}");
        }
    }
}

#[test]
fn member_count_does_not_change_result() {
    let Some(dir) = artifacts() else { return };
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 9);
    let data = datasets::sample(&st, &gt, 1200, 13);
    let mut results = Vec::new();
    for n in [2usize, 3, 7, 13] {
        let shards = datasets::partition(&data, n);
        let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(n).batched());
        let (model, _) = train(&mut eng, &st, &counts, 1200, &TrainConfig::default());
        results.push(peek_weights(&eng, &model));
    }
    for w in &results[1..] {
        for (k, (&a, &b)) in results[0].iter().zip(w).enumerate() {
            assert!((a - b).abs() <= 3, "param {k} differs across member counts");
        }
    }
}

/// The miniature selective SPN now lives in the library
/// ([`Structure::mini_demo`]) so the `infer_batch` bench and these tests
/// share one definition.
fn mini_structure() -> Structure {
    Structure::mini_demo()
}

fn mini_shard_counts(st: &Structure, n: usize) -> (Vec<Vec<u64>>, u64) {
    // seeds 5/21, shared with tests/serve.rs via the single library helper
    (datasets::synth_shard_counts(st, n, st.rows, 5, 21), st.rows as u64)
}

// Under `--features checked-session` every session below runs wrapped in
// the CheckedSession sanitizer (tag freshness, reveal discipline, phase
// rules — and, for engines, Tables 2–3 conservation); by default wrap()
// is the identity. The assertions are the same either way: the suite must
// pass bit-identically under full checking.
#[cfg(feature = "checked-session")]
use spn_mpc::protocols::checked::CheckedSession;
#[cfg(feature = "checked-session")]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> CheckedSession<S> {
    CheckedSession::new(s)
}
#[cfg(not(feature = "checked-session"))]
fn wrap<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}
#[cfg(feature = "checked-session")]
fn wrap_engine(e: Engine) -> CheckedSession<Engine> {
    let schedule = e.cfg.schedule;
    CheckedSession::with_sim_accounting(e, schedule)
}
#[cfg(not(feature = "checked-session"))]
fn wrap_engine(e: Engine) -> Engine {
    e
}
#[cfg(feature = "checked-session")]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: CheckedSession<S>) -> S {
    s.into_inner()
}
#[cfg(not(feature = "checked-session"))]
fn unwrap_session<S: spn_mpc::protocols::MpcSession>(s: S) -> S {
    s
}

#[test]
fn cross_backend_training_byte_identical() {
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let cfg = TrainConfig::default();

    let mut weights = Vec::new();
    for schedule in [Schedule::PerOp, Schedule::Batched] {
        let mut ec = EngineConfig::new(n);
        ec.schedule = schedule;
        let mut eng = wrap_engine(Engine::new(Field::paper(), ec));
        let (model, report) = train(&mut eng, &st, &counts, rows, &cfg);
        assert_eq!(report.divisions, 1);
        weights.push(reveal_weights(&mut eng, &model));
    }
    let mut sess =
        wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
    let (model, report) = train(&mut sess, &st, &counts, rows, &cfg);
    assert_eq!(report.divisions, 1);
    weights.push(reveal_weights(&mut sess, &model));
    unwrap_session(sess).shutdown().unwrap();

    assert_eq!(weights[0], weights[1], "PerOp vs Batched weights must be byte-identical");
    assert_eq!(weights[0], weights[2], "Sim vs TCP weights must be byte-identical");
    // and sane: d-scaled weights of one sum group sum to ≈ d
    let tot: i128 = weights[0].iter().sum();
    assert!((tot - 256).abs() <= 8, "group sums to {tot}");
}

#[test]
fn cross_backend_inference_byte_identical() {
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let theta = learn::default_leaf_theta(&st);
    let queries: Vec<Query> = vec![
        Query { x: vec![0, 0], marg: vec![true, true] },
        Query { x: vec![1, 0], marg: vec![false, true] },
        Query { x: vec![1, 1], marg: vec![false, false] },
    ];

    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
    let sim_roots: Vec<i128> =
        queries.iter().map(|q| private_eval(&mut eng, &st, &model, q, &theta).0).collect();

    let mut sess =
        wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
    let (model, _) = train(&mut sess, &st, &counts, rows, &TrainConfig::default());
    let tcp_roots: Vec<i128> =
        queries.iter().map(|q| private_eval(&mut sess, &st, &model, q, &theta).0).collect();
    unwrap_session(sess).shutdown().unwrap();

    assert_eq!(sim_roots, tcp_roots, "posteriors must be byte-identical across backends");
    // S(∅)·d ≈ d on both
    assert!((sim_roots[0] - 256).abs() <= 32, "S(∅)·d = {}", sim_roots[0]);
}

#[test]
fn cross_backend_batched_inference_byte_identical() {
    // The compiled-plan batch path over real TCP must reveal exactly what
    // the simulation reveals — and both must equal sequential evaluation
    // (the tagged-divpub invariant), pinning the refactor's two contracts
    // at once.
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let theta = learn::default_leaf_theta(&st);
    let queries: Vec<Query> = vec![
        Query { x: vec![0, 0], marg: vec![true, true] },
        Query { x: vec![1, 0], marg: vec![false, true] },
        Query { x: vec![0, 1], marg: vec![true, false] },
        Query { x: vec![1, 1], marg: vec![false, false] },
        Query { x: vec![0, 0], marg: vec![false, false] },
    ];

    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
    let (sim_roots, _) = private_eval_batch(&mut eng, &st, &model, &queries, &theta);

    // sequential on a fresh identically-seeded engine: bit-identical
    let mut eng2 = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let (model2, _) = train(&mut eng2, &st, &counts, rows, &TrainConfig::default());
    let seq_roots: Vec<i128> =
        queries.iter().map(|q| private_eval(&mut eng2, &st, &model2, q, &theta).0).collect();
    assert_eq!(sim_roots, seq_roots, "batch must equal sequential bit-for-bit");

    // and over real TCP members: byte-identical to the simulation
    let mut sess =
        wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
    let (model_tcp, _) = train(&mut sess, &st, &counts, rows, &TrainConfig::default());
    let (tcp_roots, _) = private_eval_batch(&mut sess, &st, &model_tcp, &queries, &theta);
    unwrap_session(sess).shutdown().unwrap();
    assert_eq!(sim_roots, tcp_roots, "batched posteriors must match across backends");

    // sanity: S(∅)·d ≈ d
    assert!((sim_roots[0] - 256).abs() <= 32, "S(∅)·d = {}", sim_roots[0]);
}

#[test]
fn cross_backend_threads4_byte_identical() {
    // The threads dimension (DESIGN.md §Field kernel): the same full
    // train-then-infer pipeline at worker-pool width 4 — on the sim
    // engine AND over TCP members — must reveal the exact bytes of the
    // serial width-1 sim run. Wide inputs first so the pooled fan-outs
    // actually clear their work floor at least once.
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let theta = learn::default_leaf_theta(&st);
    let queries: Vec<Query> = vec![
        Query { x: vec![0, 0], marg: vec![true, true] },
        Query { x: vec![1, 1], marg: vec![false, false] },
    ];
    let wide: Vec<u128> = (0..3000u128).map(|i| i * 7 + 3).collect();

    let mut all = Vec::new();
    let mut run_sim = |threads: usize| {
        let mut eng = wrap_engine(Engine::new(
            Field::paper(),
            EngineConfig::new(n).batched().with_threads(threads),
        ));
        let wides = eng.input_vec(1, &wide);
        let pairs: Vec<_> = wides.iter().copied().zip(wides.iter().copied()).collect();
        let sq = eng.mul_vec(&pairs);
        eng.mark_outputs(&sq[..16]);
        let mut revealed = eng.reveal_vec(&sq[..16]);
        let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
        let (roots, _) = private_eval_batch(&mut eng, &st, &model, &queries, &theta);
        revealed.extend(roots.iter().map(|&r| r as u128));
        revealed
    };
    all.push(run_sim(1));
    all.push(run_sim(4));

    let mut sess = wrap(
        TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n).with_threads(4))
            .unwrap(),
    );
    let wides = sess.input_vec(1, &wide);
    let pairs: Vec<_> = wides.iter().copied().zip(wides.iter().copied()).collect();
    let sq = sess.mul_vec(&pairs);
    sess.mark_outputs(&sq[..16]);
    let mut revealed = sess.reveal_vec(&sq[..16]);
    let (model, _) = train(&mut sess, &st, &counts, rows, &TrainConfig::default());
    let (roots, _) = private_eval_batch(&mut sess, &st, &model, &queries, &theta);
    revealed.extend(roots.iter().map(|&r| r as u128));
    unwrap_session(sess).shutdown().unwrap();
    all.push(revealed);

    assert_eq!(all[0], all[1], "threads=4 sim must match serial sim byte-for-byte");
    assert_eq!(all[0], all[2], "threads=4 TCP must match serial sim byte-for-byte");
}

#[test]
fn cross_backend_conditional_byte_identical() {
    // Only batched marginals were cross-backend pinned until now; the
    // conditional Pr(x | e) — two evaluations coalesced into one batch
    // plus the client-side division — must also be byte-identical
    // Sim ≡ TCP under the same seed, down to the f64 bit pattern.
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let theta = learn::default_leaf_theta(&st);
    let cases: [(&[(usize, u8)], &[(usize, u8)]); 3] = [
        (&[(0, 1)], &[(1, 1)]),
        (&[(1, 0)], &[(0, 0)]),
        (&[(0, 1)], &[]),
    ];

    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());
    let sim: Vec<(f64, u64)> = cases
        .iter()
        .map(|(x, e)| {
            let (p, s) = private_conditional(&mut eng, &st, &model, x, e, &theta);
            (p, s.messages)
        })
        .collect();

    let mut sess =
        wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
    let (model_tcp, _) = train(&mut sess, &st, &counts, rows, &TrainConfig::default());
    let tcp: Vec<f64> = cases
        .iter()
        .map(|(x, e)| private_conditional(&mut sess, &st, &model_tcp, x, e, &theta).0)
        .collect();
    unwrap_session(sess).shutdown().unwrap();

    for (i, ((ps, msgs), pt)) in sim.iter().zip(&tcp).enumerate() {
        assert_eq!(
            ps.to_bits(),
            pt.to_bits(),
            "case {i}: conditional must be byte-identical across backends ({ps} vs {pt})"
        );
        assert!(*msgs > 0);
        assert!((0.0..=1.0).contains(ps), "case {i}: Pr = {ps} out of range");
    }
}

#[test]
fn batched_inference_rounds_strictly_sublinear() {
    // NetStats::delta_since over one eval vs a B-eval batch: total rounds
    // for B = 32 must be far below 32× a single evaluation (the acceptance
    // bound is ≤ 1/4; the plan actually delivers ~1/B).
    let st = mini_structure();
    let n = 3;
    let (counts, rows) = mini_shard_counts(&st, n);
    let theta = learn::default_leaf_theta(&st);
    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let (model, _) = train(&mut eng, &st, &counts, rows, &TrainConfig::default());

    let q = Query { x: vec![1, 0], marg: vec![false, true] };
    let (_, one) = private_eval(&mut eng, &st, &model, &q, &theta);

    for bsz in [8usize, 32] {
        let batch: Vec<Query> = (0..bsz)
            .map(|i| Query { x: vec![(i % 2) as u8, 0], marg: vec![false, i % 3 == 0] })
            .collect();
        let (_, stats) = private_eval_batch(&mut eng, &st, &model, &batch, &theta);
        assert!(
            stats.rounds * 4 <= one.rounds * bsz as u64,
            "B={bsz}: {} rounds vs {}×{} sequential — not sublinear",
            stats.rounds,
            bsz,
            one.rounds
        );
    }
}

#[test]
fn cross_backend_kmeans_byte_identical() {
    use spn_mpc::kmeans::{private_kmeans, KmeansConfig, PartyData};
    use spn_mpc::protocols::division::DivisionConfig;
    use spn_mpc::rng::{Prng, Rng};

    let n = 3;
    let mut rng = Prng::seed_from_u64(4);
    let mut parties = vec![PartyData { points: vec![] }; n];
    for i in 0..90 {
        let (cx, cy) = if i % 2 == 0 { (100i64, 120i64) } else { (700, 650) };
        parties[i % n].points.push(vec![
            cx + rng.gen_range_u64(40) as i64 - 20,
            cy + rng.gen_range_u64(40) as i64 - 20,
        ]);
    }
    let init = vec![vec![0, 0], vec![800, 800]];
    let cfg = KmeansConfig { k: 2, iters: 4, division: DivisionConfig::default() };

    let mut eng = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
    let sim = private_kmeans(&mut eng, &parties, &init, &cfg);

    let mut sess =
        wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
    let tcp = private_kmeans(&mut sess, &parties, &init, &cfg);
    unwrap_session(sess).shutdown().unwrap();

    assert_eq!(sim.centroids, tcp.centroids, "centroids must be byte-identical");
    assert_eq!(sim.iterations_run, tcp.iterations_run);
    assert_eq!(sim.assignments_counts, tcp.assignments_counts);
}

#[test]
fn perop_and_batched_agree_on_every_primitive() {
    // mul, divpub and the Newton inverse must produce the same field
    // elements under both schedules — the schedules change accounting only.
    fn primitives(eng: &mut Engine) -> Vec<u128> {
        let xs = eng.input(1, &[4321, 77, 1000]);
        let ys = eng.input(2, &[789, 3, 12]);
        let pairs: Vec<_> = xs.iter().copied().zip(ys.iter().copied()).collect();
        let prods = eng.mul_vec(&pairs);
        let qs = eng.divpub_vec(&prods, 256);
        let (inv, _) = newton_inverse(eng, ys[0], 1000, &NewtonConfig::default());
        let mut ids = prods.clone();
        ids.extend(qs);
        ids.push(inv);
        eng.reveal_vec(&ids)
    }
    let mut per_op = Engine::new(Field::paper(), EngineConfig::new(5));
    let mut batched = Engine::new(Field::paper(), EngineConfig::new(5).batched());
    let a = primitives(&mut per_op);
    let b = primitives(&mut batched);
    assert_eq!(a, b, "PerOp and Batched must agree on mul, divpub and Newton");
    assert!(
        batched.net.stats.messages < per_op.net.stats.messages,
        "Batched must also be cheaper on vector ops"
    );
}

#[test]
fn tcp_transport_reveals_across_threads() {
    use spn_mpc::net::tcp;
    use spn_mpc::rng::Prng;
    use spn_mpc::sharing::additive::additive_share;
    use std::net::TcpListener;
    use std::thread;

    let f = Field::paper();
    let mut rng = Prng::seed_from_u64(77);
    let secret = 424_242u128;
    let shares = additive_share(&f, secret, 5, &mut rng);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let srv = thread::spawn(move || tcp::reveal_server_on(listener, 5, f.p).unwrap());
    let handles: Vec<_> = shares
        .into_iter()
        .enumerate()
        .map(|(i, sh)| {
            let a = addr.clone();
            thread::spawn(move || tcp::reveal_client(&a, i as u32, sh).unwrap())
        })
        .collect();
    for h in handles {
        assert_eq!(h.join().unwrap(), secret);
    }
    assert_eq!(srv.join().unwrap(), secret);
}

#[test]
fn approx_and_exact_agree_on_iid_shards() {
    let Some(dir) = artifacts() else { return };
    use spn_mpc::coordinator::approx::{approx_divide, LocalFraction};
    use spn_mpc::net::NetConfig;
    let st = Structure::load(dir.join("toy.structure.json")).unwrap();
    let gt = datasets::ground_truth_params(&st, 10);
    let data = datasets::sample(&st, &gt, 6000, 14);
    let shards = datasets::partition(&data, 3);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();

    let mut params_in = Vec::new();
    for k in 0..st.num_sum_edges {
        params_in.push(
            (0..3)
                .map(|i| LocalFraction {
                    num: counts[i][st.param_num[k]],
                    den: counts[i][st.param_den[k]],
                })
                .collect::<Vec<_>>(),
        );
    }
    let approx = approx_divide(&Field::paper(), &params_in, 256, NetConfig::default(), 5);

    let mut eng = Engine::new(Field::paper(), EngineConfig::new(3).batched());
    let (model, _) = train(&mut eng, &st, &counts, 6000, &TrainConfig::default());
    let exact = peek_weights(&eng, &model);
    for k in 0..st.num_sum_edges {
        let a = approx.revealed[k] as i128;
        let e = exact[k];
        assert!((a - e).abs() <= 12, "param {k}: approx {a} exact {e}");
    }
}
