//! The persistent private-inference server (DESIGN.md §Serving layer):
//! a multi-client TCP front-end plus a **micro-batching scheduler** over
//! one long-lived MPC session.
//!
//! CryptoSPN frames privacy-preserving SPN inference as a client/server
//! service; this module is that service for the secret-sharing protocol.
//! The Manager holds one standing session (Sim or TCP backend) with a
//! trained model's weight shares and a persistent compiled-plan
//! [`Evaluator`]; any number of clients connect over TCP and speak a small
//! length-prefixed JSON protocol. Queued queries from *all* clients
//! coalesce into one [`Evaluator::eval_batch`] call per scheduler tick —
//! the cross-query amortization of the compiled-plan refactor applied to
//! live traffic: secure rounds per query shrink ~(tick width)×.
//!
//! ## Wire protocol
//!
//! Every message is one frame: `len: u32 LE | body: len bytes of UTF-8
//! JSON` (one object per frame; [`MAX_JSON_MSG`] caps the length so a
//! desynced stream fails as a frame error, mirroring `net::tcp`).
//!
//! * server → client on connect: `{"proto":1,"name":..,"num_vars":..,
//!   "d":..,"max_batch":..}` — the client needs `num_vars` to build
//!   queries and `d` to interpret roots.
//! * client → server: `{"x":[0,1,..],"marg":[true,false,..]}` — exactly
//!   the JSONL object schema of `infer --batch` ([`query_from_json`]);
//!   or the control message `{"cmd":"shutdown"}`.
//! * server → client per query: `{"seq":..,"root":..,"p":..,"d":..,
//!   "batch":..,"stats":{..},"total":{..}}` where `seq` is the
//!   per-connection request number, `root` the revealed d-scaled root
//!   (byte-identical to a direct `private_eval_batch` at the same arrival
//!   position), `batch` the width of the tick that served it, `stats` the
//!   tick's [`NetStats`] delta and `total` this client's accumulated
//!   stats ([`NetStats::delta_since`] per tick, summed with `Add`).
//!   Malformed queries get `{"error":"..","seq":..}` and the connection
//!   stays up; error replies are written by the reader immediately, so
//!   on a pipelined connection they can overtake earlier queries'
//!   responses — attribute replies by `seq`, not position, when
//!   pipelining frames that might be rejected. A client that stops
//!   *reading* is killed after a bounded write stall
//!   ([`WRITE_STALL_TIMEOUT`]) instead of freezing the scheduler, and
//!   disconnected clients are pruned from the registry as their readers
//!   exit.
//!
//! ## Scheduler flush rules
//!
//! The scheduler owns the session on the calling thread (sessions are not
//! shared across threads — readers only enqueue). A tick flushes when the
//! queue reaches [`ServeConfig::max_batch`] **or** the oldest queued query
//! has waited [`ServeConfig::max_wait`], whichever comes first; queries
//! are drained strictly in arrival order (FIFO across all clients).
//! Because the evaluator reserves a fresh tag block per tick and tags are
//! striped per query (`spn::plan`), the revealed answers are invariant to
//! how arrivals are sliced into ticks: overall query j always lands on
//! tag block j·m. The serve integration tests pin both properties.
//!
//! ## Shutdown
//!
//! `{"cmd":"shutdown"}` (or [`ServeConfig::max_queries`]) marks the
//! session draining: queued queries are still answered, then the accept
//! loop is woken, every live connection is closed and every serve thread
//! joined — [`serve`] returns only when nothing it spawned is left
//! running. The MPC session itself outlives [`serve`]: the caller decides
//! whether to reuse it or `TcpSession::shutdown` it.
//!
//! ## Scaling out
//!
//! [`serve`] owns exactly one session; [`crate::net::fleet`] puts the
//! same wire protocol in front of S independent sessions for one model
//! (per-shard FIFO queues, least-loaded dispatch, work stealing, shard
//! death tolerance, respawn). Fleet responses additionally carry
//! `"shard"`, `"gen"` (the serving incarnation's generation — see
//! `TagStripe::generation`) and `"snum"` (the query's generation-local
//! serve index, which pins its tag block for oracle replay); the fleet
//! hello reports `"shards"`.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use super::NetStats;
use crate::json::Json;
use crate::protocols::engine::DataId;
use crate::protocols::session::MpcSession;
use crate::spn::plan::{Evaluator, Query};

/// Upper bound on one JSON message body (1 MiB — far above any real
/// query). A corrupt length prefix then fails as a diagnosable frame
/// error instead of a huge allocation.
pub const MAX_JSON_MSG: usize = 1 << 20;

/// Scheduler parameters of a serving session.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Flush a tick as soon as this many queries are queued (B).
    pub max_batch: usize,
    /// Flush a tick once the oldest queued query has waited this long (T).
    pub max_wait: Duration,
    /// Stop serving (graceful drain) after this many queries — `None`
    /// serves until a client sends `{"cmd":"shutdown"}`.
    pub max_queries: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 16, max_wait: Duration::from_millis(5), max_queries: None }
    }
}

/// What a serving session did, returned by [`serve`] after the drain.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeReport {
    /// Queries answered.
    pub queries: u64,
    /// Scheduler ticks (= [`Evaluator::eval_batch`] calls).
    pub batches: u64,
    /// Client connections accepted over the session's lifetime.
    pub clients: u64,
    /// Σ of the per-tick [`NetStats`] deltas.
    pub stats: NetStats,
    /// Widest tick served (the realized micro-batch size).
    pub max_tick: usize,
}

// --- wire helpers ---------------------------------------------------------

/// Write one `len | body` frame and flush it.
pub fn write_json_msg<W: Write>(w: &mut W, s: &str) -> Result<()> {
    let b = s.as_bytes();
    if b.len() > MAX_JSON_MSG {
        bail!("refusing to write a {}-byte message (max {MAX_JSON_MSG})", b.len());
    }
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    w.flush()?;
    Ok(())
}

/// Read one `len | body` frame into a string.
pub fn read_json_msg<R: Read>(r: &mut R) -> Result<String> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let n = u32::from_le_bytes(hdr) as usize;
    if n > MAX_JSON_MSG {
        bail!("message header claims {n} bytes (max {MAX_JSON_MSG}): corrupt or desynced stream");
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

pub(crate) fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Build a [`Query`] from one `{"x":[...],"marg":[...]}` object — the
/// shared semantics of the `infer --batch` JSONL lines and the serve wire
/// protocol: `x` entries must be 0/1 numbers, `marg` entries booleans,
/// both exactly `num_vars` long.
pub fn query_from_json(j: &Json, num_vars: usize) -> Result<Query> {
    let (Some(xj), Some(mj)) = (j.opt("x"), j.opt("marg")) else {
        bail!("each query needs \"x\" and \"marg\" arrays");
    };
    let (Json::Arr(xs), Json::Arr(ms)) = (xj, mj) else {
        bail!("\"x\" and \"marg\" must be arrays");
    };
    let mut x = Vec::with_capacity(xs.len());
    for v in xs {
        match v {
            Json::Num(n) if *n == 0.0 || *n == 1.0 => x.push(*n as u8),
            _ => bail!("\"x\" entries must be 0 or 1"),
        }
    }
    let mut marg = Vec::with_capacity(ms.len());
    for v in ms {
        match v {
            Json::Bool(b) => marg.push(*b),
            _ => bail!("\"marg\" entries must be booleans"),
        }
    }
    if x.len() != num_vars || marg.len() != num_vars {
        bail!("x/marg must each have {num_vars} entries");
    }
    Ok(Query { x, marg })
}

/// Serialize a [`Query`] as the wire's `{"x":[...],"marg":[...]}` object.
pub fn render_query_json(q: &Query) -> String {
    let xs: Vec<String> = q.x.iter().map(|b| b.to_string()).collect();
    let ms: Vec<String> = q.marg.iter().map(|b| b.to_string()).collect();
    format!("{{\"x\":[{}],\"marg\":[{}]}}", xs.join(","), ms.join(","))
}

/// Serialize a [`NetStats`] as a JSON object (rust's `Display` for finite
/// `f64` never emits exponent notation, so the value is valid JSON).
pub fn stats_json(s: &NetStats) -> String {
    format!(
        "{{\"messages\":{},\"bytes\":{},\"rounds\":{},\"exercises\":{},\"virtual_time_s\":{}}}",
        s.messages, s.bytes, s.rounds, s.exercises, s.virtual_time_s
    )
}

/// Fallible numeric field access — unlike [`Json::as_f64`], a wrong type
/// from an untrusted peer becomes an `Err`, not a panic.
pub(crate) fn num_field(j: &Json, k: &str) -> Result<f64> {
    match j.opt(k) {
        Some(Json::Num(n)) => Ok(*n),
        Some(other) => bail!("field \"{k}\" is not a number (got {other:?})"),
        None => bail!("message lacks \"{k}\""),
    }
}

/// Parse a [`stats_json`] object back into a [`NetStats`].
pub fn stats_from_json(j: &Json) -> Result<NetStats> {
    Ok(NetStats {
        messages: num_field(j, "messages")? as u64,
        bytes: num_field(j, "bytes")? as u64,
        rounds: num_field(j, "rounds")? as u64,
        exercises: num_field(j, "exercises")? as u64,
        virtual_time_s: num_field(j, "virtual_time_s")?,
    })
}

/// Render one query response. `shard` is `Some((shard, gen, snum))` only
/// on fleet servers ([`crate::net::fleet`]) — the serving shard, its
/// generation (respawn incarnation) and the query's generation-local
/// serve index; clients of a single-session [`serve`] see the exact PR-5
/// wire format.
pub(crate) fn render_response(
    seq: u64,
    root: i128,
    d: u128,
    batch: usize,
    stats: &NetStats,
    total: &NetStats,
    shard: Option<(usize, u64, u64)>,
) -> String {
    let p = root.max(0) as f64 / d as f64;
    let shard_field = match shard {
        Some((s, g, k)) => format!("\"shard\":{s},\"gen\":{g},\"snum\":{k},"),
        None => String::new(),
    };
    format!(
        "{{\"seq\":{seq},\"root\":{root},\"p\":{p},\"d\":{d},\"batch\":{batch},{shard_field}\"stats\":{},\"total\":{}}}",
        stats_json(stats),
        stats_json(total)
    )
}

// --- server side ----------------------------------------------------------

/// Writes to a client that has stopped reading fail after this long
/// (`SO_SNDTIMEO`); the connection is then marked dead and closed, so one
/// stalled client can delay the scheduler at most once — never freeze it.
pub const WRITE_STALL_TIMEOUT: Duration = Duration::from_secs(10);

/// One live client connection, shared between its reader thread (hello,
/// error replies) and the scheduler (query responses, stats totals).
/// Shared with [`crate::net::fleet`], whose readers and per-shard
/// schedulers use the same registration/reply/teardown discipline.
pub(crate) struct ConnShared {
    pub(crate) id: u64,
    /// The accepted stream itself — kept for the forced close at shutdown.
    pub(crate) stream: TcpStream,
    pub(crate) w: Mutex<BufWriter<TcpStream>>,
    /// This client's accumulated cost: the delta of every tick one of its
    /// queries rode in, summed with `NetStats::Add`.
    pub(crate) total: Mutex<NetStats>,
    pub(crate) next_seq: AtomicU64,
    /// Set on the first failed write (client gone, or stalled past
    /// [`WRITE_STALL_TIMEOUT`]): all further writes are skipped and the
    /// socket is closed.
    pub(crate) dead: std::sync::atomic::AtomicBool,
}

impl ConnShared {
    /// Register a freshly accepted client stream: nodelay + bounded write
    /// stall, with a buffered writer on a cloned handle.
    pub(crate) fn register(id: u64, stream: TcpStream) -> Option<Arc<ConnShared>> {
        let _ = stream.set_nodelay(true);
        let _ = stream.set_write_timeout(Some(WRITE_STALL_TIMEOUT));
        let wstream = stream.try_clone().ok()?;
        Some(Arc::new(ConnShared {
            id,
            stream,
            w: Mutex::new(BufWriter::with_capacity(8192, wstream)),
            total: Mutex::new(NetStats::default()),
            next_seq: AtomicU64::new(0),
            dead: std::sync::atomic::AtomicBool::new(false),
        }))
    }
}

struct Pending {
    conn: Arc<ConnShared>,
    seq: u64,
    query: Query,
    enqueued: Instant,
}

#[derive(Default)]
struct QueueState {
    queue: VecDeque<Pending>,
    shutdown: bool,
    conns: Vec<Arc<ConnShared>>,
    reader_handles: Vec<JoinHandle<()>>,
    clients_seen: u64,
}

struct Shared {
    state: Mutex<QueueState>,
    cvar: Condvar,
}

// --- poisoning policy -----------------------------------------------------
// A panicked serve/fleet thread must not cascade into every other thread
// that touches the shared queue: the guarded state is plain data, valid at
// every release point, so lock poisoning is recovered rather than
// propagated (spn-lint L004 bans bare `.unwrap()` in this layer).

/// Lock a mutex, recovering the data from a poisoned lock.
pub(crate) fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison recovery as [`lock`].
pub(crate) fn cv_wait<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`] with the same poison recovery as [`lock`].
pub(crate) fn cv_wait_timeout<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
    d: Duration,
) -> (std::sync::MutexGuard<'a, T>, std::sync::WaitTimeoutResult) {
    cv.wait_timeout(g, d).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Write one frame to a client. On failure — client gone, or stalled past
/// [`WRITE_STALL_TIMEOUT`] — the connection is marked dead and closed so
/// it can never delay the scheduler again. Returns false when dead.
pub(crate) fn reply(conn: &ConnShared, msg: &str) -> bool {
    use std::sync::atomic::Ordering::Relaxed;
    if conn.dead.load(Relaxed) {
        return false;
    }
    let ok = {
        let mut w = lock(&conn.w);
        write_json_msg(&mut *w, msg).is_ok()
    };
    if !ok {
        conn.dead.store(true, Relaxed);
        let _ = conn.stream.shutdown(Shutdown::Both);
    }
    ok
}

/// `{"error":..}` reply; carries the request's `seq` when one was
/// assigned, so pipelining clients can attribute it (error replies are
/// written immediately by the reader and may overtake in-flight query
/// responses on the wire).
pub(crate) fn reply_error(conn: &ConnShared, seq: Option<u64>, msg: &str) -> bool {
    let m = match seq {
        Some(s) => format!("{{\"error\":\"{}\",\"seq\":{s}}}", json_escape(msg)),
        None => format!("{{\"error\":\"{}\"}}", json_escape(msg)),
    };
    reply(conn, &m)
}

/// Per-connection reader: send the hello, then parse frames into queue
/// entries until disconnect or shutdown. Never touches the MPC session.
/// Every non-`cmd` frame consumes one `seq`, valid or not, so replies are
/// attributable even when interleaved.
fn reader_session(conn: &Arc<ConnShared>, shared: &Shared, hello: &str, num_vars: usize) {
    if !reply(conn, hello) {
        return;
    }
    let Ok(rstream) = conn.stream.try_clone() else { return };
    let mut r = BufReader::with_capacity(8192, rstream);
    loop {
        let Ok(txt) = read_json_msg(&mut r) else { return }; // disconnect
        let j = match Json::parse(&txt) {
            Ok(j) => j,
            Err(e) => {
                let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
                if !reply_error(conn, Some(seq), &format!("request is not JSON: {e}")) {
                    return;
                }
                continue;
            }
        };
        if let Some(cmd) = j.opt("cmd") {
            if matches!(cmd, Json::Str(c) if c.as_str() == "shutdown") {
                reply(conn, "{\"ok\":true}");
                let mut st = lock(&shared.state);
                st.shutdown = true;
                shared.cvar.notify_all();
                return;
            }
            if !reply_error(conn, None, &format!("unknown cmd {cmd:?}")) {
                return;
            }
            continue;
        }
        let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
        match query_from_json(&j, num_vars) {
            Ok(query) => {
                let mut st = lock(&shared.state);
                if st.shutdown {
                    drop(st);
                    if !reply_error(conn, Some(seq), "server is shutting down") {
                        return;
                    }
                    continue;
                }
                st.queue.push_back(Pending {
                    conn: conn.clone(),
                    seq,
                    query,
                    enqueued: Instant::now(),
                });
                shared.cvar.notify_all();
            }
            Err(e) => {
                if !reply_error(conn, Some(seq), &e.to_string()) {
                    return;
                }
            }
        }
    }
}

fn reader_loop(conn: Arc<ConnShared>, shared: Arc<Shared>, hello: Arc<String>, num_vars: usize) {
    reader_session(&conn, &shared, &hello, num_vars);
    // Prune this connection from the registry so a long-lived server does
    // not accumulate dead sockets across connection churn. Any Pending
    // still queued holds its own Arc, so the scheduler can finish (or
    // skip, if dead) its responses; the sockets close with the last Arc.
    let mut st = lock(&shared.state);
    st.conns.retain(|c| c.id != conn.id);
    // Reap join handles of readers that already exited (dropping a
    // finished handle detaches a thread that is already gone). This
    // thread's own handle stays until a later exit or teardown joins it,
    // so the vec stays O(live connections), not O(clients ever seen).
    st.reader_handles.retain(|h| !h.is_finished());
}

/// Accept loop: register each connection and spawn its reader. Exits when
/// the shutdown flag is up (a dummy self-connection wakes the `accept`).
fn listener_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    hello: Arc<String>,
    num_vars: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if lock(&shared.state).shutdown {
                    return;
                }
                // transient accept failure (e.g. fd exhaustion): back off
                // instead of spinning a core on the hot Err path
                super::backoff::pause(Duration::from_millis(50));
                continue;
            }
        };
        let mut st = lock(&shared.state);
        if st.shutdown {
            return; // the wake-up dummy connection (or a too-late client)
        }
        st.clients_seen += 1;
        let Some(conn) = ConnShared::register(st.clients_seen, stream) else { continue };
        st.conns.push(conn.clone());
        let rs = shared.clone();
        let h = hello.clone();
        st.reader_handles.push(std::thread::spawn(move || reader_loop(conn, rs, h, num_vars)));
    }
}

/// Collect the next tick: block until at least one query is queued, then
/// coalesce arrivals until the queue reaches `max_batch` or the oldest
/// entry has waited `max_wait`. Returns `None` once the queue is empty
/// *and* the session is shutting down.
fn next_tick(shared: &Shared, cfg: &ServeConfig) -> Option<Vec<Pending>> {
    let mut st = lock(&shared.state);
    loop {
        if !st.queue.is_empty() {
            break;
        }
        if st.shutdown {
            return None;
        }
        st = cv_wait(&shared.cvar, st);
    }
    // lint:allow(L004) — the loop above guarantees the queue is non-empty
    let deadline = st.queue.front().unwrap().enqueued + cfg.max_wait;
    while st.queue.len() < cfg.max_batch && !st.shutdown {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (g, to) = cv_wait_timeout(&shared.cvar, st, deadline - now);
        st = g;
        if to.timed_out() {
            break;
        }
    }
    let take = st.queue.len().min(cfg.max_batch);
    Some(st.queue.drain(..take).collect())
}

/// Run a serving session: accept clients on `listener`, micro-batch their
/// queries through `ev` over `sess`, answer each with its revealed root
/// and cost accounting, and tear everything down cleanly on shutdown.
///
/// The scheduler runs on the calling thread (it owns the session); the
/// accept loop and one reader per client run on spawned threads that are
/// all joined before this returns. Answers are byte-identical to a direct
/// `private_eval_batch` over the same queries in arrival order — the
/// tag-stripe invariant of `spn::plan`, pinned by `rust/tests/serve.rs`.
pub fn serve<S: MpcSession>(
    sess: &mut S,
    ev: &mut Evaluator,
    sum_w: &[DataId],
    learned_theta: Option<&[DataId]>,
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    if cfg.max_batch == 0 {
        bail!("serve needs max_batch ≥ 1");
    }
    let addr = listener.local_addr()?;
    let (hello, num_vars, d) = {
        let p = ev.plan();
        (
            Arc::new(format!(
                "{{\"proto\":1,\"name\":\"{}\",\"num_vars\":{},\"d\":{},\"max_batch\":{}}}",
                json_escape(&p.name),
                p.num_vars,
                p.d,
                cfg.max_batch
            )),
            p.num_vars,
            p.d,
        )
    };
    let shared = Arc::new(Shared { state: Mutex::new(QueueState::default()), cvar: Condvar::new() });
    let ls = shared.clone();
    let lh = std::thread::spawn(move || listener_loop(listener, ls, hello, num_vars));

    let mut report = ServeReport::default();
    while let Some(tick) = next_tick(&shared, cfg) {
        let queries: Vec<Query> = tick.iter().map(|p| p.query.clone()).collect();
        let (roots, delta) = ev.eval_batch(sess, &queries, sum_w, learned_theta);
        report.batches += 1;
        report.queries += tick.len() as u64;
        report.stats = report.stats + delta;
        report.max_tick = report.max_tick.max(tick.len());
        // bill the tick delta once per distinct client that rode in it
        let mut seen: Vec<u64> = Vec::new();
        for p in &tick {
            if !seen.contains(&p.conn.id) {
                seen.push(p.conn.id);
                let mut t = lock(&p.conn.total);
                *t = *t + delta;
            }
        }
        for (p, &root) in tick.iter().zip(&roots) {
            let total = *lock(&p.conn.total);
            let msg = render_response(p.seq, root, d, tick.len(), &delta, &total, None);
            reply(&p.conn, &msg); // gone/stalled clients are skipped/killed
        }
        if let Some(maxq) = cfg.max_queries {
            if report.queries >= maxq {
                let mut st = lock(&shared.state);
                st.shutdown = true;
                shared.cvar.notify_all();
            }
        }
    }
    // Graceful teardown: wake the accept loop, close every connection,
    // join every thread this session spawned — no leaks.
    let _ = TcpStream::connect(addr);
    lh.join().map_err(|_| anyhow!("serve listener thread panicked"))?;
    let (conns, readers) = {
        let mut st = lock(&shared.state);
        report.clients = st.clients_seen;
        (std::mem::take(&mut st.conns), std::mem::take(&mut st.reader_handles))
    };
    for c in &conns {
        let _ = c.stream.shutdown(Shutdown::Both);
    }
    for h in readers {
        h.join().map_err(|_| anyhow!("serve reader thread panicked"))?;
    }
    Ok(report)
}

// --- client side ----------------------------------------------------------

/// The server's hello: everything a client needs to build queries.
#[derive(Clone, Debug)]
pub struct Hello {
    pub proto: u64,
    pub name: String,
    pub num_vars: usize,
    pub d: u128,
    pub max_batch: usize,
    /// Sessions behind the front-end: 1 for a [`serve`] server, S for a
    /// [`crate::net::fleet::serve_fleet`] server (absent on old servers →
    /// parsed as 1).
    pub shards: usize,
}

/// One answered query as the client sees it.
#[derive(Clone, Copy, Debug)]
pub struct Response {
    /// Per-connection request number. *Query* responses for one
    /// connection always arrive in request order (the scheduler is FIFO);
    /// `{"error":..}` replies are written immediately by the reader and
    /// may overtake in-flight query responses — when pipelining frames
    /// that might be rejected, attribute replies by `seq` (error replies
    /// carry it too), not by position.
    pub seq: u64,
    /// Revealed d-scaled root — exact, for byte-identity checks.
    pub root: i128,
    /// `max(root, 0) / d`, the probability estimate.
    pub p: f64,
    /// Width of the scheduler tick that served this query.
    pub batch: usize,
    /// The tick's traffic delta.
    pub stats: NetStats,
    /// This connection's accumulated traffic.
    pub total: NetStats,
    /// Which fleet shard served this query (`None` from a single-session
    /// [`serve`] server). Fleet responses can interleave across shards, so
    /// pipelining clients attribute replies by `seq`.
    pub shard: Option<usize>,
    /// The serving shard's generation (respawn incarnation; `None` from a
    /// single-session server, `Some(0)` until a fleet shard respawns).
    pub gen: Option<u64>,
    /// Generation-local serve index: queries a shard incarnation served,
    /// numbered in dispatch order. Together with `gen`, pins the tag
    /// block the query used — the chaos tests sort by `snum` to replay a
    /// shard's served order on an oracle session.
    pub snum: Option<u64>,
}

/// A client connection to a [`serve`] session: blocking, with split
/// [`ServeClient::send`]/[`ServeClient::recv`] so load generators can
/// pipeline many queries on one connection.
pub struct ServeClient {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    pub hello: Hello,
}

impl ServeClient {
    /// Connect and read the server hello.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let s = TcpStream::connect(addr).map_err(|e| anyhow!("connecting to {addr}: {e}"))?;
        s.set_nodelay(true)?;
        let mut r = BufReader::with_capacity(8192, s.try_clone()?);
        let w = BufWriter::with_capacity(8192, s);
        let txt = read_json_msg(&mut r).map_err(|e| e.context("reading server hello"))?;
        let j = Json::parse(&txt).map_err(|e| anyhow!("hello is not JSON: {e}"))?;
        let hello = Hello {
            proto: num_field(&j, "proto").unwrap_or(0.0) as u64,
            name: match j.opt("name") {
                Some(Json::Str(s)) => s.clone(),
                _ => String::new(),
            },
            num_vars: num_field(&j, "num_vars").map_err(|e| e.context("bad hello"))? as usize,
            d: num_field(&j, "d").map_err(|e| e.context("bad hello"))? as u128,
            max_batch: num_field(&j, "max_batch").unwrap_or(1.0) as usize,
            shards: num_field(&j, "shards").unwrap_or(1.0) as usize,
        };
        if hello.proto != 1 {
            bail!("unsupported serve protocol version {}", hello.proto);
        }
        Ok(ServeClient { r, w, hello })
    }

    /// Send one query without waiting for its answer (pipelining).
    pub fn send(&mut self, q: &Query) -> Result<()> {
        write_json_msg(&mut self.w, &render_query_json(q))
    }

    /// Send a raw JSON text frame (protocol tooling / tests).
    pub fn send_raw(&mut self, json_text: &str) -> Result<()> {
        write_json_msg(&mut self.w, json_text)
    }

    /// Receive the next answer; an `{"error":..}` reply becomes an `Err`
    /// (the connection stays usable — the server keeps reading).
    pub fn recv(&mut self) -> Result<Response> {
        let txt = read_json_msg(&mut self.r)?;
        let j = Json::parse(&txt).map_err(|e| anyhow!("response is not JSON: {e}"))?;
        if let Some(e) = j.opt("error") {
            let msg = match e {
                Json::Str(s) => s.as_str(),
                _ => "(non-string error payload)",
            };
            match num_field(&j, "seq") {
                Ok(s) => bail!("server error (seq {}): {msg}", s as u64),
                Err(_) => bail!("server error: {msg}"),
            }
        }
        Ok(Response {
            seq: num_field(&j, "seq")? as u64,
            root: num_field(&j, "root")? as i128,
            p: num_field(&j, "p")?,
            batch: num_field(&j, "batch")? as usize,
            stats: stats_from_json(j.opt("stats").context("response lacks stats")?)?,
            total: stats_from_json(j.opt("total").context("response lacks total")?)?,
            shard: match j.opt("shard") {
                Some(Json::Num(n)) => Some(*n as usize),
                _ => None,
            },
            gen: match j.opt("gen") {
                Some(Json::Num(n)) => Some(*n as u64),
                _ => None,
            },
            snum: match j.opt("snum") {
                Some(Json::Num(n)) => Some(*n as u64),
                _ => None,
            },
        })
    }

    /// One blocking round-trip.
    pub fn query(&mut self, q: &Query) -> Result<Response> {
        self.send(q)?;
        self.recv()
    }

    /// Ask a fleet server to kill shard `shard` (chaos testing / ops
    /// drills): the shard is marked dead, its TCP member sockets (if any)
    /// are severed, and its queued queries move to surviving shards. The
    /// connection stays usable. Single-session [`serve`] servers reject
    /// the command.
    pub fn kill_shard(&mut self, shard: usize) -> Result<()> {
        write_json_msg(&mut self.w, &format!("{{\"cmd\":\"kill-shard\",\"shard\":{shard}}}"))?;
        let txt = read_json_msg(&mut self.r)?;
        let j = Json::parse(&txt).map_err(|e| anyhow!("kill-shard ack is not JSON: {e}"))?;
        if j.opt("ok") == Some(&Json::Bool(true)) {
            Ok(())
        } else {
            bail!("unexpected kill-shard ack: {txt}");
        }
    }

    /// Ask the server to drain and stop; consumes the connection.
    pub fn shutdown_server(mut self) -> Result<()> {
        write_json_msg(&mut self.w, "{\"cmd\":\"shutdown\"}")?;
        let txt = read_json_msg(&mut self.r)?;
        let j = Json::parse(&txt).map_err(|e| anyhow!("shutdown ack is not JSON: {e}"))?;
        if j.opt("ok") == Some(&Json::Bool(true)) {
            Ok(())
        } else {
            bail!("unexpected shutdown ack: {txt}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn json_msg_roundtrip_and_cap() {
        let mut buf = Vec::new();
        write_json_msg(&mut buf, "{\"x\":[1]}").unwrap();
        write_json_msg(&mut buf, "{}").unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_json_msg(&mut cur).unwrap(), "{\"x\":[1]}");
        assert_eq!(read_json_msg(&mut cur).unwrap(), "{}");
        assert!(read_json_msg(&mut cur).is_err(), "EOF must error, not hang");
        // a corrupt length prefix fails as a frame error, not an allocation
        let mut bad = Cursor::new(vec![0xff, 0xff, 0xff, 0xff]);
        assert!(read_json_msg(&mut bad).is_err());
    }

    #[test]
    fn query_json_roundtrip() {
        let q = Query { x: vec![1, 0, 1], marg: vec![false, true, false] };
        let txt = render_query_json(&q);
        let j = Json::parse(&txt).unwrap();
        let back = query_from_json(&j, 3).unwrap();
        assert_eq!(back.x, q.x);
        assert_eq!(back.marg, q.marg);
    }

    #[test]
    fn query_from_json_rejects_bad_shapes() {
        let nv = 2;
        for bad in [
            "{\"x\":[0,1]}",                          // no marg
            "{\"x\":[0,1],\"marg\":[true]}",          // wrong width
            "{\"x\":[0,2],\"marg\":[true,true]}",     // non-binary x
            "{\"x\":[0,1],\"marg\":[1,0]}",           // non-bool marg
            "{\"x\":\"01\",\"marg\":[true,true]}",    // non-array x
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(query_from_json(&j, nv).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn stats_json_roundtrip() {
        let s = NetStats {
            messages: 123,
            bytes: 45_678,
            rounds: 9,
            exercises: 4,
            virtual_time_s: 0.0375,
        };
        let j = Json::parse(&stats_json(&s)).unwrap();
        let back = stats_from_json(&j).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn response_render_parses_back() {
        let stats = NetStats { messages: 7, bytes: 700, rounds: 3, exercises: 2, virtual_time_s: 0.01 };
        let total = stats + stats;
        let txt = render_response(5, 249, 256, 4, &stats, &total, None);
        let j = Json::parse(&txt).unwrap();
        assert_eq!(j.get("seq").as_usize(), 5);
        assert_eq!(j.get("root").as_i64(), 249);
        assert_eq!(j.get("batch").as_usize(), 4);
        assert!((j.get("p").as_f64() - 249.0 / 256.0).abs() < 1e-12);
        assert_eq!(stats_from_json(j.get("total")).unwrap().messages, 14);
        assert!(j.opt("shard").is_none(), "single-session responses carry no shard");
        assert!(j.opt("gen").is_none(), "single-session responses carry no gen");
        // fleet responses name the serving shard, its generation and the
        // generation-local serve index
        let ftxt = render_response(5, 249, 256, 4, &stats, &total, Some((2, 1, 37)));
        let fj = Json::parse(&ftxt).unwrap();
        assert_eq!(fj.get("shard").as_usize(), 2);
        assert_eq!(fj.get("gen").as_usize(), 1);
        assert_eq!(fj.get("snum").as_usize(), 37);
    }

    #[test]
    fn escapes_error_payloads() {
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\u000a");
    }
}
