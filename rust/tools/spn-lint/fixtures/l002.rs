//! L002 fixture: a tag reservation whose base is thrown away.

fn reserve_and_lose(sess: &mut Sess) {
    sess.reserve_tags(8);
}

fn reserve_properly(sess: &mut Sess) -> u64 {
    let base = sess.reserve_tags(8); // decoy: bound, must not fire
    base
}
