//! Real TCP transport (std::net + threads) for smoke-scale distributed runs.
//!
//! The simulation in [`super::SimNet`] reproduces the paper's accounting;
//! this module proves the same protocol messages actually move over
//! sockets.  Each frame is: `exercise_id: u64 | from: u32 | n_elems: u32 |
//! elems: n × 16-byte little-endian field elements` (the accountant's
//! 24-byte-header + 10-byte-element model is the paper-calibrated wire
//! estimate; see DESIGN.md §4).
//!
//! The vendored crate set has no async runtime, so this uses blocking
//! sockets and `std::thread` — entirely adequate for the N ≤ 13 member
//! sessions. [`super::tcp_session::TcpSession`] drives the full
//! transport-agnostic session vocabulary over these frames.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use anyhow::Result;

/// A framed protocol message.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub exercise_id: u64,
    pub from: u32,
    pub elems: Vec<u128>,
}

impl Frame {
    /// Bytes on the wire for this frame.
    pub fn wire_bytes(&self) -> usize {
        16 + self.elems.len() * 16
    }
}

pub fn write_frame(s: &mut TcpStream, f: &Frame) -> Result<()> {
    let mut buf = Vec::with_capacity(f.wire_bytes());
    buf.extend_from_slice(&f.exercise_id.to_le_bytes());
    buf.extend_from_slice(&f.from.to_le_bytes());
    buf.extend_from_slice(&(f.elems.len() as u32).to_le_bytes());
    for e in &f.elems {
        buf.extend_from_slice(&e.to_le_bytes());
    }
    s.write_all(&buf)?;
    Ok(())
}

pub fn read_frame(s: &mut TcpStream) -> Result<Frame> {
    let mut hdr = [0u8; 16];
    s.read_exact(&mut hdr)?;
    let exercise_id = u64::from_le_bytes(hdr[0..8].try_into().unwrap());
    let from = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
    let n = u32::from_le_bytes(hdr[12..16].try_into().unwrap()) as usize;
    let mut body = vec![0u8; n * 16];
    s.read_exact(&mut body)?;
    let elems = body
        .chunks_exact(16)
        .map(|c| u128::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Frame { exercise_id, from, elems })
}

/// "Reveal to manager" over real sockets: accept `n` member connections,
/// sum the first element of each frame mod `p`, reply with the sum.
pub fn reveal_server_on(listener: TcpListener, n: usize, p: u128) -> Result<u128> {
    let mut acc = 0u128;
    let mut conns = Vec::new();
    for _ in 0..n {
        let (mut s, _) = listener.accept()?;
        let f = read_frame(&mut s)?;
        acc = (acc + f.elems[0] % p) % p;
        conns.push(s);
    }
    for s in conns.iter_mut() {
        write_frame(s, &Frame { exercise_id: 0, from: u32::MAX, elems: vec![acc] })?;
    }
    Ok(acc)
}

/// Member half of the smoke test: connect, send one share, read the sum.
pub fn reveal_client(addr: &str, from: u32, share: u128) -> Result<u128> {
    let mut s = TcpStream::connect(addr)?;
    write_frame(&mut s, &Frame { exercise_id: 0, from, elems: vec![share] })?;
    Ok(read_frame(&mut s)?.elems[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn frame_roundtrip_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let want = Frame { exercise_id: 7, from: 3, elems: vec![1, u128::MAX / 3, 42] };
        let w2 = want.clone();
        let srv = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, &w2).unwrap();
        assert_eq!(srv.join().unwrap(), want);
    }

    #[test]
    fn empty_frame_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let want = Frame { exercise_id: 1, from: 0, elems: vec![] };
        let w2 = want.clone();
        let srv = thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_frame(&mut s).unwrap()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        write_frame(&mut c, &w2).unwrap();
        assert_eq!(srv.join().unwrap(), want);
    }

    #[test]
    fn additive_reveal_over_tcp() {
        use crate::field::Field;
        use crate::rng::Prng;
        use crate::sharing::additive::additive_share;

        let f = Field::paper();
        let mut rng = Prng::seed_from_u64(9);
        let secret = 123456789u128;
        let shares = additive_share(&f, secret, 4, &mut rng);

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let srv = thread::spawn(move || reveal_server_on(listener, 4, crate::field::PAPER_P));
        let mut handles = Vec::new();
        for (i, sh) in shares.into_iter().enumerate() {
            let a = addr.clone();
            handles.push(thread::spawn(move || reveal_client(&a, i as u32, sh)));
        }
        for h in handles {
            assert_eq!(h.join().unwrap().unwrap(), secret);
        }
        assert_eq!(srv.join().unwrap().unwrap(), secret);
    }
}
