//! The Manager/Member exercise engine (paper §5.2 + Appendix A).
//!
//! The Manager schedules *exercises*; every Member executes its local part
//! against its private share store and exchanges sub-shares with the other
//! members; the Manager waits for all "finished" messages before scheduling
//! the next exercise.  This module implements that machine with per-member
//! state kept strictly separate (each [`Member`] owns its store and RNG —
//! protocol code only moves data between members through [`SimNet::send`]
//! accounting), which both documents the privacy boundary and makes the
//! message/byte/round counts of Tables 2–3 exact.
//!
//! Two scheduling modes ([`Schedule`]):
//! * `PerOp`   — one exercise per scalar operation, like the paper's
//!   implementation (and its message counts);
//! * `Batched` — vectorized exercises that pack k elements per message;
//!   the §Perf optimization (same rounds, ~k× fewer messages).

use std::collections::HashMap; // lint:allow(L003) — d⁻¹ memo, not a share store

use crate::field::Field;
use crate::net::{NetConfig, SimNet};
use crate::parallel::Pool;
use crate::rng::Prng;
use crate::sharing::shamir::ShamirCtx;

/// Handle to a secret-shared value distributed across the members.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DataId(pub u64);

/// How the manager schedules vector operations — the message-accounting
/// contract behind Tables 2–3 (see DESIGN.md §2).
///
/// For a k-wide vector operation whose body needs one full-mesh sub-share
/// exchange (e.g. [`Engine::mul_vec`]) with `n` members:
///
/// * **`PerOp`** schedules k exercises. Each costs one schedule broadcast
///   (n messages), `n·(n−1)` single-element body messages in their own
///   round, and n "finished" messages — so k·(n² + n) messages and
///   3·k rounds. This is how the paper's implementation runs, and the
///   mode its Tables 2–3 are reproduced in.
/// * **`Batched`** schedules one exercise for the whole vector; each link
///   carries all k elements in one message (`n·(n−1)` body messages
///   total, each k elements). Same round *structure*, ~k× fewer messages
///   and k× fewer rounds — the §Perf optimization, quantified by
///   `batched_mul_fewer_messages_same_result`.
///
/// Virtual time charges `latency + max_bytes/bandwidth` per round either
/// way, so `Batched` also wins wall-clock on latency-dominated links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// One exercise (and one message per link) per scalar op — paper mode.
    PerOp,
    /// One exercise per vector op; messages carry k elements.
    Batched,
}

/// Configuration for [`Engine::new`]: party count, threshold, schedule,
/// masking width, determinism seed and network cost model.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    /// Number of computing members (the Manager is not a member).
    pub n: usize,
    /// Shamir degree; defaults to ⌊(n-1)/2⌋ (see DESIGN.md §4).
    pub threshold: Option<usize>,
    /// Vector-operation scheduling mode; see [`Schedule`].
    pub schedule: Schedule,
    /// Security parameter ρ for division-by-public (§3.4); r ∈ [0, 2^ρ).
    pub rho_bits: u32,
    /// Seed for the per-member deterministic RNGs (reproducible runs).
    pub seed: u64,
    /// Latency/bandwidth/framing model for the accounted network.
    pub net: NetConfig,
    /// Worker-pool width for the member compute plane (DESIGN.md §Field
    /// kernel): products, dealing evaluations and λ-recombination fan out
    /// over up to this many scoped threads. `1` (the default) is strictly
    /// serial; any value is byte-identical by construction (RNG draws are
    /// pre-drawn in scalar order before fan-out).
    pub threads: usize,
}

impl EngineConfig {
    /// Paper-mode defaults for `n` members: `PerOp` schedule, ρ = 64,
    /// honest-majority threshold, 10 ms / 1 Gbit links.
    pub fn new(n: usize) -> Self {
        EngineConfig {
            n,
            threshold: None,
            schedule: Schedule::PerOp,
            rho_bits: 64,
            seed: 0xC0FFEE,
            net: NetConfig::default(),
            threads: 1,
        }
    }

    /// Switch to the vectorized [`Schedule::Batched`] mode.
    pub fn batched(mut self) -> Self {
        self.schedule = Schedule::Batched;
        self
    }

    /// Set the member compute plane's worker-pool width.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }
}

/// Dense per-member share store. [`DataId`]s are allocated monotonically
/// from 1 by every session backend, so a slab indexed by the id replaces
/// the seed's `HashMap<u64, u128>`: O(1) access with no hashing and no
/// per-entry heap boxes — the data-plane store of DESIGN.md §Data plane.
/// Shares are field elements `< p < 2^74`, so `u128::MAX` marks a vacant
/// slot (an id that was allocated but whose exercise never wrote here).
pub(crate) struct ShareStore {
    slots: Vec<u128>,
}

/// Sentinel for a slot no exercise has written. Never a valid share.
const VACANT: u128 = u128::MAX;

/// Size a reusable scratch vector to exactly `len` elements, skipping the
/// zero-fill memset when it already has that length. Callers guarantee
/// every slot is written before it is read (the dealing loops cover the
/// whole buffer), so stale contents are harmless — at n = 13, k = 4096 the
/// avoided fill is an ~11 MB memset per vector op.
pub(crate) fn reset_scratch(buf: &mut Vec<u128>, len: usize) {
    if buf.len() != len {
        buf.clear();
        buf.resize(len, 0);
    }
}

impl ShareStore {
    pub(crate) fn new() -> Self {
        ShareStore { slots: Vec::new() }
    }

    /// The stored share, or `None` if `id` was never written here.
    #[inline]
    pub(crate) fn get(&self, id: u64) -> Option<u128> {
        match self.slots.get(id as usize) {
            Some(&v) if v != VACANT => Some(v),
            _ => None,
        }
    }

    #[inline]
    pub(crate) fn put(&mut self, id: u64, v: u128) {
        debug_assert_ne!(v, VACANT, "share collides with the vacancy sentinel");
        let idx = id as usize;
        if idx >= self.slots.len() {
            self.slots.resize(idx + 1, VACANT);
        }
        self.slots[idx] = v;
    }
}

/// One computing party. `store` maps DataId → this member's share.
pub struct Member {
    /// Member id in `1..=n` (also the Shamir evaluation point).
    pub id: usize,
    store: ShareStore,
    rng: Prng,
}

impl Member {
    /// Diagnostics/tests only: expose this member's raw share (used by the
    /// privacy smoke tests to check shares don't coincide with secrets).
    /// Compiled only for the crate's own tests or under the opt-in
    /// `test-introspection` feature — a raw-share accessor is
    /// privacy-sensitive and not part of the advertised public API.
    #[cfg(any(test, feature = "test-introspection"))]
    #[doc(hidden)]
    pub fn share_for_test(&self, a: DataId) -> u128 {
        self.get(a)
    }

    fn get(&self, a: DataId) -> u128 {
        self.store.get(a.0).unwrap_or_else(|| panic!("member {} missing {:?}", self.id, a))
    }
    fn put(&mut self, a: DataId, v: u128) {
        self.store.put(a.0, v);
    }
}

/// The Manager plus all Members plus the accounted network.
pub struct Engine {
    /// The prime field all shares live in.
    pub field: Field,
    /// Shamir context (party set + threshold + Lagrange coefficients).
    pub shamir: ShamirCtx,
    /// The configuration this engine was built with. `schedule` may be
    /// switched between runs to compare accounting modes.
    pub cfg: EngineConfig,
    /// The computing parties, each with a private store and RNG.
    pub members: Vec<Member>,
    /// The accounted network; read `net.stats` for cost reports.
    pub net: SimNet,
    next_id: u64,
    next_tag: u64,
    #[allow(dead_code)]
    manager_rng: Prng,
    /// Flat reusable sub-share scratch for the dealing exercises
    /// (`mul_vec`/`sq2pq_inputs`/`divpub_impl`): sized on first use, its
    /// capacity persists across calls so steady-state dealing performs no
    /// per-element (or even per-call) heap allocation. See DESIGN.md
    /// §Data plane for the layouts.
    scratch_dealt: Vec<u128>,
    /// Companion scratch (local products for `mul_vec`, `z'` openings for
    /// `divpub_impl`).
    scratch_vals: Vec<u128>,
    /// Reusable buffer for Alice's batched tag-mask derivation
    /// ([`super::divpub::tagged_r_many`]) in tagged divpub.
    scratch_masks: Vec<u128>,
    /// Memoized **Montgomery-domain** `d⁻¹·R mod p` per public divisor:
    /// `Field::inv` is a full Fermat pow (~74 squarings), and training/
    /// inference divide by the same scale `d` thousands of times per
    /// session. Storing the mont image makes divpub's phase-4 multiply a
    /// division-free `mont_mul` (DESIGN.md §Field kernel).
    dinv_cache: HashMap<u128, u128>, // lint:allow(L003)
    /// Pre-drawn coefficient table scratch for the pooled dealing path
    /// ([`ShamirCtx::share_batch_into_pooled`]).
    scratch_coeffs: Vec<u128>,
    /// The member compute plane's worker pool (`cfg.threads`).
    pool: Pool,
    /// Open flight of the pipelined round engine (`None` = no flight in
    /// progress). See [`Engine::flight_submit`].
    flight: Option<FlightAcc>,
}

/// Accounting snapshot of an open flight: staged ops execute eagerly (the
/// Sim backend *is* the deterministic ready-order executor), and
/// [`Engine::flight_complete`] re-attributes their rounds to the coalesced
/// closed form of [`super::flight::sim_flight_rounds`].
struct FlightAcc {
    start_rounds: u64,
    has_mul: bool,
    has_divpub: bool,
}

impl Engine {
    /// Build an engine: constructs the Shamir context (honest-majority
    /// threshold unless overridden) and one [`Member`] per party.
    pub fn new(field: Field, cfg: EngineConfig) -> Self {
        let shamir = match cfg.threshold {
            Some(t) => ShamirCtx::with_threshold(field, cfg.n, t),
            None => ShamirCtx::new(field, cfg.n),
        };
        let members = (1..=cfg.n)
            .map(|id| Member {
                id,
                store: ShareStore::new(),
                rng: Prng::seed_from_u64(cfg.seed ^ (id as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            })
            .collect();
        Engine {
            field,
            shamir,
            cfg,
            members,
            net: SimNet::new(cfg.net),
            next_id: 0,
            next_tag: 0,
            manager_rng: Prng::seed_from_u64(cfg.seed ^ 0xABCD),
            scratch_dealt: Vec::new(),
            scratch_vals: Vec::new(),
            scratch_masks: Vec::new(),
            dinv_cache: HashMap::new(), // lint:allow(L003)
            scratch_coeffs: Vec::new(),
            pool: Pool::new(cfg.threads),
            flight: None,
        }
    }

    /// The pool to use for a k-element fan-out: below the work floor the
    /// serial pool avoids paying thread-spawn latency on small ops.
    fn pool_for(&self, k: usize) -> Pool {
        if k >= crate::parallel::MIN_CHUNK {
            self.pool
        } else {
            Pool::serial()
        }
    }

    /// Allocate `count` fresh divpub tags (monotone, never reissued); see
    /// [`Engine::divpub_vec_tagged`].
    pub fn reserve_tags(&mut self, count: u64) -> u64 {
        let base = self.next_tag;
        self.next_tag += count;
        base
    }

    /// Number of computing members.
    pub fn n(&self) -> usize {
        self.cfg.n
    }

    /// Allocate a fresh [`DataId`] handle.
    pub fn alloc(&mut self) -> DataId {
        self.next_id += 1;
        DataId(self.next_id)
    }

    fn alloc_vec(&mut self, k: usize) -> Vec<DataId> {
        (0..k).map(|_| self.alloc()).collect()
    }

    /// Number of exercise "slots" a vector op of width k consumes under the
    /// current schedule (PerOp: k, Batched: 1); used for overhead accounting.
    fn slots(&self, k: usize) -> u64 {
        match self.cfg.schedule {
            Schedule::PerOp => k as u64,
            Schedule::Batched => 1,
        }
    }

    /// Elements per message for a k-wide op (PerOp sends k single-element
    /// messages per link; Batched packs them).
    fn begin_exercise(&mut self, k: usize) {
        for _ in 0..self.slots(k) {
            self.net.exercise_overhead(self.cfg.n);
        }
    }

    fn finish_exercise(&mut self, k: usize) {
        for _ in 0..self.slots(k) {
            self.net.exercise_finish(self.cfg.n);
        }
    }

    /// Account a full-mesh sub-share exchange of k elements per ordered pair.
    fn mesh_exchange(&mut self, k: usize) {
        let n = self.cfg.n;
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for i in 0..n {
                        for j in 0..n {
                            if i != j {
                                self.net.send(i, j, 1);
                            }
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for i in 0..n {
                    for j in 0..n {
                        if i != j {
                            self.net.send(i, j, k as u64);
                        }
                    }
                }
                self.net.end_round();
            }
        }
    }

    /// Account a star exchange (one sender or one receiver) of k elements.
    fn star_exchange(&mut self, center_sends: bool, k: usize) {
        let n = self.cfg.n;
        let links = n - 1;
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for l in 0..links {
                        if center_sends {
                            self.net.send(usize::MAX, l, 1);
                        } else {
                            self.net.send(l, usize::MAX, 1);
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for l in 0..links {
                    if center_sends {
                        self.net.send(usize::MAX, l, k as u64);
                    } else {
                        self.net.send(l, usize::MAX, k as u64);
                    }
                }
                self.net.end_round();
            }
        }
    }

    // ---------------------------------------------------------------------
    // Exercises
    // ---------------------------------------------------------------------

    /// `input`: party `owner` (1-based) Shamir-deals its private values.
    pub fn input(&mut self, owner: usize, values: &[u128]) -> Vec<DataId> {
        let k = values.len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let n = self.cfg.n;
        let pool = self.pool_for(n * k);
        let mut dealt = std::mem::take(&mut self.scratch_dealt);
        reset_scratch(&mut dealt, n * k);
        {
            let Engine { shamir, members, scratch_coeffs, .. } = self;
            let deg = shamir.t;
            let m = &mut members[owner - 1];
            shamir.share_batch_into_pooled(values, deg, &mut m.rng, &mut dealt, scratch_coeffs, pool);
        }
        for (j, m) in self.members.iter_mut().enumerate() {
            for (e, &id) in ids.iter().enumerate() {
                m.put(id, dealt[j * k + e]);
            }
        }
        self.scratch_dealt = dealt;
        self.star_exchange(true, k); // owner → others
        self.finish_exercise(k);
        ids
    }

    /// A public constant as a (constant-polynomial) shared value. Local.
    pub fn constant(&mut self, c: u128) -> DataId {
        let id = self.alloc();
        let c = self.field.reduce(c);
        for m in &mut self.members {
            m.put(id, c);
        }
        id
    }

    /// Linear exercise: out = c0 + Σ ck·[ak]. Local math, but still a
    /// scheduled exercise (Appendix A counts them).
    pub fn lin(&mut self, c0: i128, terms: &[(i128, DataId)]) -> DataId {
        self.lin_vec(&[(c0, terms.to_vec())])[0]
    }

    /// Vectorized [`Engine::lin`]: each entry is `(c0, [(ck, ak), ...])`.
    pub fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId> {
        let ids = self.alloc_vec(ops.len());
        self.begin_exercise(ops.len());
        let f = self.field;
        for m in &mut self.members {
            for ((c0, terms), &id) in ops.iter().zip(&ids) {
                let mut acc = f.from_i128(*c0);
                for &(c, a) in terms {
                    acc = f.add(acc, f.mul(f.from_i128(c), m.get(a)));
                }
                m.put(id, acc);
            }
        }
        self.finish_exercise(ops.len());
        ids
    }

    /// `[a] + [b]` (local linear exercise).
    pub fn add(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (1, b)])
    }

    /// `[a] - [b]` (local linear exercise).
    pub fn sub(&mut self, a: DataId, b: DataId) -> DataId {
        self.lin(0, &[(1, a), (-1, b)])
    }

    /// Secure multiplication (BGW): local product (degree 2t) + resharing
    /// degree reduction. One mesh round; n(n-1) messages in PerOp mode.
    pub fn mul(&mut self, a: DataId, b: DataId) -> DataId {
        self.mul_vec(&[(a, b)])[0]
    }

    /// Vectorized [`Engine::mul`]: one mesh exchange for all pairs under
    /// the `Batched` schedule. Dealing runs through the flat-buffer data
    /// plane: each member's local products land in a reusable scratch
    /// vector and are dealt with one [`ShamirCtx::share_batch_into`] call —
    /// zero per-element heap allocation (DESIGN.md §Data plane).
    pub fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId> {
        let k = pairs.len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let n = self.cfg.n;
        let f = self.field;
        // dealt[i·n·k + j·k + e]: sub-share of element e from dealer i to
        // member j (party-major slab per dealer).
        let pool = self.pool_for(k);
        let mut dealt = std::mem::take(&mut self.scratch_dealt);
        let mut vals = std::mem::take(&mut self.scratch_vals);
        reset_scratch(&mut dealt, n * n * k);
        {
            let Engine { shamir, members, scratch_coeffs, .. } = self;
            let deg = shamir.t;
            for (i, m) in members.iter_mut().enumerate() {
                // Local products fan out over the pool: the k-loop is pure
                // indexed reads of this member's store into disjoint chunks
                // of the vals scratch. RNG is untouched here.
                let Member { id: mid, store, rng } = m;
                let mid = *mid;
                reset_scratch(&mut vals, k);
                {
                    let store = &*store;
                    pool.run_chunks(&mut vals, crate::parallel::MIN_CHUNK, |start, chunk| {
                        for (off, slot) in chunk.iter_mut().enumerate() {
                            let (a, b) = pairs[start + off];
                            let get = |x: DataId| {
                                store
                                    .get(x.0)
                                    .unwrap_or_else(|| panic!("member {mid} missing {x:?}"))
                            };
                            *slot = f.mul(get(a), get(b));
                        }
                    });
                }
                // Dealing pre-draws all coefficients serially (scalar draw
                // order), then fans the Vandermonde evaluations out.
                shamir.share_batch_into_pooled(
                    &vals,
                    deg,
                    rng,
                    &mut dealt[i * n * k..(i + 1) * n * k],
                    scratch_coeffs,
                    pool,
                );
            }
        }
        self.mesh_exchange(k);
        {
            let Engine { shamir, members, .. } = self;
            // λ-recombination in the Montgomery kernel: canonical sub-shares
            // against the mont λ table — division-free, canonical (hence
            // bit-identical) outputs. Member-major fan-out: each member owns
            // its store, so the writes are disjoint by construction.
            let lambda_mont = shamir.lambda_mont();
            let dealt = &dealt[..];
            pool.run_each(members, |j, m| {
                for (e, &id) in ids.iter().enumerate() {
                    let mut acc = 0u128;
                    for (i, &lm) in lambda_mont.iter().enumerate() {
                        acc = f.mont_mul_add(acc, dealt[i * n * k + j * k + e], lm);
                    }
                    m.put(id, acc);
                }
            });
        }
        self.scratch_dealt = dealt;
        self.scratch_vals = vals;
        self.finish_exercise(k);
        ids
    }

    /// Reveal to the manager (star inward). Returns the reconstruction.
    pub fn reveal(&mut self, a: DataId) -> u128 {
        self.reveal_vec(&[a])[0]
    }

    /// Vectorized [`Engine::reveal`].
    pub fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128> {
        self.begin_exercise(ids.len());
        self.star_exchange(false, ids.len());
        let out = ids
            .iter()
            .map(|&id| {
                let shares: Vec<u128> = self.members.iter().map(|m| m.get(id)).collect();
                self.shamir.reconstruct(&shares)
            })
            .collect();
        self.finish_exercise(ids.len());
        out
    }

    /// Division by a public `d` (§3.4): see [`super::divpub`] for the pure
    /// math; this wires Alice (member 1) and Bob (member 2) with accounting.
    /// Requires the shared value `u` to be an integer in `[0, 2^62]`
    /// (guaranteed by the Newton bounds; debug-asserted in tests via reveal).
    pub fn divpub(&mut self, u: DataId, d: u128) -> DataId {
        self.divpub_vec(&[u], d)[0]
    }

    /// Vectorized [`Engine::divpub`]: Alice/Bob deal for all k values in
    /// one exercise (one message per link per phase under `Batched`).
    pub fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId> {
        self.divpub_impl(us, d, None)
    }

    /// Tagged [`Engine::divpub_vec`]: element `e`'s §3.4 mask is derived as
    /// `PRF(seed, tags[e])` ([`super::divpub::tagged_r`]) instead of the
    /// next draw of Alice's RNG stream, so the ±1 rounding of each element
    /// is a function of its tag alone — invariant under any batching or
    /// evaluation order. Same wire shape and accounting as the untagged
    /// variant. Tags must be fresh ([`Engine::reserve_tags`]).
    pub fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId> {
        assert_eq!(us.len(), tags.len());
        self.divpub_impl(us, d, Some(tags))
    }

    fn divpub_impl(&mut self, us: &[DataId], d: u128, tags: Option<&[u64]>) -> Vec<DataId> {
        assert!(d > 0);
        let k = us.len();
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let n = self.cfg.n;
        let f = self.field;
        let alice = 0usize;
        let bob = if n > 1 { 1 } else { 0 };
        let rho = self.cfg.rho_bits;
        let seed = self.cfg.seed;
        // Montgomery-domain d⁻¹ (see dinv_cache docs): phase 4's per-element
        // multiply becomes a division-free mont_mul with canonical output.
        let dinv_mont = *self.dinv_cache.entry(d).or_insert_with(|| f.to_mont(f.inv(f.reduce(d))));
        let pool = self.pool_for(us.len());

        // Flat reusable scratch, element-major (e·n + j) segments for the
        // three dealt streams. Element-major keeps Alice's per-element draw
        // order (r, then r's coefficients, then q's) byte-identical to the
        // scalar protocol — see DESIGN.md §Data plane.
        let mut scratch = std::mem::take(&mut self.scratch_dealt);
        reset_scratch(&mut scratch, 3 * k * n);
        let (r_sh, rest) = scratch.split_at_mut(k * n);
        let (q_sh, w_sh) = rest.split_at_mut(k * n);

        // Phase 1: Alice deals [r], [q = r mod d]. Tagged masks come from
        // the PRF, not Alice's stream, so the whole reserved range derives
        // in one batched pass (`tagged_r_many`) before the dealing loop —
        // bit-identical to deriving each inside it, since PRF evaluations
        // consume no state; the untagged (training) path keeps the scalar
        // stream draw interleaved with the coefficient draws, whose order
        // is part of the byte-identity contract.
        {
            let Engine { shamir, members, scratch_masks, .. } = self;
            let deg = shamir.t;
            let m = &mut members[alice];
            if let Some(t) = tags {
                scratch_masks.clear();
                super::divpub::tagged_r_many(seed, t, rho, scratch_masks);
            }
            for e in 0..k {
                let r = match tags {
                    Some(_) => scratch_masks[e],
                    None => super::divpub::sample_r(&mut m.rng, rho),
                };
                let q = r % d;
                shamir.share_into(r, deg, &mut m.rng, &mut r_sh[e * n..(e + 1) * n]);
                shamir.share_into(q, deg, &mut m.rng, &mut q_sh[e * n..(e + 1) * n]);
            }
        }
        // Alice → everyone else: 2 elements per value per link.
        match self.cfg.schedule {
            Schedule::PerOp => {
                for _ in 0..k {
                    for j in 0..n {
                        if j != alice {
                            self.net.send(alice, j, 2);
                        }
                    }
                    self.net.end_round();
                }
            }
            Schedule::Batched => {
                for j in 0..n {
                    if j != alice {
                        self.net.send(alice, j, 2 * k as u64);
                    }
                }
                self.net.end_round();
            }
        }

        // Phase 2: everyone computes [z'] = [u] + [r] and sends to Bob.
        let mut z_shares = std::mem::take(&mut self.scratch_vals); // [e·n + j]
        reset_scratch(&mut z_shares, k * n);
        for (j, m) in self.members.iter().enumerate() {
            for (e, &u_id) in us.iter().enumerate() {
                z_shares[e * n + j] = f.add(m.get(u_id), r_sh[e * n + j]);
            }
        }
        self.star_exchange(false, k); // members → Bob

        // Phase 3: Bob reconstructs z' = u + r (an integer < 2^(ρ+1) « p),
        // computes w = z' mod d, and deals [w].
        {
            let Engine { shamir, members, .. } = self;
            let deg = shamir.t;
            let m = &mut members[bob];
            for e in 0..k {
                let z = shamir.reconstruct(&z_shares[e * n..(e + 1) * n]);
                let w = z % d;
                shamir.share_into(w, deg, &mut m.rng, &mut w_sh[e * n..(e + 1) * n]);
            }
        }
        self.star_exchange(true, k); // Bob → others

        // Phase 4 (local): [v] = ([u] + [q] - [w]) · d^{-1} mod p.
        // NOTE the paper prints [u] - [q] + [w]; that has residue 2(u mod d)
        // mod d — the sign must be flipped for z ≡ 0 (mod d). See DESIGN.md
        // §4 "erratum" and divpub::tests::paper_identity. Pure per-member
        // compute (no RNG), so it fans out member-major over the pool.
        {
            let (q_sh, w_sh) = (&q_sh[..], &w_sh[..]);
            pool.run_each(&mut self.members, |j, m| {
                for (e, &u_id) in us.iter().enumerate() {
                    let v = f.mont_mul(
                        f.sub(f.add(m.get(u_id), q_sh[e * n + j]), w_sh[e * n + j]),
                        dinv_mont,
                    );
                    m.put(ids[e], v);
                }
            });
        }
        self.scratch_dealt = scratch;
        self.scratch_vals = z_shares;
        self.finish_exercise(k);
        ids
    }

    /// Convert per-party additive shares (each member holds one) into
    /// polynomial shares via SQ2PQ: every member deals, then sums. Used to
    /// enter the exact pipeline from locally-computed counts (Eq. 3).
    pub fn sq2pq_inputs(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId> {
        // local_values[i][e]: member i's additive contribution to element e
        let n = self.cfg.n;
        assert_eq!(local_values.len(), n);
        let k = local_values[0].len();
        assert!(local_values.iter().all(|v| v.len() == k), "ragged contribution vectors");
        let ids = self.alloc_vec(k);
        self.begin_exercise(k);
        let f = self.field;
        // Same flat party-major-per-dealer slab as mul_vec.
        let pool = self.pool_for(k);
        let mut dealt = std::mem::take(&mut self.scratch_dealt);
        reset_scratch(&mut dealt, n * n * k);
        {
            let Engine { shamir, members, scratch_coeffs, .. } = self;
            let deg = shamir.t;
            for (i, m) in members.iter_mut().enumerate() {
                shamir.share_batch_into_pooled(
                    &local_values[i],
                    deg,
                    &mut m.rng,
                    &mut dealt[i * n * k..(i + 1) * n * k],
                    scratch_coeffs,
                    pool,
                );
            }
        }
        self.mesh_exchange(k);
        {
            // Deferred-reduction recombination: n ≤ 13 canonical terms
            // (< 2^74 each) sum raw far below u128 overflow; one reduce
            // restores the canonical (bit-identical) value.
            let dealt = &dealt[..];
            pool.run_each(&mut self.members, |j, m| {
                for (e, &id) in ids.iter().enumerate() {
                    let mut acc = 0u128;
                    for i in 0..n {
                        acc += dealt[i * n * k + j * k + e];
                    }
                    m.put(id, f.reduce(acc));
                }
            });
        }
        self.scratch_dealt = dealt;
        self.finish_exercise(k);
        ids
    }

    /// Stage one op of a flight — the pipelined round engine's coalescing
    /// surface (DESIGN.md §Round scheduler). The Sim backend executes the
    /// op *immediately* in staged order (it is the deterministic
    /// ready-order executor, so values, messages, bytes and exercises keep
    /// their exact sequential accounting); [`Engine::flight_complete`]
    /// then re-attributes the flight's rounds to the coalesced closed form
    /// [`super::flight::sim_flight_rounds`], since every staged op's
    /// traffic would share physical rounds on a coalescing transport.
    pub fn flight_submit(&mut self, op: super::flight::FlightOp) -> Vec<DataId> {
        use super::flight::FlightOp;
        assert!(!op.is_empty(), "flights stage only non-empty ops");
        if self.flight.is_none() {
            self.flight = Some(FlightAcc {
                start_rounds: self.net.stats.rounds,
                has_mul: false,
                has_divpub: false,
            });
        }
        let acc = self.flight.as_mut().expect("just installed");
        match &op {
            FlightOp::Mul(_) => acc.has_mul = true,
            FlightOp::DivpubTagged { .. } => acc.has_divpub = true,
            FlightOp::Lin(_) => {}
        }
        match op {
            FlightOp::Mul(pairs) => self.mul_vec(&pairs),
            FlightOp::Lin(ops) => self.lin_vec(&ops),
            FlightOp::DivpubTagged { us, d, tags } => self.divpub_vec_tagged(&us, d, &tags),
        }
    }

    /// Close the open flight: rounds recorded since the first
    /// [`Engine::flight_submit`] collapse to
    /// [`super::flight::sim_flight_rounds`], and the collapsed rounds'
    /// *latencies* leave virtual time with them. The serialization terms
    /// (bytes/bandwidth) of every collapsed round stay — coalescing
    /// removes round trips, not traffic. No-op without an open flight;
    /// on a degenerate n < 2 session the raw accounting is kept.
    pub fn flight_complete(&mut self) {
        let Some(acc) = self.flight.take() else { return };
        if self.cfg.n < 2 {
            return;
        }
        let seq_rounds = self.net.stats.rounds - acc.start_rounds;
        let flight_rounds = super::flight::sim_flight_rounds(acc.has_mul, acc.has_divpub);
        let collapsed = seq_rounds.saturating_sub(flight_rounds);
        self.net.stats.rounds -= collapsed;
        self.net.stats.virtual_time_s -= collapsed as f64 * self.net.cfg.latency_s;
    }

    /// Test/diagnostic-only: reconstruct without counting traffic.
    pub fn peek(&self, a: DataId) -> u128 {
        let shares: Vec<u128> = self.members.iter().map(|m| m.get(a)).collect();
        self.shamir.reconstruct(&shares)
    }

    /// Test/diagnostic-only: signed small-integer view of a shared value.
    pub fn peek_int(&self, a: DataId) -> i128 {
        self.field.to_i128(self.peek(a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;

    fn engine(n: usize) -> Engine {
        Engine::new(Field::paper(), EngineConfig::new(n))
    }

    #[test]
    fn share_store_slab_semantics() {
        let mut s = ShareStore::new();
        assert_eq!(s.get(5), None);
        s.put(5, 42);
        assert_eq!(s.get(5), Some(42));
        assert_eq!(s.get(4), None, "allocated-but-unwritten slot must read vacant");
        assert_eq!(s.get(1_000_000), None, "reads past the slab are vacant, not panics");
        s.put(2, 7);
        s.put(5, 43);
        assert_eq!((s.get(2), s.get(5)), (Some(7), Some(43)));
    }

    #[test]
    fn input_and_reveal_roundtrip() {
        let mut e = engine(5);
        let ids = e.input(2, &[42, 9999]);
        assert_eq!(e.reveal(ids[0]), 42);
        assert_eq!(e.reveal(ids[1]), 9999);
    }

    #[test]
    fn linear_ops() {
        let mut e = engine(5);
        let a = e.input(1, &[10])[0];
        let b = e.input(2, &[4])[0];
        let s = e.add(a, b);
        let d = e.sub(a, b);
        let l = e.lin(100, &[(3, a), (-2, b)]);
        assert_eq!(e.peek(s), 14);
        assert_eq!(e.peek(d), 6);
        assert_eq!(e.peek(l), 100 + 30 - 8);
    }

    #[test]
    fn secure_mul_correct() {
        for n in [3, 5, 13] {
            let mut e = engine(n);
            let a = e.input(1, &[123456])[0];
            let b = e.input(2, &[789])[0];
            let c = e.mul(a, b);
            assert_eq!(e.peek(c), 123456 * 789, "n={n}");
        }
    }

    #[test]
    fn mul_chain_stays_degree_t() {
        // After a mul, result must again be multiplicable (degree t).
        let mut e = engine(5);
        let a = e.input(1, &[7])[0];
        let b = e.input(2, &[11])[0];
        let c = e.mul(a, b);
        let d = e.mul(c, c);
        assert_eq!(e.peek(d), 7 * 11 * 7 * 11);
    }

    #[test]
    fn divpub_is_close() {
        let mut e = engine(5);
        for (u, d) in [(1000u128, 256u128), (255, 256), (0, 7), (65536, 256), (12345, 100)] {
            let id = e.input(1, &[u])[0];
            let v = e.divpub(id, d);
            let got = e.peek_int(v);
            let want = (u / d) as i128;
            assert!((got - want).abs() <= 1, "u={u} d={d}: got {got} want {want}");
        }
    }

    #[test]
    fn tagged_divpub_is_order_invariant() {
        // The same logical (u, d, tag) element reveals the same value no
        // matter how the calls around it are batched or ordered — the
        // invariance the compiled-plan batch evaluator builds on. The
        // untagged variant has no such guarantee (its ±1 rounding follows
        // Alice's RNG stream position).
        let us = [100_000u128, 77_777, 54_321];
        let tags = [10u64, 11, 12];

        // Engine A: one batched tagged call.
        let mut a = engine(5);
        let ids_a = a.input(1, &us);
        let outs_a = a.divpub_vec_tagged(&ids_a, 256, &tags);
        let got_a: Vec<i128> = outs_a.iter().map(|&id| a.peek_int(id)).collect();

        // Engine B: scalar tagged calls in reverse order, with an unrelated
        // untagged divpub interleaved to shift every RNG stream.
        let mut b = engine(5);
        let ids_b = b.input(1, &us);
        let noise = b.input(2, &[999_999])[0];
        let mut got_b = vec![0i128; 3];
        for e in (0..3).rev() {
            let _ = b.divpub(noise, 17);
            let out = b.divpub_vec_tagged(&ids_b[e..e + 1], 256, &tags[e..e + 1])[0];
            got_b[e] = b.peek_int(out);
        }
        assert_eq!(got_a, got_b, "tagged divpub must not depend on call order");
        for (e, &u) in us.iter().enumerate() {
            assert!((got_a[e] - (u / 256) as i128).abs() <= 1, "element {e} out of ±1");
        }
    }

    #[test]
    fn reserve_tags_is_monotone_and_disjoint() {
        let mut e = engine(3);
        let a = e.reserve_tags(5);
        let b = e.reserve_tags(3);
        let c = e.reserve_tags(1);
        assert_eq!((a, b, c), (0, 5, 8));
    }

    #[test]
    fn divpub_message_count_per_op() {
        let n = 5;
        let mut e = engine(n);
        let id = e.input(1, &[1000])[0];
        let before = e.net.stats;
        let _ = e.divpub(id, 256);
        let msgs = e.net.stats.messages - before.messages;
        // schedule n + alice 2(n-1)... as messages: (n-1) + (n-1) + (n-1) + finished n
        let expected = n as u64 // schedule
            + (n as u64 - 1)    // alice deals (r,q) packed per link
            + (n as u64 - 1)    // z' -> bob
            + (n as u64 - 1)    // bob deals w
            + n as u64; // finished
        assert_eq!(msgs, expected);
    }

    #[test]
    fn mul_message_count_per_op() {
        let n = 5;
        let mut e = engine(n);
        let a = e.input(1, &[3])[0];
        let b = e.input(1, &[4])[0];
        let before = e.net.stats;
        let _ = e.mul(a, b);
        let msgs = e.net.stats.messages - before.messages;
        assert_eq!(msgs, n as u64 + (n * (n - 1)) as u64 + n as u64);
    }

    #[test]
    fn batched_mul_fewer_messages_same_result() {
        let mut per_op = Engine::new(Field::paper(), EngineConfig::new(5));
        let mut batched = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let pairs: Vec<(u128, u128)> = (1..20u128).map(|i| (i, i * 7 + 1)).collect();
        for eng in [&mut per_op, &mut batched] {
            let avals: Vec<u128> = pairs.iter().map(|p| p.0).collect();
            let bvals: Vec<u128> = pairs.iter().map(|p| p.1).collect();
            let a = eng.input(1, &avals);
            let b = eng.input(2, &bvals);
            let prods = eng.mul_vec(&a.iter().copied().zip(b).collect::<Vec<_>>());
            for (i, &(x, y)) in pairs.iter().enumerate() {
                assert_eq!(eng.peek(prods[i]), x * y);
            }
        }
        assert!(batched.net.stats.messages < per_op.net.stats.messages / 5);
        assert!(batched.net.stats.virtual_time_s < per_op.net.stats.virtual_time_s / 5.0);
    }

    #[test]
    fn sq2pq_inputs_sum_local_contributions() {
        let mut e = engine(4);
        // member i contributes i+1 and 10*(i+1)
        let locals: Vec<Vec<u128>> =
            (0..4).map(|i| vec![(i + 1) as u128, 10 * (i + 1) as u128]).collect();
        let ids = e.sq2pq_inputs(&locals);
        assert_eq!(e.peek(ids[0]), 1 + 2 + 3 + 4);
        assert_eq!(e.peek(ids[1]), 10 + 20 + 30 + 40);
    }

    #[test]
    fn virtual_time_advances_with_latency() {
        let mut e = engine(5);
        let t0 = e.net.stats.virtual_time_s;
        let a = e.input(1, &[5])[0];
        let _ = e.mul(a, a);
        assert!(e.net.stats.virtual_time_s > t0 + 0.04); // several 10ms rounds
    }

    #[test]
    fn flight_collapses_rounds_but_not_messages() {
        use crate::protocols::flight::{sim_flight_rounds, FlightOp};
        // Two identically-seeded batched engines running the same logical
        // ops: one sequentially, one as a single flight. Revealed values,
        // messages, bytes and exercises must match exactly; only rounds
        // (and their latencies) collapse.
        let mut seq = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let mut fl = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let run = |e: &mut Engine, flight: bool| {
            let a = e.input(1, &[1000, 2000]);
            let b = e.input(2, &[3, 5]);
            let tags = {
                let t0 = e.reserve_tags(2);
                vec![t0, t0 + 1]
            };
            let before = e.net.stats;
            let pairs = vec![(a[0], b[0]), (a[1], b[1])];
            let outs = if flight {
                let prods = e.flight_submit(FlightOp::Mul(pairs));
                let outs =
                    e.flight_submit(FlightOp::DivpubTagged { us: prods, d: 256, tags });
                e.flight_complete();
                outs
            } else {
                let prods = e.mul_vec(&pairs);
                e.divpub_vec_tagged(&prods, 256, &tags)
            };
            let vals: Vec<i128> = outs.iter().map(|&id| e.peek_int(id)).collect();
            (vals, e.net.stats.delta_since(&before))
        };
        let (v_seq, d_seq) = run(&mut seq, false);
        let (v_fl, d_fl) = run(&mut fl, true);
        assert_eq!(v_seq, v_fl, "flight regrouping must not change revealed values");
        assert_eq!(d_fl.messages, d_seq.messages, "coalescing moves latency, not traffic");
        assert_eq!(d_fl.bytes, d_seq.bytes);
        assert_eq!(d_fl.exercises, d_seq.exercises);
        assert_eq!(d_fl.rounds, sim_flight_rounds(true, true));
        assert!(d_fl.rounds < d_seq.rounds, "{} !< {}", d_fl.rounds, d_seq.rounds);
        assert!(d_fl.virtual_time_s < d_seq.virtual_time_s);
    }

    #[test]
    fn threads4_engine_is_bit_identical_to_serial() {
        // The worker pool is an execution detail: a threads=4 engine must
        // produce the same revealed values AND the same Tables 2–3
        // accounting as the serial engine on the same seed, across every
        // primitive — including k large enough to cross the fan-out floor.
        let k = 1500;
        let run = |threads: usize| {
            let mut e =
                Engine::new(Field::paper(), EngineConfig::new(3).batched().with_threads(threads));
            let avals: Vec<u128> = (0..k as u128).map(|i| i * 3 + 1).collect();
            let bvals: Vec<u128> = (0..k as u128).map(|i| i + 7).collect();
            let a = e.input(1, &avals);
            let b = e.input(2, &bvals);
            let pairs: Vec<(DataId, DataId)> = a.iter().copied().zip(b).collect();
            let prods = e.mul_vec(&pairs);
            let divs = e.divpub_vec(&prods[..8], 256);
            let locals: Vec<Vec<u128>> = (0..3).map(|i| vec![(i + 1) as u128; k]).collect();
            let sq = e.sq2pq_inputs(&locals);
            let mut out = e.reveal_vec(&prods);
            out.extend(e.reveal_vec(&divs));
            out.extend(e.reveal_vec(&sq[..4]));
            (out, e.net.stats)
        };
        let (v1, s1) = run(1);
        let (v4, s4) = run(4);
        assert_eq!(v1, v4, "worker pool must not change any revealed value");
        assert_eq!(s1.messages, s4.messages);
        assert_eq!(s1.bytes, s4.bytes);
        assert_eq!(s1.rounds, s4.rounds);
        assert_eq!(s1.exercises, s4.exercises);
    }

    #[test]
    fn two_party_works_degenerate() {
        // n=2 → t=0: no privacy, but protocols must stay correct.
        let mut e = engine(2);
        let a = e.input(1, &[6])[0];
        let b = e.input(2, &[7])[0];
        let c = e.mul(a, b);
        assert_eq!(e.peek(c), 42);
    }
}
