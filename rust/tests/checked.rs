//! Integration pins of the `CheckedSession` protocol sanitizer
//! (DESIGN.md §Static analysis).
//!
//! Two halves:
//!
//! * **Clean paths** — the real coordinators (training, batched
//!   inference, k-means) run under full checking *with* Sim accounting
//!   conservation, and stay bit-identical to an unchecked run. This is
//!   the "the crate satisfies its own contracts" pin; CI additionally
//!   re-runs the whole cross-backend / serve / fleet suites with
//!   `--features checked-session`.
//! * **Mutant coordinators** — each negative test re-implements a small
//!   coordinator step with one deliberate contract violation of the kind
//!   a refactor could plausibly introduce. Every `should_panic`
//!   expectation pins the *specific* violation message of the class the
//!   mutant was built to trip, so a test cannot pass by stumbling into a
//!   different check first.

use spn_mpc::coordinator::infer::{private_eval_batch, Query};
use spn_mpc::coordinator::train::{reveal_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::kmeans::{private_kmeans, KmeansConfig, PartyData};
use spn_mpc::protocols::division::DivisionConfig;
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::protocols::{CheckedSession, MpcSession, SessionPhase};
use spn_mpc::spn::learn;
use spn_mpc::spn::plan::TagStripe;
use spn_mpc::spn::structure::Structure;

const MEMBERS: usize = 3;

fn mini_counts(st: &Structure, n: usize) -> (Vec<Vec<u64>>, u64) {
    // seeds 5/21: the same shards as integration.rs / serve.rs
    (datasets::synth_shard_counts(st, n, st.rows, 5, 21), st.rows as u64)
}

fn mini_queries(st: &Structure, total: usize) -> Vec<Query> {
    (0..total)
        .map(|i| {
            let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
            if i % 4 != 0 {
                let v = i % st.num_vars;
                q.x[v] = (i % 2) as u8;
                q.marg[v] = false;
            }
            q
        })
        .collect()
}

fn checked_engine(n: usize) -> CheckedSession<Engine> {
    let cfg = EngineConfig::new(n).batched();
    CheckedSession::with_sim_accounting(Engine::new(Field::paper(), cfg), cfg.schedule)
}

// ---------------------------------------------------------------- clean

/// Training + batched inference under full checking (including Tables 2–3
/// conservation on every call) reveal exactly what an unchecked run
/// reveals, with exactly the same accounting.
#[test]
fn real_coordinators_run_clean_under_full_checking_and_stay_bit_identical() {
    let st = Structure::mini_demo();
    let (counts, rows) = mini_counts(&st, MEMBERS);
    let theta = learn::default_leaf_theta(&st);
    let queries = mini_queries(&st, 6);

    let mut raw = Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched());
    let (model, _) = train(&mut raw, &st, &counts, rows, &TrainConfig::default());
    let want_w = reveal_weights(&mut raw, &model);
    let (want_roots, _) = private_eval_batch(&mut raw, &st, &model, &queries, &theta);
    let raw_stats = raw.stats();

    let mut chk = checked_engine(MEMBERS);
    let (model, _) = train(&mut chk, &st, &counts, rows, &TrainConfig::default());
    assert_eq!(reveal_weights(&mut chk, &model), want_w, "weights drift under checking");
    let (roots, _) = private_eval_batch(&mut chk, &st, &model, &queries, &theta);
    assert_eq!(roots, want_roots, "roots drift under checking");
    assert_eq!(chk.stats(), raw_stats, "the sanitizer must add zero traffic");
}

/// Private k-means (the §6 protocol on the same division primitive) is
/// likewise clean under checking and bit-identical to a raw run.
#[test]
fn private_kmeans_runs_clean_under_full_checking() {
    let n = MEMBERS;
    let mut parties = vec![PartyData { points: vec![] }; n];
    for i in 0..12usize {
        let (cx, cy) = if i % 2 == 0 { (100i64, 120i64) } else { (700, 650) };
        parties[i % n].points.push(vec![cx + i as i64, cy - i as i64]);
    }
    let init = vec![vec![0, 0], vec![800, 800]];
    let cfg = KmeansConfig { k: 2, iters: 2, division: DivisionConfig::default() };

    let mut raw = Engine::new(Field::paper(), EngineConfig::new(n).batched());
    let want = private_kmeans(&mut raw, &parties, &init, &cfg);

    let mut chk = checked_engine(n);
    let got = private_kmeans(&mut chk, &parties, &init, &cfg);
    assert_eq!(got.centroids, want.centroids, "centroids drift under checking");
    assert_eq!(got.assignments_counts, want.assignments_counts);
    assert_eq!(got.iterations_run, want.iterations_run);
}

// -------------------------------------------------------------- mutants

/// Mutant training loop that "debugs" by opening the unnormalized total —
/// a classic leak: the value is protocol-internal, not functionality
/// output, and the paper's §4 argument needs it to stay shared.
#[test]
#[should_panic(expected = "not a marked protocol output")]
fn mutant_coordinator_revealing_an_intermediate_is_caught() {
    let mut s = checked_engine(MEMBERS);
    s.declare_phase(SessionPhase::Training);
    let shares = s.input_vec(1, &[10, 20, 30]);
    let total = s.lin_vec(&[(0, shares.iter().map(|&c| (1i128, c)).collect())]);
    let _ = s.reveal_vec(&[total[0]]);
}

/// Mutant inference path that falls back to the stream-order untagged
/// divpub — exactly the regression the compiled-plan bit-identity
/// contract (DESIGN.md §Evaluation Plan) forbids.
#[test]
#[should_panic(expected = "untagged divpub_vec in the Inference phase")]
fn mutant_inference_skipping_tags_is_caught() {
    let mut s = checked_engine(MEMBERS);
    let v = s.input_vec(1, &[640])[0];
    s.declare_phase(SessionPhase::Inference);
    let _ = s.divpub_vec(&[v], 256);
}

/// Mutant scheduler that replays a tick's tag block instead of reserving
/// a fresh one — §3.4 mask reuse, the freshness contract the serve
/// scheduler exists to preserve.
#[test]
#[should_panic(expected = "reused")]
fn mutant_scheduler_replaying_a_tag_block_is_caught() {
    let mut s = checked_engine(MEMBERS);
    let v = s.input_vec(1, &[640, 320])[0];
    let base = s.reserve_tags(2);
    let tick1 = s.divpub_vec_tagged(&[v], 256, &[base]);
    // tick 2 arrives; the mutant reuses tick 1's block
    let _ = s.divpub_vec_tagged(&tick1, 256, &[base]);
}

/// Mutant divpub that invents a tag out of thin air instead of going
/// through `reserve_tags`.
#[test]
#[should_panic(expected = "never reserved")]
fn mutant_divpub_with_invented_tag_is_caught() {
    let mut s = checked_engine(MEMBERS);
    let v = s.input_vec(1, &[640])[0];
    let _ = s.divpub_vec_tagged(&[v], 256, &[77_777]);
}

/// Mutant fleet shard that installs its stripe but skips the
/// `clone_into_session` counter hand-off — its first reservation lands
/// below the stripe base, i.e. inside some other shard's tag space.
#[test]
#[should_panic(expected = "escapes the")]
fn mutant_shard_escaping_its_stripe_is_caught() {
    let mut s = checked_engine(MEMBERS);
    let stripe = TagStripe::new(1, 3);
    s.confine_tags(stripe.base(), stripe.limit());
    // engine counter still at 0: this reservation belongs to stripe 0
    let _ = s.reserve_tags(4);
}

/// Mutant that smuggles a share handle from one session into another —
/// the id numbers mean nothing across share spaces.
#[test]
#[should_panic(expected = "before it was defined")]
fn mutant_mixing_two_sessions_is_caught() {
    let mut a = Engine::new(Field::paper(), EngineConfig::new(MEMBERS));
    // burn a few ids in A so the smuggled handle is unknown to B
    let foreign = a.input_vec(1, &[1, 2, 3, 4, 5])[4];
    let mut b = checked_engine(MEMBERS);
    let local = b.input_vec(1, &[9])[0];
    let _ = b.mul_vec(&[(local, foreign)]);
}

/// Accounting drift at pipeline scale: mis-declare the schedule and the
/// very first vectorized call of real training breaks conservation
/// against the Tables 2–3 closed forms.
#[test]
#[should_panic(expected = "accounting conservation broken")]
fn mutant_accounting_schedule_lie_is_caught_by_real_training() {
    let st = Structure::mini_demo();
    let (counts, rows) = mini_counts(&st, MEMBERS);
    let mut s = CheckedSession::with_sim_accounting(
        Engine::new(Field::paper(), EngineConfig::new(MEMBERS).batched()),
        Schedule::PerOp, // lie: the engine batches
    );
    let _ = train(&mut s, &st, &counts, rows, &TrainConfig::default());
}
