//! `CheckedSession` — the dynamic MPC protocol sanitizer (DESIGN.md
//! §Static analysis).
//!
//! A zero-cost-when-unused wrapper around any [`MpcSession`] backend that
//! validates, on every trait call, the contracts the rest of the crate
//! merely *documents*:
//!
//! * **DataId hygiene** — every id a call consumes must have been defined
//!   by an earlier call on the *same* session (a [`DataId`] from another
//!   session is a different share space), and no id is defined twice.
//! * **Reveal discipline** — only ids explicitly marked as protocol
//!   outputs ([`MpcSession::mark_outputs`]) may be revealed, and each at
//!   most once. The paper's §4 security argument needs every intermediate
//!   to stay shared; an accidental `reveal_vec` of a partial product is a
//!   leak, not a bug you want to find in production.
//! * **Divpub tag freshness** — every tag passed to
//!   [`MpcSession::divpub_vec_tagged`] must come from a
//!   [`MpcSession::reserve_tags`] reservation and is consumed exactly
//!   once (mask reuse would let Bob difference two openings, §3.4). With
//!   [`MpcSession::confine_tags`] installed (the fleet's per-shard
//!   [`TagStripe`](crate::spn::plan::TagStripe) handoff), reservations
//!   escaping the stripe are violations too.
//! * **Phase discipline** — after
//!   [`declare_phase(Inference)`](MpcSession::declare_phase), the
//!   stream-order untagged [`MpcSession::divpub_vec`] is forbidden: the
//!   compiled-plan batch evaluator's bit-identity contract only holds for
//!   tagged truncations (DESIGN.md §Evaluation Plan). Training/k-means
//!   declare `Training` and keep the untagged path.
//! * **Accounting conservation** (Sim backend, opt-in) — the
//!   message/round/exercise delta of every call must equal the closed
//!   forms behind Tables 2–3 (see [`expected_costs`]). A protocol change
//!   that silently alters the accounting trips here, next to the call
//!   that did it, instead of surfacing as a drifted table in a report.
//! * **Flight hygiene** (DESIGN.md §Round scheduler) — staged
//!   [`MpcSession::submit`] runs get the same per-op input/tag checks as
//!   their standalone counterparts, with outputs noted defined
//!   immediately (later same-flight runs may reference them); under Sim
//!   accounting, the whole flight's delta at [`MpcSession::complete`]
//!   must show per-op message/exercise totals (coalescing moves latency,
//!   not traffic) with rounds collapsed to exactly
//!   [`sim_flight_rounds`].
//!
//! The wrapper is pure bookkeeping: it never touches shares, never adds
//! traffic, and calls the inner backend exactly once per operation — so a
//! checked run is *bit-identical* to an unchecked one (asserted by the
//! cross-backend suites compiled with `--features checked-session`). That
//! also makes it oblivious to the backends' internal Montgomery-domain
//! kernels and worker pools (DESIGN.md §Field kernel): only canonical
//! values cross the trait surface, for any `threads` setting.
//! Violations panic with a message starting `CheckedSession violation:` —
//! the negative tests in `tests/checked.rs` pin one panic per class.

use std::collections::HashSet;

use crate::field::Field;
use crate::net::NetStats;

use super::engine::{DataId, Schedule};
use super::flight::{sim_flight_rounds, FlightOp};
use super::session::{MpcSession, SessionPhase};

/// Per-id lifecycle bits in the flag slab (ids are monotone from 1, so a
/// dense `Vec<u8>` indexed by `DataId.0` replaces a hash set).
const DEFINED: u8 = 1;
const REVEALED: u8 = 2;
const OUTPUT: u8 = 4;

macro_rules! violation {
    ($($t:tt)*) => {
        panic!("CheckedSession violation: {}", format_args!($($t)*))
    };
}

/// Which closed-form cost row a primitive is checked against.
#[derive(Clone, Copy, Debug)]
enum Op {
    /// `input_vec` / `reveal_vec`: star exchange — (3n−1, 3) per slot.
    Star,
    /// `lin_vec`: overhead + finish only — (2n, 2) per slot.
    Lin,
    /// `mul_vec` / `sq2pq_vec`: full mesh — (n²+n, 3) per slot.
    Mesh,
    /// `divpub_vec(_tagged)`: three star phases — (5n−3, 5) per slot.
    Divpub,
    /// `constant` / `reserve_tags` / the hooks: no traffic at all.
    Local,
}

/// Per-exercise message/round closed forms for an n-member session —
/// the Tables 2–3 accounting the Sim engine implements (engine.rs is the
/// normative source; these are its per-slot totals inclusive of the
/// Appendix-A schedule broadcast and "finished" collection).
///
/// Returns `(messages, rounds)` for ONE exercise slot; a k-wide vector op
/// consumes k slots under `Schedule::PerOp` and 1 under
/// `Schedule::Batched`, and every non-local slot is one scheduled
/// exercise.
fn expected_costs(op: Op, n: u64) -> (u64, u64) {
    match op {
        Op::Star => (3 * n - 1, 3),
        Op::Lin => (2 * n, 2),
        Op::Mesh => (n * n + n, 3),
        Op::Divpub => (5 * n - 3, 5),
        Op::Local => (0, 0),
    }
}

/// Opt-in conservation checking against the Sim backend's accounting.
struct SimAccounting {
    n: u64,
    schedule: Schedule,
}

/// Accounting expectations accumulated across one staged flight (only
/// tracked under Sim accounting): the stats snapshot at the first
/// `submit`, per-op message/exercise sums, and which run kinds are
/// present — the coalesced round closed form depends only on the latter.
struct FlightChk {
    before: NetStats,
    exp_msgs: u64,
    exp_slots: u64,
    has_mul: bool,
    has_divpub: bool,
}

/// The sanitizing wrapper. Construct with [`CheckedSession::new`] (any
/// backend) or [`CheckedSession::with_sim_accounting`] (Sim backend, adds
/// the conservation check), then use it wherever an [`MpcSession`] goes —
/// it implements the trait by validating and delegating.
pub struct CheckedSession<S: MpcSession> {
    inner: S,
    /// Lifecycle flags indexed by `DataId.0`.
    flags: Vec<u8>,
    /// Monotone `[start, end)` tag reservations returned by the inner
    /// session (the trait contract makes them disjoint and sorted).
    reserved: Vec<(u64, u64)>,
    /// Tags already consumed by a tagged divpub.
    used_tags: HashSet<u64>,
    phase: SessionPhase,
    /// `Some((lo, hi))` once [`MpcSession::confine_tags`] was installed.
    stripe: Option<(u64, u64)>,
    accounting: Option<SimAccounting>,
    /// Open flight being staged via `submit` (Sim accounting only).
    flight: Option<FlightChk>,
}

impl<S: MpcSession> CheckedSession<S> {
    /// Wrap `inner` with the contract checks (no accounting conservation —
    /// correct for any backend, including TCP whose frame counts follow a
    /// different model).
    pub fn new(inner: S) -> Self {
        CheckedSession {
            inner,
            flags: Vec::new(),
            reserved: Vec::new(),
            used_tags: HashSet::new(),
            phase: SessionPhase::Training,
            stripe: None,
            accounting: None,
            flight: None,
        }
    }

    /// Wrap a **Sim** session and additionally check that every call's
    /// message/round/exercise delta matches the Tables 2–3 closed forms
    /// for `schedule`. The schedule must mirror the engine's
    /// (`EngineConfig::schedule`); if the run switches schedules mid-way,
    /// mirror it with [`CheckedSession::set_sim_schedule`].
    pub fn with_sim_accounting(inner: S, schedule: Schedule) -> Self {
        let n = inner.n() as u64;
        let mut s = CheckedSession::new(inner);
        s.accounting = Some(SimAccounting { n, schedule });
        s
    }

    /// Keep the conservation checker in sync after the caller flips the
    /// engine's schedule between runs. No-op without accounting.
    pub fn set_sim_schedule(&mut self, schedule: Schedule) {
        if let Some(acc) = &mut self.accounting {
            acc.schedule = schedule;
        }
    }

    /// The wrapped backend (read-only — e.g. `peek` diagnostics on a Sim
    /// session).
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// The wrapped backend, mutable. Calls made directly on it bypass the
    /// checks — reserved for out-of-band configuration (e.g. switching
    /// `cfg.schedule` between runs), not for protocol operations.
    pub fn inner_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    /// Unwrap, discarding the bookkeeping (e.g. to call a backend-specific
    /// `shutdown`).
    pub fn into_inner(self) -> S {
        self.inner
    }

    fn flag(&self, id: DataId) -> u8 {
        self.flags.get(id.0 as usize).copied().unwrap_or(0)
    }

    fn flag_mut(&mut self, id: DataId) -> &mut u8 {
        let idx = id.0 as usize;
        if idx >= self.flags.len() {
            self.flags.resize(idx + 1, 0);
        }
        &mut self.flags[idx]
    }

    /// Record ids a call returned. Backends allocate monotonically, so a
    /// re-defined id means the caller mixed sessions.
    fn note_defined(&mut self, ids: &[DataId], op: &str) {
        for &id in ids {
            let f = self.flag_mut(id);
            if *f & DEFINED != 0 {
                violation!("{op} returned {id:?} which is already defined (mixed sessions?)");
            }
            *f |= DEFINED;
        }
    }

    /// Every id a call consumes must be live in this session.
    fn check_inputs<I: IntoIterator<Item = DataId>>(&self, ids: I, op: &str) {
        for id in ids {
            if self.flag(id) & DEFINED == 0 {
                violation!("{op} uses {id:?} before it was defined in this session");
            }
        }
    }

    /// Is `tag` inside some reservation handed out by this session?
    fn tag_reserved(&self, tag: u64) -> bool {
        // Reservations are sorted by start (monotone counter): binary
        // search for the last range starting at or before `tag`.
        let i = self.reserved.partition_point(|r| r.0 <= tag);
        i > 0 && tag < self.reserved[i - 1].1
    }

    /// The §3.4 freshness contract for one tagged divpub's tag slice:
    /// reserved, inside the stripe when confined, never used before.
    /// Consumes the tags (marks them used).
    fn check_fresh_tags(&mut self, tags: &[u64]) {
        for &t in tags {
            if !self.tag_reserved(t) {
                violation!("divpub tag {t} was never reserved via reserve_tags");
            }
            if let Some((lo, hi)) = self.stripe {
                if t < lo || t >= hi {
                    violation!("divpub tag {t} escapes the confined stripe [{lo}, {hi})");
                }
            }
            if !self.used_tags.insert(t) {
                violation!(
                    "divpub tag {t} reused — mask reuse lets Bob difference two \
                     openings (§3.4 freshness contract)"
                );
            }
        }
    }

    /// Run `call` on the inner session; with Sim accounting enabled,
    /// check the stats delta against the closed form for `op` at vector
    /// width `k`. Degenerate widths/sessions (k = 0 under PerOp costs
    /// nothing; n < 2 collapses star/mesh rounds) skip the non-local
    /// rows rather than special-casing the formulas.
    fn counted<R>(&mut self, op: Op, k: usize, call: impl FnOnce(&mut S) -> R) -> R {
        let check = match (&self.accounting, op) {
            (None, _) => false,
            (Some(_), Op::Local) => true,
            (Some(acc), _) => k > 0 && acc.n >= 2,
        };
        if !check {
            return call(&mut self.inner);
        }
        let before = self.inner.stats();
        let out = call(&mut self.inner);
        let d = self.inner.stats().delta_since(&before);
        let acc = self.accounting.as_ref().unwrap();
        let slots = match (op, acc.schedule) {
            (Op::Local, _) => 0,
            (_, Schedule::PerOp) => k as u64,
            (_, Schedule::Batched) => 1,
        };
        let (m1, r1) = expected_costs(op, acc.n);
        let (em, er) = (m1 * slots, r1 * slots);
        if d.messages != em || d.rounds != er || d.exercises != slots {
            violation!(
                "accounting conservation broken for {op:?} (k={k}, n={}, {:?}): \
                 expected {em} msgs / {er} rounds / {slots} exercises, \
                 got {} / {} / {}",
                acc.n,
                acc.schedule,
                d.messages,
                d.rounds,
                d.exercises,
            );
        }
        out
    }
}

impl<S: MpcSession> MpcSession for CheckedSession<S> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn field(&self) -> Field {
        self.inner.field()
    }

    fn input_vec(&mut self, owner: usize, values: &[u128]) -> Vec<DataId> {
        let ids = self.counted(Op::Star, values.len(), |s| s.input_vec(owner, values));
        self.note_defined(&ids, "input_vec");
        ids
    }

    fn constant(&mut self, c: u128) -> DataId {
        let id = self.counted(Op::Local, 1, |s| s.constant(c));
        self.note_defined(&[id], "constant");
        id
    }

    fn lin_vec(&mut self, ops: &[(i128, Vec<(i128, DataId)>)]) -> Vec<DataId> {
        self.check_inputs(
            ops.iter().flat_map(|(_, terms)| terms.iter().map(|&(_, a)| a)),
            "lin_vec",
        );
        let ids = self.counted(Op::Lin, ops.len(), |s| s.lin_vec(ops));
        self.note_defined(&ids, "lin_vec");
        ids
    }

    fn mul_vec(&mut self, pairs: &[(DataId, DataId)]) -> Vec<DataId> {
        self.check_inputs(pairs.iter().flat_map(|&(a, b)| [a, b]), "mul_vec");
        let ids = self.counted(Op::Mesh, pairs.len(), |s| s.mul_vec(pairs));
        self.note_defined(&ids, "mul_vec");
        ids
    }

    fn divpub_vec(&mut self, us: &[DataId], d: u128) -> Vec<DataId> {
        if self.phase == SessionPhase::Inference {
            violation!(
                "untagged divpub_vec in the Inference phase — the compiled-plan \
                 bit-identity contract requires divpub_vec_tagged with fresh tags \
                 (DESIGN.md §Evaluation Plan)"
            );
        }
        self.check_inputs(us.iter().copied(), "divpub_vec");
        let ids = self.counted(Op::Divpub, us.len(), |s| s.divpub_vec(us, d));
        self.note_defined(&ids, "divpub_vec");
        ids
    }

    fn divpub_vec_tagged(&mut self, us: &[DataId], d: u128, tags: &[u64]) -> Vec<DataId> {
        self.check_inputs(us.iter().copied(), "divpub_vec_tagged");
        self.check_fresh_tags(tags);
        let ids = self.counted(Op::Divpub, us.len(), |s| s.divpub_vec_tagged(us, d, tags));
        self.note_defined(&ids, "divpub_vec_tagged");
        ids
    }

    fn submit(&mut self, op: FlightOp) -> Vec<DataId> {
        // Same validation as the standalone calls; outputs are noted
        // defined immediately below, so a later same-flight run may
        // reference an earlier run's outputs (per-flight DataId hygiene).
        let (cost_op, k) = match &op {
            FlightOp::Mul(pairs) => {
                self.check_inputs(pairs.iter().flat_map(|&(a, b)| [a, b]), "submit(Mul)");
                (Op::Mesh, pairs.len())
            }
            FlightOp::Lin(ops) => {
                self.check_inputs(
                    ops.iter().flat_map(|(_, terms)| terms.iter().map(|&(_, a)| a)),
                    "submit(Lin)",
                );
                (Op::Lin, ops.len())
            }
            FlightOp::DivpubTagged { us, tags, .. } => {
                self.check_inputs(us.iter().copied(), "submit(DivpubTagged)");
                self.check_fresh_tags(tags);
                (Op::Divpub, us.len())
            }
        };
        if let Some(acc) = &self.accounting {
            if acc.n >= 2 && k > 0 {
                let slots = match acc.schedule {
                    Schedule::PerOp => k as u64,
                    Schedule::Batched => 1,
                };
                let (m1, _) = expected_costs(cost_op, acc.n);
                if self.flight.is_none() {
                    self.flight = Some(FlightChk {
                        before: self.inner.stats(),
                        exp_msgs: 0,
                        exp_slots: 0,
                        has_mul: false,
                        has_divpub: false,
                    });
                }
                let fl = self.flight.as_mut().expect("just installed");
                fl.exp_msgs += m1 * slots;
                fl.exp_slots += slots;
                match cost_op {
                    Op::Mesh => fl.has_mul = true,
                    Op::Divpub => fl.has_divpub = true,
                    _ => {}
                }
            }
        }
        let ids = self.inner.submit(op);
        self.note_defined(&ids, "submit");
        ids
    }

    fn complete(&mut self) {
        self.inner.complete();
        let Some(fl) = self.flight.take() else { return };
        // Conservation for the whole flight: per-op message/exercise
        // totals survive coalescing; rounds collapse to the closed form.
        let d = self.inner.stats().delta_since(&fl.before);
        let er = sim_flight_rounds(fl.has_mul, fl.has_divpub);
        if d.messages != fl.exp_msgs || d.rounds != er || d.exercises != fl.exp_slots {
            violation!(
                "accounting conservation broken for a flight (mul={}, divpub={}): \
                 expected {} msgs / {er} rounds / {} exercises, got {} / {} / {}",
                fl.has_mul,
                fl.has_divpub,
                fl.exp_msgs,
                fl.exp_slots,
                d.messages,
                d.rounds,
                d.exercises,
            );
        }
    }

    fn reserve_tags(&mut self, count: u64) -> u64 {
        let base = self.counted(Op::Local, 0, |s| s.reserve_tags(count));
        if count > 0 {
            if let Some((lo, hi)) = self.stripe {
                let escapes = match base.checked_add(count) {
                    Some(end) => base < lo || end > hi,
                    None => true,
                };
                if escapes {
                    violation!(
                        "tag reservation [{base}, {base}+{count}) escapes the \
                         confined stripe [{lo}, {hi})"
                    );
                }
            }
            self.reserved.push((base, base + count));
        }
        base
    }

    fn reveal_vec(&mut self, ids: &[DataId]) -> Vec<u128> {
        for &id in ids {
            let f = self.flag(id);
            if f & DEFINED == 0 {
                violation!("reveal_vec of {id:?} which was never defined in this session");
            }
            if f & OUTPUT == 0 {
                violation!(
                    "reveal_vec of {id:?} which is not a marked protocol output — \
                     intermediates must stay shared (paper §4); call mark_outputs \
                     first if this value is genuinely part of the functionality"
                );
            }
            if f & REVEALED != 0 {
                violation!("double reveal of {id:?}");
            }
            *self.flag_mut(id) |= REVEALED;
        }
        self.counted(Op::Star, ids.len(), |s| s.reveal_vec(ids))
    }

    fn sq2pq_vec(&mut self, local_values: &[Vec<u128>]) -> Vec<DataId> {
        let k = local_values.first().map_or(0, |v| v.len());
        let ids = self.counted(Op::Mesh, k, |s| s.sq2pq_vec(local_values));
        self.note_defined(&ids, "sq2pq_vec");
        ids
    }

    fn stats(&self) -> NetStats {
        self.inner.stats()
    }

    fn link_states(&self) -> Vec<crate::net::MemberLinkState> {
        // Pure observation — no shares, no traffic, nothing to validate.
        self.inner.link_states()
    }

    fn declare_phase(&mut self, phase: SessionPhase) {
        self.phase = phase;
        self.counted(Op::Local, 0, |s| s.declare_phase(phase));
    }

    fn mark_outputs(&mut self, ids: &[DataId]) {
        for &id in ids {
            if self.flag(id) & DEFINED == 0 {
                violation!("mark_outputs of {id:?} which was never defined in this session");
            }
            *self.flag_mut(id) |= OUTPUT;
        }
        self.counted(Op::Local, 0, |s| s.mark_outputs(ids));
    }

    fn confine_tags(&mut self, lo: u64, hi: u64) {
        if lo > hi {
            violation!("confine_tags with an inverted stripe [{lo}, {hi})");
        }
        self.stripe = Some((lo, hi));
        self.counted(Op::Local, 0, |s| s.confine_tags(lo, hi));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig};

    fn checked(n: usize) -> CheckedSession<Engine> {
        let cfg = EngineConfig::new(n);
        CheckedSession::with_sim_accounting(Engine::new(Field::paper(), cfg), cfg.schedule)
    }

    /// A clean training-shaped pipeline passes every check under both
    /// schedules — including the conservation rows for every primitive,
    /// which pins the closed forms to the engine's actual accounting.
    #[test]
    fn clean_pipeline_passes_all_checks() {
        for batched in [false, true] {
            let mut cfg = EngineConfig::new(5);
            if batched {
                cfg = cfg.batched();
            }
            let mut s = CheckedSession::with_sim_accounting(
                Engine::new(Field::paper(), cfg),
                cfg.schedule,
            );
            s.declare_phase(SessionPhase::Training);
            let xs = s.input_vec(1, &[40, 50, 60]);
            let ys = s.input_vec(2, &[7, 8, 9]);
            let c = s.constant(3);
            let sums = s.lin_vec(&[(1, vec![(2, xs[0]), (1, c)])]);
            let pairs: Vec<_> = xs.iter().copied().zip(ys.iter().copied()).collect();
            let prods = s.mul_vec(&pairs);
            let qs = s.divpub_vec(&prods, 16); // Training: untagged OK
            let t0 = s.reserve_tags(3);
            let tagged = s.divpub_vec_tagged(&prods, 16, &[t0, t0 + 1, t0 + 2]);
            let locals: Vec<Vec<u128>> = (0..5).map(|i| vec![i as u128 + 1]).collect();
            let sq = s.sq2pq_vec(&locals);
            let mut outs = vec![sums[0], sq[0]];
            outs.extend(&qs);
            outs.extend(&tagged);
            s.mark_outputs(&outs);
            let vals = s.reveal_vec(&outs);
            assert_eq!(vals[1], 1 + 2 + 3 + 4 + 5);
            let got = vals[2] as i128;
            assert!((got - (40 * 7) / 16).abs() <= 1, "divpub is ±1-exact, got {got}");
        }
    }

    /// Checked and raw runs of the same call sequence are bit-identical,
    /// in values and in accounting.
    #[test]
    fn checked_run_is_bit_identical_to_raw() {
        let mut raw = Engine::new(Field::paper(), EngineConfig::new(5));
        let a = raw.input(1, &[123, 456])[0];
        let b = raw.input(2, &[9, 9])[0];
        let p = raw.mul(a, b);
        let q = raw.divpub(p, 256);
        let raw_val = raw.reveal(q);
        let raw_stats = raw.net.stats;

        let mut chk = checked(5);
        let a = chk.input_vec(1, &[123, 456])[0];
        let b = chk.input_vec(2, &[9, 9])[0];
        let p = chk.mul(a, b);
        let q = chk.divpub(p, 256);
        chk.mark_outputs(&[q]);
        let chk_val = chk.reveal(q);
        assert_eq!(chk_val, raw_val, "sanitizer must not change values");
        assert_eq!(chk.stats(), raw_stats, "sanitizer must not change accounting");
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn use_before_define_trips() {
        let mut s = checked(3);
        let ghost = DataId(999);
        let _ = s.mul_vec(&[(ghost, ghost)]);
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn reveal_of_unmarked_intermediate_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[5])[0];
        let _ = s.reveal_vec(&[a]); // never marked as an output
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn double_reveal_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[5])[0];
        s.mark_outputs(&[a]);
        let _ = s.reveal_vec(&[a]);
        let _ = s.reveal_vec(&[a]);
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn unreserved_tag_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[64])[0];
        let _ = s.divpub_vec_tagged(&[a], 16, &[1234]); // never reserved
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn tag_reuse_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[64])[0];
        let t = s.reserve_tags(1);
        let v = s.divpub_vec_tagged(&[a], 16, &[t]);
        let _ = s.divpub_vec_tagged(&v, 16, &[t]); // same tag again
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn untagged_divpub_in_inference_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[64])[0];
        s.declare_phase(SessionPhase::Inference);
        let _ = s.divpub_vec(&[a], 16);
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn stripe_escape_trips() {
        let mut s = checked(3);
        s.confine_tags(1000, 2000);
        // The engine's monotone counter starts at 0 — the very first
        // reservation lands below the stripe.
        let _ = s.reserve_tags(4);
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn accounting_mismatch_trips() {
        // Tell the checker the engine is PerOp while it actually batches:
        // a width-2 mul then has 1 exercise where PerOp predicts 2.
        let mut s = CheckedSession::with_sim_accounting(
            Engine::new(Field::paper(), EngineConfig::new(3).batched()),
            Schedule::PerOp,
        );
        // Width-1 calls cost the same under both schedules, so these pass…
        let a = s.input_vec(1, &[3])[0];
        let b = s.input_vec(2, &[4])[0];
        // …and the first genuinely vectorized call exposes the lie: one
        // batched exercise where PerOp predicts two.
        let _ = s.mul_vec(&[(a, b), (b, a)]);
    }

    #[test]
    fn checked_flight_passes_and_collapses_rounds() {
        let mut s = checked(5);
        s.declare_phase(SessionPhase::Inference);
        let a = s.input_vec(1, &[1000, 2000]);
        let b = s.input_vec(2, &[3, 5]);
        let t0 = s.reserve_tags(2);
        let before = s.stats();
        let prods = s.submit(FlightOp::Mul(vec![(a[0], b[0]), (a[1], b[1])]));
        let qs = s.submit(FlightOp::DivpubTagged {
            us: prods,
            d: 256,
            tags: vec![t0, t0 + 1],
        });
        s.complete();
        let d = s.stats().delta_since(&before);
        assert_eq!(d.rounds, sim_flight_rounds(true, true));
        s.mark_outputs(&qs);
        let vals = s.reveal_vec(&qs);
        let q0 = s.inner().field().to_i128(vals[0]);
        assert!((q0 - 1000 * 3 / 256).abs() <= 1, "divpub is ±1-exact, got {q0}");
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn flight_tag_reuse_trips() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[64, 128]);
        let t = s.reserve_tags(1);
        let _ = s.submit(FlightOp::DivpubTagged {
            us: vec![a[0], a[1]],
            d: 16,
            tags: vec![t, t], // same tag twice within one staged run
        });
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn flight_use_before_define_trips() {
        let mut s = checked(3);
        let ghost = DataId(999);
        let _ = s.submit(FlightOp::Mul(vec![(ghost, ghost)]));
    }

    /// The respawn handoff: a replacement session confined to a *later
    /// generation* of the same shard may only reserve inside its
    /// sub-stripe — a reservation reaching back into the dead
    /// incarnation's generation-0 tags is a violation, which is the
    /// sanitizer-level statement of the "burned tags are never reused
    /// across generations" contract (DESIGN.md §Fleet).
    #[test]
    fn respawned_generation_cannot_reach_burned_tags() {
        use crate::spn::plan::TagStripe;
        let gen0 = TagStripe::new(0, 2);
        let gen1 = TagStripe::generation(0, 2, 1);
        // gen 1 of shard 0 starts exactly where gen 0 ends
        assert_eq!(gen0.limit(), gen1.base());

        // a fresh replacement session confined to gen 1 reserves fine…
        let mut s = checked(3);
        let a = s.input_vec(1, &[640])[0];
        let burn = s.reserve_tags(gen1.base());
        assert_eq!(burn, 0, "replacement sessions start with a fresh tag space");
        s.confine_tags(gen1.base(), gen1.limit());
        let t = s.reserve_tags(1);
        assert_eq!(t, gen1.base());
        let _ = s.divpub_vec_tagged(&[a], 16, &[t]);
    }

    #[test]
    #[should_panic(expected = "CheckedSession violation")]
    fn respawned_generation_stripe_escape_trips() {
        use crate::spn::plan::TagStripe;
        let gen1 = TagStripe::generation(0, 2, 1);
        let mut s = checked(3);
        // counter only burned up to *inside* gen 0: the first reservation
        // after confinement lands below gen 1's base and must trip
        let _ = s.reserve_tags(gen1.base() - 10);
        s.confine_tags(gen1.base(), gen1.limit());
        let _ = s.reserve_tags(4);
    }

    #[test]
    fn reservations_inside_stripe_pass() {
        let mut s = checked(3);
        let a = s.input_vec(1, &[640])[0];
        // Burn the counter up to the stripe base (the clone_into_session
        // handoff), then confine and reserve inside.
        let start = s.reserve_tags(1000);
        assert_eq!(start, 0);
        s.confine_tags(1000, 2000);
        let t = s.reserve_tags(2);
        assert_eq!(t, 1000);
        let _ = s.divpub_vec_tagged(&[a], 16, &[t]);
    }
}
