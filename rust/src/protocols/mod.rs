//! The paper's secure-computation protocols (§3.4 + §4).
//!
//! * [`engine`]   — the Manager/Member exercise engine: per-member share
//!   stores, the exercise vocabulary of Appendix A (input, linear ops,
//!   multiplication, reveal, division-by-public), exact message accounting
//!   through [`crate::net::SimNet`].
//! * [`divpub`]   — the paper's novel randomized division-by-public-`d`
//!   (§3.4, the Alice/Bob trick), as pure party-local pieces.
//! * [`newton`]   — the progressive-precision Newton inverse `[d·e/b]`
//!   starting from u=1 (the paper's headline protocol).
//! * [`division`] — the full private division `⌊Σnum/Σden⌋·d` pipeline
//!   (Eq. 3): numerator×inverse, then secure truncation.
//! * [`session`]  — the transport-agnostic [`MpcSession`] trait all
//!   protocol code is generic over: [`SimSession`] (= the engine, with the
//!   paper-exact accounting) or the real-socket
//!   [`crate::net::tcp_session::TcpSession`].
//! * [`checked`]  — the [`CheckedSession`] sanitizer: wraps any backend
//!   and enforces the tag-freshness, reveal, phase and accounting
//!   contracts at runtime (DESIGN.md §Static analysis).
//! * [`flight`]   — the multi-op flight surface of the pipelined round
//!   engine ([`MpcSession::submit`]/[`MpcSession::complete`]): coalesces
//!   the traffic of independent inference steps into one framed message
//!   per member per round (DESIGN.md §Round scheduler).

pub mod checked;
pub mod divpub;
pub mod division;
pub mod engine;
pub mod flight;
pub mod newton;
pub mod session;

pub use checked::CheckedSession;
pub use division::DivisionConfig;
pub use engine::{DataId, Engine, EngineConfig, Schedule};
pub use flight::{sim_flight_rounds, FlightOp, FlightOpKind};
pub use session::{MpcSession, SessionPhase, SimSession};
