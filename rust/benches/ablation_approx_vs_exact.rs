//! §3.2 vs §3.4 ablation: accuracy and cost of the approximate path as the
//! horizontal partition skews away from iid.
//!
//! The paper includes §3.2 "just for the sake of providing the reader with
//! some numerical example" because the iid assumption "is unrealistic in
//! practice" — this bench quantifies that: weight error of the approximate
//! protocol grows with shard skew while the exact protocol stays at
//! quantization error, at a fraction of the cost.

mod common;

use spn_mpc::coordinator::approx::{approx_divide, LocalFraction};
use spn_mpc::coordinator::train::{peek_weights, train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::net::NetConfig;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::spn::{eval, learn};

fn main() {
    if !common::guard("ablation_approx_vs_exact", &["nltcs"]) {
        return;
    }
    let st = common::load("nltcs").expect("guarded above");
    let members = 5;
    let d = 256u128;
    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, 10_000, 42);
    let global = eval::counts(&st, &data);
    let oracle = learn::ml_weights_fixed(&st, &global, d);

    let mut rows = Vec::new();
    let mut errs = Vec::new();
    for skew in [0.2f64, 0.5, 0.8, 0.95] {
        let shards = if skew <= 0.2 {
            datasets::partition(&data, members)
        } else {
            datasets::partition_skewed(&data, members, skew)
        };
        let shard_counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();

        // --- approximate path (§3.2): local fractions per param -------------
        let mut params_in = Vec::new();
        for k in 0..st.num_sum_edges {
            params_in.push(
                (0..members)
                    .map(|i| LocalFraction {
                        num: shard_counts[i][st.param_num[k]],
                        den: shard_counts[i][st.param_den[k]],
                    })
                    .collect::<Vec<_>>(),
            );
        }
        let f = Field::paper();
        let approx = approx_divide(&f, &params_in, d, NetConfig::default(), 1);

        // --- exact path (§3.4) ------------------------------------------------
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(members).batched());
        let (model, report) =
            train(&mut eng, &st, &shard_counts, 10_000, &TrainConfig::default());
        let exact = peek_weights(&eng, &model);

        let mut approx_err = 0.0f64;
        let mut exact_err = 0.0f64;
        for k in 0..st.num_sum_edges {
            approx_err = approx_err.max((approx.revealed[k] as f64 - oracle[k] as f64).abs());
            exact_err = exact_err.max((exact[k] as f64 - oracle[k] as f64).abs());
        }
        errs.push((skew, approx_err, exact_err));
        rows.push(vec![
            format!("{skew:.2}"),
            format!("{:.1}", approx_err),
            format!("{:.1}", exact_err),
            format!("{}", approx.stats.messages),
            format!("{}", report.stats.messages),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Approximate (§3.2) vs exact (§3.4), nltcs, 5 members — max weight error (d=256 units)",
            &["skew", "approx err", "exact err", "approx msgs", "exact msgs"],
            &rows
        )
    );
    // exact stays at quantization error regardless of skew
    for &(_, _, e) in &errs {
        assert!(e <= 4.0, "exact path must be skew-invariant");
    }
    // approximate degrades with skew
    assert!(
        errs.last().unwrap().1 > errs.first().unwrap().1 + 2.0,
        "approximate error must grow with skew: {errs:?}"
    );
    println!("ablation_approx_vs_exact OK");
}
