//! Division of a shared value by a *public* divisor `d` (§3.4).
//!
//! The paper's novel trick replaces the integer-share conversion of
//! Algesheimer–Camenisch–Shoup [14] with a 3-round randomized protocol:
//!
//! 1. Alice samples `r ← [0, 2^ρ)`, sets `q = r mod d`, deals `[r], [q]`.
//! 2. Everyone computes `[z'] = [u] + [r]` and opens `z'` **to Bob only**.
//! 3. Bob deals `[w]` with `w = z' mod d`.
//! 4. Locally: `[v] = ([u] + [q] − [w]) · d⁻¹ (mod p)`.
//!
//! Then `u + q − w ≡ 0 (mod d)` and `u − d ≤ v·d ≤ u + d`, so `v ∈
//! [u/d − 1, u/d + 1]` — an approximate integer division with ±1 error.
//!
//! **Erratum.** The paper's step 4 prints `[u] − [q] + [w]`, whose residue
//! mod d is `2(u mod d)`, not 0; the sign must be the one above (their own
//! correctness argument `u mod d + r mod d − (r+u) mod d = 0` matches the
//! corrected sign).  `tests::paper_identity_requires_sign_flip` demonstrates
//! both.
//!
//! **Security.** The only opened value is `z' = u + r`, uniform over an
//! interval of width `2^ρ ≫ u`; Bob learns nothing about `u` unless
//! `z' ∉ [d, 2^ρ)`, which happens with probability ≤ `d/2^ρ` (ρ = 64 by
//! default). There must also be no wraparound mod p: `u + 2^ρ < p` — with
//! `u ≤ 2^62`, `ρ = 64` and `p ≈ 2^73.7` this always holds.
//!
//! **Domain boundaries** (DESIGN.md §Field kernel): both session backends
//! run step 4's `· d⁻¹` as a Montgomery multiply against a memoized
//! mont-domain `d⁻¹·R mod p`, which yields the *canonical* quotient share
//! directly — every value this module's helpers see or produce (masks,
//! `z'` openings, quotients) is canonical; nothing Montgomery-encoded ever
//! reaches a wire frame or a reveal.

use crate::rng::{Prng, Rng};

/// Alice's mask: uniform in `[0, 2^ρ)`.
pub fn sample_r<R: Rng + ?Sized>(rng: &mut R, rho_bits: u32) -> u128 {
    assert!(rho_bits > 0 && rho_bits < 128);
    rng.next_u128() & ((1u128 << rho_bits) - 1)
}

/// Tag-derived mask for the *order-invariant* divpub variant
/// (`MpcSession::divpub_vec_tagged`): `r = PRF(seed, tag)` instead of the
/// next draw of Alice's running RNG stream.
///
/// The ±1 rounding of each divpub output is a function of `r` (the carry
/// `[u mod d + r mod d ≥ d]`), so drawing `r` from a stream makes revealed
/// values depend on global evaluation order. Deriving it per tag makes the
/// same logical element yield the same output under any batching — the
/// invariance the compiled-plan batch evaluator is built on.
///
/// Security is unchanged in kind: `r` is still a fresh pseudo-random mask
/// per element *as long as tags are never reused* (reuse would hand Bob two
/// openings `u₁+r, u₂+r` and leak `u₁−u₂`); tag allocation goes through
/// the session's monotone `reserve_tags`. Like every mask in this crate the
/// PRF is the statistical xoshiro generator (see `rng` module security
/// note); a deployment swaps in a keyed CSPRNG behind the same seam.
pub fn tagged_r(seed: u64, tag: u64, rho_bits: u32) -> u128 {
    let mut rng =
        Prng::seed_from_u64(seed ^ 0x5851_F42D_4C95_7F2D ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sample_r(&mut rng, rho_bits)
}

/// Batched [`tagged_r`]: one streamed derivation for a whole tag slice,
/// appending one mask per tag to `out`. **Bit-identical to the scalar
/// loop** `for t in tags { out.push(tagged_r(seed, t, rho_bits)) }` — each
/// mask is still an independent single-draw PRF evaluation keyed by its
/// own tag (tags in a vectorized divpub are strided across queries, not
/// consecutive, so there is no whole-range shortcut to exploit); batching
/// hoists the per-call assertion and lets Alice derive a divpub's k masks
/// in one pass over the reserved range instead of k call dispatches.
pub fn tagged_r_many(seed: u64, tags: &[u64], rho_bits: u32, out: &mut Vec<u128>) {
    assert!(rho_bits > 0 && rho_bits < 128);
    let mask = (1u128 << rho_bits) - 1;
    out.reserve(tags.len());
    for &tag in tags {
        let mut rng = Prng::seed_from_u64(
            seed ^ 0x5851_F42D_4C95_7F2D ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        out.push(rng.next_u128() & mask);
    }
}

/// The plaintext mirror of the whole protocol (integers, no shares): given
/// `u`, `d` and Alice/Bob randomness, return the protocol's output `v`.
/// Used by unit tests and by the Newton plaintext mirror.
pub fn divpub_plain(u: u128, d: u128, r: u128) -> i128 {
    let q = (r % d) as i128;
    let z = u + r;
    let w = (z % d) as i128;
    let num = u as i128 + q - w;
    debug_assert_eq!(num.rem_euclid(d as i128), 0);
    num / d as i128
}

/// Worst-case output bounds: `v ∈ [u/d - 1, u/d + 1]`.
pub fn divpub_error_bound() -> i128 {
    1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Prng, Rng};

    #[test]
    fn plain_close_to_true_division() {
        let mut rng = Prng::seed_from_u64(1);
        for _ in 0..2000 {
            let u = rng.gen_bits(40);
            let d = 1 + rng.gen_bits(20);
            let r = sample_r(&mut rng, 64);
            let v = divpub_plain(u, d, r);
            let want = (u / d) as i128;
            assert!((v - want).abs() <= divpub_error_bound(), "u={u} d={d} v={v}");
        }
    }

    #[test]
    fn paper_identity_requires_sign_flip() {
        // With the paper's printed sign ([u] - [q] + [w]) the residue mod d
        // is 2(u mod d) ≠ 0 in general; with the corrected sign it is 0.
        let (u, d, r) = (1001u128, 256u128, 999_983u128);
        let q = (r % d) as i128;
        let w = ((u + r) % d) as i128;
        let corrected = u as i128 + q - w;
        let printed = u as i128 - q + w;
        assert_eq!(corrected.rem_euclid(d as i128), 0);
        assert_ne!(printed.rem_euclid(d as i128), 0);
        assert_eq!(printed.rem_euclid(d as i128), (2 * (u % d) as i128) % d as i128);
    }

    #[test]
    fn exact_when_u_multiple_of_d() {
        let mut rng = Prng::seed_from_u64(2);
        for _ in 0..200 {
            let d = 1 + rng.gen_range_u128(999);
            let k = rng.gen_bits(30);
            let u = k * d;
            let r = sample_r(&mut rng, 64);
            // u multiple of d: still ±1 (masking may carry), but centered.
            assert!((divpub_plain(u, d, r) - k as i128).abs() <= 1);
        }
    }

    #[test]
    fn tagged_r_is_a_function_of_seed_and_tag_only() {
        // Same (seed, tag) → same mask regardless of when/where it's drawn;
        // different tags → (overwhelmingly) different masks.
        assert_eq!(tagged_r(0xC0FFEE, 42, 64), tagged_r(0xC0FFEE, 42, 64));
        assert_ne!(tagged_r(0xC0FFEE, 42, 64), tagged_r(0xC0FFEE, 43, 64));
        assert_ne!(tagged_r(0xC0FFEE, 42, 64), tagged_r(0xC0FFED, 42, 64));
        for tag in 0..200 {
            assert!(tagged_r(1, tag, 64) < 1u128 << 64);
        }
    }

    #[test]
    fn tagged_r_many_is_bit_identical_to_scalar_loop() {
        // The batched derivation is an optimization seam only: every mask
        // must equal the scalar tagged_r of its tag, for strided (batch-
        // evaluator-shaped) and arbitrary tag slices alike.
        let strided: Vec<u64> = (0..4).flat_map(|b| (0..3).map(move |o| b * 7 + o)).collect();
        let arbitrary = [0u64, u64::MAX, 1, 42, 42, 1 << 63];
        for (seed, rho) in [(0xC0FFEEu64, 64u32), (1, 8), (u64::MAX, 80)] {
            for tags in [strided.as_slice(), arbitrary.as_slice()] {
                let mut got = Vec::new();
                tagged_r_many(seed, tags, rho, &mut got);
                let want: Vec<u128> =
                    tags.iter().map(|&t| tagged_r(seed, t, rho)).collect();
                assert_eq!(got, want, "seed={seed} rho={rho}");
            }
        }
        // appends, never clobbers
        let mut out = vec![7u128];
        tagged_r_many(1, &[2, 3], 64, &mut out);
        assert_eq!(out[0], 7);
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn mask_stays_below_two_pow_rho() {
        let mut rng = Prng::seed_from_u64(3);
        for rho in [8u32, 32, 64, 80] {
            for _ in 0..100 {
                assert!(sample_r(&mut rng, rho) < 1u128 << rho);
            }
        }
    }

    #[test]
    fn prop_divpub_error_and_residue() {
        crate::rng::property(512, |rng| {
            let u = rng.gen_bits(62);
            let d = 1 + rng.gen_bits(30);
            let r = sample_r(rng, 64);
            let v = divpub_plain(u, d, r);
            let want = (u / d) as i128;
            assert!((v - want).abs() <= 1, "u={u} d={d}");
            let q = (r % d) as i128;
            let w = ((u + r) % d) as i128;
            assert_eq!((u as i128 + q - w).rem_euclid(d as i128), 0);
        });
    }
}
