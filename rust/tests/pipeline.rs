//! Pipelined round engine tests (DESIGN.md §Round scheduler).
//!
//! Pins the two contracts of the dependency-DAG scheduler at once, across
//! backends:
//!
//! 1. **Byte identity** — [`Evaluator::eval_batch`] (one coalesced flight
//!    per DAG wave) reveals exactly what the stream-order
//!    [`Evaluator::eval_batch_sequential`] reveals, on `SimSession` and on
//!    real `TcpSession` members, for `mini_demo` and a deeper synthetic
//!    ladder with a long product chain and a pass-through node.
//! 2. **Rounds collapse to the critical path** — under the batched sim
//!    accounting schedule a warm batch costs exactly
//!    [`EvalPlan::pipelined_sim_rounds`] = `6·critical_depth + 9` rounds,
//!    while message/byte/exercise totals under per-op accounting are
//!    unchanged from the sequential executor (coalescing moves latency,
//!    not traffic).
//!
//! Under `--features checked-session` every session here runs wrapped in
//! the CheckedSession sanitizer, which additionally holds each flight to
//! the Tables 2–3 conservation law and per-flight DataId/tag hygiene.

use spn_mpc::field::Field;
use spn_mpc::net::tcp_session::{TcpSession, TcpSessionConfig};
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::protocols::MpcSession;
use spn_mpc::spn::{
    EvalPlan, Evaluator, Layer, LayerKind, ParamKind, Query, Src, Structure,
};
use spn_mpc::spn::structure::Stats;

#[cfg(feature = "checked-session")]
use spn_mpc::protocols::checked::CheckedSession;
#[cfg(feature = "checked-session")]
fn wrap<S: MpcSession>(s: S) -> CheckedSession<S> {
    CheckedSession::new(s)
}
#[cfg(not(feature = "checked-session"))]
fn wrap<S: MpcSession>(s: S) -> S {
    s
}
#[cfg(feature = "checked-session")]
fn wrap_engine(e: Engine) -> CheckedSession<Engine> {
    let schedule = e.cfg.schedule;
    CheckedSession::with_sim_accounting(e, schedule)
}
#[cfg(not(feature = "checked-session"))]
fn wrap_engine(e: Engine) -> Engine {
    e
}
#[cfg(feature = "checked-session")]
fn unwrap_session<S: MpcSession>(s: CheckedSession<S>) -> S {
    s.into_inner()
}
#[cfg(not(feature = "checked-session"))]
fn unwrap_session<S: MpcSession>(s: S) -> S {
    s
}

/// A synthetic 4-layer "ladder": deeper than `mini_demo` in exactly the
/// ways the scheduler must handle — a 5-child product whose chain spans 3
/// DAG waves and consumes its sum input *last*, plus a degree-1 product
/// node (a pass-through the pipelined executor never materializes).
///
/// ```text
///   root = w₂·(L4·L5·L6·(w₀·(L0·L1) + w₁·(L2·L3))) + w₃·L7
/// ```
fn ladder_structure() -> Structure {
    let st = Structure {
        name: "ladder".into(),
        num_vars: 8,
        rows: 240,
        leaf_var: (0..8).collect(),
        leaf_claim: vec![-1; 8], // plain Bernoulli leaves
        layer_widths: vec![8, 2, 1, 2, 1],
        layer_offset: vec![0, 8, 10, 11, 13],
        total_nodes: 14,
        layers: vec![
            Layer {
                kind: LayerKind::Product,
                width: 2,
                in_width: 8,
                rows: vec![0, 0, 1, 1],
                cols: vec![0, 1, 2, 3],
                param: vec![-1; 4],
            },
            Layer {
                kind: LayerKind::Sum,
                width: 1,
                in_width: 10,
                rows: vec![0, 0],
                cols: vec![0, 1],
                param: vec![0, 1],
            },
            Layer {
                // node 0: leaves 4,5,6 then the sum (col 0) LAST — a
                // 3-round chain whose final link waits on the sum wave;
                // node 1: single child leaf 7 — a pass-through.
                kind: LayerKind::Product,
                width: 2,
                in_width: 9,
                rows: vec![0, 0, 0, 0, 1],
                cols: vec![5, 6, 7, 0, 8],
                param: vec![-1; 5],
            },
            Layer {
                kind: LayerKind::Sum,
                width: 1,
                in_width: 10,
                rows: vec![0, 0],
                cols: vec![0, 1],
                param: vec![2, 3],
            },
        ],
        num_params: 4,
        num_sum_edges: 4,
        param_kind: vec![ParamKind::SumEdge; 4],
        param_num: vec![8, 9, 11, 12],
        param_den: vec![10, 10, 13, 13],
        sum_groups: vec![vec![0, 1], vec![2, 3]],
        stats: Stats { sum: 2, product: 4, leaf: 8, params: 4, edges: 11, layers: 4 },
    };
    st.validate().expect("ladder structure must validate");
    st
}

/// d-scaled sum weights per param id; each group sums to exactly d = 256
/// so an all-marginal query evaluates to exactly d (no divpub rounding).
fn weights_for(st: &Structure) -> Vec<u128> {
    match st.num_sum_edges {
        2 => vec![64, 192],
        4 => vec![64, 192, 128, 128],
        n => panic!("no test weights for {n} sum edges"),
    }
}

fn queries_for(nv: usize) -> Vec<Query> {
    vec![
        Query { x: vec![0; nv], marg: vec![false; nv] },
        Query { x: vec![1; nv], marg: vec![false; nv] },
        Query {
            x: (0..nv).map(|i| (i % 2) as u8).collect(),
            marg: (0..nv).map(|i| i % 3 == 0).collect(),
        },
        Query { x: vec![0; nv], marg: vec![true; nv] },
    ]
}

fn plan_for(st: &Structure) -> EvalPlan {
    EvalPlan::compile(st, &vec![0.5; st.num_leaves()], 256)
}

fn both_structures() -> Vec<Structure> {
    vec![Structure::mini_demo(), ladder_structure()]
}

#[test]
fn ladder_compiles_with_expected_dag() {
    let st = ladder_structure();
    let plan = plan_for(&st);
    // divpubs: 2 (layer-0 chain links) + 1 (sum) + 3 (ladder chain links;
    // the pass-through node truncates nothing) + 1 (root sum)
    assert_eq!(plan.divpubs_per_query, 7);
    // sequential executor: 1 + 1 + 3 + 1 round-trips
    assert_eq!(plan.chain_rounds(), 6);
    // the DAG overlaps the two product chains: leaf-fed rounds of the
    // ladder run concurrently with layer 0 and the first sum, so the
    // critical path is 4, not 6
    assert_eq!(plan.critical_depth(), 4);
    assert_eq!(plan.pipelined_sim_rounds(), 6 * 4 + 9);
    // the degree-1 product node is an unmaterialized alias to its leaf
    assert_eq!(plan.pass_through[2][1], Some(Src::Leaf(7)));
    assert_eq!(plan.pass_through[2][0], None);
}

#[test]
fn pipelined_equals_sequential_bit_exact_on_sim() {
    for st in both_structures() {
        let plan = plan_for(&st);
        let qs = queries_for(st.num_vars);
        let w = weights_for(&st);

        let mut a = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(3).batched()));
        let wa = a.input_vec(1, &w);
        let (pipe, _) = Evaluator::new(plan.clone()).eval_batch(&mut a, &qs, &wa, None);

        let mut b = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(3).batched()));
        let wb = b.input_vec(1, &w);
        let (seq, _) =
            Evaluator::new(plan.clone()).eval_batch_sequential(&mut b, &qs, &wb, None);

        assert_eq!(pipe, seq, "{}: pipelined must equal sequential bit-for-bit", st.name);
        // group weights sum to d exactly, so the all-marginal query is
        // rounding-free: S(∅)·d = d on the nose
        assert_eq!(pipe[3], 256, "{}: S(∅)·d", st.name);
    }
}

#[test]
fn pipelined_message_and_exercise_totals_match_perop() {
    // Coalescing moves latency, not traffic: under per-op accounting the
    // flight path spends exactly the sequential messages/bytes/exercises,
    // and strictly fewer rounds.
    for st in both_structures() {
        let plan = plan_for(&st);
        let qs = queries_for(st.num_vars);
        let w = weights_for(&st);

        let mut a = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(3)));
        let wa = a.input_vec(1, &w);
        let (pipe, sa) = Evaluator::new(plan.clone()).eval_batch(&mut a, &qs, &wa, None);

        let mut b = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(3)));
        let wb = b.input_vec(1, &w);
        let (seq, sb) =
            Evaluator::new(plan.clone()).eval_batch_sequential(&mut b, &qs, &wb, None);

        assert_eq!(pipe, seq, "{}", st.name);
        assert_eq!(sa.messages, sb.messages, "{}: message totals must not change", st.name);
        assert_eq!(sa.bytes, sb.bytes, "{}: byte totals must not change", st.name);
        assert_eq!(sa.exercises, sb.exercises, "{}: exercise totals must not change", st.name);
        assert!(
            sa.rounds < sb.rounds,
            "{}: pipelined {} rounds must beat sequential {}",
            st.name,
            sa.rounds,
            sb.rounds
        );
    }
}

#[test]
fn warm_pipelined_rounds_equal_six_depth_plus_nine() {
    // The acceptance bound of the round scheduler: a warm batch (slope
    // cache built) costs exactly the closed form — input star 3 + leaf
    // flight 3 + 6 per DAG wave + reveal 3 — under batched accounting.
    for st in both_structures() {
        let plan = plan_for(&st);
        let qs = queries_for(st.num_vars);
        let w = weights_for(&st);

        let mut sess = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(3).batched()));
        let ws = sess.input_vec(1, &w);
        let mut ev = Evaluator::new(plan);
        let (_, cold) = ev.eval_batch(&mut sess, &qs, &ws, None);
        let (_, warm) = ev.eval_batch(&mut sess, &qs, &ws, None);

        let want = ev.plan().pipelined_sim_rounds();
        assert_eq!(want, 6 * ev.plan().critical_depth() as u64 + 9, "{}", st.name);
        assert_eq!(
            warm.rounds, want,
            "{}: warm batch rounds must equal the DAG critical path",
            st.name
        );
        // the cold batch additionally pays the query-independent slope lin
        assert_eq!(cold.rounds, want + 2, "{}: cold batch = warm + slope", st.name);
    }
}

#[test]
fn pipelined_tcp_byte_identical_to_sim_and_fewer_round_trips() {
    // The same flights over real sockets: one OP_FLIGHT frame per member
    // per wave, answers byte-identical to the simulation's (and to the
    // sequential TCP executor on an identically-seeded fresh session,
    // which consumes the same tag block and hence the same PRF masks).
    for st in both_structures() {
        let plan = plan_for(&st);
        let qs = queries_for(st.num_vars);
        let w = weights_for(&st);
        let n = 3;

        let mut sim = wrap_engine(Engine::new(Field::paper(), EngineConfig::new(n).batched()));
        let wsim = sim.input_vec(1, &w);
        let (sim_roots, _) = Evaluator::new(plan.clone()).eval_batch(&mut sim, &qs, &wsim, None);

        let mut tp =
            wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
        let wtp = tp.input_vec(1, &w);
        let (tcp_pipe, sp) = Evaluator::new(plan.clone()).eval_batch(&mut tp, &qs, &wtp, None);
        unwrap_session(tp).shutdown().unwrap();

        let mut ts =
            wrap(TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n)).unwrap());
        let wts = ts.input_vec(1, &w);
        let (tcp_seq, ss) =
            Evaluator::new(plan.clone()).eval_batch_sequential(&mut ts, &qs, &wts, None);
        unwrap_session(ts).shutdown().unwrap();

        assert_eq!(tcp_pipe, sim_roots, "{}: TCP flights must match the sim", st.name);
        assert_eq!(tcp_pipe, tcp_seq, "{}: TCP flights must match sequential TCP", st.name);
        assert!(
            sp.rounds < ss.rounds,
            "{}: coalesced TCP rounds {} must beat sequential {}",
            st.name,
            sp.rounds,
            ss.rounds
        );
    }
}

#[test]
fn pipelined_eval_is_thread_count_invariant() {
    // The worker-pool dimension (DESIGN.md §Field kernel) composes with
    // the flight scheduler: the same pipelined batch evaluation at
    // worker-pool width 4 — sim engine and TCP members — reveals the
    // exact bytes of the serial width-1 sim run, with identical
    // accounting.
    for st in both_structures() {
        let plan = plan_for(&st);
        let qs = queries_for(st.num_vars);
        let w = weights_for(&st);
        let n = 3;

        let mut outs = Vec::new();
        for threads in [1usize, 4] {
            let mut sim = wrap_engine(Engine::new(
                Field::paper(),
                EngineConfig::new(n).batched().with_threads(threads),
            ));
            let wsim = sim.input_vec(1, &w);
            outs.push(Evaluator::new(plan.clone()).eval_batch(&mut sim, &qs, &wsim, None));
        }
        let mut tp = wrap(
            TcpSession::spawn_local(Field::paper(), TcpSessionConfig::new(n).with_threads(4))
                .unwrap(),
        );
        let wtp = tp.input_vec(1, &w);
        let (tcp_roots, _) = Evaluator::new(plan.clone()).eval_batch(&mut tp, &qs, &wtp, None);
        unwrap_session(tp).shutdown().unwrap();

        let (r1, s1) = &outs[0];
        let (r4, s4) = &outs[1];
        assert_eq!(r4, r1, "{}: threads=4 sim roots must match serial", st.name);
        assert_eq!(
            (s4.messages, s4.bytes, s4.rounds, s4.exercises),
            (s1.messages, s1.bytes, s1.rounds, s1.exercises),
            "{}: pool width must not change accounting",
            st.name
        );
        assert_eq!(&tcp_roots, r1, "{}: threads=4 TCP roots must match serial sim", st.name);
    }
}
