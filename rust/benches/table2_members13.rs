//! Table 2: private training cost with 13 members + manager at 10 ms
//! latency — messages, traffic, time — next to the paper's numbers.
//!
//! Absolute counts differ (our engine needs fewer exercises per division
//! than the authors' implementation; see EXPERIMENTS.md), so the table also
//! reports the *shape*: each dataset's cost normalized to nltcs. The
//! paper's own costs scale with the number of sum nodes (one Newton
//! inversion each) — ours must reproduce that scaling.

mod common;

use spn_mpc::bench::JsonSink;
use spn_mpc::metrics::{group_thousands, render_table};
use spn_mpc::protocols::engine::Schedule;

const PAPER_MSGS: [(&str, u64, f64, f64); 4] = [
    ("nltcs", 4_231_815, 170.0, 6952.0),
    ("jester", 3_290_901, 133.0, 5622.0),
    ("baudio", 5_800_005, 233.0, 9088.0),
    ("bnetflix", 8_622_747, 347.0, 15640.0),
];

fn run(members: usize, table: &str, json: &mut JsonSink) {
    let mut rows = Vec::new();
    let mut ours_msgs = Vec::new();
    for (name, p_msgs, p_mb, p_time) in PAPER_MSGS {
        let (report, wall) =
            common::train_run(name, members, Schedule::PerOp).expect("guarded in main");
        ours_msgs.push((name, report.stats.messages as f64));
        json.push("table2_members13", &format!("{name}_messages"), report.stats.messages as f64);
        json.push("table2_members13", &format!("{name}_mb"), report.stats.megabytes());
        json.push("table2_members13", &format!("{name}_virtual_s"), report.stats.virtual_time_s);
        json.push("table2_members13", &format!("{name}_wall_s"), wall);
        rows.push(vec![
            name.to_string(),
            group_thousands(p_msgs),
            group_thousands(report.stats.messages),
            format!("{:.0}", p_mb),
            format!("{:.1}", report.stats.megabytes()),
            format!("{:.0}", p_time),
            format!("{:.0}", report.stats.virtual_time_s),
            format!("{:.2}", wall),
        ]);
    }
    println!(
        "{}",
        render_table(
            &format!("{table} — {members} members + manager, 10 ms latency"),
            &[
                "Dataset",
                "msgs (paper)",
                "msgs (ours)",
                "MB (paper)",
                "MB (ours)",
                "s (paper)",
                "s (ours, virtual)",
                "s (wall)"
            ],
            &rows
        )
    );

    // shape check: normalized to nltcs, ours must track the paper's ordering
    let base_p = PAPER_MSGS[0].1 as f64;
    let base_o = ours_msgs[0].1;
    println!("normalized message cost (nltcs = 1.00):");
    let mut ok = true;
    for ((name, p, _, _), (_, o)) in PAPER_MSGS.iter().zip(&ours_msgs) {
        let rp = *p as f64 / base_p;
        let ro = *o / base_o;
        println!("  {name:9} paper {rp:.2}  ours {ro:.2}");
        ok &= (rp - ro).abs() / rp < 0.45;
    }
    assert!(ok, "message-cost shape must track the paper (±45%)");
    // ordering check: jester < nltcs < baudio < bnetflix
    assert!(ours_msgs[1].1 < ours_msgs[0].1, "jester must be cheapest");
    assert!(ours_msgs[0].1 < ours_msgs[2].1 && ours_msgs[2].1 < ours_msgs[3].1);
    println!("shape OK\n");
}

fn main() {
    let mut json = JsonSink::from_env_args();
    if !common::guard("table2_members13", &common::DEBD) {
        json.finish().expect("write --json output");
        return;
    }
    run(13, "Table 2", &mut json);
    json.finish().expect("write --json output");
}
