//! Table 3: private training cost with 5 members + manager (same layout as
//! Table 2), plus the member-scaling ratio the two tables imply.

mod common;

use spn_mpc::bench::JsonSink;
use spn_mpc::metrics::{group_thousands, render_table};
use spn_mpc::protocols::engine::Schedule;

const PAPER: [(&str, u64, f64, f64); 4] = [
    ("nltcs", 915_273, 36.0, 2101.0),
    ("jester", 711_813, 28.0, 1640.0),
    ("baudio", 1_254_423, 49.0, 2880.0),
    ("bnetflix", 1_864_893, 73.0, 4344.0),
];

fn main() {
    let mut json = JsonSink::from_env_args();
    if !common::guard("table3_members5", &common::DEBD) {
        json.finish().expect("write --json output");
        return;
    }
    let mut rows = Vec::new();
    let mut ours5 = Vec::new();
    for (name, p_msgs, p_mb, p_time) in PAPER {
        let (report, wall) =
            common::train_run(name, 5, Schedule::PerOp).expect("guarded above");
        ours5.push(report.stats.messages as f64);
        json.push("table3_members5", &format!("{name}_messages"), report.stats.messages as f64);
        json.push("table3_members5", &format!("{name}_mb"), report.stats.megabytes());
        json.push("table3_members5", &format!("{name}_virtual_s"), report.stats.virtual_time_s);
        json.push("table3_members5", &format!("{name}_wall_s"), wall);
        rows.push(vec![
            name.to_string(),
            group_thousands(p_msgs),
            group_thousands(report.stats.messages),
            format!("{:.0}", p_mb),
            format!("{:.1}", report.stats.megabytes()),
            format!("{:.0}", p_time),
            format!("{:.0}", report.stats.virtual_time_s),
            format!("{:.2}", wall),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Table 3 — 5 members + manager, 10 ms latency",
            &[
                "Dataset",
                "msgs (paper)",
                "msgs (ours)",
                "MB (paper)",
                "MB (ours)",
                "s (paper)",
                "s (ours, virtual)",
                "s (wall)"
            ],
            &rows
        )
    );

    // member scaling: paper's 13-member/5-member message ratio is ~4.6
    // (mesh resharing dominates: ~n(n-1) per multiplication).
    let (r13, _) = common::train_run("nltcs", 13, Schedule::PerOp).expect("guarded above");
    let ratio = r13.stats.messages as f64 / ours5[0];
    let paper_ratio = 4_231_815.0 / 915_273.0;
    println!(
        "member scaling on nltcs: 13-member/5-member messages = {ratio:.2} (paper {paper_ratio:.2})"
    );
    assert!(
        ratio > 2.5 && ratio < 9.0,
        "scaling must be superlinear in members (mesh resharing)"
    );
    json.push("table3_members5", "nltcs_member_scaling_ratio", ratio);
    json.finish().expect("write --json output");
    println!("table3 OK");
}
