//! The full private division of Eq. (3): `ŵ = d·(Σₖ numᵏ)/(Σₖ denᵏ)`.
//!
//! Pipeline (§3.4, last paragraph): Newton inverse of the shared denominator
//! (`[I] ≈ d·E/den`), one secure multiplication per numerator
//! (`[num]·[I]`), then a secure truncation (division by the public scale
//! `E`) — yielding shares of an integer ≈ `d·num/den ∈ [0, d]`.
//!
//! The weights of one sum node share a denominator, so one Newton
//! inversion serves all of a node's child numerators — this is why the
//! paper's Tables 2–3 costs scale with the number of sum nodes, not the
//! number of parameters. Since the lockstep-Newton refactor the
//! coordinator goes further and calls [`divide_many`] once per *model*:
//! every sum node's inversion advances in the same vectorized iteration,
//! so the round count no longer scales with the sum-node count at all
//! (PerOp message totals — the Tables 2–3 quantities — are unchanged).

use super::engine::DataId;
use super::newton::{newton_inverse_vec, NewtonConfig};
use super::session::MpcSession;

/// End-to-end division parameters (paper §5.3: d=256, n=16, t=5).
#[derive(Clone, Copy, Debug, Default)]
pub struct DivisionConfig {
    /// Parameters of the Newton inversion stage; see
    /// [`NewtonConfig`](super::newton::NewtonConfig).
    pub newton: NewtonConfig,
}

/// `[num]/[den]·d` for a single pair, over any [`MpcSession`] backend.
/// `bmax` is the public upper bound on the denominator (the total dataset
/// size — public in the horizontal partitioning setting).
pub fn private_divide<S: MpcSession>(
    sess: &mut S,
    num: DataId,
    den: DataId,
    bmax: u128,
    cfg: &DivisionConfig,
) -> DataId {
    divide_shared_den(sess, &[num], den, bmax, cfg)[0]
}

/// All numerators against one shared denominator: one Newton inversion,
/// then per-numerator multiply + truncate. The single-group case of
/// [`divide_many`] (identical call sequence, accounting and RNG draws).
pub fn divide_shared_den<S: MpcSession>(
    sess: &mut S,
    nums: &[DataId],
    den: DataId,
    bmax: u128,
    cfg: &DivisionConfig,
) -> Vec<DataId> {
    divide_many(sess, &[(den, nums.to_vec())], bmax, cfg).pop().unwrap()
}

/// Many denominator groups at once: `groups[g]` is `(denominator,
/// numerators sharing it)`. One *vectorized* Newton inversion covers every
/// denominator ([`newton_inverse_vec`] — all groups' iterations advance in
/// lockstep and share communication rounds), then a single multiply +
/// truncate sweep over every `(numerator, inverse)` pair. Returns one
/// weight vector per group, in group order.
///
/// This is the training hot path: the whole model's divisions cost one
/// Newton schedule's worth of rounds instead of one per sum node.
pub fn divide_many<S: MpcSession>(
    sess: &mut S,
    groups: &[(DataId, Vec<DataId>)],
    bmax: u128,
    cfg: &DivisionConfig,
) -> Vec<Vec<DataId>> {
    if groups.is_empty() {
        return Vec::new();
    }
    let dens: Vec<DataId> = groups.iter().map(|g| g.0).collect();
    let (invs, pl) = newton_inverse_vec(sess, &dens, bmax, &cfg.newton);
    let mut pairs: Vec<(DataId, DataId)> = Vec::new();
    for ((_, nums), &inv) in groups.iter().zip(&invs) {
        for &num in nums {
            pairs.push((num, inv));
        }
    }
    let prods = sess.mul_vec(&pairs);
    let qs = sess.divpub_vec(&prods, pl.final_scale);
    let mut out = Vec::with_capacity(groups.len());
    let mut off = 0;
    for (_, nums) in groups {
        out.push(qs[off..off + nums.len()].to_vec());
        off += nums.len();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig};

    fn eng(n: usize) -> Engine {
        Engine::new(Field::paper(), EngineConfig::new(n))
    }

    fn run_division(n: usize, nums: &[u128], dens: &[u128]) -> Vec<i128> {
        // Each of the n parties holds per-party numerators/denominators;
        // here we test the share-combining + division core by feeding the
        // already-summed values through party 1.
        let mut e = eng(n);
        let den_sum: u128 = dens.iter().sum();
        let den = e.input(1, &[den_sum])[0];
        let num_ids = e.input(1, nums);
        let cfg = DivisionConfig::default();
        let ids = divide_shared_den(&mut e, &num_ids, den, 20000, &cfg);
        ids.iter().map(|&id| e.peek_int(id)).collect()
    }

    #[test]
    fn matches_true_scaled_division() {
        let nums = [71u128, 209, 320];
        let dens = [256u128, 786, 1127];
        let den: u128 = dens.iter().sum();
        let got = run_division(5, &nums, &dens);
        for (g, &num) in got.iter().zip(&nums) {
            let want = (256 * num / den) as i128;
            assert!((g - want).abs() <= 3, "num={num}: got {g} want {want}");
        }
    }

    #[test]
    fn weights_sum_to_d() {
        // Completeness: Σ_j ŵ_ij = d (up to rounding) when Σ nums = den.
        let nums = [123u128, 456, 789, 32];
        let den: u128 = nums.iter().sum();
        let got = run_division(5, &nums, &[den]);
        let total: i128 = got.iter().sum();
        assert!((total - 256).abs() <= 8, "Σŵ = {total}");
    }

    #[test]
    fn zero_numerator_gives_zero_weight() {
        let got = run_division(3, &[0, 100], &[100]);
        assert!(got[0].abs() <= 1);
    }

    #[test]
    fn paper_example1_values_exact_path() {
        // Example 1's numbers through the EXACT path: ŵ = 0.277 → d·ŵ ≈ 71.
        // (the paper uses d=1000 for the approximate path; here d=256.)
        let nums = [71u128 + 209 + 320];
        let dens = [256u128 + 786 + 1127];
        let got = run_division(3, &nums, &dens);
        let want = (256.0f64 * 600.0 / 2169.0).floor() as i128; // 70
        assert!((got[0] - want).abs() <= 3, "got {} want {want}", got[0]);
    }

    #[test]
    fn divide_many_matches_per_group_division_and_amortizes_rounds() {
        let groups_in: [(&[u128], u128); 3] =
            [(&[71, 209, 320], 2169), (&[5, 95], 100), (&[123, 456, 789, 32], 1400)];
        let cfg = DivisionConfig::default();

        // One divide_many call over all groups.
        let mut e = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let groups: Vec<(DataId, Vec<DataId>)> = groups_in
            .iter()
            .map(|&(nums, den)| {
                let den = e.input(1, &[den])[0];
                (den, e.input(1, nums))
            })
            .collect();
        let before = e.net.stats;
        let many = divide_many(&mut e, &groups, 20000, &cfg);
        let many_rounds = e.net.stats.delta_since(&before).rounds;
        for ((nums, den), ws) in groups_in.iter().zip(&many) {
            for (&num, &w) in nums.iter().zip(ws) {
                let got = e.peek_int(w);
                let want = (256 * num / den) as i128;
                assert!((got - want).abs() <= 3, "num={num}/{den}: got {got} want {want}");
            }
        }

        // Per-group calls on an identical engine: same quality, ~3× rounds.
        let mut e2 = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let groups2: Vec<(DataId, Vec<DataId>)> = groups_in
            .iter()
            .map(|&(nums, den)| {
                let den = e2.input(1, &[den])[0];
                (den, e2.input(1, nums))
            })
            .collect();
        let before = e2.net.stats;
        for (den, nums) in &groups2 {
            let _ = divide_shared_den(&mut e2, nums, *den, 20000, &cfg);
        }
        let seq_rounds = e2.net.stats.delta_since(&before).rounds;
        assert!(
            many_rounds * 2 < seq_rounds,
            "grouped division must amortize rounds: {many_rounds} vs {seq_rounds}"
        );
    }

    #[test]
    fn prop_division_accuracy() {
        crate::rng::property(16, |rng| {
            use crate::rng::Rng;
            let den = 1 + rng.gen_range_u128(4999);
            let num = rng.gen_range_u128(den + 1);
            let n = 3 + rng.gen_range_u64(3) as usize;
            let got = run_division(n, &[num], &[den])[0];
            let want = (256 * num / den) as i128;
            assert!((got - want).abs() <= 4, "num={} den={} got={} want={}", num, den, got, want);
        });
    }
}
