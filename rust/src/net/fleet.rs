//! The sharded serve fleet (DESIGN.md §Serving layer, §Fleet): S
//! independent MPC sessions for one trained model behind a single TCP
//! front-end.
//!
//! [`crate::net::serve::serve`] owns exactly one session, so every client
//! serializes through one secure-round pipeline. The fleet scales out
//! horizontally: each **shard** is a full session (Sim engine or TCP
//! member set) holding its own replica of the trained weight shares
//! (deterministic replay under the shared seed — see
//! [`crate::coordinator::serve::train_and_serve_fleet`]) and its own
//! [`Evaluator`] confined to stripe s of the partitioned divpub-tag space
//! ([`TagStripe`]). Tag freshness is a *per-session* invariant, so the
//! stripes need no cross-shard coordination, and a shard's answers are
//! byte-identical to a direct `private_eval_batch` on that shard's
//! session.
//!
//! ## Dispatch
//!
//! One FIFO queue per shard; readers route each arriving query to the
//! least-loaded live shard (queue depth + in-flight tick width, ties to
//! the lowest index). A query may pin itself to a shard with an optional
//! `"shard":s` field — honored while that shard is live (the byte-identity
//! and chaos tests use this), otherwise it falls back to least-loaded.
//! A shard whose own queue is empty **steals** the back half of the
//! longest live queue (skipping entries pinned to the victim), so one hot
//! queue cannot idle the rest of the fleet. Per-shard scheduling keeps
//! the single-session flush rules ([`ServeConfig::max_batch`] /
//! [`ServeConfig::max_wait`]) per shard.
//!
//! Responses carry a `"shard"` field and can interleave across shards on
//! one connection — fleet clients attribute replies by `seq`.
//!
//! ## Degrade, don't crash
//!
//! Each tick's evaluation runs under `catch_unwind`: a session whose
//! transport dies (TCP members gone) or that is killed by the
//! `{"cmd":"kill-shard","shard":s}` chaos command panics mid-op, the
//! shard is marked **dead**, and every query it owed — the interrupted
//! tick plus its queue — is re-dispatched to surviving shards. The
//! interrupted tick's reserved tags are burned unrevealed, which is safe:
//! freshness only forbids *reuse*, and survivors evaluate with their own
//! stripe-local tags. With zero survivors the front-end answers errors
//! but keeps accepting connections, so `{"cmd":"shutdown"}` still drains
//! and the clean-shutdown teardown still runs.

use std::collections::VecDeque;
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::serve::{
    cv_wait, cv_wait_timeout, json_escape, lock, query_from_json, read_json_msg,
    render_response, reply, reply_error, ConnShared, ServeConfig,
};
use super::NetStats;
use crate::json::Json;
use crate::protocols::engine::DataId;
use crate::protocols::session::MpcSession;
use crate::spn::plan::{Evaluator, Query, TagStripe};

/// Out-of-band shard kill switch: severs the shard's transport so its
/// next secure op aborts. TCP shards install
/// `TcpSession::sever_handle`; Sim shards have no transport to cut and
/// rely on the killed flag alone.
pub type ShardSever = Box<dyn Fn() + Send + Sync>;

/// One shard of a serve fleet: a session, its striped evaluator, and its
/// replica of the model's weight shares.
pub struct FleetShard<'a, S: MpcSession> {
    /// The shard's MPC session (exclusively owned by its scheduler
    /// thread for the lifetime of [`serve_fleet`]).
    pub sess: &'a mut S,
    /// Plan evaluator confined to this shard's [`TagStripe`] (built via
    /// `Evaluator::clone_into_session`).
    pub ev: Evaluator,
    /// Sum-weight share handles in `sess`.
    pub sum_w: Vec<DataId>,
    /// Learned leaf-θ share handles in `sess` (None = public defaults).
    pub learned_theta: Option<Vec<DataId>>,
    /// Optional transport kill switch for `kill-shard` (TCP shards).
    pub sever: Option<ShardSever>,
}

/// What one shard did, inside a [`FleetReport`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardReport {
    /// Queries this shard answered.
    pub queries: u64,
    /// Scheduler ticks this shard ran.
    pub batches: u64,
    /// Widest tick this shard served.
    pub max_tick: usize,
    /// Σ of this shard's per-tick [`NetStats`] deltas.
    pub stats: NetStats,
    /// Did this shard die (session panic or kill-shard)?
    pub dead: bool,
}

/// What a fleet did, returned by [`serve_fleet`] after the drain.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    /// Queries answered across all shards.
    pub queries: u64,
    /// Scheduler ticks across all shards.
    pub batches: u64,
    /// Client connections accepted over the fleet's lifetime.
    pub clients: u64,
    /// Σ of every shard's stats.
    pub stats: NetStats,
    /// Widest tick any shard served.
    pub max_tick: usize,
    /// Number of shards the fleet started with.
    pub shards: usize,
    /// Shards dead by the end of the run.
    pub dead_shards: usize,
    /// Queries moved off a dying shard onto survivors.
    pub redispatched: u64,
    /// Per-shard breakdown, indexed by shard.
    pub per_shard: Vec<ShardReport>,
}

// --- shared front-end state ------------------------------------------------

struct FPending {
    conn: Arc<ConnShared>,
    seq: u64,
    query: Query,
    enqueued: Instant,
    /// Client-requested shard, if any (kept so stealing never moves a
    /// pinned query off its live shard).
    pin: Option<usize>,
}

#[derive(Default)]
struct ShardQueue {
    queue: VecDeque<FPending>,
    /// Width of the tick the shard is currently evaluating (load signal
    /// for least-loaded dispatch).
    in_flight: usize,
    /// Session gone; never routed to again.
    dead: bool,
    /// kill-shard received; the scheduler turns this into `dead` on its
    /// next wake-up.
    killed: bool,
}

#[derive(Default)]
struct FleetState {
    shards: Vec<ShardQueue>,
    shutdown: bool,
    /// Queries answered fleet-wide (drives `max_queries`).
    answered: u64,
    redispatched: u64,
    conns: Vec<Arc<ConnShared>>,
    reader_handles: Vec<JoinHandle<()>>,
    clients_seen: u64,
}

struct FleetShared {
    state: Mutex<FleetState>,
    cvar: Condvar,
    /// Per-shard transport kill switches (`None` for Sim shards).
    severs: Vec<Option<ShardSever>>,
    nshards: usize,
}

/// Least-loaded live shard, honoring a live pin. `None` = no live shard.
fn route(st: &FleetState, pin: Option<usize>) -> Option<usize> {
    if let Some(p) = pin {
        let sq = &st.shards[p];
        if !sq.dead && !sq.killed {
            return Some(p);
        }
    }
    st.shards
        .iter()
        .enumerate()
        .filter(|(_, q)| !q.dead && !q.killed)
        .min_by_key(|(i, q)| (q.queue.len() + q.in_flight, *i))
        .map(|(i, _)| i)
}

/// The longest live queue worth stealing from (≥ 2 entries, not `thief`).
fn steal_victim(st: &FleetState, thief: usize) -> Option<usize> {
    st.shards
        .iter()
        .enumerate()
        .filter(|&(i, q)| i != thief && !q.dead && !q.killed && q.queue.len() >= 2)
        .max_by_key(|(_, q)| q.queue.len())
        .map(|(i, _)| i)
}

/// Take up to half of `victim`'s queue (capped at `max_batch`) from the
/// back, skipping entries pinned to the victim; the stolen run keeps its
/// FIFO order.
fn steal_from(q: &mut VecDeque<FPending>, max_batch: usize, victim: usize) -> Vec<FPending> {
    let want = (q.len() / 2).min(max_batch);
    let mut got = Vec::new();
    while got.len() < want {
        match q.pop_back() {
            Some(p) if p.pin != Some(victim) => got.push(p),
            Some(pinned) => {
                q.push_back(pinned);
                break;
            }
            None => break,
        }
    }
    got.reverse();
    got
}

/// Next tick for shard `s`: its own queue under the single-session flush
/// rules, else stolen work, else block. `Some(vec![])` signals a pending
/// kill (the scheduler panics into the death path); `None` means drained
/// shutdown.
fn next_fleet_tick(shared: &FleetShared, s: usize, cfg: &ServeConfig) -> Option<Vec<FPending>> {
    let mut st = lock(&shared.state);
    loop {
        if st.shards[s].dead {
            return None;
        }
        if st.shards[s].killed {
            return Some(Vec::new());
        }
        if !st.shards[s].queue.is_empty() {
            break;
        }
        if let Some(v) = steal_victim(&st, s) {
            let stolen = steal_from(&mut st.shards[v].queue, cfg.max_batch, v);
            if !stolen.is_empty() {
                st.shards[s].in_flight = stolen.len();
                return Some(stolen);
            }
        }
        if st.shutdown {
            return None;
        }
        st = cv_wait(&shared.cvar, st);
    }
    // coalesce arrivals exactly like the single-session scheduler
    // lint:allow(L004) — the loop above guarantees the queue is non-empty
    let deadline = st.shards[s].queue.front().unwrap().enqueued + cfg.max_wait;
    while st.shards[s].queue.len() < cfg.max_batch && !st.shutdown && !st.shards[s].killed {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let (g, to) = cv_wait_timeout(&shared.cvar, st, deadline - now);
        st = g;
        if to.timed_out() {
            break;
        }
    }
    let take = st.shards[s].queue.len().min(cfg.max_batch);
    let tick: Vec<FPending> = st.shards[s].queue.drain(..take).collect();
    st.shards[s].in_flight = tick.len();
    Some(tick)
}

/// One shard's scheduler: owns the session, serves ticks until drained
/// shutdown or death. Runs on a scoped thread inside [`serve_fleet`].
fn shard_scheduler<S: MpcSession>(
    s: usize,
    shard: &mut FleetShard<'_, S>,
    shared: &FleetShared,
    cfg: &ServeConfig,
    d: u128,
) -> ShardReport {
    let mut rep = ShardReport::default();
    while let Some(tick) = next_fleet_tick(shared, s, cfg) {
        let queries: Vec<Query> = tick.iter().map(|p| p.query.clone()).collect();
        // Read the kill flag *outside* the unwind region: panicking while
        // holding the state lock would poison it for the whole front-end.
        let killed = { lock(&shared.state).shards[s].killed };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if killed {
                panic!("shard {s} killed by command");
            }
            shard.ev.eval_batch(
                shard.sess,
                &queries,
                &shard.sum_w,
                shard.learned_theta.as_deref(),
            )
        }));
        match outcome {
            Ok((roots, delta)) => {
                rep.queries += tick.len() as u64;
                rep.batches += 1;
                rep.stats = rep.stats + delta;
                rep.max_tick = rep.max_tick.max(tick.len());
                // bill the tick delta once per distinct client in the tick
                let mut seen: Vec<u64> = Vec::new();
                for p in &tick {
                    if !seen.contains(&p.conn.id) {
                        seen.push(p.conn.id);
                        let mut t = lock(&p.conn.total);
                        *t = *t + delta;
                    }
                }
                for (p, &root) in tick.iter().zip(&roots) {
                    let total = *lock(&p.conn.total);
                    let msg =
                        render_response(p.seq, root, d, tick.len(), &delta, &total, Some(s));
                    reply(&p.conn, &msg);
                }
                let mut st = lock(&shared.state);
                st.shards[s].in_flight = 0;
                st.answered += tick.len() as u64;
                if let Some(maxq) = cfg.max_queries {
                    if st.answered >= maxq {
                        st.shutdown = true;
                    }
                }
                shared.cvar.notify_all();
            }
            Err(_) => {
                // The session is gone mid-tick. Mark the shard dead and
                // move every query it owed — the interrupted tick plus its
                // queue — to survivors. The tick's reserved tags are
                // burned unrevealed (freshness only forbids reuse);
                // survivors answer with their own stripe-local tags.
                let mut lost = Vec::new();
                {
                    let mut st = lock(&shared.state);
                    st.shards[s].dead = true;
                    st.shards[s].in_flight = 0;
                    let mut orphans = tick;
                    orphans.extend(st.shards[s].queue.drain(..));
                    st.redispatched += orphans.len() as u64;
                    for mut p in orphans {
                        if p.pin == Some(s) {
                            p.pin = None;
                        }
                        match route(&st, p.pin) {
                            Some(t) => st.shards[t].queue.push_back(p),
                            None => lost.push(p),
                        }
                    }
                    shared.cvar.notify_all();
                }
                for p in lost {
                    reply_error(
                        &p.conn,
                        Some(p.seq),
                        &format!("shard {s} died with no surviving shards"),
                    );
                }
                rep.dead = true;
                break;
            }
        }
    }
    rep
}

// --- front-end (readers + accept loop) -------------------------------------

/// Parse an optional integer `"shard"` routing hint in `0..nshards`.
/// `Ok(None)` = unpinned; `Err` = present but unusable.
fn parse_pin(j: &Json, nshards: usize) -> Result<Option<usize>> {
    match j.opt("shard") {
        None => Ok(None),
        Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 && (*n as usize) < nshards => {
            Ok(Some(*n as usize))
        }
        Some(_) => bail!("\"shard\" must be an integer in 0..{nshards}"),
    }
}

/// Per-connection reader: hello, then frames → routed queue entries.
/// Extends the single-session reader with the `"shard"` pin and the
/// `kill-shard` chaos command. Never touches any MPC session.
fn fleet_reader_session(conn: &Arc<ConnShared>, shared: &FleetShared, hello: &str, num_vars: usize) {
    if !reply(conn, hello) {
        return;
    }
    let Ok(rstream) = conn.stream.try_clone() else { return };
    let mut r = BufReader::with_capacity(8192, rstream);
    let nshards = shared.nshards;
    loop {
        let Ok(txt) = read_json_msg(&mut r) else { return }; // disconnect
        let j = match Json::parse(&txt) {
            Ok(j) => j,
            Err(e) => {
                let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
                if !reply_error(conn, Some(seq), &format!("request is not JSON: {e}")) {
                    return;
                }
                continue;
            }
        };
        if let Some(cmd) = j.opt("cmd") {
            if matches!(cmd, Json::Str(c) if c.as_str() == "shutdown") {
                reply(conn, "{\"ok\":true}");
                let mut st = lock(&shared.state);
                st.shutdown = true;
                shared.cvar.notify_all();
                return;
            }
            if matches!(cmd, Json::Str(c) if c.as_str() == "kill-shard") {
                match parse_pin(&j, nshards) {
                    Ok(Some(t)) => {
                        {
                            let mut st = lock(&shared.state);
                            st.shards[t].killed = true;
                            shared.cvar.notify_all();
                        }
                        // sever outside the lock: closing sockets can block
                        if let Some(f) = &shared.severs[t] {
                            f();
                        }
                        if !reply(conn, &format!("{{\"ok\":true,\"killed\":{t}}}")) {
                            return;
                        }
                    }
                    _ => {
                        if !reply_error(
                            conn,
                            None,
                            &format!("kill-shard needs \"shard\" in 0..{nshards}"),
                        ) {
                            return;
                        }
                    }
                }
                continue;
            }
            if !reply_error(conn, None, &format!("unknown cmd {cmd:?}")) {
                return;
            }
            continue;
        }
        let seq = conn.next_seq.fetch_add(1, Ordering::SeqCst);
        let pin = match parse_pin(&j, nshards) {
            Ok(p) => p,
            Err(e) => {
                if !reply_error(conn, Some(seq), &e.to_string()) {
                    return;
                }
                continue;
            }
        };
        match query_from_json(&j, num_vars) {
            Ok(query) => {
                let mut st = lock(&shared.state);
                if st.shutdown {
                    drop(st);
                    if !reply_error(conn, Some(seq), "server is shutting down") {
                        return;
                    }
                    continue;
                }
                match route(&st, pin) {
                    Some(t) => {
                        st.shards[t].queue.push_back(FPending {
                            conn: conn.clone(),
                            seq,
                            query,
                            enqueued: Instant::now(),
                            pin,
                        });
                        shared.cvar.notify_all();
                    }
                    None => {
                        drop(st);
                        if !reply_error(conn, Some(seq), "no live shards") {
                            return;
                        }
                    }
                }
            }
            Err(e) => {
                if !reply_error(conn, Some(seq), &e.to_string()) {
                    return;
                }
            }
        }
    }
}

fn fleet_reader_loop(
    conn: Arc<ConnShared>,
    shared: Arc<FleetShared>,
    hello: Arc<String>,
    num_vars: usize,
) {
    fleet_reader_session(&conn, &shared, &hello, num_vars);
    // prune, exactly like the single-session reader (queued FPendings hold
    // their own Arc, so in-flight responses still go out)
    let mut st = lock(&shared.state);
    st.conns.retain(|c| c.id != conn.id);
    st.reader_handles.retain(|h| !h.is_finished());
}

/// Accept loop: register connections, spawn readers, exit on shutdown
/// (woken by a dummy self-connection, as in the single-session server).
fn fleet_listener_loop(
    listener: TcpListener,
    shared: Arc<FleetShared>,
    hello: Arc<String>,
    num_vars: usize,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if lock(&shared.state).shutdown {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        let mut st = lock(&shared.state);
        if st.shutdown {
            return;
        }
        st.clients_seen += 1;
        let Some(conn) = ConnShared::register(st.clients_seen, stream) else { continue };
        st.conns.push(conn.clone());
        let rs = shared.clone();
        let h = hello.clone();
        st.reader_handles
            .push(std::thread::spawn(move || fleet_reader_loop(conn, rs, h, num_vars)));
    }
}

/// Run a serve fleet: accept clients on `listener` and micro-batch their
/// queries across the `shards` — one scheduler thread per shard, each
/// exclusively owning its session. Returns after a drained shutdown with
/// every spawned thread joined; the sessions outlive the call (the caller
/// shuts them down, using their lossy path for dead shards).
///
/// Every shard must serve the same compiled plan; each shard's answers
/// are byte-identical to a direct `private_eval_batch` of the queries it
/// served, in its served order, on a session with the same seed, training
/// replay, and [`TagStripe`] (pinned by `rust/tests/fleet.rs`).
pub fn serve_fleet<S: MpcSession + Send>(
    mut shards: Vec<FleetShard<'_, S>>,
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<FleetReport> {
    if cfg.max_batch == 0 {
        bail!("serve_fleet needs max_batch ≥ 1");
    }
    if shards.is_empty() {
        bail!("serve_fleet needs at least one shard");
    }
    let (num_vars, d) = (shards[0].ev.plan().num_vars, shards[0].ev.plan().d);
    for sh in &shards {
        let p = sh.ev.plan();
        if p.num_vars != num_vars || p.d != d {
            bail!("every fleet shard must serve the same compiled plan");
        }
        let stripe = sh.ev.stripe();
        if stripe.map(|st| st.shards()) != Some(shards.len()) {
            bail!(
                "shard evaluator stripe {stripe:?} does not match a {}-shard fleet \
                 (build shards via Evaluator::clone_into_session)",
                shards.len()
            );
        }
    }
    let nshards = shards.len();
    let addr = listener.local_addr()?;
    let hello = Arc::new(format!(
        "{{\"proto\":1,\"name\":\"{}\",\"num_vars\":{},\"d\":{},\"max_batch\":{},\"shards\":{}}}",
        json_escape(&shards[0].ev.plan().name),
        num_vars,
        d,
        cfg.max_batch,
        nshards
    ));
    let severs: Vec<Option<ShardSever>> = shards.iter_mut().map(|sh| sh.sever.take()).collect();
    let shared = Arc::new(FleetShared {
        state: Mutex::new(FleetState {
            shards: (0..nshards).map(|_| ShardQueue::default()).collect(),
            ..FleetState::default()
        }),
        cvar: Condvar::new(),
        severs,
        nshards,
    });
    let ls = shared.clone();
    let lhello = hello.clone();
    let lh = std::thread::spawn(move || fleet_listener_loop(listener, ls, lhello, num_vars));

    let mut per_shard: Vec<ShardReport> = Vec::with_capacity(nshards);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(nshards);
        for (s, shard) in shards.iter_mut().enumerate() {
            let sh: &FleetShared = &shared;
            handles.push(scope.spawn(move || shard_scheduler(s, shard, sh, cfg, d)));
        }
        // Hold the front door open until shutdown even if every scheduler
        // died: readers keep answering errors and the shutdown command
        // must still drain cleanly.
        {
            let mut st = lock(&shared.state);
            while !st.shutdown {
                st = cv_wait(&shared.cvar, st);
            }
        }
        for h in handles {
            per_shard
                .push(h.join().unwrap_or(ShardReport { dead: true, ..ShardReport::default() }));
        }
    });
    // graceful teardown, exactly like the single-session server
    let _ = TcpStream::connect(addr);
    lh.join().map_err(|_| anyhow!("fleet listener thread panicked"))?;
    let (conns, readers, clients, redispatched) = {
        let mut st = lock(&shared.state);
        (
            std::mem::take(&mut st.conns),
            std::mem::take(&mut st.reader_handles),
            st.clients_seen,
            st.redispatched,
        )
    };
    for c in &conns {
        let _ = c.stream.shutdown(Shutdown::Both);
    }
    for h in readers {
        h.join().map_err(|_| anyhow!("fleet reader thread panicked"))?;
    }

    let mut report = FleetReport {
        clients,
        shards: nshards,
        redispatched,
        per_shard: per_shard.clone(),
        ..FleetReport::default()
    };
    for r in &per_shard {
        report.queries += r.queries;
        report.batches += r.batches;
        report.stats = report.stats + r.stats;
        report.max_tick = report.max_tick.max(r.max_tick);
        report.dead_shards += r.dead as usize;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pend(pin: Option<usize>) -> FPending {
        // a connected TCP pair so ConnShared::register has a real socket
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = TcpStream::connect(l.local_addr().unwrap()).unwrap();
        let conn = ConnShared::register(1, a).unwrap();
        FPending {
            conn,
            seq: 0,
            query: Query { x: vec![0], marg: vec![true] },
            enqueued: Instant::now(),
            pin,
        }
    }

    fn state(loads: &[(usize, usize, bool)]) -> FleetState {
        // (queued, in_flight, dead) per shard
        let mut st = FleetState::default();
        for &(queued, in_flight, dead) in loads {
            let mut q = ShardQueue { in_flight, dead, ..ShardQueue::default() };
            for _ in 0..queued {
                q.queue.push_back(pend(None));
            }
            st.shards.push(q);
        }
        st
    }

    #[test]
    fn routing_is_least_loaded_with_live_pins() {
        let st = state(&[(3, 0, false), (0, 2, false), (1, 0, false)]);
        assert_eq!(route(&st, None), Some(2), "lowest queue+in_flight wins");
        assert_eq!(route(&st, Some(0)), Some(0), "a live pin is honored");
        let st = state(&[(0, 0, true), (5, 0, false)]);
        assert_eq!(route(&st, Some(0)), Some(1), "a dead pin falls back");
        let st = state(&[(0, 0, true), (0, 0, true)]);
        assert_eq!(route(&st, None), None, "no live shard → no route");
    }

    #[test]
    fn stealing_takes_the_unpinned_back_half_in_order() {
        let mut q: VecDeque<FPending> = VecDeque::new();
        for seq in 0..6 {
            let mut p = pend(None);
            p.seq = seq;
            q.push_back(p);
        }
        let got = steal_from(&mut q, 16, 0);
        assert_eq!(got.len(), 3, "half of six");
        assert_eq!(got.iter().map(|p| p.seq).collect::<Vec<_>>(), vec![3, 4, 5], "FIFO kept");
        assert_eq!(q.len(), 3);

        // entries pinned to the victim are never stolen
        let mut q: VecDeque<FPending> = VecDeque::new();
        for seq in 0..4 {
            let mut p = pend(Some(7));
            p.seq = seq;
            q.push_back(p);
        }
        assert!(steal_from(&mut q, 16, 7).is_empty());
        assert_eq!(q.len(), 4);
    }
}
