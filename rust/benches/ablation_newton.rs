//! §3.4 protocol ablation: Newton convergence from u=1 and the effect of
//! the guard bits (our refinement; g=0 is the paper-literal iteration).
//!
//! Reports, per guard-bit setting, the worst/mean relative error of the
//! computed inverse over the denominator range, plus the per-division
//! message cost as iterations change — the paper's claim that ⌈log d⌉
//! iterations suffice from u=1 is checked explicitly.

use spn_mpc::field::Field;
use spn_mpc::metrics::render_table;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::protocols::newton::{newton_inverse, newton_plain, plan, NewtonConfig};
use spn_mpc::rng::Prng;

fn main() {
    let bmax = 16384u128;

    // --- guard-bit sweep (plaintext mirror, dense b sweep) -------------------
    let mut rows = Vec::new();
    for g in [0u32, 2, 4, 6, 8, 10] {
        let cfg = NewtonConfig { guard_bits: g, ..NewtonConfig::default() };
        let mut worst = 0.0f64;
        let mut mean = 0.0f64;
        let mut collapses = 0u32;
        let mut count = 0u32;
        let mut rng = Prng::seed_from_u64(7);
        for b in (1..=bmax).step_by(97) {
            let (u, pl) = newton_plain(b, bmax, &cfg, 64, &mut rng);
            let want = (cfg.d * pl.final_scale / b) as f64;
            let rel = ((u as f64) - want).abs() / want.max(1.0);
            worst = worst.max(rel);
            mean += rel;
            count += 1;
            if rel > 0.5 {
                collapses += 1;
            }
        }
        rows.push(vec![
            format!("{g}"),
            format!("{:.4}", mean / count as f64),
            format!("{:.4}", worst),
            format!("{collapses}/{count}"),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Newton inverse accuracy vs guard bits (d=256, b in [1, 16384])",
            &["guard bits g", "mean rel err", "worst rel err", "collapses"],
            &rows
        )
    );

    // --- warmup-count claim: ⌈log₂ D₀⌉ warmup iterations reach f ≤ 2 ---------
    let cfg = NewtonConfig::default();
    let pl = plan(&cfg, bmax);
    println!(
        "plan for bmax={bmax}: e0={} D0={} warmup={} (= ⌈log₂ D₀⌉ + t = {} + {}) refine={}",
        pl.e0,
        pl.d0,
        pl.warmup,
        pl.warmup - cfg.t_extra,
        cfg.t_extra,
        pl.refine
    );
    assert_eq!(pl.warmup - cfg.t_extra, 128 - (pl.d0 - 1).leading_zeros());

    // --- refine-iteration sweep: cost vs accuracy over the engine ------------
    let mut rows = Vec::new();
    for refine in [4u32, 8, 16, 24] {
        let cfg = NewtonConfig { refine_iters: refine, ..NewtonConfig::default() };
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(5));
        let b = 1234u128;
        let bid = eng.input(1, &[b])[0];
        let before = eng.net.stats.messages;
        let (uid, pl) = newton_inverse(&mut eng, bid, 2000, &cfg);
        let msgs = eng.net.stats.messages - before;
        let u = eng.peek_int(uid);
        let want = (cfg.d * pl.final_scale / b) as f64;
        rows.push(vec![
            format!("{refine}"),
            format!("{:.5}", ((u as f64) - want).abs() / want),
            format!("{msgs}"),
            format!("{}", pl.warmup + refine),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Cost vs accuracy per refine iterations (n=5 members, b=1234)",
            &["refine iters", "rel err", "messages/division", "total iters"],
            &rows
        )
    );
    println!("ablation_newton OK");
}
