//! Table 1: statistics of the SPN structures. The generated structures are
//! calibrated to match the paper exactly; this bench prints both side by
//! side and fails loudly on divergence.

mod common;

use spn_mpc::metrics::render_table;

const PAPER: [(&str, [usize; 6]); 4] = [
    ("nltcs", [13, 26, 74, 100, 112, 9]),
    ("jester", [10, 20, 225, 245, 254, 5]),
    ("baudio", [17, 36, 282, 318, 334, 7]),
    ("bnetflix", [27, 54, 265, 319, 345, 7]),
];

fn main() {
    if !common::guard("table1_structures", &common::DEBD) {
        return;
    }
    let mut rows = Vec::new();
    let mut all_match = true;
    for (name, paper) in PAPER {
        let st = common::load(name).expect("guarded above");
        let ours = [
            st.stats.sum,
            st.stats.product,
            st.stats.leaf,
            st.stats.params,
            st.stats.edges,
            st.stats.layers,
        ];
        let ok = ours == paper;
        all_match &= ok;
        rows.push(vec![
            name.to_string(),
            format!("{:?}", paper),
            format!("{:?}", ours),
            if ok { "exact".into() } else { "MISMATCH".into() },
        ]);
    }
    println!(
        "{}",
        render_table(
            "Table 1 — structure statistics [sum, product, leaf, params, edges, layers]",
            &["Dataset", "paper", "generated", "match"],
            &rows
        )
    );
    assert!(all_match, "Table 1 must match the paper exactly");
    println!("table1 OK");
}
