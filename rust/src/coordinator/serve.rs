//! The standing-service coordinator: train once, then hand the live MPC
//! session to the micro-batching scheduler of [`crate::net::serve`]
//! (DESIGN.md §Serving layer).
//!
//! This is the `spn-mpc serve` entrypoint's core: the same generic
//! [`MpcSession`] drives training and then serving, so the weight shares
//! never leave the members — the scheduler evaluates client queries over
//! exactly the `DataId` handles training produced. The plan is compiled
//! once ([`EvalPlan::compile`]) and one persistent [`Evaluator`] answers
//! every scheduler tick; per-client [`crate::net::NetStats`] deltas ride
//! back in each response.

use std::net::TcpListener;

use anyhow::Result;

use crate::coordinator::train::{train, SharedModel, TrainConfig, TrainReport};
use crate::net::serve::{serve, ServeConfig, ServeReport};
use crate::protocols::session::MpcSession;
use crate::spn::plan::{EvalPlan, Evaluator};
use crate::spn::structure::Structure;

/// Serve an already-trained model: compile its plan, build the persistent
/// [`Evaluator`], and run the scheduler until shutdown. The session stays
/// usable afterwards (TCP callers still own its `shutdown()`).
pub fn serve_model<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    model: &SharedModel,
    default_leaf_theta: &[f64],
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let plan = EvalPlan::compile(st, default_leaf_theta, model.d);
    let mut ev = Evaluator::new(plan);
    serve(sess, &mut ev, &model.sum_w, model.leaf_theta.as_deref(), listener, cfg)
}

/// Train on the parties' local counts, then serve the learned shares over
/// the same session — the full `spn-mpc serve` pipeline.
#[allow(clippy::too_many_arguments)]
pub fn train_and_serve<S: MpcSession>(
    sess: &mut S,
    st: &Structure,
    shard_counts: &[Vec<u64>],
    rows_total: u64,
    tcfg: &TrainConfig,
    default_leaf_theta: &[f64],
    listener: TcpListener,
    cfg: &ServeConfig,
) -> Result<(ServeReport, TrainReport)> {
    let (model, treport) = train(sess, st, shard_counts, rows_total, tcfg);
    let report = serve_model(sess, st, &model, default_leaf_theta, listener, cfg)?;
    Ok((report, treport))
}
