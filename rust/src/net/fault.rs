//! Deterministic fault injection for the serve fleet (DESIGN.md §Fleet).
//!
//! Chaos testing a fleet with ad-hoc kill commands is racy: whether a
//! query lands before or after the kill depends on thread scheduling, so
//! a failure seen once may never reproduce. A [`FaultPlan`] instead pins
//! every injected fault to a *logical* instant — the per-shard **wake
//! counter**, which increments once per scheduler wake (a query tick or a
//! health probe) and persists across respawned generations. Two runs of
//! the same plan against the same query schedule inject at the same
//! logical points, making the chaos acceptance tests replayable.
//!
//! Three fault kinds cover the failure modes the self-healing layer must
//! survive:
//!
//! * [`FaultKind::Sever`] — cut the shard's member sockets (the transport
//!   failure a crashed member causes); the next secure round errors and
//!   the shard dies, exercising quarantine + respawn.
//! * [`FaultKind::Delay`] — stall the scheduler before the wake executes,
//!   modelling a hung peer; read deadlines and probes must cope.
//! * [`FaultKind::Panic`] — panic inside the shard scheduler's guarded
//!   section, modelling a protocol-level crash; the panic payload must
//!   surface in the [`ShardReport`](crate::net::fleet::ShardReport)
//!   instead of being swallowed.
//!
//! Plans come from the `--fault-plan` CLI flag (see [`FaultPlan::parse`])
//! or are built directly in tests ([`FaultPlan::new`] /
//! [`FaultPlan::seeded`]).

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::rng::{Prng, Rng};

/// What to inject when an event matures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Sever the shard's member sockets via its registered sever handle.
    Sever,
    /// Stall the scheduler for this many milliseconds before the wake.
    Delay(u64),
    /// Panic inside the shard scheduler's guarded section.
    Panic,
}

/// One scheduled fault: `kind` fires at the first wake of `shard` whose
/// wake counter has reached `wake`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub shard: usize,
    pub wake: u64,
    pub kind: FaultKind,
}

/// A seeded, replayable schedule of fault events. Interior-mutable so the
/// fleet's scheduler threads can consume events through a shared `&self`.
pub struct FaultPlan {
    seed: u64,
    /// `(event, fired)` — each event injects at most once.
    events: Mutex<Vec<(FaultEvent, bool)>>,
}

impl FaultPlan {
    /// A plan from an explicit event list (the test API).
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        FaultPlan { seed: 0, events: Mutex::new(events.into_iter().map(|e| (e, false)).collect()) }
    }

    /// The canonical chaos schedule: every shard severed exactly once, at
    /// a wake drawn deterministically from `[0, horizon)` by `seed`.
    pub fn seeded(seed: u64, shards: usize, horizon: u64) -> FaultPlan {
        let mut rng = Prng::seed_from_u64(seed);
        let events = (0..shards)
            .map(|s| {
                let wake = rng.gen_range_u64(horizon.max(1));
                (FaultEvent { shard: s, wake, kind: FaultKind::Sever }, false)
            })
            .collect();
        FaultPlan { seed, events: Mutex::new(events) }
    }

    /// Parse a `--fault-plan` spec: comma-separated events
    /// `sever:SHARD@WAKE`, `delay:SHARD@WAKE:MS`, `panic:SHARD@WAKE`, or
    /// the shorthand `seeded:SEED[:HORIZON]` (every shard severed once at
    /// a seed-drawn wake below HORIZON, default 8).
    pub fn parse(spec: &str, shards: usize) -> Result<FaultPlan> {
        let mut events = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = match item.split_once(':') {
                Some(kr) => kr,
                None => bail!("fault-plan item {item:?}: expected KIND:ARGS"),
            };
            if kind == "seeded" {
                let (seed_s, horizon_s) = match rest.split_once(':') {
                    Some((a, b)) => (a, b),
                    None => (rest, "8"),
                };
                let seed: u64 = seed_s.parse().map_err(|_| {
                    anyhow::anyhow!("fault-plan seeded seed {seed_s:?} is not a u64")
                })?;
                let horizon: u64 = horizon_s.parse().map_err(|_| {
                    anyhow::anyhow!("fault-plan seeded horizon {horizon_s:?} is not a u64")
                })?;
                let seeded = FaultPlan::seeded(seed, shards, horizon);
                events.extend(seeded.events.into_inner().expect("fresh mutex").into_iter().map(|(e, _)| e));
                continue;
            }
            let (shard_s, tail) = match rest.split_once('@') {
                Some(st) => st,
                None => bail!("fault-plan item {item:?}: expected {kind}:SHARD@WAKE"),
            };
            let shard: usize = shard_s
                .parse()
                .map_err(|_| anyhow::anyhow!("fault-plan shard {shard_s:?} is not an index"))?;
            if shard >= shards {
                bail!("fault-plan targets shard {shard} of a {shards}-shard fleet");
            }
            let (wake_s, ms_s) = match tail.split_once(':') {
                Some(wm) => (wm.0, Some(wm.1)),
                None => (tail, None),
            };
            let wake: u64 = wake_s
                .parse()
                .map_err(|_| anyhow::anyhow!("fault-plan wake {wake_s:?} is not a u64"))?;
            let fk = match (kind, ms_s) {
                ("sever", None) => FaultKind::Sever,
                ("panic", None) => FaultKind::Panic,
                ("delay", Some(ms)) => FaultKind::Delay(ms.parse().map_err(|_| {
                    anyhow::anyhow!("fault-plan delay ms {ms:?} is not a u64")
                })?),
                ("delay", None) => bail!("fault-plan delay needs delay:SHARD@WAKE:MS"),
                _ => bail!("fault-plan kind {kind:?}: expected sever, delay, panic or seeded"),
            };
            events.push(FaultEvent { shard, wake, kind: fk });
        }
        if events.is_empty() {
            bail!("fault-plan {spec:?} contains no events");
        }
        Ok(FaultPlan::new(events))
    }

    /// Consume (at most) one matured event for `shard` at wake counter
    /// `wake`: the first unfired event whose trigger wake has been
    /// reached. Returns its kind, or `None` when nothing is due.
    pub fn take(&self, shard: usize, wake: u64) -> Option<FaultKind> {
        let mut ev = self.events.lock().expect("fault-plan events poisoned");
        for (e, fired) in ev.iter_mut() {
            if !*fired && e.shard == shard && wake >= e.wake {
                *fired = true;
                return Some(e.kind);
            }
        }
        None
    }

    /// Human-readable schedule for the SERVE banner and logs.
    pub fn summary(&self) -> String {
        let ev = self.events.lock().expect("fault-plan events poisoned");
        let items: Vec<String> = ev
            .iter()
            .map(|(e, _)| match e.kind {
                FaultKind::Sever => format!("sever:{}@{}", e.shard, e.wake),
                FaultKind::Delay(ms) => format!("delay:{}@{}:{ms}", e.shard, e.wake),
                FaultKind::Panic => format!("panic:{}@{}", e.shard, e.wake),
            })
            .collect();
        format!("seed={} [{}]", self.seed, items.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_replay_and_fire_once() {
        let a = FaultPlan::seeded(42, 3, 8);
        let b = FaultPlan::seeded(42, 3, 8);
        for s in 0..3 {
            // walk both plans through the same wakes: identical schedules
            let mut hits = Vec::new();
            for w in 0..16 {
                let ka = a.take(s, w);
                let kb = b.take(s, w);
                assert_eq!(ka, kb, "same seed, same schedule");
                if let Some(k) = ka {
                    assert_eq!(k, FaultKind::Sever);
                    hits.push(w);
                }
            }
            assert_eq!(hits.len(), 1, "each shard severed exactly once, got {hits:?}");
            assert!(hits[0] < 8, "sever wake respects the horizon");
        }
        // a different seed moves at least one event
        let c = FaultPlan::seeded(43, 3, 1 << 20);
        assert_ne!(a.summary(), c.summary());
    }

    #[test]
    fn parse_round_trips_all_kinds() {
        let p = FaultPlan::parse("sever:0@3, delay:1@2:250, panic:2@0", 3).expect("valid spec");
        assert_eq!(p.take(0, 2), None, "wake 2 is before the trigger");
        assert_eq!(p.take(0, 3), Some(FaultKind::Sever));
        assert_eq!(p.take(0, 4), None, "events fire once");
        assert_eq!(p.take(1, 7), Some(FaultKind::Delay(250)), "matured events fire late");
        assert_eq!(p.take(2, 0), Some(FaultKind::Panic));

        assert!(FaultPlan::parse("sever:5@0", 3).is_err(), "out-of-range shard rejected");
        assert!(FaultPlan::parse("freeze:0@0", 3).is_err(), "unknown kind rejected");
        assert!(FaultPlan::parse("delay:0@0", 3).is_err(), "delay needs ms");
        assert!(FaultPlan::parse("", 3).is_err(), "empty plan rejected");
        let s = FaultPlan::parse("seeded:9", 4).expect("seeded shorthand");
        let mut count = 0;
        for sh in 0..4 {
            for w in 0..8 {
                if s.take(sh, w).is_some() {
                    count += 1;
                }
            }
        }
        assert_eq!(count, 4, "seeded shorthand severs every shard once");
    }
}
