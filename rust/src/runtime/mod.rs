//! PJRT runtime: load and execute the AOT'd HLO artifacts from rust.
//!
//! This is the Layer-3 ↔ Layer-2 bridge: `make artifacts` lowers the JAX
//! counts/eval graphs (which call the Pallas layer kernels) to HLO *text*,
//! and this module compiles and runs them on the PJRT CPU client — python
//! never executes on the request path.  Pattern follows
//! /opt/xla-example/load_hlo (text interchange because xla_extension 0.5.1
//! rejects jax ≥ 0.5's 64-bit-id protos).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::json::Json;
use crate::spn::structure::Structure;

/// Artifact bundle for one dataset structure.
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub batch: usize,
    pub num_vars: usize,
    pub num_params: usize,
    pub counts_out: usize,
    pub structure_path: PathBuf,
    pub counts_hlo: PathBuf,
    pub eval_hlo: PathBuf,
}

/// Parsed artifacts/manifest.json.
pub fn read_manifest(dir: impl AsRef<Path>) -> Result<Vec<ArtifactInfo>> {
    let dir = dir.as_ref();
    let txt = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {:?}/manifest.json — run `make artifacts`", dir))?;
    let j = Json::parse(&txt).map_err(|e| anyhow!("{e}"))?;
    let mut out = Vec::new();
    if let Json::Obj(ds) = j.get("datasets") {
        for (name, info) in ds {
            out.push(ArtifactInfo {
                name: name.clone(),
                batch: info.get("batch").as_usize(),
                num_vars: info.get("num_vars").as_usize(),
                num_params: info.get("num_params").as_usize(),
                counts_out: info.get("counts_out").as_usize(),
                structure_path: dir.join(info.get("structure").as_str()),
                counts_hlo: dir.join(info.get("counts_hlo").as_str()),
                eval_hlo: dir.join(info.get("eval_hlo").as_str()),
            });
        }
    }
    Ok(out)
}

/// The PJRT client; compiled executables borrow from it logically (the xla
/// crate keeps its own refcounts).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn load_counts(&self, info: &ArtifactInfo) -> Result<CountsExe> {
        let proto = xla::HloModuleProto::from_text_file(
            info.counts_hlo.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(CountsExe {
            exe,
            batch: info.batch,
            num_vars: info.num_vars,
            out_len: info.counts_out,
        })
    }

    pub fn load_eval(&self, info: &ArtifactInfo) -> Result<EvalExe> {
        let proto = xla::HloModuleProto::from_text_file(
            info.eval_hlo.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(EvalExe {
            exe,
            batch: info.batch,
            num_vars: info.num_vars,
            num_params: info.num_params,
        })
    }
}

/// Compiled counts graph: (X:(B,nv) f32, row_mask:(B,) f32) -> (counts,).
pub struct CountsExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub num_vars: usize,
    pub out_len: usize,
}

impl CountsExe {
    /// Counts over a shard of any size: chunked through the fixed-batch
    /// executable with row masking on the tail chunk.
    pub fn counts(&self, shard: &[Vec<u8>]) -> Result<Vec<u64>> {
        let mut acc = vec![0u64; self.out_len];
        for chunk in shard.chunks(self.batch) {
            let mut xbuf = vec![0f32; self.batch * self.num_vars];
            let mut mask = vec![0f32; self.batch];
            for (i, row) in chunk.iter().enumerate() {
                debug_assert_eq!(row.len(), self.num_vars);
                for (v, &b) in row.iter().enumerate() {
                    xbuf[i * self.num_vars + v] = b as f32;
                }
                mask[i] = 1.0;
            }
            let x = xla::Literal::vec1(&xbuf)
                .reshape(&[self.batch as i64, self.num_vars as i64])?;
            let m = xla::Literal::vec1(&mask);
            let result = self.exe.execute::<xla::Literal>(&[x, m])?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            let vals = out.to_vec::<f32>()?;
            anyhow::ensure!(vals.len() == self.out_len, "counts output length mismatch");
            for (a, v) in acc.iter_mut().zip(vals) {
                // per-chunk counts are small integers; exact in f32
                *a += v.round() as u64;
            }
        }
        Ok(acc)
    }
}

/// Compiled eval graph: (X, marg, params) -> (logS per row,).
pub struct EvalExe {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub num_vars: usize,
    pub num_params: usize,
}

impl EvalExe {
    /// Log-likelihoods for up to `batch` rows (padded internally).
    pub fn logeval(&self, rows: &[Vec<u8>], marg: &[bool], params: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(rows.len() <= self.batch, "eval chunk too large");
        anyhow::ensure!(params.len() == self.num_params);
        let mut xbuf = vec![0f32; self.batch * self.num_vars];
        for (i, row) in rows.iter().enumerate() {
            for (v, &b) in row.iter().enumerate() {
                xbuf[i * self.num_vars + v] = b as f32;
            }
        }
        let x = xla::Literal::vec1(&xbuf)
            .reshape(&[self.batch as i64, self.num_vars as i64])?;
        let mg: Vec<f32> = marg.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
        let mgl = xla::Literal::vec1(&mg);
        let ps: Vec<f32> = params.iter().map(|&p| p as f32).collect();
        let psl = xla::Literal::vec1(&ps);
        let result = self.exe.execute::<xla::Literal>(&[x, mgl, psl])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let vals = out.to_vec::<f32>()?;
        Ok(vals[..rows.len()].iter().map(|&v| v as f64).collect())
    }

    /// Mean log-likelihood over an arbitrary-size dataset (chunked).
    pub fn mean_loglik(&self, data: &[Vec<u8>], params: &[f64]) -> Result<f64> {
        let marg = vec![false; self.num_vars];
        let mut tot = 0.0;
        for chunk in data.chunks(self.batch) {
            tot += self.logeval(chunk, &marg, params)?.iter().sum::<f64>();
        }
        Ok(tot / data.len() as f64)
    }
}

/// Convenience: load structure + counts + eval for one dataset name.
pub struct DatasetRuntime {
    pub structure: Structure,
    pub counts: CountsExe,
    pub eval: EvalExe,
}

pub fn load_dataset(rt: &Runtime, dir: impl AsRef<Path>, name: &str) -> Result<DatasetRuntime> {
    let infos = read_manifest(&dir)?;
    let info = infos
        .iter()
        .find(|i| i.name == name)
        .ok_or_else(|| anyhow!("dataset {name} not in manifest"))?;
    Ok(DatasetRuntime {
        structure: Structure::load(&info.structure_path)?,
        counts: rt.load_counts(info)?,
        eval: rt.load_eval(info)?,
    })
}

/// Default artifacts directory (crate root / artifacts).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_if_present() {
        let Ok(infos) = read_manifest(default_artifacts_dir()) else { return };
        assert!(infos.iter().any(|i| i.name == "toy"));
        for i in &infos {
            assert!(i.batch > 0 && i.counts_out > 0);
        }
    }
}
