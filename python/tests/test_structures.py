"""Structure generator: Table-1 exactness + SPN structural invariants."""

import numpy as np
import pytest

from compile import structures

ALL = list(structures.RECIPES)
DEBD = list(structures.PAPER_TABLE1)


@pytest.mark.parametrize("name", DEBD)
def test_table1_exact(name):
    st = structures.build(name)
    assert st["stats"] == structures.PAPER_TABLE1[name]


@pytest.mark.parametrize("name", ALL)
def test_layering_alternates_and_root_single(name):
    st = structures.build(name)
    kinds = [l["kind"] for l in st["layers"]]
    assert kinds[0] == "product"
    for a, b in zip(kinds, kinds[1:]):
        assert a != b, "layers must alternate"
    assert kinds[-1] == "sum"
    assert st["layers"][-1]["width"] == 1, "single root"
    assert st["num_layers"] == len(st["layers"]) + 1  # paper counts leaf layer


@pytest.mark.parametrize("name", ALL)
def test_edges_within_bounds(name):
    st = structures.build(name)
    w0 = st["layer_widths"][0]
    for li, layer in enumerate(st["layers"]):
        prev_w = layer["in_width"] - w0
        assert prev_w == (st["layer_widths"][li] if li > 0 else 0)
        for r, c in zip(layer["rows"], layer["cols"]):
            assert 0 <= r < layer["width"]
            assert 0 <= c < layer["in_width"]


@pytest.mark.parametrize("name", ALL)
def test_sum_params_grouped_and_complete(name):
    st = structures.build(name)
    nse = st["num_sum_edges"]
    seen = set()
    for layer in st["layers"]:
        for p in layer["param"]:
            if layer["kind"] == "sum":
                assert 0 <= p < nse
                assert p not in seen
                seen.add(p)
            else:
                assert p == -1
    assert seen == set(range(nse))
    covered = sorted(p for g in st["sum_groups"] for p in g)
    assert covered == list(range(nse))
    for g in st["sum_groups"]:
        assert len(g) >= 2


@pytest.mark.parametrize("name", ALL)
def test_every_node_has_parent_except_root(name):
    """Tree property: each non-root node referenced exactly once as a child."""
    st = structures.build(name)
    w0 = st["layer_widths"][0]
    leaf_refs = np.zeros(w0, dtype=int)
    for li, layer in enumerate(st["layers"]):
        prev_w = layer["in_width"] - w0
        prev_refs = np.zeros(prev_w, dtype=int)
        for c in layer["cols"]:
            if c < prev_w:
                prev_refs[c] += 1
            else:
                leaf_refs[c - prev_w] += 1
        if li > 0:
            assert (prev_refs == 1).all(), "each node has exactly one parent"
    assert (leaf_refs == 1).all()


@pytest.mark.parametrize("name", ALL)
def test_selectivity(name):
    """At most one child of every sum node is positive for any instance."""
    st = structures.build(name)
    rng = np.random.default_rng(3)
    w0 = st["layer_widths"][0]
    leaf_var = np.asarray(st["leaf_var"])
    leaf_claim = np.asarray(st["leaf_claim"])
    for _ in range(50):
        row = rng.integers(0, 2, size=st["num_vars"])
        pos_leaf = np.where(leaf_claim < 0, 1.0, (row[leaf_var] == leaf_claim))
        pos = [pos_leaf]
        for li, layer in enumerate(st["layers"]):
            prev = pos[-1] if li > 0 else np.zeros(0)
            inp = np.concatenate([prev, pos_leaf]) if li > 0 else pos_leaf
            out = np.zeros(layer["width"])
            if layer["kind"] == "product":
                deg = np.zeros(layer["width"]); acc = np.zeros(layer["width"])
                for r, c in zip(layer["rows"], layer["cols"]):
                    deg[r] += 1; acc[r] += inp[c]
                out = (acc >= deg - 0.5).astype(float)
            else:
                per_row = {}
                for r, c in zip(layer["rows"], layer["cols"]):
                    per_row.setdefault(r, []).append(inp[c])
                    out[r] = max(out[r], inp[c])
                for r, vals in per_row.items():
                    assert sum(v > 0 for v in vals) <= 1, "selectivity violated"
            pos.append(out)
        assert pos[-1][0] == 1.0, "root positive for complete evidence"


@pytest.mark.parametrize("name", ALL)
def test_param_num_den_indices(name):
    st = structures.build(name)
    w0 = st["layer_widths"][0]
    total = st["total_nodes"]
    for k, (num, den) in enumerate(zip(st["param_num"], st["param_den"])):
        if st["param_kind"][k] == "sum":
            assert 0 <= num < total and 0 <= den < total
        else:
            assert total <= num < total + w0
            assert 0 <= den < w0


def test_determinism():
    a = structures.build("nltcs", seed=7)
    b = structures.build("nltcs", seed=7)
    assert a == b
    c = structures.build("nltcs", seed=8)
    assert c["leaf_var"] != a["leaf_var"]   # different var permutation
    assert c["stats"] == a["stats"]          # same Table-1 stats
