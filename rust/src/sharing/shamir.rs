//! Shamir polynomial secret sharing over `Z_p` [13] with the degree-reduction
//! machinery for BGW-style secure multiplication.
//!
//! Party `i ∈ 1..=n` holds `f(i)` for a random degree-`t` polynomial with
//! `f(0) = secret`.  The paper states `k = n` (§2.2.2) but also multiplies
//! polynomial shares, which requires `2t + 1 ≤ n` evaluation points; we
//! therefore default to the BGW honest-majority threshold `t = ⌊(n-1)/2⌋`
//! and document the deviation in DESIGN.md §4 (the `--threshold` CLI flag
//! exposes it).

use crate::rng::Rng;

use crate::field::Field;

/// Shamir context for a fixed party set `1..=n` and degree `t`.
#[derive(Clone, Debug)]
pub struct ShamirCtx {
    /// The field all polynomials live in.
    pub f: Field,
    /// Number of parties; party `i ∈ 1..=n` holds evaluation point `i`.
    pub n: usize,
    /// Polynomial degree (threshold): any `t` shares reveal nothing,
    /// `t + 1` reconstruct. Secure multiplication requires `2t < n`.
    pub t: usize,
    /// Lagrange coefficients at 0 for interpolating from all n points
    /// (valid for any polynomial of degree ≤ n-1, in particular degree 2t).
    lagrange0: Vec<u128>,
    /// Row-major n×n Vandermonde power table: `vander[(i-1)·n + j] = iʲ mod
    /// p` for party `i ∈ 1..=n`, exponent `j ∈ 0..n`. Precomputed once so a
    /// deal is a coefficient/power dot product instead of a per-party Horner
    /// chain — the flat-buffer data plane's kernel (DESIGN.md §Data plane).
    /// Covers every legal polynomial degree (`deg ≤ 2t < n`).
    vander: Vec<u128>,
}

impl ShamirCtx {
    /// Standard honest-majority threshold.
    pub fn new(f: Field, n: usize) -> Self {
        Self::with_threshold(f, n, (n - 1) / 2)
    }

    /// Explicit threshold; rejects `2t ≥ n` (which would break secure
    /// multiplication — the §4 deviation documented in DESIGN.md §4).
    pub fn with_threshold(f: Field, n: usize, t: usize) -> Self {
        assert!(n >= 1 && (n as u128) < f.p, "party ids must be distinct mod p");
        assert!(2 * t < n, "secure multiplication needs 2t+1 <= n (got n={n}, t={t})");
        let lagrange0 = Self::lagrange_at_zero(&f, &(1..=n as u128).collect::<Vec<_>>());
        let mut vander = Vec::with_capacity(n * n);
        for x in 1..=n as u128 {
            let mut pw = 1u128;
            for _ in 0..n {
                vander.push(pw);
                pw = f.mul(pw, x);
            }
        }
        ShamirCtx { f, n, t, lagrange0, vander }
    }

    /// λ_j such that g(0) = Σ λ_j·g(x_j) for any g with deg g < |xs|.
    pub fn lagrange_at_zero(f: &Field, xs: &[u128]) -> Vec<u128> {
        let mut out = Vec::with_capacity(xs.len());
        for (j, &xj) in xs.iter().enumerate() {
            let mut num = 1u128;
            let mut den = 1u128;
            for (m, &xm) in xs.iter().enumerate() {
                if m == j {
                    continue;
                }
                num = f.mul(num, f.sub(0, xm)); // (0 - x_m)
                den = f.mul(den, f.sub(xj, xm));
            }
            out.push(f.mul(num, f.inv(den)));
        }
        out
    }

    /// Share `secret` with a fresh degree-`t` polynomial; returns `n` shares
    /// where index `i` is party `i+1`'s share `f(i+1)`.
    pub fn share<R: Rng + ?Sized>(&self, secret: u128, rng: &mut R) -> Vec<u128> {
        self.share_deg(secret, self.t, rng)
    }

    /// Share with an explicit polynomial degree (used by tests to build
    /// degree-2t sharings directly).
    pub fn share_deg<R: Rng + ?Sized>(&self, secret: u128, deg: usize, rng: &mut R) -> Vec<u128> {
        let mut out = vec![0u128; self.n];
        self.share_batch_into(&[secret], deg, rng, &mut out);
        out
    }

    /// Deal `k = secrets.len()` secrets with fresh degree-`deg` polynomials
    /// into the flat **party-major** buffer `out`: `out[(i-1)·k + e]` is
    /// party i's share of secret `e`. `out.len()` must be exactly `n·k`.
    ///
    /// Coefficients are drawn from `rng` in *exactly* the order a loop of
    /// scalar [`ShamirCtx::share_deg`] calls draws them — secret 0's `deg`
    /// random coefficients first, then secret 1's, and so on — so a batched
    /// deal is draw-for-draw (and therefore share-for-share) identical to
    /// the scalar path. The cross-backend byte-identity contract of
    /// [`MpcSession`](crate::protocols::session::MpcSession) rests on this
    /// order; `tests::batch_share_matches_scalar_draw_for_draw` pins it
    /// against an independent Horner reference.
    ///
    /// Polynomial evaluation reads the precomputed Vandermonde power table,
    /// so dealing performs **zero heap allocation per element** (one
    /// reusable coefficient buffer per call) — the §Perf iteration-3 hot
    /// path (EXPERIMENTS.md). The per-party dot product itself is the
    /// deferred-reduction kernel of §Perf iteration 6 ([`Self::eval_row`]).
    pub fn share_batch_into<R: Rng + ?Sized>(
        &self,
        secrets: &[u128],
        deg: usize,
        rng: &mut R,
        out: &mut [u128],
    ) {
        let f = &self.f;
        let n = self.n;
        let k = secrets.len();
        assert_eq!(out.len(), n * k, "out must hold n·k = {}·{} shares", n, k);
        assert!(deg < n, "power table covers degrees < n (got deg={deg}, n={n})");
        let mut coeffs: Vec<u128> = Vec::with_capacity(deg + 1);
        for (e, &secret) in secrets.iter().enumerate() {
            coeffs.clear();
            coeffs.push(secret % f.p);
            for _ in 0..deg {
                coeffs.push(f.rand(rng));
            }
            for i in 0..n {
                out[i * k + e] = Self::eval_row(f, &coeffs, &self.vander[i * n..i * n + deg + 1]);
            }
        }
    }

    /// Coefficient/power dot product with **deferred modular reduction**
    /// (§Perf iteration 6). `Field::dot` reduces every term (a `u128 %`
    /// plus a compare-and-branch per coefficient); this kernel instead
    /// walks *fixed-width* chunks of raw [`Field::mul_unreduced`] folds —
    /// each fold is `< 2^119`, so a chunk of `CHUNK = 8` sums below
    /// `2^122` with no possibility of `u128` overflow — and reduces once
    /// per chunk, merging the partial into the running total with a
    /// branch-free conditional subtract (`acc < 2p` after the add, and
    /// `(acc >= p) as u128` is 0 or 1). The constant trip count of the
    /// inner loop is what lets the compiler unroll/vectorize it.
    ///
    /// Only *when* reduction happens changes, never the value mod p, and
    /// the result is kept canonical (`< p`) at every chunk boundary — so
    /// outputs are bit-identical to `f.dot` and the draw-order contract
    /// above is untouched (`tests::batch_share_matches_scalar_draw_for_draw`
    /// still pins the whole path against the legacy Horner reference).
    #[inline]
    fn eval_row(f: &Field, coeffs: &[u128], powers: &[u128]) -> u128 {
        debug_assert_eq!(coeffs.len(), powers.len());
        const CHUNK: usize = 8; // 8 · 2^119 < 2^122: headroom of 2^6 chunks
        let mut acc = 0u128;
        for (cs, ps) in coeffs.chunks(CHUNK).zip(powers.chunks(CHUNK)) {
            let mut part = 0u128;
            for (&c, &pw) in cs.iter().zip(ps) {
                part += f.mul_unreduced(c, pw);
            }
            acc += part % f.p;
            acc -= f.p * ((acc >= f.p) as u128);
        }
        acc
    }

    /// Deal one secret into `out` (`out[i-1]` = party i's share): the k = 1
    /// case of [`ShamirCtx::share_batch_into`], for protocol phases whose
    /// draw order interleaves several logical values per element (§3.4's
    /// r/q pairs) and therefore cannot batch across elements.
    pub fn share_into<R: Rng + ?Sized>(
        &self,
        secret: u128,
        deg: usize,
        rng: &mut R,
        out: &mut [u128],
    ) {
        self.share_batch_into(&[secret], deg, rng, out);
    }

    /// Reconstruct from all `n` shares (degree up to n-1, so also 2t).
    pub fn reconstruct(&self, shares: &[u128]) -> u128 {
        assert_eq!(shares.len(), self.n);
        self.f.dot(&self.lagrange0, shares)
    }

    /// Reconstruct from a subset of `(party_id, share)` pairs; needs at
    /// least `deg+1` points for a degree-`deg` polynomial.
    pub fn reconstruct_subset(&self, points: &[(usize, u128)], deg: usize) -> u128 {
        assert!(points.len() > deg, "not enough shares for degree {deg}");
        let xs: Vec<u128> = points.iter().map(|&(i, _)| i as u128).collect();
        let lam = Self::lagrange_at_zero(&self.f, &xs);
        let ys: Vec<u128> = points.iter().map(|&(_, y)| y).collect();
        self.f.dot(&lam, &ys)
    }

    /// The λ vector for full-set reconstruction (used by the degree-reduction
    /// step of secure multiplication: new_share_j = Σ_i λ_i · subshare_{i→j}).
    pub fn lambda(&self) -> &[u128] {
        &self.lagrange0
    }

    /// A "public constant" share: the constant polynomial, share = c for all.
    pub fn const_share(&self, c: u128) -> u128 {
        c % self.f.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, EXAMPLE_P};
    use crate::rng::Prng;

    fn ctx(n: usize) -> ShamirCtx {
        ShamirCtx::new(Field::paper(), n)
    }

    #[test]
    fn roundtrip_various_n() {
        let mut rng = Prng::seed_from_u64(1);
        for n in [1, 2, 3, 5, 13] {
            let c = ctx(n);
            for _ in 0..20 {
                let x = c.f.rand(&mut rng);
                let sh = c.share(x, &mut rng);
                assert_eq!(c.reconstruct(&sh), x, "n={n}");
            }
        }
    }

    #[test]
    fn reconstruct_from_t_plus_1_subset() {
        let mut rng = Prng::seed_from_u64(2);
        let c = ctx(7); // t = 3
        let x = 123456u128;
        let sh = c.share(x, &mut rng);
        let pts: Vec<(usize, u128)> = [2usize, 4, 5, 7].iter().map(|&i| (i, sh[i - 1])).collect();
        assert_eq!(c.reconstruct_subset(&pts, c.t), x);
    }

    #[test]
    fn t_shares_reveal_nothing_statistically() {
        // With t=2, any 2 shares of two different secrets are identically
        // distributed; smoke-test by bucketing share 1 of fixed secrets.
        let mut rng = Prng::seed_from_u64(3);
        let c = ShamirCtx::new(Field::new(EXAMPLE_P), 5);
        let mut b0 = [0u32; 8];
        let mut b1 = [0u32; 8];
        for _ in 0..4096 {
            b0[(c.share(0, &mut rng)[0] % 8) as usize] += 1;
            b1[(c.share(EXAMPLE_P - 1, &mut rng)[0] % 8) as usize] += 1;
        }
        for i in 0..8 {
            let (a, b) = (b0[i] as f64, b1[i] as f64);
            assert!((a - b).abs() / (a + b) < 0.2, "{b0:?} vs {b1:?}");
        }
    }

    #[test]
    fn linear_homomorphism() {
        let mut rng = Prng::seed_from_u64(4);
        let c = ctx(5);
        let f = &c.f;
        let (x, y) = (f.rand(&mut rng), f.rand(&mut rng));
        let sx = c.share(x, &mut rng);
        let sy = c.share(y, &mut rng);
        let alpha = 7u128;
        let sz: Vec<u128> = sx
            .iter()
            .zip(&sy)
            .map(|(&a, &b)| f.add(f.mul(alpha, a), b))
            .collect();
        assert_eq!(c.reconstruct(&sz), f.add(f.mul(alpha, x), y));
    }

    #[test]
    fn share_products_reconstruct_with_degree_2t() {
        let mut rng = Prng::seed_from_u64(5);
        let c = ctx(5); // t=2, 2t=4 < 5
        let f = &c.f;
        let (x, y) = (12345u128, 9999u128);
        let sx = c.share(x, &mut rng);
        let sy = c.share(y, &mut rng);
        let prod: Vec<u128> = sx.iter().zip(&sy).map(|(&a, &b)| f.mul(a, b)).collect();
        assert_eq!(c.reconstruct(&prod), f.mul(x, y));
    }

    #[test]
    fn const_share_reconstructs() {
        let c = ctx(5);
        let sh = vec![c.const_share(42); 5];
        assert_eq!(c.reconstruct(&sh), 42);
    }

    #[test]
    #[should_panic]
    fn rejects_threshold_too_high_for_mult() {
        ShamirCtx::with_threshold(Field::paper(), 4, 2); // 2t = 4 >= n
    }

    /// The seed implementation of `share_deg` (per-secret coefficient Vec +
    /// per-party Horner chain), kept verbatim as the reference the batched
    /// Vandermonde path must match draw-for-draw and share-for-share.
    fn share_deg_reference(
        c: &ShamirCtx,
        secret: u128,
        deg: usize,
        rng: &mut Prng,
    ) -> Vec<u128> {
        let f = &c.f;
        let mut coeffs = Vec::with_capacity(deg + 1);
        coeffs.push(secret % f.p);
        for _ in 0..deg {
            coeffs.push(f.rand(rng));
        }
        (1..=c.n as u128)
            .map(|x| coeffs.iter().rev().fold(0u128, |acc, &cf| f.add(f.mul(acc, x), cf)))
            .collect()
    }

    #[test]
    fn batch_share_matches_scalar_draw_for_draw() {
        // share_batch_into ≡ a loop of scalar share calls: same Prng seed →
        // identical flat buffer AND identical post-call RNG position (so a
        // protocol step after a batched deal sees the same stream a scalar
        // deal would leave). Checked against the legacy Horner reference,
        // not against share_deg (which now delegates to the batch path).
        crate::rng::property(64, |rng| {
            let n = 1 + rng.gen_range_u64(13) as usize;
            let c = ctx(n);
            let k = rng.gen_range_u64(9) as usize;
            let deg = if rng.gen_bool(0.5) { c.t } else { 2 * c.t };
            let secrets: Vec<u128> = (0..k).map(|_| c.f.rand(rng)).collect();

            let mut r_batch = Prng::seed_from_u64(0xBA7C4 + n as u64);
            let mut r_scalar = r_batch.clone();
            let mut flat = vec![0u128; n * k];
            c.share_batch_into(&secrets, deg, &mut r_batch, &mut flat);
            for (e, &s) in secrets.iter().enumerate() {
                let want = share_deg_reference(&c, s, deg, &mut r_scalar);
                for i in 0..n {
                    assert_eq!(flat[i * k + e], want[i], "n={n} k={k} deg={deg} e={e} i={i}");
                }
                assert_eq!(c.reconstruct(&want), s % c.f.p);
            }
            assert_eq!(
                r_batch.next_u64(),
                r_scalar.next_u64(),
                "batch and scalar dealing must consume the same number of draws"
            );
        });
    }

    #[test]
    fn eval_row_matches_field_dot_exactly() {
        // The deferred-reduction kernel is an optimization seam only: for
        // every length (sub-chunk, exact chunk, multi-chunk) and random
        // operands it must reproduce Field::dot bit-for-bit.
        let f = Field::paper();
        crate::rng::property(128, |rng| {
            let len = 1 + rng.gen_range_u64(20) as usize;
            let cs: Vec<u128> = (0..len).map(|_| f.rand(rng)).collect();
            let ps: Vec<u128> = (0..len).map(|_| f.rand(rng)).collect();
            assert_eq!(ShamirCtx::eval_row(&f, &cs, &ps), f.dot(&cs, &ps), "len={len}");
        });
    }

    #[test]
    fn share_into_is_the_k1_batch() {
        let c = ctx(5);
        let mut r1 = Prng::seed_from_u64(42);
        let mut r2 = Prng::seed_from_u64(42);
        let mut out = vec![0u128; 5];
        c.share_into(9999, c.t, &mut r1, &mut out);
        assert_eq!(out, c.share_deg(9999, c.t, &mut r2));
        assert_eq!(c.reconstruct(&out), 9999);
    }

    #[test]
    #[should_panic]
    fn batch_share_rejects_wrong_buffer_size() {
        let c = ctx(5);
        let mut rng = Prng::seed_from_u64(7);
        let mut out = vec![0u128; 9]; // needs 5·2 = 10
        c.share_batch_into(&[1, 2], c.t, &mut rng, &mut out);
    }

    #[test]
    fn prop_roundtrip_deg_t_and_2t() {
        crate::rng::property(128, |rng| {
            let n = 1 + rng.gen_range_u64(13) as usize;
            let c = ctx(n);
            let x = c.f.rand(rng);
            let sh = c.share_deg(x, c.t, rng);
            assert_eq!(c.reconstruct(&sh), x);
            let sh2 = c.share_deg(x, 2 * c.t, rng);
            assert_eq!(c.reconstruct(&sh2), x);
        });
    }
}
