//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64) and a tiny
//! property-test driver.
//!
//! The vendored crate set has no `rand`; this module provides what the
//! protocols need: uniform u64/u128, ranges, and bit-masked draws.
//!
//! **Security note.** xoshiro256++ is a *statistical* generator. The
//! simulation results (message counts, accuracy, timing) do not depend on
//! cryptographic strength, and determinism is what makes the tables and
//! tests reproducible. A deployment of these protocols must swap in a
//! CSPRNG (e.g. ChaCha20) behind the same interface — the `Rng` trait
//! below is the seam.

/// Minimal RNG interface used throughout the crate.
pub trait Rng {
    fn next_u64(&mut self) -> u64;

    fn next_u128(&mut self) -> u128 {
        ((self.next_u64() as u128) << 64) | self.next_u64() as u128
    }

    /// Uniform in `[0, bound)` via rejection sampling (bound > 0).
    fn gen_range_u128(&mut self, bound: u128) -> u128 {
        assert!(bound > 0);
        let bits = 128 - (bound - 1).leading_zeros();
        let mask = if bits >= 128 { u128::MAX } else { (1u128 << bits) - 1 };
        loop {
            let x = self.next_u128() & mask;
            if x < bound {
                return x;
            }
        }
    }

    fn gen_range_u64(&mut self, bound: u64) -> u64 {
        self.gen_range_u128(bound as u128) as u64
    }

    /// Uniform in `[0, 2^bits)`.
    fn gen_bits(&mut self, bits: u32) -> u128 {
        assert!(bits > 0 && bits <= 128);
        if bits == 128 {
            self.next_u128()
        } else {
            self.next_u128() & ((1u128 << bits) - 1)
        }
    }

    /// Uniform f64 in [0, 1).
    fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }
}

/// xoshiro256++ by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, as recommended by the xoshiro authors.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Xoshiro256 { s }
    }
}

impl Rng for Xoshiro256 {
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

/// Default generator used across the crate.
pub type Prng = Xoshiro256;

/// Tiny property-test driver: run `f` on `cases` seeded RNGs. Failures
/// report the case seed so they can be replayed as a unit test.
pub fn property(cases: u64, mut f: impl FnMut(&mut Prng)) {
    for case in 0..cases {
        let mut rng = Prng::seed_from_u64(0x5EED_0000 + case);
        f(&mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::seed_from_u64(1);
        let mut b = Prng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::seed_from_u64(2);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn range_respects_bound() {
        let mut r = Prng::seed_from_u64(3);
        for bound in [1u128, 2, 7, 1 << 20, u64::MAX as u128, u128::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range_u128(bound) < bound);
            }
        }
    }

    #[test]
    fn bits_respects_width() {
        let mut r = Prng::seed_from_u64(4);
        for bits in [1u32, 8, 63, 64, 74, 127, 128] {
            for _ in 0..100 {
                let x = r.gen_bits(bits);
                if bits < 128 {
                    assert!(x < 1u128 << bits);
                }
            }
        }
    }

    #[test]
    fn uniformish_buckets() {
        let mut r = Prng::seed_from_u64(5);
        let mut buckets = [0u32; 16];
        for _ in 0..16000 {
            buckets[(r.gen_range_u64(16)) as usize] += 1;
        }
        for &b in &buckets {
            assert!((800..1200).contains(&b), "{buckets:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::seed_from_u64(6);
        let mut acc = 0.0;
        for _ in 0..10000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        assert!((acc / 10000.0 - 0.5).abs() < 0.02);
    }
}
