//! Node-based SPN DAG (§2.3): arbitrary sum/product/leaf graphs with
//! validation and exact evaluation — the general substrate underneath the
//! layered artifact format, and home of the paper's Figure-1 example.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

/// Leaf semantics: indicator of `var == value` (Figure 1 style) or a
/// Bernoulli with parameter θ (SPFlow style).
#[derive(Clone, Debug)]
pub enum Node {
    Indicator { var: usize, value: u8 },
    Bernoulli { var: usize, theta: f64 },
    Sum { children: Vec<usize>, weights: Vec<f64> },
    Product { children: Vec<usize> },
}

/// An SPN as a node arena; `root` indexes into `nodes`. Children must have
/// smaller indices than their parents (topological by construction).
#[derive(Clone, Debug, Default)]
pub struct Spn {
    pub nodes: Vec<Node>,
    pub root: usize,
    pub num_vars: usize,
}

impl Spn {
    pub fn add(&mut self, n: Node) -> usize {
        if let Node::Indicator { var, .. } | Node::Bernoulli { var, .. } = n {
            self.num_vars = self.num_vars.max(var + 1);
        }
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    /// Scope (set of variables) per node.
    pub fn scopes(&self) -> Vec<BTreeSet<usize>> {
        let mut out: Vec<BTreeSet<usize>> = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let s = match n {
                Node::Indicator { var, .. } | Node::Bernoulli { var, .. } => {
                    BTreeSet::from([*var])
                }
                Node::Sum { children, .. } | Node::Product { children } => {
                    let mut s = BTreeSet::new();
                    for &c in children {
                        s.extend(out[c].iter().copied());
                    }
                    s
                }
            };
            out.push(s);
        }
        out
    }

    /// Validate: child ordering, completeness (sum children share scope),
    /// decomposability (product children disjoint), normalized weights.
    pub fn validate(&self) -> Result<()> {
        if self.root >= self.nodes.len() {
            bail!("root out of range");
        }
        let scopes = self.scopes();
        for (i, n) in self.nodes.iter().enumerate() {
            match n {
                Node::Sum { children, weights } => {
                    if children.is_empty() || children.len() != weights.len() {
                        bail!("sum {i}: bad children/weights");
                    }
                    if children.iter().any(|&c| c >= i) {
                        bail!("sum {i}: child ordering violated");
                    }
                    let s0 = &scopes[children[0]];
                    if children.iter().any(|&c| &scopes[c] != s0) {
                        bail!("sum {i} is not complete");
                    }
                    let tot: f64 = weights.iter().sum();
                    if (tot - 1.0).abs() > 1e-6 || weights.iter().any(|&w| w < 0.0) {
                        bail!("sum {i}: weights must be a distribution (sum={tot})");
                    }
                }
                Node::Product { children } => {
                    if children.is_empty() {
                        bail!("product {i}: no children");
                    }
                    if children.iter().any(|&c| c >= i) {
                        bail!("product {i}: child ordering violated");
                    }
                    let mut seen: BTreeSet<usize> = BTreeSet::new();
                    for &c in children {
                        if !scopes[c].is_disjoint(&seen) {
                            bail!("product {i} is not decomposable");
                        }
                        seen.extend(scopes[c].iter().copied());
                    }
                }
                Node::Bernoulli { theta, .. } => {
                    if !(0.0..=1.0).contains(theta) {
                        bail!("bernoulli {i}: theta out of range");
                    }
                }
                Node::Indicator { value, .. } => {
                    if *value > 1 {
                        bail!("indicator {i}: value must be 0/1");
                    }
                }
            }
        }
        Ok(())
    }

    /// Check selectivity empirically on all 2^v complete instances (small v)
    /// — at most one child of every sum node positive.
    pub fn is_selective_exhaustive(&self) -> bool {
        assert!(self.num_vars <= 16, "exhaustive check only for small SPNs");
        for bits in 0..(1u32 << self.num_vars) {
            let x: Vec<u8> = (0..self.num_vars).map(|v| ((bits >> v) & 1) as u8).collect();
            let vals = self.eval_all(&x, &vec![false; self.num_vars]);
            for n in &self.nodes {
                if let Node::Sum { children, .. } = n {
                    let pos = children.iter().filter(|&&c| vals[c] > 0.0).count();
                    if pos > 1 {
                        return false;
                    }
                }
            }
        }
        true
    }

    /// Evaluate all node values for one instance. `marg[v]` marginalizes v
    /// (its leaves evaluate to 1).
    pub fn eval_all(&self, x: &[u8], marg: &[bool]) -> Vec<f64> {
        let mut vals = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let v = match n {
                Node::Indicator { var, value } => {
                    if marg[*var] {
                        1.0
                    } else if x[*var] == *value {
                        1.0
                    } else {
                        0.0
                    }
                }
                Node::Bernoulli { var, theta } => {
                    if marg[*var] {
                        1.0
                    } else if x[*var] == 1 {
                        *theta
                    } else {
                        1.0 - *theta
                    }
                }
                Node::Sum { children, weights } => children
                    .iter()
                    .zip(weights)
                    .map(|(&c, &w)| w * vals[c])
                    .sum(),
                Node::Product { children } => children.iter().map(|&c| vals[c]).product(),
            };
            vals.push(v);
        }
        vals
    }

    /// Root value S(x) (with marginalization).
    pub fn eval(&self, x: &[u8], marg: &[bool]) -> f64 {
        self.eval_all(x, marg)[self.root]
    }

    /// Marginal query Pr(x | e) = S(x ∧ e) / S(e) (§4 of the paper).
    pub fn conditional(&self, xe: &[u8], x_vars: &[usize], e_vars: &[usize]) -> f64 {
        let mut marg_all = vec![true; self.num_vars];
        for &v in x_vars.iter().chain(e_vars) {
            marg_all[v] = false;
        }
        let s_xe = self.eval(xe, &marg_all);
        let mut marg_e = vec![true; self.num_vars];
        for &v in e_vars {
            marg_e[v] = false;
        }
        let s_e = self.eval(xe, &marg_e);
        s_xe / s_e
    }
}

/// The paper's Figure-1 SPN over X1, X2 (weights as printed).
pub fn figure1() -> Spn {
    let mut g = Spn::default();
    let x1 = g.add(Node::Indicator { var: 0, value: 1 });
    let nx1 = g.add(Node::Indicator { var: 0, value: 0 });
    let x2 = g.add(Node::Indicator { var: 1, value: 1 });
    let nx2 = g.add(Node::Indicator { var: 1, value: 0 });
    let s1 = g.add(Node::Sum { children: vec![x1, nx1], weights: vec![0.3, 0.7] });
    let s2 = g.add(Node::Sum { children: vec![x1, nx1], weights: vec![0.6, 0.4] });
    let s3 = g.add(Node::Sum { children: vec![x2, nx2], weights: vec![0.2, 0.8] });
    let s4 = g.add(Node::Sum { children: vec![x2, nx2], weights: vec![0.1, 0.9] });
    let p1 = g.add(Node::Product { children: vec![s1, s3] });
    let p2 = g.add(Node::Product { children: vec![s1, s4] });
    let p3 = g.add(Node::Product { children: vec![s2, s4] });
    let s = g.add(Node::Sum { children: vec![p1, p2, p3], weights: vec![0.4, 0.5, 0.1] });
    g.root = s;
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure1_validates() {
        figure1().validate().unwrap();
    }

    #[test]
    fn figure1_matches_hand_computation() {
        let g = figure1();
        // x = (X1=1, X2=1): S1=0.3 S2=0.6 S3=0.2 S4=0.1
        // P1=0.06 P2=0.03 P3=0.06, S = 0.4*0.06+0.5*0.03+0.1*0.06 = 0.045
        let v = g.eval(&[1, 1], &[false, false]);
        assert!((v - 0.045).abs() < 1e-12, "{v}");
    }

    #[test]
    fn figure1_normalized() {
        let g = figure1();
        let total: f64 = (0..4)
            .map(|b| g.eval(&[(b & 1) as u8, (b >> 1) as u8], &[false, false]))
            .sum();
        assert!((total - 1.0).abs() < 1e-12);
        // full marginalization = 1
        assert!((g.eval(&[0, 0], &[true, true]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn conditional_is_bayes_consistent() {
        let g = figure1();
        // Pr(X1=1 | X2=1) = S(x1=1, x2=1)/S(x2=1)
        let joint = g.eval(&[1, 1], &[false, false]);
        let ev = g.eval(&[1, 1], &[true, false]);
        let c = g.conditional(&[1, 1], &[0], &[1]);
        assert!((c - joint / ev).abs() < 1e-12);
        assert!((0.0..=1.0).contains(&c));
    }

    #[test]
    fn figure1_is_not_selective() {
        // Figure 1's sums mix both indicator polarities: both children can
        // be positive under marginalization... but for complete evidence an
        // indicator pair sum has exactly one positive child; the ROOT sum
        // mixes overlapping products and is not selective.
        let g = figure1();
        assert!(!g.is_selective_exhaustive());
    }

    #[test]
    fn validation_catches_bad_networks() {
        // incomplete sum
        let mut g = Spn::default();
        let a = g.add(Node::Indicator { var: 0, value: 1 });
        let b = g.add(Node::Indicator { var: 1, value: 1 });
        let s = g.add(Node::Sum { children: vec![a, b], weights: vec![0.5, 0.5] });
        g.root = s;
        assert!(g.validate().is_err());

        // non-decomposable product
        let mut g = Spn::default();
        let a = g.add(Node::Indicator { var: 0, value: 1 });
        let b = g.add(Node::Indicator { var: 0, value: 0 });
        let p = g.add(Node::Product { children: vec![a, b] });
        g.root = p;
        assert!(g.validate().is_err());

        // unnormalized weights
        let mut g = Spn::default();
        let a = g.add(Node::Indicator { var: 0, value: 1 });
        let b = g.add(Node::Indicator { var: 0, value: 0 });
        let s = g.add(Node::Sum { children: vec![a, b], weights: vec![0.5, 0.9] });
        g.root = s;
        assert!(g.validate().is_err());
    }

    #[test]
    fn bernoulli_leaves_evaluate() {
        let mut g = Spn::default();
        let a = g.add(Node::Bernoulli { var: 0, theta: 0.25 });
        let b = g.add(Node::Bernoulli { var: 1, theta: 0.5 });
        let p = g.add(Node::Product { children: vec![a, b] });
        g.root = p;
        g.validate().unwrap();
        assert!((g.eval(&[1, 0], &[false, false]) - 0.125).abs() < 1e-12);
        assert!((g.eval(&[1, 0], &[false, true]) - 0.25).abs() < 1e-12);
    }
}
