#![allow(dead_code)]
//! Shared helpers for the bench targets (plain-main harness; the vendored
//! crate set has no criterion).

use spn_mpc::coordinator::train::{train, TrainConfig, TrainReport};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::spn::eval;
use spn_mpc::spn::structure::Structure;

pub const DEBD: [&str; 4] = ["nltcs", "jester", "baudio", "bnetflix"];

pub fn load(name: &str) -> Structure {
    let p = format!("{}/artifacts/{name}.structure.json", env!("CARGO_MANIFEST_DIR"));
    Structure::load(p).expect("run `make artifacts` first")
}

/// Full private-training accounting run for one dataset (native counts —
/// the runtime path is exercised by the examples/integration tests; benches
/// measure the protocol).
pub fn train_run(name: &str, members: usize, schedule: Schedule) -> (TrainReport, f64) {
    let st = load(name);
    let gt = datasets::ground_truth_params(&st, 7);
    let data = datasets::sample(&st, &gt, st.rows, 42);
    let shards = datasets::partition(&data, members);
    let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
    let mut cfg = EngineConfig::new(members);
    cfg.schedule = schedule;
    let mut eng = Engine::new(Field::paper(), cfg);
    let t0 = std::time::Instant::now();
    let (_, report) = train(&mut eng, &st, &counts, st.rows as u64, &TrainConfig::default());
    (report, t0.elapsed().as_secs_f64())
}
