//! §6 / Eq. (7): the division primitive powering private k-means — cost and
//! accuracy across party counts and cluster counts.

use spn_mpc::bench::JsonSink;
use spn_mpc::field::Field;
use spn_mpc::kmeans::{plain_kmeans, private_kmeans, KmeansConfig, PartyData};
use spn_mpc::metrics::render_table;
use spn_mpc::protocols::division::DivisionConfig;
use spn_mpc::protocols::engine::{Engine, EngineConfig};
use spn_mpc::rng::{Prng, Rng};

fn make_blobs(k: usize, per: usize, seed: u64) -> Vec<Vec<i64>> {
    let mut rng = Prng::seed_from_u64(seed);
    let centers: Vec<(i64, i64)> =
        (0..k).map(|i| (150 + 350 * (i as i64 % 3), 200 + 400 * (i as i64 / 3))).collect();
    (0..k * per)
        .map(|i| {
            let (cx, cy) = centers[i % k];
            vec![
                cx + rng.gen_range_u64(100) as i64 - 50,
                cy + rng.gen_range_u64(100) as i64 - 50,
            ]
        })
        .collect()
}

fn main() {
    let mut json = JsonSink::from_env_args();
    let mut rows = Vec::new();
    for (members, k) in [(2usize, 2usize), (3, 2), (3, 3), (5, 3), (5, 4)] {
        let all = make_blobs(k, 60, 9);
        let mut parties = vec![PartyData { points: vec![] }; members];
        for (i, p) in all.iter().enumerate() {
            parties[i % members].points.push(p.clone());
        }
        let init: Vec<Vec<i64>> =
            (0..k).map(|i| vec![400 + 7 * i as i64, 450 - 11 * i as i64]).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(members).batched());
        let cfg = KmeansConfig { k, iters: 12, division: DivisionConfig::default() };
        let t0 = std::time::Instant::now();
        let out = private_kmeans(&mut eng, &parties, &init, &cfg);
        let wall = t0.elapsed().as_secs_f64();
        let plain = plain_kmeans(&all, &init, 12);
        let mut max_dev = 0i64;
        for (a, b) in out.centroids.iter().zip(&plain) {
            for (x, y) in a.iter().zip(b) {
                max_dev = max_dev.max((x - y).abs());
            }
        }
        assert!(max_dev <= 8, "centroids must match plaintext Lloyd's");
        let case = format!("n{members}_k{k}");
        json.push("kmeans", &format!("{case}_messages"), out.stats.messages as f64);
        json.push("kmeans", &format!("{case}_virtual_s"), out.stats.virtual_time_s);
        json.push("kmeans", &format!("{case}_wall_s"), wall);
        json.push("kmeans", &format!("{case}_max_dev"), max_dev as f64);
        rows.push(vec![
            format!("{members}"),
            format!("{k}"),
            format!("{}", out.iterations_run),
            format!("{max_dev}"),
            format!("{}", out.stats.messages),
            format!("{:.1}", out.stats.virtual_time_s),
            format!("{:.2}", wall),
        ]);
    }
    println!(
        "{}",
        render_table(
            "Private k-means on Eq. (7) divisions (batched schedule)",
            &["members", "k", "iters", "max centroid dev", "messages", "virtual s", "wall s"],
            &rows
        )
    );
    json.finish().expect("write --json output");
    println!("kmeans bench OK");
}
