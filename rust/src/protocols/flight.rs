//! The multi-op *flight* surface of the pipelined round engine
//! (DESIGN.md §Round scheduler).
//!
//! A **flight** is a group of secure operations whose network traffic is
//! coalesced into one framed message per member per physical round: the
//! Manager *stages* operations with [`MpcSession::submit`] (which returns
//! their output [`DataId`]s immediately — ids are Manager-assigned and
//! need no round trip) and then *launches* the whole group with
//! [`MpcSession::complete`]. The compiled-plan batch evaluator uses one
//! flight per dependency-DAG wave, so a batch's secure rounds drop to the
//! DAG's critical-path depth instead of the plan's step count.
//!
//! Only the three inference primitives are flightable — `mul`, `lin` and
//! *tagged* divpub. Untagged divpub is deliberately absent: its rounding
//! mask comes from Alice's RNG *stream position*, so reordering or
//! coalescing it would change revealed values. Tagged divpub's mask is
//! `PRF(seed, tag)` ([`super::divpub::tagged_r`]), a pure function of the
//! element's identity, which is exactly what makes a flight's regrouping
//! of traffic byte-transparent: `mul`/`lin` are value-exact on
//! reconstruction (share randomness cancels) and every divpub's ±1
//! rounding is pinned by its tag, not by when its exercise ran.
//!
//! Within one flight, a staged op may read the outputs of *earlier* ops in
//! the same flight (the evaluator's per-wave `Mul → Lin → DivpubTagged`
//! chain relies on it); both backends execute staged ops in submission
//! order, so the dataflow resolves without an extra barrier. Ops must be
//! non-empty — a wave with nothing of some kind simply does not stage that
//! kind.
//!
//! [`MpcSession::submit`]: super::session::MpcSession::submit
//! [`MpcSession::complete`]: super::session::MpcSession::complete

use super::engine::DataId;

/// One staged operation of a flight. Mirrors the vectorized session
/// primitives ([`mul_vec`], [`lin_vec`], [`divpub_vec_tagged`]) — a
/// backend without a coalescing transport executes each exactly as the
/// corresponding direct call.
///
/// [`mul_vec`]: super::session::MpcSession::mul_vec
/// [`lin_vec`]: super::session::MpcSession::lin_vec
/// [`divpub_vec_tagged`]: super::session::MpcSession::divpub_vec_tagged
#[derive(Clone, Debug)]
pub enum FlightOp {
    /// Secure multiplications (BGW resharing) for all pairs.
    Mul(Vec<(DataId, DataId)>),
    /// Affine exercises `c0 + Σ ck·[ak]` (local math, scheduled exercise).
    Lin(Vec<(i128, Vec<(i128, DataId)>)>),
    /// Order-invariant divisions by public `d`, one fresh tag per element.
    DivpubTagged { us: Vec<DataId>, d: u128, tags: Vec<u64> },
}

/// The kind of a [`FlightOp`] — what the wire/accounting layers dispatch on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlightOpKind {
    Mul,
    Lin,
    DivpubTagged,
}

impl FlightOp {
    /// Number of vector elements (= output ids) the op produces.
    pub fn len(&self) -> usize {
        match self {
            FlightOp::Mul(pairs) => pairs.len(),
            FlightOp::Lin(ops) => ops.len(),
            FlightOp::DivpubTagged { us, .. } => us.len(),
        }
    }

    /// Whether the op is empty (backends may reject empty ops; the
    /// evaluator never stages one).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The op's kind.
    pub fn kind(&self) -> FlightOpKind {
        match self {
            FlightOp::Mul(_) => FlightOpKind::Mul,
            FlightOp::Lin(_) => FlightOpKind::Lin,
            FlightOp::DivpubTagged { .. } => FlightOpKind::DivpubTagged,
        }
    }
}

/// Secure rounds one coalesced flight costs under the Sim accountant
/// (per batch, independent of how many ops of each kind were staged):
///
/// * a base of **2** — the schedule broadcast and the completion sweep,
///   what a lone affine exercise already pays (`lin_vec` = 2 rounds);
/// * **+1** if the flight contains any multiplication — the single mesh
///   resharing exchange every coalesced `mul` shares;
/// * **+3** if it contains any tagged divpub — the Alice-deal, z'-opening
///   and Bob-deal relay trio, shared by every coalesced division
///   (sequential divpub = 5 rounds = this 3 plus the base 2).
///
/// [`Engine::complete`](super::engine::Engine) re-attributes the rounds of
/// a finished flight to this closed form (messages, bytes and exercises
/// keep their exact per-op accounting — coalescing moves *latency*, not
/// traffic); [`CheckedSession`](super::checked::CheckedSession) re-derives
/// it independently and panics if a backend's accounting drifts.
pub fn sim_flight_rounds(has_mul: bool, has_divpub: bool) -> u64 {
    2 + has_mul as u64 + 3 * has_divpub as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flight_op_len_and_kind() {
        let m = FlightOp::Mul(vec![(DataId(1), DataId(2)), (DataId(3), DataId(4))]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.kind(), FlightOpKind::Mul);
        assert!(!m.is_empty());
        let l = FlightOp::Lin(vec![(0, vec![(1, DataId(1))])]);
        assert_eq!(l.len(), 1);
        assert_eq!(l.kind(), FlightOpKind::Lin);
        let d = FlightOp::DivpubTagged { us: vec![], d: 256, tags: vec![] };
        assert!(d.is_empty());
        assert_eq!(d.kind(), FlightOpKind::DivpubTagged);
    }

    #[test]
    fn flight_rounds_closed_form() {
        // lone lin flight = a lin exercise; divpub-only = a divpub; the
        // full mul+divpub wave of the batch evaluator = 6.
        assert_eq!(sim_flight_rounds(false, false), 2);
        assert_eq!(sim_flight_rounds(true, false), 3);
        assert_eq!(sim_flight_rounds(false, true), 5);
        assert_eq!(sim_flight_rounds(true, true), 6);
    }
}
