//! Simulated Manager/Member network (§5.2 + Appendix A of the paper).
//!
//! The paper's testbed runs one Manager and N Members over WebSockets with a
//! 10 ms internal latency and reports *message counts*, *traffic* and
//! *wall-clock time* (Tables 2–3).  Those quantities are deterministic
//! functions of the protocol schedule, so we reproduce them with a
//! discrete-event accounting model instead of sleeping through hours of
//! virtual latency:
//!
//! * every logical message is counted exactly (count + serialized bytes);
//! * virtual time advances per communication *round*: all messages sent in
//!   one round travel in parallel, costing `latency + max_bytes/bandwidth`;
//! * the Manager schedules exercises sequentially, exactly like Appendix A:
//!   a schedule broadcast down, the exercise's internal rounds, then a
//!   "finished" message from every member — all accounted.
//!
//! A real TCP transport with the same wire format lives in [`tcp`], and
//! [`tcp_session::TcpSession`] drives the full session vocabulary over it —
//! the deployment-path implementation of
//! [`MpcSession`](crate::protocols::session::MpcSession), byte-identical to
//! the simulation under the same seed.

pub mod backoff;
pub mod fault;
pub mod fleet;
pub mod serve;
pub mod tcp;
pub mod tcp_session;
pub mod wire;

/// Health of one manager↔member link, as observed by the transport
/// (DESIGN.md §Fleet). [`tcp_session::TcpSession`] tracks one per member:
/// a reply slower than the soft threshold marks the link `Degraded`; an
/// I/O error (including a tripped read/write deadline) marks it `Down`.
/// Surfaced per shard through
/// [`MpcSession::link_states`](crate::protocols::session::MpcSession::link_states)
/// into [`fleet::ShardReport`] and the serve status line. The Sim backend
/// has no links and reports an empty vector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MemberLinkState {
    /// Replies arrive within the soft latency threshold.
    #[default]
    Up,
    /// Recent replies were slow — the member may be about to fail.
    Degraded,
    /// An I/O error or deadline expiry ended the link.
    Down,
}

/// Wire/latency model. Defaults reproduce the paper's setting.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// One-way per-message latency (paper: 10 ms).
    pub latency_s: f64,
    /// Link bandwidth in bytes/second (paper: LAN; 1 Gbit/s assumed).
    pub bandwidth_bps: f64,
    /// Framing overhead per message: exercise id, sender id, data id, length.
    pub header_bytes: u64,
    /// Payload bytes per field element (74-bit prime → 10 bytes).
    pub share_bytes: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            latency_s: 0.010,
            bandwidth_bps: 125_000_000.0,
            header_bytes: 24,
            share_bytes: 10,
        }
    }
}

/// Exact traffic/time accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NetStats {
    /// Logical messages sent (schedule + body + finished).
    pub messages: u64,
    /// Serialized bytes (header + share payloads).
    pub bytes: u64,
    /// Communication rounds (parallel messages share a round).
    pub rounds: u64,
    /// Exercises the Manager scheduled.
    pub exercises: u64,
    /// Simulated wall-clock: Σ per-round `latency + max_bytes/bandwidth`.
    pub virtual_time_s: f64,
}

/// Component-wise sum — combine the costs of two protocol runs (e.g. the
/// two evaluations of a conditional query).
impl std::ops::Add for NetStats {
    type Output = NetStats;

    fn add(self, rhs: NetStats) -> NetStats {
        NetStats {
            messages: self.messages + rhs.messages,
            bytes: self.bytes + rhs.bytes,
            rounds: self.rounds + rhs.rounds,
            exercises: self.exercises + rhs.exercises,
            virtual_time_s: self.virtual_time_s + rhs.virtual_time_s,
        }
    }
}

impl NetStats {
    pub fn megabytes(&self) -> f64 {
        self.bytes as f64 / 1_000_000.0
    }

    /// Difference of two running-total snapshots: `self` (taken after a
    /// protocol ran) minus `before`. The standard way to cost one protocol
    /// run over any [`MpcSession`](crate::protocols::session::MpcSession).
    pub fn delta_since(&self, before: &NetStats) -> NetStats {
        NetStats {
            messages: self.messages - before.messages,
            bytes: self.bytes - before.bytes,
            rounds: self.rounds - before.rounds,
            exercises: self.exercises - before.exercises,
            virtual_time_s: self.virtual_time_s - before.virtual_time_s,
        }
    }
}

/// Discrete-event accountant for the simulated network.
#[derive(Clone, Debug)]
pub struct SimNet {
    /// The wire/latency model in force.
    pub cfg: NetConfig,
    /// Running totals; diff before/after a protocol to cost it.
    pub stats: NetStats,
    round_max_bytes: u64,
    round_open: bool,
}

impl SimNet {
    pub fn new(cfg: NetConfig) -> Self {
        SimNet { cfg, stats: NetStats::default(), round_max_bytes: 0, round_open: false }
    }

    /// Record one message carrying `elems` field elements. Messages recorded
    /// between two `end_round` calls travel in parallel.
    pub fn send(&mut self, _from: usize, _to: usize, elems: u64) {
        let bytes = self.cfg.header_bytes + elems * self.cfg.share_bytes;
        self.stats.messages += 1;
        self.stats.bytes += bytes;
        self.round_max_bytes = self.round_max_bytes.max(bytes);
        self.round_open = true;
    }

    /// Close a communication round: latency + serialization of the largest
    /// message in the round (links are parallel).
    pub fn end_round(&mut self) {
        if !self.round_open {
            return;
        }
        self.stats.rounds += 1;
        self.stats.virtual_time_s +=
            self.cfg.latency_s + self.round_max_bytes as f64 / self.cfg.bandwidth_bps;
        self.round_max_bytes = 0;
        self.round_open = false;
    }

    /// Account local computation time (measured off the critical path).
    pub fn compute(&mut self, seconds: f64) {
        self.stats.virtual_time_s += seconds;
    }

    /// Manager → members schedule broadcast + members → manager "finished"
    /// (Appendix A). Called around every exercise by the engine.
    pub fn exercise_overhead(&mut self, n: usize) {
        self.stats.exercises += 1;
        for m in 0..n {
            self.send(usize::MAX, m, 1); // schedule msg (small payload)
        }
        self.end_round();
        // body rounds happen in between (engine calls send/end_round)
    }

    pub fn exercise_finish(&mut self, n: usize) {
        self.end_round(); // flush any open body round
        for m in 0..n {
            self.send(m, usize::MAX, 0); // "finished"
        }
        self.end_round();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_messages_and_bytes() {
        let mut net = SimNet::new(NetConfig::default());
        net.send(0, 1, 3);
        net.send(1, 2, 1);
        net.end_round();
        assert_eq!(net.stats.messages, 2);
        assert_eq!(net.stats.bytes, 24 + 30 + 24 + 10);
        assert_eq!(net.stats.rounds, 1);
        assert!((net.stats.virtual_time_s - (0.010 + 54.0 / 125e6)).abs() < 1e-12);
    }

    #[test]
    fn parallel_messages_share_latency() {
        let mut net = SimNet::new(NetConfig::default());
        for i in 0..100 {
            net.send(0, i, 1);
        }
        net.end_round();
        assert_eq!(net.stats.rounds, 1);
        assert!(net.stats.virtual_time_s < 0.011);
    }

    #[test]
    fn empty_round_is_free() {
        let mut net = SimNet::new(NetConfig::default());
        net.end_round();
        net.end_round();
        assert_eq!(net.stats.rounds, 0);
        assert_eq!(net.stats.virtual_time_s, 0.0);
    }

    #[test]
    fn delta_since_diffs_every_counter() {
        let mut net = SimNet::new(NetConfig::default());
        net.send(0, 1, 3);
        net.end_round();
        let before = net.stats;
        net.send(1, 0, 2);
        net.send(0, 1, 2);
        net.end_round();
        let d = net.stats.delta_since(&before);
        assert_eq!(d.messages, 2);
        assert_eq!(d.rounds, 1);
        assert_eq!(d.bytes, 2 * (24 + 20));
        assert!(d.virtual_time_s > 0.0);
    }

    #[test]
    fn add_sums_every_counter() {
        let a = NetStats { messages: 3, bytes: 100, rounds: 2, exercises: 1, virtual_time_s: 0.5 };
        let b = NetStats { messages: 7, bytes: 11, rounds: 4, exercises: 2, virtual_time_s: 1.25 };
        let s = a + b;
        assert_eq!(s.messages, 10);
        assert_eq!(s.bytes, 111);
        assert_eq!(s.rounds, 6);
        assert_eq!(s.exercises, 3);
        assert!((s.virtual_time_s - 1.75).abs() < 1e-12);
    }

    #[test]
    fn exercise_overhead_counts_schedule_and_finished() {
        let mut net = SimNet::new(NetConfig::default());
        net.exercise_overhead(5);
        net.exercise_finish(5);
        assert_eq!(net.stats.messages, 10);
        assert_eq!(net.stats.exercises, 1);
        assert_eq!(net.stats.rounds, 2);
    }
}
