// L008 fixture: bare thread::sleep in the net layer. The path also ends
// in net/fleet.rs, an L004 path, so everything here is unwrap/expect-free.
// A comment mentioning thread::sleep must not fire.

pub fn wait_for_peer() {
    std::thread::sleep(std::time::Duration::from_millis(50));
}

pub fn sanctioned_wait() {
    // lint:allow(L008) — decoy: the line-above suppression must hold
    std::thread::sleep(std::time::Duration::from_millis(50));
}
