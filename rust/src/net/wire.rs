//! Shared frame-layout vocabulary of the TCP data plane: the opcode
//! numbers, frame geometry and party-major/element-major stride math that
//! [`super::tcp`] (framing) and [`super::tcp_session`] (the session
//! driver + member event loop) must agree on byte-for-byte.
//!
//! Both sides of the wire compile against *these* definitions, so a
//! layout change is a one-file edit the compiler propagates — and the
//! paired `wire-layout: v3` comment markers in `tcp.rs`/`tcp_session.rs`
//! (checked by spn-lint L005, see DESIGN.md §Static analysis) force the
//! prose documentation to move together with it.

/// Version of the frame layout. Bump when any constant or stride rule in
/// this module changes meaning, and update the `wire-layout: v3` markers
/// in `tcp.rs` and `tcp_session.rs` to match (spn-lint L005 enforces the
/// pairing). v3 added the coalesced [`OP_FLIGHT`] container frame of the
/// pipelined round engine.
pub const WIRE_LAYOUT_VERSION: u32 = 3;

/// Frame header: `exercise_id: u64 | from: u32 | n_elems: u32`.
pub const FRAME_HDR_BYTES: usize = 16;

/// One little-endian field element on the wire.
pub const ELEM_BYTES: usize = 16;

/// Upper bound on elements in one frame (256 MiB of payload — far above
/// any real exercise). A corrupt or desynced stream whose next 16 bytes
/// decode to an absurd length then fails as a diagnosable frame error
/// instead of a multi-GiB `Vec` allocation abort.
pub const MAX_FRAME_ELEMS: usize = 1 << 24;

/// Bytes on the wire for a frame of `n_elems` elements.
pub fn wire_bytes_for(n_elems: usize) -> usize {
    FRAME_HDR_BYTES + n_elems * ELEM_BYTES
}

// --- exercise opcodes -------------------------------------------------------
// First element of a broadcast frame. The vectorized vocabulary of the
// session API; every op carries its width k.

pub const OP_INPUT: u128 = 1;
pub const OP_CONST: u128 = 2;
pub const OP_LIN: u128 = 3;
pub const OP_MUL: u128 = 4;
pub const OP_DIVPUB: u128 = 5;
pub const OP_REVEAL: u128 = 6;
pub const OP_SQ2PQ: u128 = 7;
pub const OP_SHUTDOWN: u128 = 8;
pub const OP_DIVPUB_TAGGED: u128 = 9;
/// Coalesced multi-op container (wire-layout v3, the pipelined round
/// engine): `[OP_FLIGHT, n_runs, run₀.., run₁.., ..]` where each *run* is
/// byte-for-byte a standalone [`OP_MUL`], [`OP_LIN`] or
/// [`OP_DIVPUB_TAGGED`] broadcast body. Members execute runs in order
/// (later runs may reference earlier runs' output ids); the manager then
/// drives each run's relay phases in the same order, so one flight costs
/// one schedule broadcast however many ops it carries. Only those three
/// opcodes are flightable — untagged divpub's mask is stream-order-
/// dependent and must stay a standalone exercise.
pub const OP_FLIGHT: u128 = 10;

/// Length in elements of one flight run body starting at `e[0]`, or
/// `None` if `e[0]` is not a flightable opcode. This is the walk both
/// sides of the socket use to split an [`OP_FLIGHT`] frame back into its
/// runs, so it lives here with the rest of the layout math.
pub fn flight_run_len(e: &[u128]) -> Option<usize> {
    match e[0] {
        OP_MUL => Some(2 + 3 * e[1] as usize), // [op, k, outs, as, bs]
        OP_DIVPUB_TAGGED => Some(3 + 3 * e[1] as usize), // [op, k, d, outs, us, tags]
        OP_LIN => {
            // [op, k, (out, c0, t, (c, a)×t)×k] — variable, walk the ops
            let k = e[1] as usize;
            let mut i = 2;
            for _ in 0..k {
                let t = e[i + 2] as usize;
                i += 3 + 2 * t;
            }
            Some(i)
        }
        _ => None,
    }
}

// --- stride math ------------------------------------------------------------
// Dealer→manager frames for input/mul/sq2pq are party-major (the flat
// batch-dealing layout of `share_batch_into`); manager→member frames are
// element-major with dealer-inner stride; §3.4 divpub interleaves Alice's
// two deals per element (the draw-order contract).

/// Party-major dealer frame: slot of member `j`'s sub-share of element
/// `e` in a width-`k` deal (`dealt[j·k + e]`). `j` is 0-based.
#[inline]
pub fn party_major(j: usize, k: usize, e: usize) -> usize {
    j * k + e
}

/// Element-major relay frame with dealer-inner stride: slot of dealer
/// `i`'s sub-share of element `e` in an `n`-member session
/// (`out[e·n + i]`). `i` is 0-based.
#[inline]
pub fn element_major(e: usize, n: usize, i: usize) -> usize {
    e * n + i
}

/// Alice's divpub deal, `[r]` half: slot of member `j`'s sub-share of
/// element `e`'s mask `r` (`alice[e·2n + j]`).
#[inline]
pub fn divpub_r_slot(e: usize, n: usize, j: usize) -> usize {
    e * 2 * n + j
}

/// Alice's divpub deal, `[q = r mod d]` half: slot of member `j`'s
/// sub-share of element `e`'s `q` (`alice[e·2n + n + j]`).
#[inline]
pub fn divpub_q_slot(e: usize, n: usize, j: usize) -> usize {
    e * 2 * n + n + j
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_geometry() {
        assert_eq!(wire_bytes_for(0), FRAME_HDR_BYTES);
        assert_eq!(wire_bytes_for(3), 16 + 48);
    }

    #[test]
    fn flight_run_len_walks_each_flightable_body() {
        // [OP_MUL, k=2, outs×2, a×2, b×2] = 8 elements
        assert_eq!(flight_run_len(&[OP_MUL, 2, 9, 10, 1, 2, 3, 4]), Some(8));
        // [OP_DIVPUB_TAGGED, k=1, d, out, u, tag] = 6 elements
        assert_eq!(flight_run_len(&[OP_DIVPUB_TAGGED, 1, 256, 9, 1, 42]), Some(6));
        // [OP_LIN, k=2, (out, c0, t=1, c, a), (out, c0, t=0)] = 10 elements
        assert_eq!(
            flight_run_len(&[OP_LIN, 2, 9, 5, 1, 7, 3, 10, 0, 0]),
            Some(10)
        );
        // untagged divpub and everything else is unflightable
        assert_eq!(flight_run_len(&[OP_DIVPUB, 1, 256, 9, 1]), None);
        assert_eq!(flight_run_len(&[OP_REVEAL, 1, 9]), None);
        assert_eq!(flight_run_len(&[OP_FLIGHT, 0]), None);
    }

    #[test]
    fn strides_cover_their_frames_disjointly() {
        // party-major covers 0..n*k exactly once
        let (n, k) = (3usize, 4usize);
        let mut seen = vec![false; n * k];
        for j in 0..n {
            for e in 0..k {
                let s = party_major(j, k, e);
                assert!(!seen[s]);
                seen[s] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // divpub r/q halves tile 0..2nk without overlap
        let mut seen = vec![false; 2 * n * k];
        for e in 0..k {
            for j in 0..n {
                for s in [divpub_r_slot(e, n, j), divpub_q_slot(e, n, j)] {
                    assert!(!seen[s]);
                    seen[s] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
        assert_eq!(element_major(2, n, 1), 7);
    }
}
