//! L006 fixture: a design-doc reference that resolves nowhere.

/// The alias map is described in DESIGN.md §Totally Imaginary Section.
fn documented() {}
