// L008 carve-out fixture: net/backoff.rs is the one sanctioned home for a
// raw sleep (it IS the pause primitive), so nothing here may fire.

pub fn pause(d: std::time::Duration) {
    std::thread::sleep(d);
}
