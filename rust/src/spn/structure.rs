//! The layered structure format shared with the python compile path.
//!
//! `python/compile/structures.py` generates structures whose statistics
//! match Table 1 of the paper exactly, and serializes them as JSON; this
//! module parses and validates them on the rust side.  The same file is
//! baked (as dense matrices) into the counts/eval HLO artifacts, so both
//! sides agree on node numbering by construction.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Product,
    Sum,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamKind {
    /// A sum-edge weight (what the paper's protocol learns).
    SumEdge,
    /// A Bernoulli leaf parameter (learned only in `--learn-leaves` mode).
    Leaf,
}

/// One non-leaf layer. The layer's *input* is `concat(previous layer,
/// leaves)`; `cols` index into that concatenation.
#[derive(Clone, Debug)]
pub struct Layer {
    pub kind: LayerKind,
    pub width: usize,
    pub in_width: usize,
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
    /// Parameter id per edge; -1 for product edges.
    pub param: Vec<i64>,
}

/// Table-1 style statistics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stats {
    pub sum: usize,
    pub product: usize,
    pub leaf: usize,
    pub params: usize,
    pub edges: usize,
    pub layers: usize,
}

#[derive(Clone, Debug)]
pub struct Structure {
    pub name: String,
    pub num_vars: usize,
    /// DEBD-matched dataset row count for this structure's source dataset.
    pub rows: usize,
    pub leaf_var: Vec<usize>,
    pub leaf_claim: Vec<i64>, // -1 = plain Bernoulli, 0/1 = gate claim
    pub layer_widths: Vec<usize>,
    pub layer_offset: Vec<usize>,
    pub total_nodes: usize,
    pub layers: Vec<Layer>,
    pub num_params: usize,
    pub num_sum_edges: usize,
    pub param_kind: Vec<ParamKind>,
    /// Index into the counts vector (act counts ++ x1 counts) per param.
    pub param_num: Vec<usize>,
    pub param_den: Vec<usize>,
    /// Per-sum-node groups of sum-edge param ids (weights sum to 1).
    pub sum_groups: Vec<Vec<usize>>,
    pub stats: Stats,
}

impl Structure {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let s = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Self::from_json_str(&s)
    }

    pub fn from_json_str(s: &str) -> Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
        let layers = j
            .get("layers")
            .as_arr()
            .iter()
            .map(|l| {
                let kind = match l.get("kind").as_str() {
                    "product" => LayerKind::Product,
                    "sum" => LayerKind::Sum,
                    k => bail!("unknown layer kind {k}"),
                };
                Ok(Layer {
                    kind,
                    width: l.get("width").as_usize(),
                    in_width: l.get("in_width").as_usize(),
                    rows: l.get("rows").usize_vec(),
                    cols: l.get("cols").usize_vec(),
                    param: l.get("param").i64_vec(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let stats_j = j.get("stats");
        let st = Structure {
            name: j.get("name").as_str().to_string(),
            num_vars: j.get("num_vars").as_usize(),
            rows: j.get("rows").as_usize(),
            leaf_var: j.get("leaf_var").usize_vec(),
            leaf_claim: j.get("leaf_claim").i64_vec(),
            layer_widths: j.get("layer_widths").usize_vec(),
            layer_offset: j.get("layer_offset").usize_vec(),
            total_nodes: j.get("total_nodes").as_usize(),
            layers,
            num_params: j.get("num_params").as_usize(),
            num_sum_edges: j.get("num_sum_edges").as_usize(),
            param_kind: j
                .get("param_kind")
                .as_arr()
                .iter()
                .map(|k| match k.as_str() {
                    "sum" => ParamKind::SumEdge,
                    _ => ParamKind::Leaf,
                })
                .collect(),
            param_num: j.get("param_num").usize_vec(),
            param_den: j.get("param_den").usize_vec(),
            sum_groups: j.get("sum_groups").as_arr().iter().map(|g| g.usize_vec()).collect(),
            stats: Stats {
                sum: stats_j.get("sum").as_usize(),
                product: stats_j.get("product").as_usize(),
                leaf: stats_j.get("leaf").as_usize(),
                params: stats_j.get("params").as_usize(),
                edges: stats_j.get("edges").as_usize(),
                layers: stats_j.get("layers").as_usize(),
            },
        };
        st.validate()?;
        Ok(st)
    }

    /// Number of leaves (width of layer 0).
    pub fn num_leaves(&self) -> usize {
        self.layer_widths[0]
    }

    /// A miniature selective SPN built directly in code — no artifacts
    /// needed: 2 variables, 4 gate leaves, one product layer, one sum root,
    /// i.e. `w₀·[x₀=1 ∧ x₁=1] + w₁·[x₀=0 ∧ x₁=0]`. Small enough that the
    /// TCP backend trains it in well under a second, rich enough to
    /// exercise SQ2PQ, Newton, divpub and the layered inference ladder.
    /// Used by the artifact-free `cross_backend_*` integration tests and
    /// the `infer_batch` bench.
    pub fn mini_demo() -> Structure {
        let st = Structure {
            name: "mini".into(),
            num_vars: 2,
            rows: 240,
            leaf_var: vec![0, 1, 0, 1],
            leaf_claim: vec![1, 1, 0, 0],
            layer_widths: vec![4, 2, 1],
            layer_offset: vec![0, 4, 6],
            total_nodes: 7,
            layers: vec![
                Layer {
                    kind: LayerKind::Product,
                    width: 2,
                    in_width: 4,
                    rows: vec![0, 0, 1, 1],
                    cols: vec![0, 1, 2, 3],
                    param: vec![-1, -1, -1, -1],
                },
                Layer {
                    kind: LayerKind::Sum,
                    width: 1,
                    in_width: 6,
                    rows: vec![0, 0],
                    cols: vec![0, 1],
                    param: vec![0, 1],
                },
            ],
            num_params: 6,
            num_sum_edges: 2,
            param_kind: vec![
                ParamKind::SumEdge,
                ParamKind::SumEdge,
                ParamKind::Leaf,
                ParamKind::Leaf,
                ParamKind::Leaf,
                ParamKind::Leaf,
            ],
            param_num: vec![4, 5, 7, 8, 9, 10],
            param_den: vec![6, 6, 0, 1, 2, 3],
            sum_groups: vec![vec![0, 1]],
            stats: Stats { sum: 1, product: 2, leaf: 4, params: 2, edges: 6, layers: 2 },
        };
        st.validate().expect("mini structure must validate");
        st
    }

    /// Length of the counts vector the artifact emits.
    pub fn counts_len(&self) -> usize {
        self.total_nodes + self.num_leaves()
    }

    /// Structural validation: widths, edge bounds, alternation, parameter
    /// coverage, tree property (every non-root node has exactly one parent).
    pub fn validate(&self) -> Result<()> {
        let w0 = self.num_leaves();
        if self.leaf_var.len() != w0 || self.leaf_claim.len() != w0 {
            bail!("leaf arrays inconsistent with layer 0 width");
        }
        for &v in &self.leaf_var {
            if v >= self.num_vars {
                bail!("leaf var {v} out of range");
            }
        }
        if self.layer_widths.len() != self.layers.len() + 1 {
            bail!("layer_widths length mismatch");
        }
        let mut expect = LayerKind::Product;
        for (li, l) in self.layers.iter().enumerate() {
            if l.kind != expect {
                bail!("layer {li} breaks product/sum alternation");
            }
            expect = if expect == LayerKind::Product { LayerKind::Sum } else { LayerKind::Product };
            if l.width != self.layer_widths[li + 1] {
                bail!("layer {li} width mismatch");
            }
            let prev_w = if li > 0 { self.layer_widths[li] } else { 0 };
            if l.in_width != prev_w + w0 {
                bail!("layer {li} in_width mismatch");
            }
            if l.rows.len() != l.cols.len() || l.rows.len() != l.param.len() {
                bail!("layer {li} COO arrays inconsistent");
            }
            for (&r, &c) in l.rows.iter().zip(&l.cols) {
                if r >= l.width || c >= l.in_width {
                    bail!("layer {li} edge ({r},{c}) out of bounds");
                }
            }
            // every row must have at least one edge
            let mut deg = vec![0usize; l.width];
            for &r in &l.rows {
                deg[r] += 1;
            }
            if deg.iter().any(|&d| d == 0) {
                bail!("layer {li} has a childless node");
            }
        }
        if self.layers.last().map(|l| l.width) != Some(1) {
            bail!("root layer must have width 1");
        }
        // tree property
        let mut leaf_refs = vec![0usize; w0];
        for (li, l) in self.layers.iter().enumerate() {
            let prev_w = if li > 0 { self.layer_widths[li] } else { 0 };
            let mut prev_refs = vec![0usize; prev_w];
            for &c in &l.cols {
                if c < prev_w {
                    prev_refs[c] += 1;
                } else {
                    leaf_refs[c - prev_w] += 1;
                }
            }
            if li > 0 && prev_refs.iter().any(|&r| r != 1) {
                bail!("layer {} nodes must have exactly one parent", li - 1);
            }
        }
        if leaf_refs.iter().any(|&r| r != 1) {
            bail!("every leaf must have exactly one parent");
        }
        // params
        if self.param_kind.len() != self.num_params
            || self.param_num.len() != self.num_params
            || self.param_den.len() != self.num_params
        {
            bail!("param arrays inconsistent");
        }
        let mut seen = vec![false; self.num_sum_edges];
        for l in &self.layers {
            for &p in &l.param {
                if l.kind == LayerKind::Sum {
                    let p = usize::try_from(p).map_err(|_| anyhow::anyhow!("negative sum param"))?;
                    if p >= self.num_sum_edges || seen[p] {
                        bail!("bad/duplicate sum param {p}");
                    }
                    seen[p] = true;
                } else if p != -1 {
                    bail!("product edge with param");
                }
            }
        }
        if seen.iter().any(|&s| !s) {
            bail!("uncovered sum params");
        }
        let covered: usize = self.sum_groups.iter().map(|g| g.len()).sum();
        if covered != self.num_sum_edges {
            bail!("sum_groups do not cover sum edges");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact(name: &str) -> Option<Structure> {
        let p = format!("{}/artifacts/{name}.structure.json", env!("CARGO_MANIFEST_DIR"));
        std::fs::read_to_string(p).ok().map(|s| Structure::from_json_str(&s).unwrap())
    }

    #[test]
    fn loads_and_validates_toy() {
        let Some(st) = artifact("toy") else { return };
        assert_eq!(st.name, "toy");
        assert_eq!(st.num_vars, 4);
        assert_eq!(st.layers.last().unwrap().width, 1);
    }

    #[test]
    fn table1_stats_match_paper() {
        let expect = [
            ("nltcs", Stats { sum: 13, product: 26, leaf: 74, params: 100, edges: 112, layers: 9 }),
            ("jester", Stats { sum: 10, product: 20, leaf: 225, params: 245, edges: 254, layers: 5 }),
            ("baudio", Stats { sum: 17, product: 36, leaf: 282, params: 318, edges: 334, layers: 7 }),
            ("bnetflix", Stats { sum: 27, product: 54, leaf: 265, params: 319, edges: 345, layers: 7 }),
        ];
        for (name, want) in expect {
            let Some(st) = artifact(name) else { continue };
            assert_eq!(st.stats, want, "{name}");
        }
    }

    #[test]
    fn mini_demo_validates_and_has_expected_shape() {
        let st = Structure::mini_demo();
        assert_eq!(st.num_vars, 2);
        assert_eq!(st.num_leaves(), 4);
        assert_eq!(st.layers.last().unwrap().width, 1);
        assert_eq!(st.sum_groups, vec![vec![0, 1]]);
    }

    #[test]
    fn rejects_broken_structures() {
        let Some(st) = artifact("toy") else { return };
        // childless node
        let mut bad = st.clone();
        bad.layers[0].rows.clear();
        bad.layers[0].cols.clear();
        bad.layers[0].param.clear();
        assert!(bad.validate().is_err());
        // out-of-bounds edge
        let mut bad = st.clone();
        bad.layers[0].cols[0] = 10_000;
        assert!(bad.validate().is_err());
        // double-parent leaf
        let mut bad = st.clone();
        let c0 = bad.layers[0].cols[0];
        bad.layers[0].rows.push(0);
        bad.layers[0].cols.push(c0);
        bad.layers[0].param.push(-1);
        assert!(bad.validate().is_err());
    }
}
