// L009 fixture: raw `% p` reduction in a protocols/ file. A comment
// mentioning x % f.p must not fire (comment-line skip), and neither must
// divisor math.

pub fn leaky_reduce(x: u128, f: &Field) -> u128 {
    x % f.p
}

pub fn sanctioned_reduce(x: u128, f: &Field) -> u128 {
    // lint:allow(L009) — decoy: the line-above suppression must hold
    x % f.p
}

pub fn divisor_math_is_exempt(z: u128, d: u128) -> u128 {
    z % d
}

pub fn other_moduli_are_exempt(i: usize, n: usize, k: usize) -> usize {
    (i % n) + (i % k.min(7))
}

#[cfg(test)]
mod tests {
    // Test modules exercise forbidden shapes on purpose: a raw reduction
    // below must not fire.
    pub fn reference(x: u128, f: &Field) -> u128 {
        x % f.p
    }
}
