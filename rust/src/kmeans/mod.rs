//! Private k-means clustering on the division primitive (§6 / Eq. (7)).
//!
//! Jha, Kruger & McDaniel's protocol needs exactly the functionality of
//! Eq. (7): parties holding (sum, count) pairs jointly compute
//! (Σ sums)/(Σ counts) — a new centroid coordinate — without revealing the
//! local sums/counts.  The paper's point (§6) is that its secret-sharing
//! division replaces their OPE/HE primitives; this module demonstrates it:
//! each Lloyd iteration assigns points locally, then every centroid
//! coordinate is updated with one private division over the engine.
//!
//! Coordinates are fixed-point integers scaled by `scale` (e.g. 1000).

use crate::protocols::division::{divide_shared_den, DivisionConfig};
use crate::protocols::session::{MpcSession, SessionPhase};
use crate::net::NetStats;

/// One party's local view of the data: points in fixed-point coordinates.
#[derive(Clone, Debug)]
pub struct PartyData {
    pub points: Vec<Vec<i64>>,
}

/// k-means configuration.
#[derive(Clone, Copy, Debug)]
pub struct KmeansConfig {
    pub k: usize,
    pub iters: usize,
    pub division: DivisionConfig,
}

/// Result: revealed centroids per iteration + traffic.
pub struct KmeansOutcome {
    pub centroids: Vec<Vec<i64>>,
    pub assignments_counts: Vec<u64>,
    pub stats: NetStats,
    pub iterations_run: usize,
}

fn dist2(a: &[i64], b: &[i64]) -> i128 {
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as i128).pow(2)).sum()
}

/// Run private k-means across the session's parties (simulated engine or
/// real TCP members). `init` are public initial centroids (as in [2], the
/// centroids are revealed each round; the private inputs are the per-party
/// point sets).
pub fn private_kmeans<S: MpcSession>(
    sess: &mut S,
    parties: &[PartyData],
    init: &[Vec<i64>],
    cfg: &KmeansConfig,
) -> KmeansOutcome {
    let n = sess.n();
    assert_eq!(parties.len(), n);
    let dim = init[0].len();
    let before = sess.stats();
    // k-means divisions ride the Training (stream-order divpub) discipline.
    sess.declare_phase(SessionPhase::Training);
    let mut centroids: Vec<Vec<i64>> = init.to_vec();
    let total_points: u64 = parties.iter().map(|p| p.points.len() as u64).sum();
    // public bound for the division: count ≤ total points; sums need the
    // coordinate range — normalize sums to non-negative by offset.
    let offset: i64 = parties
        .iter()
        .flat_map(|p| p.points.iter().flat_map(|pt| pt.iter().copied()))
        .min()
        .unwrap_or(0)
        .min(0);

    let mut counts_out = vec![0u64; cfg.k];
    let mut iterations_run = 0;
    for _ in 0..cfg.iters {
        iterations_run += 1;
        // --- local assignment + local sums/counts --------------------------
        // locals[c][party] = (count, sum per dim) with offset-shifted coords
        let mut cnt_loc = vec![vec![0u128; n]; cfg.k];
        let mut sum_loc = vec![vec![vec![0u128; n]; dim]; cfg.k];
        for (pi, pd) in parties.iter().enumerate() {
            for pt in &pd.points {
                let c = (0..cfg.k)
                    .min_by_key(|&c| dist2(pt, &centroids[c]))
                    .unwrap();
                cnt_loc[c][pi] += 1;
                for (d, &x) in pt.iter().enumerate() {
                    sum_loc[c][d][pi] += (x - offset) as u128;
                }
            }
        }

        // --- private centroid update per cluster ---------------------------
        // d-scaled division would quantize too hard for coordinates, so use
        // a dedicated Newton config whose d equals the coordinate scale.
        let max_coord_sum: u128 = total_points as u128
            * (parties
                .iter()
                .flat_map(|p| p.points.iter().flat_map(|pt| pt.iter().copied()))
                .max()
                .unwrap_or(1)
                - offset)
                .max(1) as u128;
        let _ = max_coord_sum;
        let mut new_centroids = Vec::with_capacity(cfg.k);
        for c in 0..cfg.k {
            let den_raw = sess.sq2pq_vec(&cnt_loc[c].iter().map(|&v| vec![v]).collect::<Vec<_>>())[0];
            let den = sess.lin(1, &[(1, den_raw)]); // +1 smoothing, b ≥ 1
            let nums: Vec<_> = (0..dim)
                .map(|d| {
                    sess.sq2pq_vec(
                        &sum_loc[c][d].iter().map(|&v| vec![v]).collect::<Vec<_>>(),
                    )[0]
                })
                .collect();
            let ws = divide_shared_den(sess, &nums, den, total_points as u128 + 1, &cfg.division);
            // reveal the centroid (public per [2])
            let f = sess.field();
            sess.mark_outputs(&ws);
            let revealed = sess.reveal_vec(&ws);
            let coord: Vec<i64> = revealed
                .iter()
                .map(|&v| {
                    let q = f.to_i128(v).max(0);
                    // q ≈ d·sum/count → divide by d to get the mean
                    (q / cfg.division.newton.d as i128) as i64 + offset
                })
                .collect();
            counts_out[c] = cnt_loc[c].iter().sum::<u128>() as u64;
            new_centroids.push(coord);
        }
        if new_centroids == centroids {
            centroids = new_centroids;
            break;
        }
        centroids = new_centroids;
    }

    let stats = sess.stats().delta_since(&before);
    KmeansOutcome { centroids, assignments_counts: counts_out, stats, iterations_run }
}

/// Plaintext Lloyd's algorithm — the oracle the private version must match.
pub fn plain_kmeans(all_points: &[Vec<i64>], init: &[Vec<i64>], iters: usize) -> Vec<Vec<i64>> {
    let k = init.len();
    let dim = init[0].len();
    let mut centroids = init.to_vec();
    for _ in 0..iters {
        let mut sums = vec![vec![0i128; dim]; k];
        let mut cnts = vec![0i128; k];
        for pt in all_points {
            let c = (0..k).min_by_key(|&c| dist2(pt, &centroids[c])).unwrap();
            cnts[c] += 1;
            for (d, &x) in pt.iter().enumerate() {
                sums[c][d] += x as i128;
            }
        }
        let next: Vec<Vec<i64>> = (0..k)
            .map(|c| {
                (0..dim)
                    .map(|d| (sums[c][d] / (cnts[c] + 1).max(1)) as i64)
                    .collect()
            })
            .collect();
        if next == centroids {
            break;
        }
        centroids = next;
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::Field;
    use crate::protocols::engine::{Engine, EngineConfig};
    use crate::rng::{Prng, Rng};

    fn blob(rng: &mut Prng, cx: i64, cy: i64, n: usize, spread: i64) -> Vec<Vec<i64>> {
        (0..n)
            .map(|_| {
                vec![
                    cx + (rng.gen_range_u64(2 * spread as u64) as i64 - spread),
                    cy + (rng.gen_range_u64(2 * spread as u64) as i64 - spread),
                ]
            })
            .collect()
    }

    #[test]
    fn private_matches_plain_on_blobs() {
        let mut rng = Prng::seed_from_u64(1);
        let a = blob(&mut rng, 100, 100, 60, 20);
        let b = blob(&mut rng, 900, 800, 60, 20);
        let all: Vec<Vec<i64>> = a.iter().chain(&b).cloned().collect();
        // split across 3 parties round-robin
        let mut parties = vec![PartyData { points: vec![] }; 3];
        for (i, pt) in all.iter().enumerate() {
            parties[i % 3].points.push(pt.clone());
        }
        let init = vec![vec![0, 0], vec![1000, 1000]];
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(3).batched());
        let cfg = KmeansConfig { k: 2, iters: 6, division: DivisionConfig::default() };
        let out = private_kmeans(&mut eng, &parties, &init, &cfg);
        let plain = plain_kmeans(&all, &init, 6);
        for (c_priv, c_plain) in out.centroids.iter().zip(&plain) {
            for (a, b) in c_priv.iter().zip(c_plain) {
                assert!((a - b).abs() <= 8, "private {c_priv:?} vs plain {c_plain:?}");
            }
        }
        assert_eq!(out.assignments_counts.iter().sum::<u64>(), 120);
        assert!(out.stats.messages > 0);
    }

    #[test]
    fn converges_and_stops_early() {
        let mut rng = Prng::seed_from_u64(2);
        let a = blob(&mut rng, 50, 50, 40, 5);
        let b = blob(&mut rng, 500, 500, 40, 5);
        let mut parties = vec![PartyData { points: vec![] }; 2];
        for (i, pt) in a.iter().chain(&b).enumerate() {
            parties[i % 2].points.push(pt.clone());
        }
        let init = vec![vec![0, 0], vec![600, 600]];
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(2).batched());
        let cfg = KmeansConfig { k: 2, iters: 20, division: DivisionConfig::default() };
        let out = private_kmeans(&mut eng, &parties, &init, &cfg);
        assert!(out.iterations_run < 20, "should converge early");
    }
}
