//! Dependency-free scoped worker pool for the member compute plane
//! (DESIGN.md §Field kernel).
//!
//! `std::thread::scope` only — no crates.io, no `unsafe`, no persistent
//! threads. A [`Pool`] is a plain degree-of-parallelism knob (`Copy`, a
//! `usize`): callers hand it a mutable slice and a chunk closure, and the
//! pool splits the slice into disjoint `&mut` chunks with
//! `split_at_mut`, one scoped thread per chunk. Below the work floor the
//! call degrades to a plain serial loop, so `threads = 1` (the default
//! everywhere) compiles to exactly the pre-pool code path.
//!
//! **Determinism contract:** the pool parallelizes *pure element-indexed
//! compute* only. Anything order-sensitive — RNG draws above all — is
//! pre-drawn serially in the pinned scalar order *before* fan-out (see
//! `ShamirCtx::share_batch_into_pooled`), so draw-order byte-identity
//! holds by construction, not by scheduling luck. Every writer owns a
//! disjoint chunk of the output slab; there is no shared mutable state,
//! no locks, and joins happen before the scope returns, so results are
//! in place (and identical for any thread count) when the call returns.

/// Minimum elements per spawned chunk. Spawning a scoped thread costs
/// tens of microseconds; at ~10 ns/element a chunk must be ≥ ~1k elements
/// before fan-out can win, so smaller jobs stay serial.
pub const MIN_CHUNK: usize = 1024;

/// A degree-of-parallelism handle. Cheap to copy; `threads == 1` means
/// strictly serial (no scope, no spawn).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// A pool running up to `threads` chunks concurrently (clamped ≥ 1).
    pub fn new(threads: usize) -> Self {
        Pool { threads: threads.max(1) }
    }

    /// The serial pool: every `run_*` call is a plain loop.
    pub fn serial() -> Self {
        Pool { threads: 1 }
    }

    /// Configured degree of parallelism.
    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_serial(&self) -> bool {
        self.threads == 1
    }

    /// Split `out` into at most `self.threads` contiguous chunks of at
    /// least `min_chunk` elements and run `f(start_index, chunk)` on each,
    /// concurrently when more than one chunk results. `f` sees the chunk's
    /// offset into the original slice so it can index side tables.
    ///
    /// Serial fallback (1 chunk) when the pool is serial, the slice is
    /// shorter than `2·min_chunk`, or `min_chunk == 0` would not split.
    pub fn run_chunks<T, F>(&self, out: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let len = out.len();
        if len == 0 {
            return;
        }
        let floor = min_chunk.max(1);
        let want = (len / floor).min(self.threads);
        if want <= 1 {
            f(0, out);
            return;
        }
        let chunk = len.div_ceil(want);
        std::thread::scope(|s| {
            let fr = &f;
            let mut rem = out;
            let mut start = 0;
            while !rem.is_empty() {
                let take = chunk.min(rem.len());
                let (head, tail) = std::mem::take(&mut rem).split_at_mut(take);
                s.spawn(move || fr(start, head));
                start += take;
                rem = tail;
            }
        });
    }

    /// Run `f(index, item)` over every item, one scoped thread per item
    /// when the pool is parallel — the member-major fan-out (`n` members,
    /// each owning its store and RNG, so items are naturally disjoint).
    /// Serial pools run a plain loop in index order.
    pub fn run_each<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        if self.threads <= 1 || items.len() <= 1 {
            for (i, it) in items.iter_mut().enumerate() {
                f(i, it);
            }
            return;
        }
        std::thread::scope(|s| {
            let fr = &f;
            for (i, it) in items.iter_mut().enumerate() {
                s.spawn(move || fr(i, it));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_chunks_agree() {
        for threads in [1usize, 2, 4, 7] {
            let pool = Pool::new(threads);
            let mut out = vec![0u128; 10_000];
            pool.run_chunks(&mut out, 16, |start, chunk| {
                for (off, slot) in chunk.iter_mut().enumerate() {
                    *slot = (start + off) as u128 * 3 + 1;
                }
            });
            let want: Vec<u128> = (0..10_000u128).map(|i| i * 3 + 1).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn small_slices_stay_serial_and_complete() {
        let pool = Pool::new(8);
        let mut out = vec![0u32; 100];
        pool.run_chunks(&mut out, MIN_CHUNK, |start, chunk| {
            assert_eq!(start, 0, "below the floor there must be one chunk");
            assert_eq!(chunk.len(), 100);
            for (i, s) in chunk.iter_mut().enumerate() {
                *s = i as u32;
            }
        });
        assert_eq!(out[99], 99);
    }

    #[test]
    fn run_each_touches_every_item_once() {
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let mut items = vec![0u64; 13];
            pool.run_each(&mut items, |i, it| *it += i as u64 + 1);
            let want: Vec<u64> = (0..13).map(|i| i + 1).collect();
            assert_eq!(items, want, "threads={threads}");
        }
    }

    #[test]
    fn zero_threads_clamps_to_serial() {
        let p = Pool::new(0);
        assert!(p.is_serial());
        assert_eq!(p.threads(), 1);
        let mut out = vec![1u8; 4];
        p.run_chunks(&mut out, 0, |_, c| c.iter_mut().for_each(|x| *x *= 2));
        assert_eq!(out, vec![2u8; 4]);
    }

    #[test]
    fn empty_slice_is_a_no_op() {
        let mut out: Vec<u128> = Vec::new();
        Pool::new(4).run_chunks(&mut out, 8, |_, _| panic!("no chunk expected"));
    }
}
