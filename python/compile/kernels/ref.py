"""Pure-jnp / numpy oracle for the Pallas kernels and the counts pipeline.

This is the CORE correctness signal for Layer 1: `python/tests/test_kernel.py`
checks the Pallas kernels against these functions with hypothesis-driven
shape sweeps, and `test_model.py` checks the whole counts pipeline against an
independent per-instance recursive evaluator built from the structure JSON
(COO edge lists — shares no code with the dense-matrix path under test).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import spn_layer as K


def layer_apply_ref(x, mt, deg, gate, mode: int):
    y = jnp.dot(x.astype(jnp.float32), mt.astype(jnp.float32))
    if mode == K.MODE_OR:
        y = (y > 0.5).astype(jnp.float32)
    elif mode == K.MODE_AND:
        y = (y > deg[None, :] - 0.5).astype(jnp.float32)
    elif mode == K.MODE_GATE:
        y = y * gate
    return y


def masked_count_ref(a, row_mask):
    return jnp.sum(a * row_mask[:, None], axis=0)


# ---------------------------------------------------------------------------
# Independent recursive oracle over the structure JSON (numpy, per instance).
# ---------------------------------------------------------------------------

def counts_recursive(st: dict, data: np.ndarray) -> np.ndarray:
    """Return concat(act counts per node [leaves, layers...], x1 counts)."""
    w0 = st["layer_widths"][0]
    total = st["total_nodes"]
    leaf_var = np.asarray(st["leaf_var"])
    leaf_claim = np.asarray(st["leaf_claim"])

    cnt = np.zeros(total + w0, dtype=np.float64)
    for row in data:
        # bottom-up positivity per layer
        pos_leaf = np.where(leaf_claim < 0, 1.0, (row[leaf_var] == leaf_claim))
        pos_layers = [pos_leaf]
        for li, layer in enumerate(st["layers"]):
            prev = pos_layers[-1] if li > 0 else np.zeros(0)
            inp = np.concatenate([prev, pos_leaf]) if li > 0 else pos_leaf
            if layer["kind"] == "product":
                deg = np.zeros(layer["width"])
                acc = np.zeros(layer["width"])
                for r, c in zip(layer["rows"], layer["cols"]):
                    deg[r] += 1
                    acc[r] += inp[c]
                out = (acc >= deg - 0.5).astype(float)
            else:
                out = np.zeros(layer["width"])
                for r, c in zip(layer["rows"], layer["cols"]):
                    out[r] = max(out[r], inp[c])
            pos_layers.append(out)

        # top-down activation
        act_layers = [np.zeros(w) for w in st["layer_widths"]]
        act_leaf = np.zeros(w0)
        L = len(st["layers"])
        act_layers[L] = pos_layers[L].copy()     # root of the tree: act = pos
        for li in range(L - 1, -1, -1):
            layer = st["layers"][li]
            prev_w = layer["in_width"] - w0
            a_out = act_layers[li + 1]
            for r, c in zip(layer["rows"], layer["cols"]):
                down = a_out[r]
                if c < prev_w:
                    v = down * pos_layers[li][c]
                    act_layers[li][c] = max(act_layers[li][c], v)
                else:
                    lf = c - prev_w
                    act_leaf[lf] = max(act_leaf[lf], down * pos_leaf[lf])

        flat = np.concatenate([act_leaf] + [act_layers[i + 1] for i in range(L)])
        cnt[:total] += flat
        cnt[total:] += act_leaf * row[leaf_var]
    return cnt


def logeval_recursive(st: dict, data: np.ndarray, params: np.ndarray,
                      marg: np.ndarray) -> np.ndarray:
    """Per-instance log S(x) with Bernoulli leaves; marg[v]=1 marginalizes v."""
    leaf_var = np.asarray(st["leaf_var"])
    nse = st["num_sum_edges"]
    out = np.zeros(len(data))
    for bi, row in enumerate(data):
        theta = params[nse:]
        x = row[leaf_var]
        m = marg[leaf_var].astype(bool)
        lp = np.where(x > 0.5, np.log(np.maximum(theta, 1e-30)),
                      np.log(np.maximum(1.0 - theta, 1e-30)))
        leaf_ll = np.where(m, 0.0, lp)
        vals = [leaf_ll]
        for li, layer in enumerate(st["layers"]):
            prev = vals[-1] if li > 0 else np.zeros(0)
            inp = np.concatenate([prev, leaf_ll]) if li > 0 else leaf_ll
            if layer["kind"] == "product":
                o = np.zeros(layer["width"])
                for r, c in zip(layer["rows"], layer["cols"]):
                    o[r] += inp[c]
            else:
                acc = [[] for _ in range(layer["width"])]
                for r, c, p in zip(layer["rows"], layer["cols"], layer["param"]):
                    acc[r].append(np.log(max(params[p], 1e-30)) + inp[c])
                o = np.zeros(layer["width"])
                for r in range(layer["width"]):
                    mx = max(acc[r])
                    o[r] = mx + np.log(sum(np.exp(np.array(acc[r]) - mx)))
            vals.append(o)
        out[bi] = vals[-1][0]
    return out
