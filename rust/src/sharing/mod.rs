//! Secret-sharing schemes (§2.2.2 of the paper).
//!
//! * [`additive`] — additive sharing over `Z_p` and the *joint random
//!   sharing of zero* (JRSZ) used by the approximate path (§3.2).
//! * [`shamir`]   — Shamir polynomial sharing with Lagrange reconstruction
//!   and the degree-reduction combinators that power secure multiplication.
//! * [`convert`]  — SQ2PQ [14]: additive → polynomial share conversion.

pub mod additive;
pub mod convert;
pub mod shamir;

pub use additive::{additive_share, jrsz, reconstruct_additive};
pub use convert::sq2pq_local_deal;
pub use shamir::ShamirCtx;
