//! Arbitrary-precision unsigned integers (little-endian u64 limbs).
//!
//! Just enough for Paillier: add/sub/cmp, schoolbook mul, divrem, modpow
//! (square-and-multiply with Barrett-free reduction via divrem), gcd/lcm,
//! modular inverse, Miller–Rabin, and random prime generation.  Not
//! constant-time — this is a *cost baseline*, not a production HE library
//! (stated in DESIGN.md; the paper's point is that even an ideal HE
//! implementation loses to secret sharing by orders of magnitude).

use crate::rng::Rng;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUint {
    /// Little-endian limbs; no trailing zero limbs (canonical form).
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        BigUint { limbs: vec![] }
    }

    pub fn one() -> Self {
        BigUint { limbs: vec![1] }
    }

    pub fn from_u128(x: u128) -> Self {
        let mut l = vec![x as u64, (x >> 64) as u64];
        while l.last() == Some(&0) {
            l.pop();
        }
        BigUint { limbs: l }
    }

    pub fn to_u128(&self) -> Option<u128> {
        match self.limbs.len() {
            0 => Some(0),
            1 => Some(self.limbs[0] as u128),
            2 => Some(self.limbs[0] as u128 | (self.limbs[1] as u128) << 64),
            _ => None,
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn is_even(&self) -> bool {
        self.limbs.first().map_or(true, |l| l & 1 == 0)
    }

    pub fn bits(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => 64 * (self.limbs.len() - 1) + (64 - top.leading_zeros() as usize),
        }
    }

    fn norm(mut self) -> Self {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
        self
    }

    pub fn cmp_big(&self, other: &Self) -> std::cmp::Ordering {
        use std::cmp::Ordering::*;
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for i in (0..self.limbs.len()).rev() {
            match self.limbs[i].cmp(&other.limbs[i]) {
                Equal => continue,
                o => return o,
            }
        }
        Equal
    }

    pub fn add(&self, other: &Self) -> Self {
        let n = self.limbs.len().max(other.limbs.len());
        let mut out = Vec::with_capacity(n + 1);
        let mut carry = 0u128;
        for i in 0..n {
            let a = *self.limbs.get(i).unwrap_or(&0) as u128;
            let b = *other.limbs.get(i).unwrap_or(&0) as u128;
            let s = a + b + carry;
            out.push(s as u64);
            carry = s >> 64;
        }
        if carry > 0 {
            out.push(carry as u64);
        }
        BigUint { limbs: out }.norm()
    }

    /// self - other; panics on underflow.
    pub fn sub(&self, other: &Self) -> Self {
        assert!(self.cmp_big(other) != std::cmp::Ordering::Less, "bigint underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0i128;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i] as i128;
            let b = *other.limbs.get(i).unwrap_or(&0) as i128;
            let mut d = a - b - borrow;
            if d < 0 {
                d += 1i128 << 64;
                borrow = 1;
            } else {
                borrow = 0;
            }
            out.push(d as u64);
        }
        BigUint { limbs: out }.norm()
    }

    pub fn mul(&self, other: &Self) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let t = out[i + j] as u128 + a as u128 * b as u128 + carry;
                out[i + j] = t as u64;
                carry = t >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let t = out[k] as u128 + carry;
                out[k] = t as u64;
                carry = t >> 64;
                k += 1;
            }
        }
        BigUint { limbs: out }.norm()
    }

    pub fn shl_bits(&self, sh: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let limb_sh = sh / 64;
        let bit_sh = sh % 64;
        let mut out = vec![0u64; self.limbs.len() + limb_sh + 1];
        for (i, &l) in self.limbs.iter().enumerate() {
            out[i + limb_sh] |= l << bit_sh;
            if bit_sh > 0 {
                out[i + limb_sh + 1] |= l >> (64 - bit_sh);
            }
        }
        BigUint { limbs: out }.norm()
    }

    pub fn shr_bits(&self, sh: usize) -> Self {
        let limb_sh = sh / 64;
        if limb_sh >= self.limbs.len() {
            return Self::zero();
        }
        let bit_sh = sh % 64;
        let mut out = Vec::with_capacity(self.limbs.len() - limb_sh);
        for i in limb_sh..self.limbs.len() {
            let mut v = self.limbs[i] >> bit_sh;
            if bit_sh > 0 && i + 1 < self.limbs.len() {
                v |= self.limbs[i + 1] << (64 - bit_sh);
            }
            out.push(v);
        }
        BigUint { limbs: out }.norm()
    }

    pub fn bit(&self, i: usize) -> bool {
        let limb = i / 64;
        self.limbs.get(limb).map_or(false, |l| (l >> (i % 64)) & 1 == 1)
    }

    /// Long division: (quotient, remainder). Bit-shift based; O(bits·limbs).
    pub fn divrem(&self, div: &Self) -> (Self, Self) {
        assert!(!div.is_zero(), "division by zero");
        if self.cmp_big(div) == std::cmp::Ordering::Less {
            return (Self::zero(), self.clone());
        }
        let shift = self.bits() - div.bits();
        let mut rem = self.clone();
        let mut quot = Self::zero();
        for s in (0..=shift).rev() {
            let cand = div.shl_bits(s);
            if rem.cmp_big(&cand) != std::cmp::Ordering::Less {
                rem = rem.sub(&cand);
                // set bit s of quot
                let limb = s / 64;
                if quot.limbs.len() <= limb {
                    quot.limbs.resize(limb + 1, 0);
                }
                quot.limbs[limb] |= 1u64 << (s % 64);
            }
        }
        (quot.norm(), rem)
    }

    pub fn rem(&self, m: &Self) -> Self {
        self.divrem(m).1
    }

    pub fn mulmod(&self, other: &Self, m: &Self) -> Self {
        self.mul(other).rem(m)
    }

    pub fn modpow(&self, exp: &Self, m: &Self) -> Self {
        let mut base = self.rem(m);
        let mut acc = Self::one().rem(m);
        for i in 0..exp.bits() {
            if exp.bit(i) {
                acc = acc.mulmod(&base, m);
            }
            base = base.mulmod(&base, m);
        }
        acc
    }

    pub fn gcd(&self, other: &Self) -> Self {
        let (mut a, mut b) = (self.clone(), other.clone());
        while !b.is_zero() {
            let r = a.rem(&b);
            a = b;
            b = r;
        }
        a
    }

    pub fn lcm(&self, other: &Self) -> Self {
        self.mul(other).divrem(&self.gcd(other)).0
    }

    /// Modular inverse via extended Euclid (values as signed bigint pairs).
    pub fn modinv(&self, m: &Self) -> Option<Self> {
        // extended gcd with (sign, magnitude) coefficients
        let (mut r0, mut r1) = (m.clone(), self.rem(m));
        let (mut s0, mut s1) = ((false, Self::zero()), (false, Self::one()));
        while !r1.is_zero() {
            let (q, r2) = r0.divrem(&r1);
            // s2 = s0 - q*s1
            let qs1 = q.mul(&s1.1);
            let s2 = signed_sub(&s0, &(s1.0, qs1));
            r0 = r1;
            r1 = r2;
            s0 = s1;
            s1 = s2;
        }
        if r0.cmp_big(&Self::one()) != std::cmp::Ordering::Equal {
            return None;
        }
        // normalize sign
        let inv = if s0.0 { m.sub(&s0.1.rem(m)) } else { s0.1.rem(m) };
        Some(inv.rem(m))
    }

    pub fn rand_bits<R: Rng + ?Sized>(rng: &mut R, bits: usize) -> Self {
        assert!(bits > 0);
        let limbs = bits.div_ceil(64);
        let mut l: Vec<u64> = (0..limbs).map(|_| rng.next_u64()).collect();
        let top_bits = bits - (limbs - 1) * 64;
        if top_bits < 64 {
            l[limbs - 1] &= (1u64 << top_bits) - 1;
        }
        // force exact bit length
        l[limbs - 1] |= 1u64 << (top_bits - 1);
        BigUint { limbs: l }.norm()
    }

    pub fn is_probable_prime<R: Rng + ?Sized>(&self, rounds: u32, rng: &mut R) -> bool {
        if self.is_zero() {
            return false;
        }
        for small in [2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
            let s = Self::from_u128(small as u128);
            if self.cmp_big(&s) == std::cmp::Ordering::Equal {
                return true;
            }
            if self.rem(&s).is_zero() {
                return false;
            }
        }
        let one = Self::one();
        let two = Self::from_u128(2);
        if self.cmp_big(&two) == std::cmp::Ordering::Less {
            return false;
        }
        let n1 = self.sub(&one);
        let mut d = n1.clone();
        let mut r = 0usize;
        while d.is_even() {
            d = d.shr_bits(1);
            r += 1;
        }
        'witness: for _ in 0..rounds {
            // witness in [2, n-2]
            let a = loop {
                let c = Self::rand_bits(rng, self.bits().max(3) - 1);
                if c.cmp_big(&two) != std::cmp::Ordering::Less
                    && c.cmp_big(&n1) == std::cmp::Ordering::Less
                {
                    break c;
                }
            };
            let mut x = a.modpow(&d, self);
            if x.cmp_big(&one) == std::cmp::Ordering::Equal
                || x.cmp_big(&n1) == std::cmp::Ordering::Equal
            {
                continue;
            }
            for _ in 0..r - 1 {
                x = x.mulmod(&x, self);
                if x.cmp_big(&n1) == std::cmp::Ordering::Equal {
                    continue 'witness;
                }
            }
            return false;
        }
        true
    }

    pub fn gen_prime<R: Rng + ?Sized>(rng: &mut R, bits: usize) -> Self {
        loop {
            let mut c = Self::rand_bits(rng, bits);
            if c.is_even() {
                c = c.add(&Self::one());
            }
            if c.is_probable_prime(16, rng) {
                return c;
            }
        }
    }
}

type Signed = (bool, BigUint); // (negative?, magnitude)

fn signed_sub(a: &Signed, b: &Signed) -> Signed {
    match (a.0, b.0) {
        (false, false) => {
            if a.1.cmp_big(&b.1) != std::cmp::Ordering::Less {
                (false, a.1.sub(&b.1))
            } else {
                (true, b.1.sub(&a.1))
            }
        }
        (true, true) => signed_sub(&(false, b.1.clone()), &(false, a.1.clone())),
        (false, true) => (false, a.1.add(&b.1)),
        (true, false) => (true, a.1.add(&b.1)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Prng;

    fn big(x: u128) -> BigUint {
        BigUint::from_u128(x)
    }

    #[test]
    fn add_sub_mul_small_match_u128() {
        let mut rng = Prng::seed_from_u64(1);
        for _ in 0..500 {
            let a = rng.gen_bits(60);
            let b = rng.gen_bits(60);
            assert_eq!(big(a).add(&big(b)).to_u128(), Some(a + b));
            assert_eq!(big(a.max(b)).sub(&big(a.min(b))).to_u128(), Some(a.max(b) - a.min(b)));
            assert_eq!(big(a).mul(&big(b)).to_u128(), Some(a * b));
        }
    }

    #[test]
    fn divrem_matches_u128() {
        let mut rng = Prng::seed_from_u64(2);
        for _ in 0..500 {
            let a = rng.gen_bits(100);
            let b = 1 + rng.gen_bits(60);
            let (q, r) = big(a).divrem(&big(b));
            assert_eq!(q.to_u128(), Some(a / b));
            assert_eq!(r.to_u128(), Some(a % b));
        }
    }

    #[test]
    fn divrem_reconstructs() {
        let mut rng = Prng::seed_from_u64(3);
        for _ in 0..50 {
            let a = BigUint::rand_bits(&mut rng, 300);
            let b = BigUint::rand_bits(&mut rng, 150);
            let (q, r) = a.divrem(&b);
            assert_eq!(q.mul(&b).add(&r), a);
            assert!(r.cmp_big(&b) == std::cmp::Ordering::Less);
        }
    }

    #[test]
    fn modpow_matches_u128_field() {
        let p = crate::field::PAPER_P;
        let f = crate::field::Field::paper();
        let mut rng = Prng::seed_from_u64(4);
        for _ in 0..20 {
            let a = rng.gen_range_u128(p);
            let e = rng.gen_bits(40);
            let want = f.pow(a, e);
            let got = big(a).modpow(&big(e), &big(p)).to_u128().unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn modinv_works() {
        let mut rng = Prng::seed_from_u64(5);
        let p = big(crate::field::PAPER_P);
        for _ in 0..20 {
            let a = big(1 + rng.gen_range_u128(crate::field::PAPER_P - 1));
            let inv = a.modinv(&p).unwrap();
            assert_eq!(a.mulmod(&inv, &p).to_u128(), Some(1));
        }
        // non-invertible
        assert!(big(6).modinv(&big(12)).is_none());
    }

    #[test]
    fn miller_rabin_agrees_with_known_values() {
        let mut rng = Prng::seed_from_u64(6);
        for prime in [2u128, 3, 5, 65537, (1 << 20) + 7, crate::field::PAPER_P] {
            assert!(big(prime).is_probable_prime(16, &mut rng), "{prime}");
        }
        for comp in [1u128, 4, 100, 65536, (1 << 20) + 9, 3215031751] {
            assert!(!big(comp).is_probable_prime(16, &mut rng), "{comp}");
        }
    }

    #[test]
    fn gen_prime_has_requested_bits() {
        let mut rng = Prng::seed_from_u64(7);
        let p = BigUint::gen_prime(&mut rng, 96);
        assert_eq!(p.bits(), 96);
        assert!(p.is_probable_prime(16, &mut rng));
    }

    #[test]
    fn shifts_roundtrip() {
        let mut rng = Prng::seed_from_u64(8);
        for _ in 0..100 {
            let a = BigUint::rand_bits(&mut rng, 200);
            for sh in [1usize, 13, 64, 77, 130] {
                assert_eq!(a.shl_bits(sh).shr_bits(sh), a);
            }
        }
    }

    #[test]
    fn gcd_lcm() {
        assert_eq!(big(12).gcd(&big(18)).to_u128(), Some(6));
        assert_eq!(big(12).lcm(&big(18)).to_u128(), Some(36));
        assert_eq!(big(17).gcd(&big(13)).to_u128(), Some(1));
    }
}
