//! The approximate solution of §3.2: each party computes its local fraction
//! `f^k = num^k/den^k`, scales it to `F^k = ⌊d·f^k/N⌉`, and masks it with a
//! JRSZ zero-share.  The sum of the masked shares is (d times) the average
//! of local fractions — correct when shards are near-iid, biased otherwise
//! (the `ablation_approx_vs_exact` bench quantifies the bias vs skew).

use crate::field::Field;
use crate::net::{NetConfig, NetStats, SimNet};
use crate::rng::Prng;
use crate::sharing::additive::jrsz;

/// One party's input for one parameter.
#[derive(Clone, Copy, Debug)]
pub struct LocalFraction {
    pub num: u64,
    pub den: u64,
}

/// Result of the approximate protocol for a batch of parameters.
pub struct ApproxOutcome {
    /// Additive shares: shares[k][party] (each party holds one element).
    pub shares: Vec<Vec<u128>>,
    /// Revealed d-scaled approximations (for verification / reporting).
    pub revealed: Vec<u128>,
    pub stats: NetStats,
}

/// Run §3.2 for `params.len()` parameters across `n` parties.
/// `params[k][i]` is party i's local (num, den) for parameter k.
pub fn approx_divide(
    f: &Field,
    params: &[Vec<LocalFraction>],
    d: u128,
    net_cfg: NetConfig,
    seed: u64,
) -> ApproxOutcome {
    let n = params.first().map(|p| p.len()).unwrap_or(0);
    assert!(n > 0);
    let mut net = SimNet::new(net_cfg);
    let mut rng = Prng::seed_from_u64(seed);
    let mut shares = Vec::with_capacity(params.len());
    let mut revealed = Vec::with_capacity(params.len());

    for locals in params {
        // Preprocessing: JRSZ dealt by the manager (third party), one share
        // per member (n messages, 1 round).
        let masks = jrsz(f, n, &mut rng);
        for i in 0..n {
            net.send(usize::MAX, i, 1);
        }
        net.end_round();

        // Local: F^k = round(d * num / den / N), masked.
        let mut sh = Vec::with_capacity(n);
        for (i, loc) in locals.iter().enumerate() {
            let fk = if loc.den == 0 {
                0u128
            } else {
                // round(d*num / (den*N))
                let numer = d * loc.num as u128 * 2 + (loc.den as u128 * n as u128);
                numer / (2 * loc.den as u128 * n as u128)
            };
            sh.push(f.add(fk % f.p, masks[i]));
        }

        // Reveal to manager: n messages, 1 round.
        for i in 0..n {
            net.send(i, usize::MAX, 1);
        }
        net.end_round();
        revealed.push(f.sum(&sh));
        shares.push(sh);
    }

    ApproxOutcome { shares, revealed, stats: net.stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, EXAMPLE_P};

    /// Example 1 of the paper, digit for digit.
    #[test]
    fn paper_example_1() {
        let f = Field::new(EXAMPLE_P); // p = 2^20 + 7
        let d = 1000u128;
        let n = 3;
        let r = [752508u128, 776879, 567779]; // given JRSZ output
        assert_eq!(f.sum(&r), 0, "paper's r-values sum to 0 mod p");
        let nums = [71u64, 209, 320];
        let dens = [256u64, 786, 1127];

        // F^k = round(d * f^k / N) as the paper computes them
        let mut fk = Vec::new();
        for i in 0..n {
            let numer = d * nums[i] as u128 * 2 + dens[i] as u128 * n as u128;
            fk.push(numer / (2 * dens[i] as u128 * n as u128));
        }
        assert_eq!(fk, vec![92, 89, 95], "paper's (F¹,F²,F³)");

        let shares: Vec<u128> = (0..n).map(|i| f.add(fk[i], r[i])).collect();
        assert_eq!(shares, vec![752600, 776968, 567874], "paper's (F̂¹,F̂²,F̂³)");
        assert_eq!(f.sum(&shares), 276, "reconstruction = 0.276 · d");

        // true value for comparison: 0.277 scaled
        let true_w = (71.0 + 209.0 + 320.0) / (256.0 + 786.0 + 1127.0);
        assert!((f.sum(&shares) as f64 / d as f64 - true_w).abs() < 0.002);
    }

    #[test]
    fn approx_protocol_end_to_end() {
        let f = Field::new(EXAMPLE_P);
        let locals = vec![
            vec![
                LocalFraction { num: 71, den: 256 },
                LocalFraction { num: 209, den: 786 },
                LocalFraction { num: 320, den: 1127 },
            ],
        ];
        let out = approx_divide(&f, &locals, 1000, NetConfig::default(), 1);
        assert_eq!(out.revealed.len(), 1);
        // average of fractions ≈ 0.276; allow rounding
        let got = out.revealed[0] as f64 / 1000.0;
        assert!((got - 0.276).abs() < 0.003, "{got}");
        // accounting: 2 rounds, 2n messages
        assert_eq!(out.stats.messages, 6);
        assert_eq!(out.stats.rounds, 2);
    }

    #[test]
    fn approx_bias_under_skew() {
        // identical num/den ratios → unbiased; skewed ratios → biased
        let f = Field::new(EXAMPLE_P);
        let iid = vec![vec![
            LocalFraction { num: 100, den: 400 },
            LocalFraction { num: 101, den: 399 },
            LocalFraction { num: 99, den: 401 },
        ]];
        let skew = vec![vec![
            LocalFraction { num: 0, den: 800 },
            LocalFraction { num: 300, den: 300 },
            LocalFraction { num: 0, den: 100 },
        ]];
        let d = 10_000u128;
        let got_iid =
            approx_divide(&f, &iid, d, NetConfig::default(), 2).revealed[0] as f64 / d as f64;
        let got_skew =
            approx_divide(&f, &skew, d, NetConfig::default(), 2).revealed[0] as f64 / d as f64;
        let truth = 300.0 / 1200.0;
        assert!((got_iid - truth).abs() < 0.001);
        assert!((got_skew - truth).abs() > 0.05, "skew should bias: {got_skew}");
    }

    #[test]
    fn zero_denominator_contributes_zero() {
        let f = Field::new(EXAMPLE_P);
        let locals =
            vec![vec![LocalFraction { num: 0, den: 0 }, LocalFraction { num: 50, den: 100 }]];
        let out = approx_divide(&f, &locals, 1000, NetConfig::default(), 3);
        // average of (0, 0.5)/2 = 0.25
        assert!((out.revealed[0] as f64 / 1000.0 - 0.25).abs() < 0.002);
    }
}
