//! Claim 2(d): "CryptoSPN is outperformed by our protocol."
//!
//! One private marginal inference per structure, measured on our secret-
//! sharing path (per-op AND batched schedules), against the CryptoSPN
//! garbled-circuit cost model (gate counts per float op as used by
//! CryptoSPN's ABY backend + this machine's measured AES-equivalent rate).
//!
//! The shape to reproduce: GC moves orders of magnitude more bytes; the
//! secret-sharing path is round-bound (latency), GC is compute/bandwidth-
//! bound.  On traffic our protocol wins everywhere; on latency-dominated
//! links the batched schedule is required to also win on time.

mod common;

use spn_mpc::coordinator::infer::{private_eval, Query};
use spn_mpc::coordinator::train::{train, TrainConfig};
use spn_mpc::datasets;
use spn_mpc::field::Field;
use spn_mpc::gc;
use spn_mpc::metrics::{group_thousands, render_table};
use spn_mpc::protocols::engine::{Engine, EngineConfig, Schedule};
use spn_mpc::spn::{eval, learn};

fn main() {
    if !common::guard("baseline_cryptospn", &common::DEBD) {
        return;
    }
    let aes = gc::measure_aes_per_sec(5_000_000);
    println!("AES-equivalent rate: {:.1}M blocks/s\n", aes / 1e6);
    let mut rows = Vec::new();
    for name in common::DEBD {
        let st = common::load(name).expect("guarded above");
        // quick training for weight shares
        let gt = datasets::ground_truth_params(&st, 7);
        let data = datasets::sample(&st, &gt, 2000, 42);
        let shards = datasets::partition(&data, 5);
        let counts: Vec<Vec<u64>> = shards.iter().map(|s| eval::counts(&st, s)).collect();
        let mut eng = Engine::new(Field::paper(), EngineConfig::new(5).batched());
        let (model, _) = train(&mut eng, &st, &counts, 2000, &TrainConfig::default());
        let theta = learn::default_leaf_theta(&st);

        let mut q = Query { x: vec![0; st.num_vars], marg: vec![true; st.num_vars] };
        q.x[0] = 1;
        q.marg[0] = false;

        eng.cfg.schedule = Schedule::PerOp;
        let (_, per_op) = private_eval(&mut eng, &st, &model, &q, &theta);
        eng.cfg.schedule = Schedule::Batched;
        let (_, batched) = private_eval(&mut eng, &st, &model, &q, &theta);

        let cost = gc::inference_cost(&st);
        let gc_s = gc::estimate_seconds(&cost, aes, 125e6, 0.010);
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", per_op.megabytes()),
            format!("{:.3}", batched.megabytes()),
            format!("{:.2}", cost.bytes as f64 / 1e6),
            format!("{:.1}x", cost.bytes as f64 / batched.bytes as f64),
            format!("{:.2}", per_op.virtual_time_s),
            format!("{:.2}", batched.virtual_time_s),
            format!("{:.2}", gc_s),
            group_thousands(cost.and_gates),
        ]);
        // the headline: secret sharing moves far fewer bytes
        assert!(cost.bytes > 10 * batched.bytes, "{name}: GC must cost >10x traffic");
    }
    println!(
        "{}",
        render_table(
            "One private marginal inference: this work vs CryptoSPN (GC cost model)",
            &[
                "Dataset",
                "ours MB (per-op)",
                "ours MB (batched)",
                "GC MB",
                "GC/ours traffic",
                "ours s (per-op)",
                "ours s (batched)",
                "GC s (est)",
                "GC AND gates"
            ],
            &rows
        )
    );
    println!("baseline_cryptospn OK");
}
