//! Clean fixture: every line here is a decoy for some skip rule — if any
//! finding lands in this file, `spn-lint --self-check` fails.
//!
//! A comment mentioning divpub_vec( must not trip L001, and this resolving
//! reference must not trip L006: see DESIGN.md §Session API.

struct Sess;

impl Sess {
    // A definition line is not a call site (L001 skips `fn divpub_vec`).
    fn divpub_vec(&mut self, us: &[u64], _d: u128) -> Vec<u64> {
        us.to_vec()
    }

    fn reserve_tags(&mut self, _count: u64) -> u64 {
        0
    }
}

fn well_behaved(sess: &mut Sess) -> u64 {
    // Bound result: L002 must not fire.
    let base = sess.reserve_tags(3);
    // Suppressed call: the lint:allow machinery is what keeps this clean.
    let _ = sess.divpub_vec(&[base], 16); // lint:allow(L001)
    base
}

#[cfg(test)]
mod tests {
    use super::*;

    // Everything below the file's #[cfg(test)] marker is out of scope —
    // these would both fire if the cutoff rule broke.
    fn deliberately_bad(sess: &mut Sess) {
        sess.divpub_vec(&[1], 4);
        sess.reserve_tags(9);
    }
}
