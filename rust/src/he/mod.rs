//! Homomorphic-encryption baseline (§3.3 of the paper).
//!
//! The paper sketches an exact solution where parties encrypt `d·num_i` and
//! `den_i` under an additively homomorphic scheme, a leader aggregates
//! ciphertexts, and the division is done with the word-wise FHE method of
//! Çetin et al. [17].  The point of the baseline is cost: HE is orders of
//! magnitude slower than secret sharing.
//!
//! We implement textbook **Paillier** (additively homomorphic) over an
//! in-tree arbitrary-precision integer ([`bigint`]) — the vendored crate
//! set has no bignum crate, and building the substrate is in scope.  The
//! `baseline_he` bench measures real encrypt/add/decrypt times at 512–2048
//! bit moduli and reports the aggregation cost next to the secret-sharing
//! path; the division-circuit cost is extrapolated per [17]'s gate counts
//! (documented in the bench output).

pub mod bigint;
pub mod paillier;

pub use paillier::{Keypair, Paillier};
